"""Benchmark harness — one entry per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows:
  * ``table1_*`` / ``fig2_*``  — Table 1 / Fig. 2: 8 KB copy latency+energy
    per mechanism, from the functional substrate (data-correct copies) with
    the calibrated command-level timing model; derived = modeled ns / uJ and
    the paper's headline ratios.
  * ``fig3_*``  — VILLA hit rate + weighted-speedup improvement on the
    synthetic 4-core workloads (Ramulator-style controller sim).
  * ``fig4_*``  — combined RISC/+VILLA/+LIP speedups and energy reduction.
  * ``rbm_bandwidth`` — Sec. 2's 26x-channel claim.
  * ``kernel_*`` — Pallas kernels (interpret mode) vs jnp oracles.
  * ``ring_*``  — LISA hop-chain collectives on 8 host devices (subprocess).
  * ``train/serve_throughput`` — end-to-end reduced-model system benches.
  * ``bank_*`` — bank-contention A/B: load-dependent p99, wave overlap
    vs serialization, refresh stalls (writes ``BENCH_bank.json``).
  * ``roofline_*`` — live lowering + HLO byte/flop attribution of every
    audited jitted entry point (writes ``ROOFLINE_REPORT.json``).

Every invocation appends its headline gates to ``BENCH_TRAJECTORY.jsonl``
(strict JSON per line, monotone ``seq`` — validated by ``--check``).
"""
from __future__ import annotations

import json
import os
import statistics
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402

ROWS = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def _time(fn, n=5, warmup=1):
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


# ---------------------------------------------------------------------------
def bench_table1():
    from repro.core.dram import substrate as S
    from repro.core.dram.spec import DDR3_1600

    # Costs are reported from the full-geometry spec (Table-1 exact); the
    # functional bank uses short 1 KB rows so the data-correct copy we *time*
    # stays small.
    spec = DDR3_1600
    bank_spec = DDR3_1600.with_geometry(16, 16, 1024)
    bank = S.make_bank(bank_spec, key=jax.random.key(0))
    paper = {"RC-InterSA": (1363.75, 4.33), "RC-Bank": (701.25, 2.08),
             "RC-IntraSA": (83.75, 0.06), "LISA-RISC-1": (148.5, 0.09),
             "LISA-RISC-7": (196.5, 0.12), "LISA-RISC-15": (260.5, 0.17),
             "memcpy": (None, 6.2)}
    # Table-1 row -> (registry mechanism, src_sa, src_row, dst_sa, dst_row):
    # each row times the mechanism actually named, via execute_copy.
    copies = {"memcpy": ("memcpy", 0, 1, 7, 2),
              "RC-InterSA": ("rc_intersa", 0, 1, 7, 2),
              "RC-Bank": ("rc_bank", 0, 1, 7, 2),
              "RC-IntraSA": ("rc_intrasa", 0, 1, 0, 2),
              "LISA-RISC-1": ("lisa", 0, 1, 1, 2),
              "LISA-RISC-7": ("lisa", 0, 1, 7, 2),
              "LISA-RISC-15": ("lisa", 0, 1, 15, 2)}
    for mech, (lat, ene) in spec.table1().items():
        plat, pene = paper[mech]
        name, *args = copies[mech]
        us = _time(lambda: jax.block_until_ready(
            S.execute_copy(bank, name, *args,
                           spec=bank_spec).state.row_buffer))
        row(f"table1_{mech}", us,
            f"lat_ns={lat:.2f};paper={plat};energy_uJ={ene:.3f};paper={pene}")
    lat1, e1 = spec.table1()["LISA-RISC-1"]
    row("fig2_latency_ratio_vs_rowclone", 0.0,
        f"{spec.copy_latency('rc_intersa')/lat1:.1f}x;paper=9x")
    row("fig2_energy_ratio_vs_rowclone", 0.0,
        f"{spec.copy_energy('rc_intersa')/e1:.1f}x;paper=48x")
    row("fig2_energy_ratio_vs_memcpy", 0.0,
        f"{spec.copy_energy('memcpy')/e1:.1f}x;paper=69x")
    row("rbm_bandwidth", 0.0,
        f"{spec.rbm_bw_gbps:.0f}GB/s="
        f"{spec.rbm_bw_gbps/spec.channel_bw_gbps:.1f}x_channel;paper=26x")


def bench_fig3_fig4():
    from repro.core.dram.controller import (MechanismConfig, simulate_grid,
                                            weighted_speedup)
    from repro.core.dram.traces import TraceConfig, generate_batch

    # "50 workloads": sweep copy-intensity x locality (5 x 5 x 2 seeds).
    # All 50 traces are generated in one vmapped call (workload knobs are
    # traced data) and the whole (mechanism x workload) grid runs as ONE
    # vmapped execution of the single jitted simulator (mechanism config is
    # traced data too), instead of re-jitting per cell.
    t0 = time.perf_counter()
    tcfg = TraceConfig(n_requests=4096)
    cells = [(copy_prob, zipf, seed)
             for copy_prob in (0.002, 0.005, 0.01, 0.02, 0.04)
             for zipf in (1.0, 1.2, 1.4, 1.6, 1.8)
             for seed in (1, 2)]
    traces = generate_batch(
        jnp.stack([jax.random.key(s) for _, _, s in cells]),
        jnp.asarray([cp for cp, _, _ in cells]),
        jnp.asarray([z for _, z, _ in cells]), tcfg)
    names = ["base", "lisa", "villa", "comb", "rc_villa", "lip"]
    grid = simulate_grid(traces, tcfg, [
        MechanismConfig("memcpy"),
        MechanismConfig("lisa"),
        MechanismConfig("lisa", use_villa=True),
        MechanismConfig("lisa", use_villa=True, use_lip=True),
        MechanismConfig("memcpy", use_villa=True,
                        villa_copy_mech="rc_intersa"),
        MechanismConfig("memcpy", use_lip=True),
    ])
    jax.block_until_ready(grid)
    base = {k: v[0] for k, v in grid.items()}
    res = {n: {k: v[i] for k, v in grid.items()}
           for i, n in enumerate(names) if n != "base"}
    ws_all = {k: np.asarray(weighted_speedup(base["core_stall"],
                                             r["core_stall"]))
              for k, r in res.items()}
    hits = np.asarray(res["villa"]["villa_hit_rate"])
    en_red = 1 - np.asarray(res["comb"]["energy_uJ"]) / np.asarray(
        base["energy_uJ"])
    total_us = (time.perf_counter() - t0) * 1e6 / 50
    gm = lambda xs: float(np.exp(np.mean(np.log(np.maximum(xs, 1e-9)))))
    row("fig3_villa_hit_rate", total_us,
        f"mean={np.mean(hits):.3f};paper_range=0.15-0.8")
    row("fig3_villa_vs_risc_gain", total_us,
        f"+{(gm(ws_all['villa'])/gm(ws_all['lisa'])-1)*100:.1f}%;paper=+16.5%")
    row("fig3_rc_villa_ws", total_us,
        f"{(gm(ws_all['rc_villa'])-1)*100:.1f}%;paper=-52.3%")
    row("fig4_risc_ws", total_us,
        f"+{(gm(ws_all['lisa'])-1)*100:.1f}%;paper=+59.6%")
    row("fig4_lip_over_risc_villa", total_us,
        f"+{(gm(ws_all['comb'])/gm(ws_all['villa'])-1)*100:.1f}%;paper=+8.8%")
    row("fig4_lip_alone_ws", total_us,
        f"+{(gm(ws_all['lip'])-1)*100:.1f}%;paper=+10.3%")
    row("fig4_combined_ws", total_us,
        f"+{(gm(ws_all['comb'])-1)*100:.1f}%;paper=+94.8%")
    row("fig4_combined_energy_reduction", total_us,
        f"-{np.mean(en_red)*100:.1f}%;paper=-49%")


def bench_kernels():
    from repro.kernels import ops
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 8, 256, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 4, 256, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 4, 256, 64), jnp.bfloat16)
    us_k = _time(lambda: jax.block_until_ready(
        ops.flash_attention(q, k, v, block_q=64, block_k=64)))
    us_r = _time(lambda: jax.block_until_ready(
        ops.flash_attention_ref(q, k, v)))
    err = float(jnp.abs(
        ops.flash_attention(q, k, v, block_q=64, block_k=64).astype(jnp.float32)
        - ops.flash_attention_ref(q, k, v).astype(jnp.float32)).max())
    row("kernel_flash_attention_interpret", us_k,
        f"ref_us={us_r:.0f};max_err={err:.1e}")

    x = jax.random.normal(jax.random.key(1), (512, 512))
    us_c = _time(lambda: jax.block_until_ready(ops.rbm_copy(x)))
    row("kernel_rbm_copy_interpret", us_c,
        f"bytes={x.size*4};ok={bool((ops.rbm_copy(x)==x).all())}")

    pages = jax.random.normal(jax.random.key(2), (32, 8, 128))
    table = jnp.arange(16, dtype=jnp.int32) % 32
    us_g = _time(lambda: jax.block_until_ready(ops.villa_gather(pages, table)))
    ok = bool((ops.villa_gather(pages, table) == pages[table]).all())
    row("kernel_villa_gather_interpret", us_g, f"ok={ok}")

    upd = jax.random.normal(jax.random.key(3), (16, 8, 128))
    # non-donating non-jit entry so the timed region is the scatter alone
    # (ops.villa_scatter donates its pages arg)
    from repro.kernels.rbm_copy import villa_scatter as scatter_nodonate
    scat = jax.jit(scatter_nodonate, static_argnames=("interpret",))
    us_s = _time(lambda: jax.block_until_ready(scat(pages, table, upd)))
    ok = bool((scat(pages, table, upd)
               == ops.villa_scatter_ref(pages, table, upd)).all())
    row("kernel_villa_scatter_interpret", us_s, f"ok={ok}")


RING_BENCH = r"""
import time, statistics, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.lisa import rbm

mesh = jax.make_mesh((8,), ("x",))
x = jax.random.normal(jax.random.key(0), (8, 1 << 16))

ring = jax.jit(jax.shard_map(lambda s: rbm.ring_allreduce(s, "x"),
                             mesh=mesh, in_specs=P("x"), out_specs=P("x")))
psum = jax.jit(jax.shard_map(lambda s: jax.lax.psum(s, "x"),
                             mesh=mesh, in_specs=P("x"), out_specs=P("x")))
def t(f):
    f(x).block_until_ready()
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); f(x).block_until_ready()
        ts.append((time.perf_counter()-t0)*1e6)
    return statistics.median(ts)
ru, pu = t(ring), t(psum)
ok = bool(jnp.allclose(ring(x), psum(x), atol=1e-4))
print(f"RESULT,{ru:.1f},{pu:.1f},{ok}")
"""


def bench_ring_collectives():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", RING_BENCH],
                       capture_output=True, text=True, timeout=480, env=env)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT"):
            _, ru, pu, ok = line.split(",")
            row("ring_allreduce_8dev", float(ru),
                f"xla_psum_us={pu};allclose={ok}")
            return
    row("ring_allreduce_8dev", -1.0, f"failed:{r.stderr[-120:]}")


def bench_train_throughput():
    from repro.configs import get_reduced
    from repro.data.pipeline import DataConfig, batch_at
    from repro.launch.mesh import make_local_mesh
    from repro.optim.adamw import OptConfig
    from repro.train.step import (ParallelConfig, init_train_state,
                                  make_train_step)
    cfg = get_reduced("tinyllama-1.1b")
    pcfg = ParallelConfig(fsdp=False)
    state = init_train_state(cfg, jax.random.key(0), pcfg)
    _, compile_step, _ = make_train_step(
        cfg, make_local_mesh(1, 1), pcfg,
        OptConfig(warmup_steps=1, total_steps=100))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)
    batch = batch_at(dcfg, 0)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          (state, batch))
    step = compile_step(*shapes)
    state, _ = step(state, batch)                     # warmup/compile
    t0 = time.perf_counter()
    n = 5
    for i in range(n):
        state, m = step(state, batch_at(dcfg, i + 1))
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    toks = n * 8 * 128
    row("train_throughput_reduced_cpu", dt / n * 1e6,
        f"tokens_per_s={toks/dt:.0f};loss={float(m['loss']):.3f}")


def bench_serve_throughput(out_path="BENCH_serve.json"):
    """Serving hot path A/B: one-sync batched decode vs the pre-PR grouped
    path, plus paged suspend/resume bandwidth.  Writes ``BENCH_serve.json``.

    Prompt lengths are staggered so slot positions stay ragged — the
    continuous-batching steady state, where the grouped path degrades to one
    dispatch per distinct position plus one sync per slot."""
    from repro.configs import get_reduced
    from repro.models import lm as LM
    from repro.serve.engine import Engine, Request

    cfg = get_reduced("tinyllama-1.1b")
    params = LM.init_lm(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, ln).astype(np.int32)
               for ln in (6, 9, 12, 15)]
    n_steps = 48

    def run(step_name):
        eng = Engine(cfg, params, slots=4, max_len=96, n_sessions=16)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new=10**9))
        step = getattr(eng, step_name)
        step()                                   # warm the jit caches
        t0 = time.perf_counter()
        for _ in range(n_steps):
            step()
        jax.block_until_ready(eng.cache)
        dt = time.perf_counter() - t0
        return eng, n_steps * len(prompts) / dt, dt

    eng_new, tps_new, dt_new = run("step")
    eng_old, tps_old, dt_old = run("step_unbatched")
    speedup = tps_new / tps_old

    # suspend/resume bandwidth through the paged VILLA store (Pallas
    # gather/scatter path); bytes are true dtype bytes, both directions.
    eng = Engine(cfg, params, slots=4, max_len=96, n_sessions=16)
    eng.submit(Request(uid=0, prompt=prompts[0], max_new=2))
    while eng.active:
        eng.step()
    slot = eng.resume(0, extra_new=2)            # warm suspend/resume jits
    eng.suspend(slot)
    n_moves = 24
    t0 = time.perf_counter()
    for _ in range(n_moves):
        eng.suspend(eng.resume(0, extra_new=2))
    jax.block_until_ready(eng.sessions.slow)
    dt_mv = time.perf_counter() - t0
    gbps = 2 * n_moves * eng.snapshot_bytes / dt_mv / 1e9

    # fused waves: a burst of equal-length requests completes on one step
    # (ONE suspend_many dispatch), then the whole burst resumes in ONE
    # resume_many dispatch — the compile counts pin both waves to a single
    # compilation (pre-fix this bench never drove a wave, so the recorded
    # resume_many count was a vacuous 0).
    eng_w = Engine(cfg, params, slots=4, max_len=96, n_sessions=16)
    for i in range(4):
        eng_w.submit(Request(uid=i, prompt=prompts[0], max_new=3))
    while eng_w.active:
        eng_w.step()                     # burst completion: one fused wave
    assert eng_w.stats["suspends"] == 4, eng_w.stats
    eng_w.resume_many([0, 1, 2, 3], extra_new=2)     # one fused resume wave
    while eng_w.active:
        eng_w.step()
    wave_cc = eng_w.compile_counts()
    assert wave_cc["suspend_many"] in (1, -1), wave_cc
    assert wave_cc["resume_many"] in (1, -1), wave_cc

    bench = {
        "decode_tokens_per_s": round(tps_new, 1),
        "legacy_tokens_per_s": round(tps_old, 1),
        "decode_speedup": round(speedup, 2),
        "decode_dispatches_per_step": eng_new.stats["decode_dispatches"]
        / (n_steps + 1),
        "legacy_dispatches_per_step": eng_old.stats["decode_dispatches"]
        / (n_steps + 1),
        "suspend_resume_gbps": round(gbps, 4),
        "snapshot_bytes": eng.snapshot_bytes,
        # decode/prefill from the throughput engine, suspend/resume from the
        # bandwidth engine, the fused waves from the wave engine (each from
        # the engine that exercised that path)
        "compile_counts": {**eng_new.compile_counts(),
                           "suspend": eng.compile_counts()["suspend"],
                           "resume": eng.compile_counts()["resume"],
                           "suspend_many": wave_cc["suspend_many"],
                           "resume_many": wave_cc["resume_many"]},
        "wave": {"suspend_wave_sessions": 4, "resume_wave_sessions": 4,
                 "suspend_many_compiles": wave_cc["suspend_many"],
                 "resume_many_compiles": wave_cc["resume_many"]},
        "config": {"arch": "tinyllama-1.1b-reduced", "slots": 4,
                   "max_len": 96, "steps": n_steps,
                   "prompt_lens": [len(p) for p in prompts]},
    }
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2, allow_nan=False)
    row("serve_decode_one_sync", 1e6 / max(tps_new, 1e-9),
        f"tokens_per_s={tps_new:.1f};speedup_vs_grouped={speedup:.2f}x")
    row("serve_decode_grouped_legacy", 1e6 / max(tps_old, 1e-9),
        f"tokens_per_s={tps_old:.1f}")
    row("serve_suspend_resume_paged", dt_mv / (2 * n_moves) * 1e6,
        f"GB/s={gbps:.3f};snapshot_bytes={eng.snapshot_bytes}")
    row("serve_decode_compile_count", 0.0,
        f"{bench['compile_counts']['decode']}")
    row("serve_fused_wave_compiles", 0.0,
        f"suspend_many={wave_cc['suspend_many']};"
        f"resume_many={wave_cc['resume_many']}")


def bench_movement(out_path="BENCH_movement.json"):
    """Movement-substrate A/B: the planned path (movement.plan/execute
    inside the engine's jitted suspend/resume) vs the pre-redesign legacy
    path (the same pack + VILLA policy + Pallas gather/scatter, called
    directly without plans).  Both lower to the same XLA; the bench pins
    the plan/execute indirection at <= 5% overhead (it is trace-time-only)
    and records the plans' modeled MovementCost.  Writes
    ``BENCH_movement.json``."""
    import statistics as stats
    import warnings as W
    from functools import partial

    from repro.configs import get_reduced
    from repro.core.dram.villa import villa_access
    from repro.kernels.rbm_copy import villa_gather, villa_scatter
    from repro.models import lm as LM
    from repro.serve import paged_store as PSm
    from repro.serve.engine import Engine, Request

    cfg = get_reduced("tinyllama-1.1b")
    params = LM.init_lm(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    def make_engine():
        eng = Engine(cfg, params, slots=4, max_len=96, n_sessions=16)
        eng.submit(Request(uid=0, prompt=prompt, max_new=2))
        while eng.active:
            eng.step()
        return eng

    eng = make_engine()
    pspec, vcfg = eng.page_spec, eng.villa_cfg

    # ---- legacy direct-call path: pre-redesign movement, no plans --------
    def _read(arr, i):
        n_, spp, P, d = arr.shape
        tbl = i * spp + jnp.arange(spp, dtype=jnp.int32)
        return villa_gather(arr.reshape(n_ * spp, P, d), tbl)

    def _write(arr, i, data):
        n_, spp, P, d = arr.shape
        tbl = i * spp + jnp.arange(spp, dtype=jnp.int32)
        return villa_scatter(arr.reshape(n_ * spp, P, d), tbl,
                             data).reshape(arr.shape)

    @partial(jax.jit, donate_argnums=(1,))
    def legacy_suspend(cache, store, slot, idx):
        pages = PSm.pack_slot(pspec, cache, slot)
        slow = _write(store.slow, idx, pages)
        resident = store.policy.tags == idx
        s = jnp.argmax(resident)
        fast = jnp.where(resident.any(), _write(store.fast, s, pages),
                         store.fast)
        return store._replace(slow=slow, fast=fast)

    @partial(jax.jit, donate_argnums=(0, 1))
    def legacy_resume(cache, store, slot, idx):
        policy, hit, insert, victim = villa_access(store.policy, idx, vcfg)
        slow_data = _read(store.slow, idx)
        fast = jnp.where(insert, _write(store.fast, victim, slow_data),
                         store.fast)
        s = jnp.argmax(policy.tags == idx)
        pages = jnp.where(hit, _read(fast, s), slow_data)
        store = store._replace(policy=policy, fast=fast,
                               hits=store.hits + hit.astype(jnp.int32),
                               accesses=store.accesses + 1)
        return PSm.unpack_into_slot(pspec, cache, slot, pages), store

    # Both paths driven at identical granularity: the jitted move bodies.
    zero = jnp.int32(0)

    def drive_planned(state, n):
        cache, store = state
        for _ in range(n):
            cache, store = eng._resume(cache, store, zero, zero)
            store = eng._suspend(cache, store, zero, zero)
        jax.block_until_ready(store.slow)
        return cache, store

    def drive_legacy(state, n):
        cache, store = state
        for _ in range(n):
            cache, store = legacy_resume(cache, store, zero, zero)
            store = legacy_suspend(cache, store, zero, zero)
        jax.block_until_ready(store.slow)
        return cache, store

    n_moves, rounds = 16, 5
    with W.catch_warnings():
        W.filterwarnings("ignore",
                         message="Some donated buffers were not usable")
        st_p = (eng.cache, eng.sessions)
        eng2 = make_engine()
        st_l = (eng2.cache, eng2.sessions)
        st_p = drive_planned(st_p, 2)            # warm both jit caches
        st_l = drive_legacy(st_l, 2)
        t_planned, t_legacy = [], []
        for _ in range(rounds):                  # interleave to share noise
            t0 = time.perf_counter()
            st_p = drive_planned(st_p, n_moves)
            t_planned.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            st_l = drive_legacy(st_l, n_moves)
            t_legacy.append(time.perf_counter() - t0)
    us_planned = stats.median(t_planned) / (2 * n_moves) * 1e6
    us_legacy = stats.median(t_legacy) / (2 * n_moves) * 1e6
    ratio = us_planned / us_legacy

    cc = eng.compile_counts()
    bench = {
        "planned_us_per_move": round(us_planned, 2),
        "legacy_us_per_move": round(us_legacy, 2),
        "planned_over_legacy": round(ratio, 4),
        "within_5pct": bool(ratio <= 1.05),
        # deterministic trace-time-only guard: the planned bodies compile
        # once each, however many moves ran (-1 = no jit-cache probe)
        "planned_compile_counts": {"suspend": cc["suspend"],
                                   "resume": cc["resume"]},
        "snapshot_bytes": eng.snapshot_bytes,
        "plan_suspend": eng.plan_suspend.describe(),
        "plan_resume": eng.plan_resume.describe(),
        "modeled_ns_lisa_per_move": eng.plan_resume.cost.ns_lisa,
        "modeled_ns_memcpy_per_move": eng.plan_resume.cost.ns_memcpy,
        "modeled_advantage": round(eng.plan_resume.cost.advantage, 2),
        "config": {"arch": "tinyllama-1.1b-reduced", "n_moves": n_moves,
                   "rounds": rounds, "workload": "serve suspend/resume"},
    }
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2, allow_nan=False)
    row("movement_planned_suspend_resume", us_planned,
        f"ratio_vs_legacy={ratio:.3f};within_5pct={bench['within_5pct']}")
    row("movement_legacy_suspend_resume", us_legacy,
        f"modeled_advantage={bench['modeled_advantage']}x")


def _roofline_attribution(path="ROOFLINE_REPORT.json"):
    """Span-name -> roofline attrs from the committed live report (empty
    dict when absent/unreadable): traced decode/prefill spans then carry
    the dominant HLO kernel and its byte/flop totals, tying the virtual
    timeline back to the lowered IR."""
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            rep = json.load(f)
        entries = rep["entries"]
    except (OSError, ValueError, KeyError, TypeError):
        return {}

    def attrs(e):
        return {"hlo_dominant": e["dominant"],
                "hlo_gflops": round(e["flops"] / 1e9, 4),
                "hlo_gbytes": round(e["bytes"] / 1e9, 4)}

    out = {}
    if "decode" in entries:
        out["decode"] = attrs(entries["decode"])
    buckets = sorted(n for n in entries if n.startswith("prefill["))
    if buckets:
        out["prefill"] = attrs(entries[buckets[-1]])
    return out


def bench_sched(out_path="BENCH_sched.json"):
    """Scheduler A/B: ``fifo`` vs ``cost_aware`` serving the SAME offered
    load (identical arrival stream, engine geometry and virtual-clock
    constants).  Latency runs on the scheduler's modeled clock — decode
    ticks plus occupancy-aware Table-1 movement pricing — so the comparison
    is deterministic (job completion depends on token *counts*, never token
    values) and CI can gate on it: ``cost_aware`` must beat ``fifo`` on p99
    latency or SLO attainment, and every scheduler-issued suspend/resume
    must stay ONE fused dispatch per wave (compile-count asserted).
    Writes ``BENCH_sched.json``."""
    from repro import sched
    from repro.configs import get_reduced
    from repro.models import lm as LM
    from repro.serve.engine import Engine

    cfg = get_reduced("tinyllama-1.1b")
    params = LM.init_lm(cfg, jax.random.key(0))
    wl = sched.WorkloadConfig(
        n_fresh=8, n_followups=28, mean_gap_ns=1600.0,
        arrival="bursty", burst=4, zipf_s=1.8, think_ns=2000.0,
        class_slo_ns=(40_000.0, 150_000.0, float("inf")))
    arrivals = sched.generate_workload(wl, seed=4, vocab_size=cfg.vocab_size)

    results = {}
    for pol in ("fifo", "cost_aware"):
        eng = Engine(cfg, params, slots=4, max_len=96,
                     n_sessions=sched.n_sessions_for(wl))
        tracer = None
        if pol == "cost_aware":
            # the headline arm runs traced: zero device dispatches, zero
            # schedule impact — the summary gains a "trace" rollup block
            from repro.obs import Tracer
            tracer = Tracer()
            tracer.bind_attribution(_roofline_attribution())
        s = sched.Scheduler(eng, policy=pol, arrivals=arrivals,
                            tracer=tracer)
        t0 = time.perf_counter()
        summary = s.run()
        dt = time.perf_counter() - t0
        resume_widths = s.metrics.wave_widths("resume_wave")
        suspend_widths = (s.metrics.wave_widths("preempt_suspend")
                          + s.metrics.wave_widths("complete_suspend"))
        cc = eng.compile_counts()
        # fused-dispatch invariants: every resume the engine performed came
        # from a scheduler wave, and each distinct wave width compiles once
        assert eng.stats["resumes"] == sum(resume_widths), (pol, resume_widths)
        assert eng.stats["suspends"] == sum(suspend_widths), (pol,
                                                              suspend_widths)
        # resume waves always route through resume_many (any width); a
        # single-slot suspend routes through the unbatched suspend body —
        # so each entry point compiles at most once per distinct wave width
        n_resume_shapes = len(set(resume_widths))
        n_suspend_shapes = len({w for w in suspend_widths if w > 1})
        assert cc["resume_many"] in (-1, *range(n_resume_shapes + 1)), (
            pol, resume_widths, cc)
        assert cc["suspend_many"] in (-1, *range(n_suspend_shapes + 1)), (
            pol, suspend_widths, cc)
        results[pol] = {
            **summary,
            "ticks": s.tick_count,
            "resume_wave_widths": resume_widths,
            "compile_counts": {k: cc[k] for k in
                               ("decode", "resume_many", "suspend_many")},
            "wall_seconds": round(dt, 2),
        }

    fifo, ca = results["fifo"], results["cost_aware"]
    p99_gain = fifo["p99_latency_ns"] / max(ca["p99_latency_ns"], 1e-9)
    slo_gain = ca["slo_attainment"] - fifo["slo_attainment"]
    import dataclasses
    import math
    # strict-JSON artifact: the batch class's infinite SLO must not leak as
    # a bare `Infinity` literal (json.dump emits it for float('inf'))
    load = {k: ([("inf" if isinstance(x, float) and math.isinf(x) else x)
                 for x in v] if isinstance(v, tuple) else v)
            for k, v in dataclasses.asdict(wl).items()}
    bench = {
        **results,
        "p99_speedup_cost_aware": round(p99_gain, 3),
        "slo_attainment_gain": round(slo_gain, 4),
        "cost_aware_beats_fifo": bool(p99_gain > 1.0 or slo_gain > 0.0),
        "config": {"arch": "tinyllama-1.1b-reduced", "slots": 4,
                   "seed": 4, "offered_load": load},
    }
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2, allow_nan=False)
    row("sched_fifo", 0.0,
        f"p99_us={fifo['p99_latency_ns']/1e3:.1f};"
        f"slo={fifo['slo_attainment']}")
    row("sched_cost_aware", 0.0,
        f"p99_us={ca['p99_latency_ns']/1e3:.1f};slo={ca['slo_attainment']};"
        f"p99_speedup={p99_gain:.2f}x;beats_fifo="
        f"{bench['cost_aware_beats_fifo']}")
    row("sched_movement_advantage", 0.0,
        f"{ca['movement']['advantage']}x_lisa_vs_memcpy")


def bench_cluster(out_path="BENCH_cluster.json"):
    """Cluster serving A/Bs on the deterministic virtual clock (the
    BENCH_sched idiom: completion depends on token COUNTS, never values,
    so CI gates on exact numbers).  Two comparisons:

      * **1 vs 4 replicas at equal offered load** — the same arrival
        stream driven through a 1-replica and a 4-replica cluster; the
        4-replica run must complete >= 2x the jobs before deadline misses
        begin (and strictly more jobs within SLO).
      * **migration on vs off** — a skewed-residence burst (sessions
        concentrated on one replica while long jobs pin the others, then
        all return at once with a tight SLO); migration-enabled placement
        fans the burst across idle replicas via priced hop-chain plans,
        migration-off serializes on the home replica and misses.

    Writes ``BENCH_cluster.json``."""
    import math

    from repro import sched
    from repro.configs import get_reduced
    from repro.models import lm as LM
    from repro.serve.cluster import Cluster

    cfg = get_reduced("tinyllama-1.1b")
    params = LM.init_lm(cfg, jax.random.key(0))

    def jobs_before_first_miss(records):
        n = 0
        for j in sorted(records, key=lambda r: r.done_ns):
            if math.isfinite(j.slo_ns) and not j.slo_met:
                break
            n += 1
        return n

    def in_slo_jobs(records):
        return sum(1 for j in records
                   if math.isfinite(j.slo_ns) and j.slo_met)

    # ---- 1 vs 4 replicas, equal offered load -----------------------------
    wl = sched.WorkloadConfig(
        n_fresh=12, n_followups=24, mean_gap_ns=900.0, arrival="bursty",
        burst=4, zipf_s=1.4, think_ns=2500.0,
        class_slo_ns=(35_000.0, 120_000.0, math.inf))
    arrivals = sched.generate_workload(wl, seed=4, vocab_size=cfg.vocab_size)
    scale = {}
    for n_rep in (1, 4):
        cl = Cluster(cfg, params, n_replicas=n_rep, slots=4, max_len=96,
                     n_sessions=sched.n_sessions_for(wl))
        s = sched.ClusterScheduler(cl, arrivals=arrivals)
        t0 = time.perf_counter()
        summary = s.run()
        scale[f"replicas{n_rep}"] = {
            "jobs_completed": summary["jobs_completed"],
            "jobs_before_first_miss": jobs_before_first_miss(s.metrics.jobs),
            "jobs_in_slo": in_slo_jobs(s.metrics.jobs),
            "p99_latency_ns": summary["p99_latency_ns"],
            "slo_attainment": summary["slo_attainment"],
            "ticks": s.tick_count,
            "decode_compiles": cl.compile_counts()["decode"],
            "wall_seconds": round(time.perf_counter() - t0, 2),
        }
    r1, r4 = scale["replicas1"], scale["replicas4"]
    scaling = r4["jobs_before_first_miss"] / max(
        r1["jobs_before_first_miss"], 1)

    # ---- migration on vs off (skewed-residence burst) --------------------
    # one scenario definition, two drivers: tests/test_cluster.py asserts
    # the same stream at test scale (sched.skewed_residence_burst)
    mig = {}
    for enabled in (True, False):
        cl = Cluster(cfg, params, n_replicas=4, slots=1, max_len=96,
                     n_sessions=128)
        s = sched.ClusterScheduler(
            cl, arrivals=sched.skewed_residence_burst(cfg.vocab_size),
            cfg=sched.SchedConfig(age_every=64), migrate=enabled)
        summary = s.run()
        burst = [j for j in s.metrics.jobs if j.priority == 0]
        mig["migration_on" if enabled else "migration_off"] = {
            "jobs_completed": summary["jobs_completed"],
            "slo_attainment": summary["slo_attainment"],
            "burst_slo_met": sum(j.slo_met for j in burst),
            "burst_jobs": len(burst),
            "sessions_migrated": summary["migration"]["sessions_migrated"],
            "p99_latency_ns": summary["p99_latency_ns"],
            "per_replica_utilization": summary["per_replica_utilization"],
        }
    on, off = mig["migration_on"], mig["migration_off"]

    bench = {
        **scale,
        "scaling_before_miss": round(scaling, 2),
        "scales_2x": bool(scaling >= 2.0
                          and r4["jobs_in_slo"] > r1["jobs_in_slo"]),
        **mig,
        "migration_wins": bool(
            on["slo_attainment"] > off["slo_attainment"]
            and on["sessions_migrated"] >= 2
            and off["sessions_migrated"] == 0),
        "config": {"arch": "tinyllama-1.1b-reduced", "seed": 4,
                   "scale_slots_per_replica": 4,
                   "migration_slots_per_replica": 1,
                   "offered_load": "bursty gap=900 zipf=1.4 12f+24r",
                   "burst": "4-session skewed-residence, slo=18us"},
    }
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2, allow_nan=False)
    row("cluster_scale_1v4", 0.0,
        f"before_miss={r1['jobs_before_first_miss']}->"
        f"{r4['jobs_before_first_miss']};x{bench['scaling_before_miss']};"
        f"slo={r1['slo_attainment']}->{r4['slo_attainment']}")
    row("cluster_migration_ab", 0.0,
        f"slo_on={on['slo_attainment']};slo_off={off['slo_attainment']};"
        f"migrations={on['sessions_migrated']};"
        f"wins={bench['migration_wins']}")


def bench_faults(out_path="BENCH_faults.json"):
    """Chaos bench: seeded fault injection against the cluster scheduler,
    gated on the zero-silent-corruption identity and graceful degradation.
    Four deterministic scenarios (fixed seeds, virtual-clock latencies):

      * **detection** — recovery OFF: every injected at-rest corruption is
        accounted for — caught by the device-side checksum verify at resume
        or still sitting at rest for the end-of-run scrub.  No incident is
        ever silent.
      * **recovery** — recovery ON with periodic snapshots: corrupt
        sessions are restored from their last clean snapshot before they
        resume; the same ledger identity holds.
      * **recovery_parity** — replica death mid-service: snapshot, fail
        the replica, restore the session on a survivor, resume — the
        decode must be token-identical to the uninterrupted run (the PR 5
        migration-parity chain, extended across a failure).
      * **degradation** — the same offered load clean vs faulted: the
        faulted run must retain >= 70% of the clean run's SLO attainment
        and still complete every job (graceful, not collapsing).

    Writes ``BENCH_faults.json``."""
    from repro import sched
    from repro.configs import get_reduced
    from repro.faults import (FaultInjector, FaultSpec, restore_session,
                              snapshot_sessions)
    from repro.models import lm as LM
    from repro.serve.cluster import Cluster
    from repro.serve.engine import Request

    cfg = get_reduced("tinyllama-1.1b")
    params = LM.init_lm(cfg, jax.random.key(0))
    wl = sched.WorkloadConfig(n_fresh=4, n_followups=6)
    arrivals = sched.generate_workload(wl, seed=5, vocab_size=cfg.vocab_size)
    n_sessions = sched.n_sessions_for(wl)

    def chaos_run(spec, snapshot_every=0):
        inj = FaultInjector(spec) if spec is not None else None
        cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=48,
                     n_sessions=n_sessions, faults=inj)
        s = sched.ClusterScheduler(cl, arrivals=arrivals,
                                   snapshot_every=snapshot_every)
        summary = s.run()
        out = {"jobs_completed": summary["jobs_completed"],
               "slo_attainment": summary["slo_attainment"],
               "p99_latency_ns": summary["p99_latency_ns"],
               "faults": summary["faults"]}
        if inj is not None:
            out["ledger"] = inj.summary()
            out["verify_failed"] = cl.verify_failure_count()
            out["at_rest_corrupt"] = int(cl.scrub())
        return out

    def accounted(r):
        led = r["ledger"]
        closed = (led["detected"] + led["recovered"] + led["destroyed"]
                  + led["at_rest_corrupt"])
        return (led["new_corrupt"] == closed
                and r["verify_failed"] == led["detected"]
                and r["at_rest_corrupt"] == led["at_rest_corrupt"])

    # ---- detection (recovery off) + recovery (snapshots on) --------------
    detection = chaos_run(FaultSpec(rate=0.4, seed=7, recover=False))
    detection["all_accounted"] = accounted(detection)
    recovery = chaos_run(FaultSpec(rate=0.4, seed=3), snapshot_every=2)
    recovery["all_accounted"] = accounted(recovery)

    # ---- recovery parity: fail a replica, restore, decode bit-exact ------
    def greedy_ref(prompt, n_new):
        from repro.models import lm as L
        cache = L.init_cache(cfg, 1, max_len=48)
        logits, cache = L.prefill(cfg, params, jnp.asarray(prompt)[None],
                                  cache)
        toks = [int(jnp.argmax(logits[0, -1]))]
        pos = len(prompt)
        while len(toks) < n_new:
            lg, cache = L.decode_step(cfg, params, cache,
                                      jnp.asarray([[toks[-1]]]),
                                      jnp.int32(pos))
            toks.append(int(jnp.argmax(lg[0, 0])))
            pos += 1
        return toks

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    straight = greedy_ref(prompt, 8)
    inj = FaultInjector(FaultSpec(rate=0.0, seed=1))
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=48,
                 n_sessions=8, faults=inj)
    req = Request(uid=7, prompt=prompt, max_new=4)
    cl.submit(req, replica=0)
    while cl.active:
        cl.step()
    snaps, snap_cost = snapshot_sessions(cl)
    cl.fail_replica(0)
    assert 7 not in cl.session_pos          # the snapshot is the only copy
    recover_cost = restore_session(cl, snaps[7], 1)
    slot = cl.resume(7, extra_new=5)        # seed + 4 new tokens
    r2 = cl.active[slot]
    while cl.active:
        cl.step()
    parity = {
        "tokens_match": req.generated + r2.generated[1:] == straight,
        "verify_failed": cl.verify_failure_count(),
        "snapshot_ns_lisa": round(snap_cost.ns_lisa, 2),
        "recover_ns_lisa": round(recover_cost.ns_lisa, 2),
    }

    # ---- graceful degradation: clean vs faulted SLO at equal load --------
    clean = chaos_run(None)
    faulted = chaos_run(FaultSpec(rate=0.4, seed=3,
                                  replica_failures=((25, 1),)),
                        snapshot_every=2)
    retention = ((faulted["slo_attainment"] / clean["slo_attainment"])
                 if clean["slo_attainment"] else 1.0)
    degradation = {
        "clean_slo": clean["slo_attainment"],
        "faulted_slo": faulted["slo_attainment"],
        "slo_retention": round(retention, 4),
        "clean_jobs": clean["jobs_completed"],
        "faulted_jobs": faulted["jobs_completed"],
        "ledger": faulted["ledger"],
    }

    bench = {
        "detection": detection,
        "recovery": recovery,
        "recovery_parity": parity,
        "degradation": degradation,
        "zero_silent_corruption": bool(detection["all_accounted"]
                                       and recovery["all_accounted"]),
        "graceful_degradation": bool(
            retention >= 0.7
            and faulted["jobs_completed"] == clean["jobs_completed"]),
        "config": {"arch": "tinyllama-1.1b-reduced", "replicas": 2,
                   "slots": 2, "max_len": 48, "workload_seed": 5,
                   "fault_seeds": {"detection": 7, "recovery": 3,
                                   "degradation": 3}},
    }
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2, allow_nan=False)
    row("faults_detection", 0.0,
        f"injected={detection['ledger']['new_corrupt']};"
        f"detected={detection['verify_failed']};"
        f"at_rest={detection['at_rest_corrupt']};"
        f"accounted={detection['all_accounted']}")
    row("faults_recovery", 0.0,
        f"recovered={recovery['ledger']['recovered']};"
        f"accounted={recovery['all_accounted']}")
    row("faults_recovery_parity", 0.0,
        f"tokens_match={parity['tokens_match']};"
        f"verify_failed={parity['verify_failed']}")
    row("faults_degradation", 0.0,
        f"slo_retention={degradation['slo_retention']};"
        f"graceful={bench['graceful_degradation']}")


def bench_bank(out_path="BENCH_bank.json"):
    """Bank-contention A/B under the virtual clock (DESIGN.md Sec. 15).
    Three deterministic arms, gated exactly:

      * **offered load** — the same open-loop request stream (fixed
        service, round-robin banks) at 1x vs 2x rate, contention on vs
        off.  On: per-bank queues grow with load, so p99 sojourn is
        strictly worse at 2x.  Off: the multiplexer is a pass-through and
        p99 is EXACTLY the service time at both loads (flat).
      * **wave overlap** — one migration-wave's routes priced from the
        real resume plan: disjoint-bank routes complete in less than the
        sum of their isolated costs (bank-level parallelism), same-bank
        routes serialize exactly (completion == sum).
      * **scheduler A/B** — the same arrival stream through the tick loop
        with ``contention`` off vs on: identical jobs and identical
        movement bills (contention never reprices), p99 no better with
        contention on, and the run observes refresh stalls (the virtual
        time crosses several tREFI windows).

    Writes ``BENCH_bank.json``."""
    from repro import sched
    from repro.configs import get_reduced
    from repro.core.dram.bank import RequestMultiplexer
    from repro.core.dram.spec import DDR3_1600
    from repro.models import lm as LM
    from repro.sched.metrics import percentile_ns
    from repro.serve.engine import Engine

    # ---- arm 1: open-loop sojourn vs offered load ------------------------
    service_ns, n_banks, n_req = 600.0, 4, 400

    def sojourn_p99(enabled, gap_ns):
        m = RequestMultiplexer(DDR3_1600, n_banks=n_banks, enabled=enabled)
        sj = []
        for i in range(n_req):
            ready = i * gap_ns
            _, end = m.submit(m.bank_of(i), ready, service_ns)
            sj.append(end - ready)
        return round(percentile_ns(sj, 99), 3), m

    # 1x: per-bank utilization 600/800 — queues drain between refreshes;
    # 2x: 600/400 — overloaded, per-bank queues grow without bound
    p99_on_1x, _ = sojourn_p99(True, 200.0)
    p99_on_2x, m_2x = sojourn_p99(True, 100.0)
    p99_off_1x, _ = sojourn_p99(False, 200.0)
    p99_off_2x, _ = sojourn_p99(False, 100.0)
    load = {"service_ns": service_ns, "n_banks": n_banks,
            "n_requests": n_req,
            "on": {"p99_1x": p99_on_1x, "p99_2x": p99_on_2x},
            "off": {"p99_1x": p99_off_1x, "p99_2x": p99_off_2x},
            "mux_2x": m_2x.snapshot()}

    # ---- arms 2+3 share the reduced model ---------------------------------
    cfg = get_reduced("tinyllama-1.1b")
    params = LM.init_lm(cfg, jax.random.key(0))
    eng0 = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    route_ns = eng0.plan_resume.cost.ns_lisa    # one route's isolated bill
    n_routes = 3
    mux = RequestMultiplexer(DDR3_1600, n_banks=8)
    disjoint = mux.wave([(r, route_ns) for r in range(n_routes)], 0.0)
    mux2 = RequestMultiplexer(DDR3_1600, n_banks=8)
    same_bank = mux2.wave([(0, route_ns)] * n_routes, 0.0)
    waves = {"route_ns": round(route_ns, 3), "n_routes": n_routes,
             "sum_isolated_ns": round(n_routes * route_ns, 3),
             "disjoint_completion_ns": round(disjoint, 3),
             "same_bank_completion_ns": round(same_bank, 3)}

    wl = sched.WorkloadConfig(n_fresh=8, n_followups=16, mean_gap_ns=1200.0,
                              arrival="bursty", burst=4, zipf_s=1.5,
                              think_ns=2000.0)
    arrivals = sched.generate_workload(wl, seed=4, vocab_size=cfg.vocab_size)
    ab = {}
    for contention in (False, True):
        eng = Engine(cfg, params, slots=2, max_len=96,
                     n_sessions=sched.n_sessions_for(wl))
        s = sched.Scheduler(eng, arrivals=arrivals,
                            cfg=sched.SchedConfig(contention=contention))
        t0 = time.perf_counter()
        summary = s.run()
        arm = {"jobs_completed": summary["jobs_completed"],
               "p99_latency_ns": summary["p99_latency_ns"],
               "movement_ns_lisa": summary["movement"]["ns_lisa"],
               "movement_advantage": summary["movement"]["advantage"],
               "virtual_ns": round(s.now_ns, 2),
               "ticks": s.tick_count,
               "wall_seconds": round(time.perf_counter() - t0, 2)}
        if contention:
            arm["stalls"] = summary.get("stalls", {})
            arm["mux"] = s.mux.snapshot()
        ab["contention_on" if contention else "contention_off"] = arm
    off, on = ab["contention_off"], ab["contention_on"]

    # pricing invariance needs an IDENTICAL schedule in both arms (the
    # bursty A/B above diverges: the shifted clock feeds back into
    # admission), so it gates on a sequential stream whose decisions
    # cannot depend on completion times
    rng = np.random.default_rng(11)
    seq_arrivals = [
        sched.Arrival(t_ns=i * 400.0, uid=i, kind="fresh", priority=1,
                      slo_ns=float("inf"), new_tokens=2,
                      prompt=rng.integers(0, cfg.vocab_size,
                                          4).astype(np.int32))
        for i in range(6)]
    bills = {}
    for contention in (False, True):
        eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
        s = sched.Scheduler(eng, arrivals=list(seq_arrivals),
                            cfg=sched.SchedConfig(contention=contention))
        summary = s.run()
        bills["on" if contention else "off"] = {
            "ns_lisa": summary["movement"]["ns_lisa"],
            "ns_memcpy": summary["movement"]["ns_memcpy"],
            "advantage": summary["movement"]["advantage"],
            "jobs_completed": summary["jobs_completed"]}

    gates = {
        "on_p99_load_dependent": bool(p99_on_2x > p99_on_1x),
        "off_p99_flat": bool(p99_off_1x == p99_off_2x == service_ns),
        "disjoint_routes_overlap": bool(
            disjoint < n_routes * route_ns and disjoint >= route_ns),
        "same_bank_serializes_exactly": bool(
            same_bank == n_routes * route_ns),
        "contention_never_reprices": bool(
            bills["on"] == bills["off"]),
        "same_jobs_served": bool(
            on["jobs_completed"] == off["jobs_completed"]),
        # the bank model moves completion times both ways: same-bank queues
        # and refresh windows delay, disjoint-bank wave overlap accelerates
        # vs the serial contention-off clock — the gate is that it SHIFTS
        # the clock without touching the bill, not a one-sided inequality
        "contention_shifts_the_clock": bool(
            on["p99_latency_ns"] != off["p99_latency_ns"]),
        "refresh_stalls_observed": bool(
            on["mux"]["n_decode_stalls"] >= 1),
    }
    bench = {
        "load": load, "waves": waves, **ab,
        "pricing_invariance": bills, "gates": gates,
        "config": {"arch": "tinyllama-1.1b-reduced", "seed": 4,
                   "timing": {"tREFI": DDR3_1600.timing.tREFI,
                              "tRFC": DDR3_1600.timing.tRFC},
                   "offered_load": "bursty gap=1200 zipf=1.5 8f+16r"},
    }
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2, allow_nan=False)
    row("bank_load_p99", 0.0,
        f"on_1x={p99_on_1x};on_2x={p99_on_2x};"
        f"off_flat={gates['off_p99_flat']}")
    row("bank_wave_overlap", 0.0,
        f"disjoint={waves['disjoint_completion_ns']};"
        f"same_bank={waves['same_bank_completion_ns']};"
        f"sum={waves['sum_isolated_ns']}")
    row("bank_sched_ab", 0.0,
        f"p99_off={off['p99_latency_ns']};p99_on={on['p99_latency_ns']};"
        f"decode_stalls={on['mux']['n_decode_stalls']};"
        f"gates_ok={all(gates.values())}")


def bench_fork(out_path="BENCH_fork.json"):
    """Shared-prefix serving A/B: 64 sessions sharing one long system
    prompt, forked (zero-copy CoW aliasing — the RowClone analogue) vs
    admitted independently (one prefill each).  Writes ``BENCH_fork.json``.

    The fork-ON arm prefills the shared prefix ONCE, forks 64 children off
    the suspended template (pure host bookkeeping — the in-bench dispatch
    delta pins ZERO device work), then forces a store-index collision on
    the shared row to exercise the demotion path (a shared snapshot is
    migrated, never destroyed).  The fork-OFF arm prefills the same prefix
    64 times.  Both arms then decode the same per-child divergence seeds;
    the gate demands bit-exact tokens — aliasing must be invisible to the
    data path."""
    from repro.analysis import testlib as TL
    from repro.configs import get_reduced
    from repro.models import lm as LM
    from repro.serve.engine import Engine, Request

    cfg = get_reduced("tinyllama-1.1b")
    params = LM.init_lm(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    n_children, decode_n = 64, 4
    prefix = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    seeds = [int(s) for s in rng.integers(1, cfg.vocab_size, n_children)]
    # geometry: template uid 0 homes at row 0; children uids 6..69 home at
    # rows 6..69, leaving rows 1..5 as demotion headroom so the forced
    # collision never cascades into the children's own write-breaks
    template_uid, collider_uid = 0, 70
    children = list(range(6, 6 + n_children))

    def mk():
        return Engine(cfg, params, slots=8, max_len=96, n_sessions=70)

    # warm the shared jits (prefill/suspend/decode and the wave widths both
    # arms use) so admission wall-clock measures the steady state
    eng_w = mk()
    eng_w.submit(Request(uid=0, prompt=prefix, max_new=1))
    eng_w.resume_many([0], extra_new=1 + decode_n)
    while eng_w.active:
        eng_w.step()

    def drain(eng, toks):
        while eng.active:
            for _, req in eng.step():
                toks[req.uid] = [int(t) for t in req.generated]

    def decode_children(eng):
        toks = {}
        for i in range(0, n_children, eng.slots):
            wave = children[i:i + eng.slots]
            eng.resume_many(wave, extra_new=1 + decode_n)
            drain(eng, toks)
        return toks

    # ---- fork ON: prefill once, alias everywhere --------------------------
    eng_on = mk()
    eng_on.adopt_jits(eng_w)
    jax.block_until_ready(eng_on.sessions.slow)
    t0 = time.perf_counter()
    eng_on.submit(Request(uid=template_uid, prompt=prefix, max_new=1))
    before = TL.snapshot_stats(eng_on)
    eng_on.fork_many(template_uid, children, seed_tokens=seeds)
    jax.block_until_ready(eng_on.sessions.slow)
    admit_on_s = time.perf_counter() - t0
    # the fork fast path is PURE host bookkeeping: zero fused dispatches,
    # zero device->host transfers over the fork_many window
    TL.assert_dispatch_delta(before, eng_on.stats, decode=0, host=0)
    fork_zero_dispatch = (
        eng_on.stats["decode_dispatches"] == before["decode_dispatches"]
        and eng_on.stats["host_transfers"] == before["host_transfers"])
    # collide with the SHARED row while all 64 aliases still read it: the
    # fork-aware store demotes (clones + repoints) instead of destroying
    eng_on.submit(Request(uid=collider_uid, prompt=prefix, max_new=1))
    assert eng_on.stats["demotions"] == 1, eng_on.stats
    toks_on = decode_children(eng_on)
    stats_on = dict(eng_on.stats)
    verify_failed_on = eng_on.verify_failure_count()

    # ---- fork OFF: 64 independent admissions ------------------------------
    eng_off = mk()
    eng_off.adopt_jits(eng_w)
    jax.block_until_ready(eng_off.sessions.slow)
    t0 = time.perf_counter()
    for uid, seed in zip(children, seeds):
        eng_off.submit(Request(uid=uid, prompt=prefix, max_new=1))
        eng_off.reseed(uid, seed)
    jax.block_until_ready(eng_off.sessions.slow)
    admit_off_s = time.perf_counter() - t0
    eng_off.submit(Request(uid=collider_uid, prompt=prefix, max_new=1))
    toks_off = decode_children(eng_off)
    stats_off = dict(eng_off.stats)

    tokens_match = toks_on == toks_off and len(toks_on) == n_children
    fp = eng_on.plan_fork.cost
    modeled_ratio = fp.ns_memcpy / fp.ns_lisa
    bench = {
        "n_children": n_children,
        "prefix_len": len(prefix),
        "decode_per_child": decode_n,
        "fork_on": {
            "shared_prefix_prefills": 1,     # the template's, ever
            "prefills": stats_on["prefills"],
            "forks": stats_on["forks"],
            "bytes_not_copied": stats_on["bytes_not_copied"],
            "demotions": stats_on["demotions"],
            "evictions": stats_on["evictions"],
            "verify_failed": verify_failed_on,
            "admission_s": round(admit_on_s, 6),
        },
        "fork_off": {
            "shared_prefix_prefills": n_children,
            "prefills": stats_off["prefills"],
            "forks": stats_off["forks"],
            "bytes_not_copied": stats_off["bytes_not_copied"],
            "admission_s": round(admit_off_s, 6),
        },
        # modeled per-session admission: the fork-kind plan prices the alias
        # as RowClone FPM (ns_lisa) vs the full-snapshot copy it avoids
        # (ns_memcpy) — the Table-1 gap at serving granularity
        "modeled_admission_ratio": round(modeled_ratio, 2),
        "modeled_fork_ns_lisa": fp.ns_lisa,
        "modeled_fork_ns_memcpy": fp.ns_memcpy,
        "bytes_not_copied": stats_on["bytes_not_copied"],
        "snapshot_bytes": eng_on.snapshot_bytes,
        "fork_zero_dispatch": bool(fork_zero_dispatch),
        "tokens_match": bool(tokens_match),
        "admission_speedup_wallclock": round(
            admit_off_s / max(admit_on_s, 1e-9), 2),   # recorded, not gated
        "config": {"arch": "tinyllama-1.1b-reduced", "slots": 8,
                   "max_len": 96, "n_sessions": 70,
                   "template_uid": template_uid,
                   "collider_uid": collider_uid,
                   "child_uids": [children[0], children[-1]],
                   "seed": 7},
    }
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2, allow_nan=False)
    row("fork_admission", admit_on_s * 1e6 / n_children,
        f"prefills_on={stats_on['prefills']};"
        f"prefills_off={stats_off['prefills']};"
        f"wallclock_speedup={bench['admission_speedup_wallclock']}x")
    row("fork_modeled_ratio", 0.0,
        f"rowclone_vs_memcpy={bench['modeled_admission_ratio']}x;"
        f"bytes_not_copied={bench['bytes_not_copied']}")
    row("fork_cow_divergence", 0.0,
        f"tokens_match={tokens_match};demotions={stats_on['demotions']};"
        f"evictions={stats_on['evictions']};"
        f"zero_dispatch={fork_zero_dispatch}")


# ---------------------------------------------------------------------------
# --check: validate committed BENCH_*.json against their deterministic gates
# ---------------------------------------------------------------------------

def _check_serve(b, errs):
    if not b["decode_tokens_per_s"] > 0:
        errs.append("serve: decode_tokens_per_s not positive")
    if not b["suspend_resume_gbps"] > 0:
        errs.append("serve: suspend_resume_gbps not positive")
    if b["compile_counts"]["decode"] not in (1, -1):
        errs.append(f"serve: decode compiled "
                    f"{b['compile_counts']['decode']}x")
    for k in ("suspend_many_compiles", "resume_many_compiles"):
        if b["wave"][k] not in (1, -1):
            errs.append(f"serve: {k}={b['wave'][k]}")


def _check_movement(b, errs):
    for k, v in b["planned_compile_counts"].items():
        if v not in (1, -1):
            errs.append(f"movement: {k} compiled {v}x")
    if not b["modeled_advantage"] > 1:
        errs.append("movement: Table-1 advantage lost")
    if b["planned_over_legacy"] > 1.5:
        errs.append(f"movement: planned path {b['planned_over_legacy']}x "
                    f"of legacy (structural overhead)")


def _check_sched(b, errs):
    if not b["cost_aware_beats_fifo"]:
        errs.append("sched: cost_aware no longer beats fifo")
    for pol in ("fifo", "cost_aware"):
        r = b[pol]
        if r["jobs_completed"] != 36:
            errs.append(f"sched: {pol} completed {r['jobs_completed']} "
                        f"jobs, expected 36")
        widths = r["resume_wave_widths"]
        if r["decisions"]["resume_wave"] != len(widths):
            errs.append(f"sched: {pol} resume decisions != wave count")
        cc = r["compile_counts"]
        if cc["resume_many"] not in (-1, *range(len(set(widths)) + 1)):
            errs.append(f"sched: {pol} resume_many compiles {cc}")
        if cc["decode"] not in (1, -1):
            errs.append(f"sched: {pol} decode compiles {cc['decode']}")
    tr = b["cost_aware"].get("trace")
    if not tr or not tr.get("spans"):
        errs.append("sched: cost_aware arm lost its trace rollup")
    else:
        for phase in ("tick", "decode", "move", "leg"):
            if phase not in tr["per_phase"]:
                errs.append(f"sched: trace rollup missing phase {phase!r}")


def _check_cluster(b, errs):
    if not b["scales_2x"]:
        errs.append(f"cluster: 4-replica scaling "
                    f"{b['scaling_before_miss']}x < 2x before misses")
    if not b["migration_wins"]:
        errs.append("cluster: migration-on no longer beats migration-off")
    for k in ("replicas1", "replicas4", "migration_on", "migration_off"):
        if b[k]["jobs_completed"] < 1:
            errs.append(f"cluster: {k} completed no jobs")
    if b["migration_on"]["jobs_completed"] != \
            b["migration_off"]["jobs_completed"]:
        errs.append("cluster: A/B arms completed different job counts")


def _check_faults(b, errs):
    if not b["zero_silent_corruption"]:
        errs.append("faults: an injected corruption went unaccounted "
                    "(zero-silent-corruption gate)")
    if not b["graceful_degradation"]:
        errs.append(f"faults: SLO retention "
                    f"{b['degradation']['slo_retention']} < 0.7 or jobs "
                    f"lost under chaos (graceful-degradation gate)")
    if not b["recovery_parity"]["tokens_match"]:
        errs.append("faults: post-failure restored decode diverged from "
                    "the uninterrupted run")
    if b["recovery_parity"]["verify_failed"] != 0:
        errs.append("faults: snapshot-restored session failed the device "
                    "checksum verify")
    for scen in ("detection", "recovery"):
        led = b[scen]["ledger"]
        if led["new_corrupt"] < 3:
            errs.append(f"faults: {scen} scenario injected only "
                        f"{led['new_corrupt']} corruptions (needs >= 3 to "
                        f"be a meaningful gate)")
        if b[scen]["verify_failed"] != led["detected"]:
            errs.append(f"faults: {scen} device detections "
                        f"{b[scen]['verify_failed']} != ledger "
                        f"{led['detected']}")
    if b["recovery"]["ledger"]["recovered"] < 1:
        errs.append("faults: recovery scenario never exercised a "
                    "snapshot restore")


def _check_fork(b, errs):
    n = b["n_children"]
    if b["fork_on"]["shared_prefix_prefills"] != 1:
        errs.append("fork: fork-on arm prefilled the shared prefix more "
                    "than once (amortization gate)")
    if b["fork_off"]["shared_prefix_prefills"] < 64 or n < 64:
        errs.append(f"fork: A/B must span >= 64 shared-prefix sessions "
                    f"(got {n})")
    if b["fork_on"]["forks"] != n:
        errs.append(f"fork: {b['fork_on']['forks']} forks for {n} children")
    if not b["modeled_admission_ratio"] >= 10:
        errs.append(f"fork: modeled admission ratio "
                    f"{b['modeled_admission_ratio']}x < 10x (RowClone FPM "
                    f"pricing gate)")
    if not b["bytes_not_copied"] > 0:
        errs.append("fork: no bytes credited to the zero-copy path")
    if not b["fork_zero_dispatch"]:
        errs.append("fork: fork_many issued device work (zero-dispatch "
                    "fast-path gate)")
    if not b["tokens_match"]:
        errs.append("fork: forked children diverged from independent "
                    "sessions (bit-exactness gate)")
    if b["fork_on"]["demotions"] != 1:
        errs.append(f"fork: shared-row collision recorded "
                    f"{b['fork_on']['demotions']} demotions, expected 1")
    if b["fork_on"]["evictions"] != 0:
        errs.append(f"fork: {b['fork_on']['evictions']} evictions — a "
                    f"shared snapshot was destroyed, not migrated")
    if b["fork_on"]["verify_failed"] != 0:
        errs.append(f"fork: {b['fork_on']['verify_failed']} checksum "
                    f"failures after demotion (sidecar must travel with "
                    f"the clone)")


def _check_bank(b, errs):
    """``BENCH_bank.json``: recompute every contention gate from the
    recorded values — the artifact must not merely CLAIM the gates passed
    (regenerate with ``python benchmarks/run.py bank``)."""
    load, waves = b["load"], b["waves"]
    on, off = load["on"], load["off"]
    if not on["p99_2x"] > on["p99_1x"]:
        errs.append(f"bank: contention-on p99 not load-dependent "
                    f"({on['p99_1x']} -> {on['p99_2x']} at 2x)")
    if not (off["p99_1x"] == off["p99_2x"] == load["service_ns"]):
        errs.append(f"bank: contention-off p99 not flat at the service "
                    f"time ({off['p99_1x']}, {off['p99_2x']})")
    total = waves["sum_isolated_ns"]
    if not waves["disjoint_completion_ns"] < total:
        errs.append(f"bank: disjoint-route wave "
                    f"{waves['disjoint_completion_ns']} !< sum of isolated "
                    f"costs {total}")
    if not waves["disjoint_completion_ns"] >= waves["route_ns"]:
        errs.append("bank: disjoint-route wave faster than one route")
    if waves["same_bank_completion_ns"] != total:
        errs.append(f"bank: same-bank wave "
                    f"{waves['same_bank_completion_ns']} != sum of "
                    f"isolated costs {total} (must serialize exactly)")
    sa, sb = b["contention_on"], b["contention_off"]
    if sa["jobs_completed"] != sb["jobs_completed"]:
        errs.append("bank: the A/B arms served different job counts")
    bills = b["pricing_invariance"]
    if bills["on"] != bills["off"]:
        errs.append("bank: contention repriced the identical-schedule "
                    "sequential stream (must only shift the clock)")
    if sa["p99_latency_ns"] == sb["p99_latency_ns"]:
        errs.append("bank: contention never moved a completion time "
                    "(the A/B arms are identical)")
    if sa["mux"]["n_decode_stalls"] < 1:
        errs.append("bank: scheduler A/B observed no decode refresh stall")
    if sa["virtual_ns"] < b["config"]["timing"]["tREFI"]:
        errs.append("bank: A/B run too short to cross one tREFI window")
    for gate, ok in b["gates"].items():
        if ok is not True:
            errs.append(f"bank: gate {gate} recorded as {ok!r}")


def _check_lint(b, errs):
    """The committed repro-lint report: clean, waiver-free, and covering
    every registered jitted entry point (regenerate with
    ``python -m repro.analysis --strict --audit --report
    LINT_REPORT.json``)."""
    if b["schema"] != "repro-lint-report/v1":
        errs.append(f"lint: unknown report schema {b['schema']!r}")
        return
    if b["findings"]:
        errs.append(f"lint: {len(b['findings'])} active finding(s) in the "
                    f"committed report")
    if b["waived"]:
        errs.append(f"lint: {len(b['waived'])} waiver(s) active — the "
                    f"waiver file must stay empty")
    audit = b["audit"]
    if audit.get("findings"):
        errs.append(f"lint: {len(audit['findings'])} dispatch-audit "
                    f"finding(s)")
    names = {t["name"] for t in audit.get("targets", ())}
    need = {"decode", "suspend", "suspend_many", "resume", "resume_many",
            "migrate", "simulate_params"}
    if need - names:
        errs.append(f"lint: audit missing entry points {sorted(need - names)}")
    if not any(n.startswith("prefill[") for n in names):
        errs.append("lint: audit covers no prefill bucket")
    for t in audit.get("targets", ()):
        if t["donated_leaves"] != t["expected_donated_leaves"]:
            errs.append(f"lint: {t['name']} donation not verified "
                        f"({t['donated_leaves']}/"
                        f"{t['expected_donated_leaves']} buffers)")
        if t.get("jaxpr_host_transfer_eqns", 0) or \
                t.get("hlo_host_transfer_ops", 0):
            errs.append(f"lint: {t['name']} has in-graph host transfers")


def _check_roofline(b, errs):
    """The committed live-roofline report: every audited entry point
    present with positive traffic and a kernel attribution (regenerate
    with ``python benchmarks/run.py roofline``)."""
    if b["schema"] != "roofline-report/v1":
        errs.append(f"roofline: unknown report schema {b['schema']!r}")
        return
    names = set(b["entries"])
    need = {"decode", "suspend", "suspend_many", "resume", "resume_many",
            "migrate", "simulate_params"}
    if need - names:
        errs.append(f"roofline: missing entry points {sorted(need - names)}")
    if not any(n.startswith("prefill[") for n in names):
        errs.append("roofline: no prefill bucket attributed")
    if b["n_entry_points"] != len(names):
        errs.append(f"roofline: n_entry_points {b['n_entry_points']} != "
                    f"{len(names)} entries")
    if len(names) < 9:
        errs.append(f"roofline: {len(names)} entry points, expected >= 9")
    for n in sorted(names):
        e = b["entries"][n]
        if not e["bytes"] > 0:
            errs.append(f"roofline: {n} has no memory traffic")
        if not e["flops"] >= 0:
            errs.append(f"roofline: {n} flops negative")
        if not e["top_kernels"]:
            errs.append(f"roofline: {n} has no kernel attribution")
        elif e["dominant"] != e["top_kernels"][0]["name"]:
            errs.append(f"roofline: {n} dominant kernel disagrees with "
                        f"its top_kernels ranking")


BENCH_SCHEMAS = {
    "BENCH_serve.json": _check_serve,
    "BENCH_movement.json": _check_movement,
    "BENCH_sched.json": _check_sched,
    "BENCH_cluster.json": _check_cluster,
    "BENCH_faults.json": _check_faults,
    "BENCH_fork.json": _check_fork,
    "BENCH_bank.json": _check_bank,
    "LINT_REPORT.json": _check_lint,
    "ROOFLINE_REPORT.json": _check_roofline,
}


def check_artifacts(root=".") -> int:
    """Validate every committed BENCH_*.json against its deterministic-gate
    schema (``benchmarks/run.py --check``).  Wall-clock numbers are recorded
    data and never gated; the gates are the platform-independent invariants
    CI relies on.  Returns the number of failures."""
    def reject(const):
        raise ValueError(f"non-strict JSON constant {const}")

    errs, clean = [], 0
    for name, check in BENCH_SCHEMAS.items():
        before = len(errs)
        path = os.path.join(root, name)
        if not os.path.exists(path):
            errs.append(f"{name}: missing (regenerate and commit it)")
            continue
        try:
            with open(path) as f:
                payload = json.load(f, parse_constant=reject)
            check(payload, errs)
        except ValueError as e:
            errs.append(f"{name}: invalid strict JSON ({e})")
        except (KeyError, TypeError) as e:
            errs.append(f"{name}: schema drifted ({type(e).__name__}: {e})")
        clean += len(errs) == before
    before = len(errs)
    _check_trajectory(os.path.join(root, "BENCH_TRAJECTORY.jsonl"), errs,
                      reject)
    clean += len(errs) == before
    for e in errs:
        print(f"CHECK FAIL {e}")
    print(f"bench check: {clean}/{len(BENCH_SCHEMAS) + 1} artifacts clean, "
          f"{len(errs)} failure(s)")
    return len(errs)


# the gate keys every trajectory line must carry: the core artifacts that
# have existed since the log began (newer artifacts appear in later lines
# only, so they are validated as a subset, not required)
TRAJECTORY_CORE_GATES = frozenset({
    "BENCH_serve.json", "BENCH_movement.json",
    "BENCH_sched.json", "BENCH_cluster.json"})


def _check_trajectory(path, errs, reject):
    """``BENCH_TRAJECTORY.jsonl``: strict JSON per line, ``seq`` a strictly
    increasing int, and every line's ``gates`` dict keyed by known
    artifact names (with the core four always present, values strictly
    ``true``/``false``/``null``) — an append-only record of every bench
    invocation's headline gates (plot it to see the repo's trajectory)."""
    name = os.path.basename(path)
    if not os.path.exists(path):
        errs.append(f"{name}: missing (run any bench to append a line)")
        return
    last = None
    with open(path) as f:
        for i, line in enumerate(f, 1):
            if not line.strip():
                errs.append(f"{name}:{i}: blank line in append-only log")
                continue
            try:
                rec = json.loads(line, parse_constant=reject)
            except ValueError as e:
                errs.append(f"{name}:{i}: invalid strict JSON ({e})")
                continue
            seq = rec.get("seq")
            if not isinstance(seq, int):
                errs.append(f"{name}:{i}: seq missing or not an int")
                continue
            if last is not None and seq <= last:
                errs.append(f"{name}:{i}: seq {seq} not monotone "
                            f"(previous {last})")
            last = seq
            if not isinstance(rec.get("benches"), list):
                errs.append(f"{name}:{i}: benches missing or not a list")
            if not isinstance(rec.get("rows"), dict):
                errs.append(f"{name}:{i}: rows missing or not a dict")
            gates = rec.get("gates")
            if not isinstance(gates, dict):
                errs.append(f"{name}:{i}: gates missing or not a dict")
                continue
            unknown = set(gates) - set(BENCH_SCHEMAS)
            if unknown:
                errs.append(f"{name}:{i}: unknown gate keys "
                            f"{sorted(unknown)}")
            missing = TRAJECTORY_CORE_GATES - set(gates)
            if missing:
                errs.append(f"{name}:{i}: core gate keys missing "
                            f"{sorted(missing)}")
            for k, v in gates.items():
                if v is not None and not isinstance(v, bool):
                    errs.append(f"{name}:{i}: gate {k} is {v!r}, expected "
                                f"true/false/null")
    if last is None:
        errs.append(f"{name}: no records")


def _append_trajectory(benches, path="BENCH_TRAJECTORY.jsonl"):
    """Append one strict-JSON line per bench invocation: which benches ran,
    every headline ``derived`` value this run printed, and each committed
    artifact's gate status at append time.  ``seq`` continues monotonically
    from the last committed line (``--check`` validates)."""
    last = -1
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                if line.strip():
                    try:
                        seq = json.loads(line).get("seq", -1)
                        if isinstance(seq, int):
                            last = max(last, seq)
                    except ValueError:
                        pass
    gates = {}
    for name, check in BENCH_SCHEMAS.items():
        if not os.path.exists(name):
            gates[name] = None          # never generated: not a failure
            continue
        art_errs = []
        try:
            with open(name) as f:
                check(json.load(f), art_errs)
        except (ValueError, KeyError, TypeError) as e:
            art_errs.append(str(e))
        gates[name] = not art_errs
    rec = {"seq": last + 1, "ts": round(time.time(), 2),
           "benches": sorted(benches),
           "rows": {name: derived for name, _us, derived in ROWS},
           "gates": gates}
    with open(path, "a") as f:
        f.write(json.dumps(rec, sort_keys=True, separators=(",", ":"),
                           allow_nan=False) + "\n")


def bench_roofline(out_path="ROOFLINE_REPORT.json"):
    """Live roofline attribution over the audited hot path: lower every
    registered jitted entry point (``analysis.entrypoints.default_targets``
    — the SAME live jit objects serving runs and repro-lint audits) at
    audit geometry, run the optimized HLO through ``roofline.hlo.analyze``
    + ``roofline.attribution.attribute``, and write ``ROOFLINE_REPORT.json``
    (strict JSON, schema ``roofline-report/v1``, validated by ``--check``).
    This replaces the old dry-run-artifact scan: the report now always
    describes the code as committed, not a stale experiment directory."""
    from repro.analysis.entrypoints import default_targets
    from repro.roofline import attribution as ATTR
    from repro.roofline import hlo as H

    targets, engine = default_targets()
    entries = {}
    for t in targets:
        t0 = time.perf_counter()
        txt = t.fn.lower(*t.args, **t.kwargs).compile().as_text()
        dt = time.perf_counter() - t0
        cost = H.analyze(txt)
        top = ATTR.attribute(txt, top=5)
        names = list(top)
        flops, nbytes = cost["flops"], cost["bytes"]
        entries[t.name] = {
            "flops": flops,
            "bytes": nbytes,
            "bytes_kernel_adjusted": cost["bytes_kernel_adjusted"],
            "link_bytes_total": cost["link_bytes_total"],
            "arithmetic_intensity": round(flops / max(nbytes, 1.0), 4),
            "dominant": names[0] if names else None,
            "top_kernels": [{"name": k, "weighted_bytes": v}
                            for k, v in top.items()],
            "compile_s": round(dt, 3),
        }
        row(f"roofline_{t.name}", dt * 1e6,
            f"GF={flops / 1e9:.3f};GB={nbytes / 1e9:.4f};"
            f"AI={entries[t.name]['arithmetic_intensity']}")
    report = {
        "schema": "roofline-report/v1",
        "arch": "tinyllama-1.1b-reduced",
        "geometry": {"slots": engine.slots, "max_len": engine.max_len},
        "n_entry_points": len(entries),
        "entries": entries,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True, allow_nan=False)
        f.write("\n")
    row("roofline_entry_points", 0.0, f"{len(entries)}")


BENCHES = {
    "table1": bench_table1,
    "fig34": bench_fig3_fig4,
    "kernels": bench_kernels,
    "ring": bench_ring_collectives,
    "train": bench_train_throughput,
    "serve": bench_serve_throughput,
    "movement": bench_movement,
    "sched": bench_sched,
    "cluster": bench_cluster,
    "faults": bench_faults,
    "fork": bench_fork,
    "bank": bench_bank,
    "roofline": bench_roofline,
}


def main(argv=None) -> None:
    """Run all benches, or a subset: ``python benchmarks/run.py serve train``.
    ``--check`` instead validates the committed BENCH_*.json artifacts
    against their deterministic-gate schemas (no benches run)."""
    argv = list(argv if argv is not None else sys.argv[1:])
    if "--check" in argv:
        argv.remove("--check")
        if argv:
            raise SystemExit("--check takes no bench names")
        raise SystemExit(1 if check_artifacts() else 0)
    sel = set(argv)
    unknown = sel - set(BENCHES)
    if unknown:
        raise SystemExit(f"unknown benches {sorted(unknown)}; "
                         f"choose from {sorted(BENCHES)}")
    print("name,us_per_call,derived")
    ran = []
    for name, fn in BENCHES.items():
        if not sel or name in sel:
            fn()
            ran.append(name)
    if ran:
        _append_trajectory(ran)


if __name__ == "__main__":
    main()
