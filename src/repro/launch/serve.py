"""Serving launcher: the cost-aware continuous-batching scheduler serving a
synthetic traffic stream (Poisson/bursty arrivals, Zipfian session re-use).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
      --requests 12 --followups 24 --policy cost_aware

This module drives no engine loop of its own: every submit, suspend and
resume is a :class:`repro.sched.Scheduler` decision — admission comes from
the scheduler's queue (overflow *queues*, it never crashes the engine), the
suspend/resume traffic drains as fused waves (one dispatch per wave), and
the policy consults each move's modeled :class:`~repro.movement.plan
.MovementCost`.  ``--policy fifo`` reproduces the pre-scheduler behavior
for A/B runs (``benchmarks/run.py sched`` automates that comparison).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro import sched
from repro.configs import get_config, get_reduced
from repro.models import lm
from repro.serve.engine import Engine


def main(argv=None) -> dict:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-len", type=int, default=96)
    p.add_argument("--requests", type=int, default=8,
                   help="fresh sessions (may exceed --slots: overflow queues)")
    p.add_argument("--followups", "--resumes", type=int, default=16,
                   dest="followups", help="follow-up (resume) arrivals")
    p.add_argument("--policy", default="cost_aware",
                   choices=sched.policies())
    p.add_argument("--mean-gap-ns", type=float, default=2000.0)
    p.add_argument("--bursty", action="store_true")
    p.add_argument("--zipf-s", type=float, default=1.3)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    wl_prompt_lens = (6, 8, 10, 12)
    if args.max_new < 1:
        p.error(f"--max-new must be >= 1 (got {args.max_new})")
    if args.max_len < max(wl_prompt_lens) + args.max_new:
        p.error(f"--max-len {args.max_len} cannot hold the synthetic "
                f"workload: prompts run up to {max(wl_prompt_lens)} tokens "
                f"plus --max-new {args.max_new} decode positions")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params = lm.init_lm(cfg, jax.random.key(args.seed))

    wl = sched.WorkloadConfig(
        n_fresh=args.requests, n_followups=args.followups,
        mean_gap_ns=args.mean_gap_ns,
        arrival="bursty" if args.bursty else "poisson",
        zipf_s=args.zipf_s, prompt_lens=wl_prompt_lens,
        new_tokens=tuple(sorted({max(args.max_new // 2, 1), args.max_new})))
    arrivals = sched.generate_workload(wl, seed=args.seed,
                                       vocab_size=cfg.vocab_size)
    # the store holds one snapshot per session — admission pressure is the
    # QUEUE's problem (a burst beyond --slots waits, it never raises
    # EngineFull), store pressure would be silent eviction, so size it out
    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len,
                 n_sessions=sched.n_sessions_for(wl))
    s = sched.Scheduler(eng, policy=args.policy, arrivals=arrivals)

    t0 = time.time()
    summary = s.run()
    dt = time.time() - t0

    out = {
        "policy": args.policy,
        **summary,
        **{k: eng.stats[k] for k in ("decoded_tokens", "suspends", "resumes",
                                     "decode_dispatches", "host_transfers")},
        "villa_hit_rate": round(eng.hit_rate(), 3),
        "decode_compile_count": eng.compile_counts()["decode"],
        "ticks": s.tick_count,
        "tokens_per_s": round(eng.stats["decoded_tokens"] / max(dt, 1e-9), 1),
        "seconds": round(dt, 1),
    }
    print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
