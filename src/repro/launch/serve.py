"""Serving launcher: the cost-aware scheduler serving synthetic traffic on
one engine or a multi-replica cluster with live session migration.

Quickstart::

  # one replica, cost-aware continuous batching
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
      --requests 12 --followups 24 --policy cost_aware

  # four replicas on a mesh ring: placement + cost-priced live migration
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
      --replicas 4 --slots 2 --requests 16 --followups 32

  # the migration-off A/B arm (resumes pinned to their home replica)
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
      --replicas 4 --no-migrate

This module drives no engine loop of its own: every submit, suspend,
resume — and with ``--replicas > 1`` every placement and migration — is a
:class:`repro.sched.Scheduler` / :class:`repro.sched.ClusterScheduler`
decision.  Admission overflow QUEUES (never EngineFull), suspend/resume
traffic drains as fused waves (one dispatch per replica per wave), and
migrations cross the mesh as priced ``hop_chain`` movement plans.
``--policy fifo`` reproduces the pre-scheduler behavior for A/B runs
(``benchmarks/run.py sched`` and ``cluster`` automate the comparisons).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import sched
from repro.configs import get_config, get_reduced
from repro.models import lm
from repro.serve.cluster import Cluster
from repro.serve.engine import Engine, Request

QUICKSTART = """examples:
  %(prog)s --arch tinyllama-1.1b --reduced --requests 12 --followups 24
  %(prog)s --arch tinyllama-1.1b --reduced --replicas 4 --slots 2
  %(prog)s --arch tinyllama-1.1b --reduced --replicas 4 --no-migrate \
--policy fifo

With --replicas N the engines sit on a mesh ring (DESIGN.md Sec. 10):
placement scores each replica by free slots, VILLA fast-tier occupancy and
the modeled ICI hop cost from the session's residence; a resume placed off
its home replica live-migrates the suspended pages as one fused hop-chain
plan per route.  --no-migrate pins every resume to its home replica (the
SLO A/B arm).

Chaos (DESIGN.md Sec. 12): --fault-rate R injects seeded at-rest bit rot
and migration-leg corruption at per-event probability R.  Every corruption
is caught by the per-page checksum sidecar; with recovery on (default) the
scheduler retries corrupted movement legs (priced, backoff on the virtual
clock) and restores corrupt sessions from periodic snapshots; --no-recovery
turns the run into a detection-only audit.  --fault-seed picks the chaos
RNG stream; the same (rate, seed) replays the same faults bit-for-bit:

  %(prog)s --arch tinyllama-1.1b --reduced --replicas 2 --slots 2 \
--fault-rate 0.25 --fault-seed 7

Shared-prefix forking (DESIGN.md Sec. 13): --fork-prefix N prefills one
N-token system prompt ONCE, then admits every fresh session as a zero-copy
FORK of that template (refcounted page alias, RowClone FPM pricing); each
session diverges at its first decode and copies-on-write at its first
suspend.  --no-fork is the A/B arm: the same shared prefix is prepended to
every prompt and prefilled per session.  The prefix must leave room for
the decode budget: --fork-prefix + --max-new <= --max-len.

  %(prog)s --arch tinyllama-1.1b --reduced --requests 12 --fork-prefix 24"""


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(
        epilog=QUICKSTART,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--replicas", type=int, default=1,
                   help="engine replicas on the mesh ring (default 1; >1 "
                        "enables placement + live session migration)")
    p.add_argument("--no-migrate", action="store_true",
                   help="pin resumes to their home replica (A/B arm; only "
                        "meaningful with --replicas > 1)")
    p.add_argument("--slots", type=int, default=4,
                   help="decode slots PER replica")
    p.add_argument("--max-len", type=int, default=96)
    p.add_argument("--requests", type=int, default=8,
                   help="fresh sessions (may exceed the slot count: "
                        "overflow queues)")
    p.add_argument("--followups", "--resumes", type=int, default=16,
                   dest="followups", help="follow-up (resume) arrivals")
    p.add_argument("--policy", default=None, choices=sched.policies(),
                   help="scheduling policy (default: cost_aware, or "
                        "cost_aware_cluster with --replicas > 1)")
    p.add_argument("--mean-gap-ns", type=float, default=2000.0)
    p.add_argument("--bursty", action="store_true")
    p.add_argument("--zipf-s", type=float, default=1.3)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--fault-rate", type=float, default=0.0,
                   help="seeded chaos: per-event probability of injecting "
                        "a fault (at-rest bit rot, migration-leg "
                        "corruption); 0 disables injection (default)")
    p.add_argument("--fault-seed", type=int, default=None,
                   help="chaos RNG stream (default: --seed); the same "
                        "(rate, seed) pair replays identical faults")
    p.add_argument("--no-recovery", action="store_true",
                   help="detection-only chaos: count checksum detections "
                        "but never retry or restore (audit arm)")
    p.add_argument("--snapshot-every", type=int, default=4,
                   help="ticks between session-snapshot refreshes backing "
                        "chaos recovery (0 disables snapshots)")
    p.add_argument("--fork-prefix", type=int, default=None, metavar="N",
                   help="serve every fresh session as a zero-copy fork of "
                        "ONE N-token shared system prompt (prefilled once; "
                        "children alias its snapshot and copy-on-write at "
                        "divergence)")
    p.add_argument("--no-fork", action="store_true",
                   help="A/B arm for --fork-prefix: prepend the same shared "
                        "prefix to every prompt and prefill it per session "
                        "(no aliasing)")
    p.add_argument("--trace-out", default=None, metavar="PATH",
                   help="record the run's virtual-clock span trace and "
                        "write it as Chrome trace_events JSON (open in "
                        "Perfetto); composes with every chaos/fork flag — "
                        "fault incidents, retries, snapshots, forks and "
                        "CoW breaks all appear as spans")
    args = p.parse_args(argv)

    wl_prompt_lens = (6, 8, 10, 12)
    if args.replicas < 1:
        p.error(f"--replicas must be >= 1 (got {args.replicas})")
    if args.max_new < 1:
        p.error(f"--max-new must be >= 1 (got {args.max_new})")
    if args.max_len < max(wl_prompt_lens) + args.max_new:
        p.error(f"--max-len {args.max_len} cannot hold the synthetic "
                f"workload: prompts run up to {max(wl_prompt_lens)} tokens "
                f"plus --max-new {args.max_new} decode positions")
    if not 0.0 <= args.fault_rate <= 1.0:
        p.error(f"--fault-rate must be a probability in [0, 1] "
                f"(got {args.fault_rate})")
    if args.snapshot_every < 0:
        p.error(f"--snapshot-every must be >= 0 (got {args.snapshot_every})")
    if args.fault_rate > 0 and args.replicas < 2:
        p.error("--fault-rate needs --replicas >= 2: chaos injection "
                "targets the cluster scheduler (migration legs, replica "
                "storage)")
    if (args.no_recovery or args.fault_seed is not None) \
            and args.fault_rate == 0:
        p.error("--no-recovery / --fault-seed are chaos flags: set "
                "--fault-rate > 0 to enable injection first")
    if args.no_fork and args.fork_prefix is None:
        p.error("--no-fork is the A/B arm of --fork-prefix: set "
                "--fork-prefix N to define the shared prefix first")
    if args.fork_prefix is not None:
        if args.fork_prefix < 1:
            p.error(f"--fork-prefix must be >= 1 (got {args.fork_prefix})")
        # the envelope: a forked child resumes at position N and must fit
        # its whole decode budget before max_len (the engine refuses
        # out-of-envelope resumes — fail fast at the CLI instead)
        if args.fork_prefix + args.max_new > args.max_len:
            p.error(f"--fork-prefix {args.fork_prefix} + --max-new "
                    f"{args.max_new} exceeds --max-len {args.max_len}: the "
                    f"shared prefix must leave room for the decode budget")
        if args.no_fork and (args.fork_prefix + max(wl_prompt_lens)
                             + args.max_new > args.max_len):
            p.error(f"--no-fork prefills the prefix plus each prompt (up "
                    f"to {max(wl_prompt_lens)} tokens): --max-len "
                    f"{args.max_len} is too small for --fork-prefix "
                    f"{args.fork_prefix} + --max-new {args.max_new}")
    policy = args.policy or ("cost_aware_cluster" if args.replicas > 1
                             else "cost_aware")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params = lm.init_lm(cfg, jax.random.key(args.seed))

    wl = sched.WorkloadConfig(
        n_fresh=args.requests, n_followups=args.followups,
        mean_gap_ns=args.mean_gap_ns,
        arrival="bursty" if args.bursty else "poisson",
        zipf_s=args.zipf_s, prompt_lens=wl_prompt_lens,
        new_tokens=tuple(sorted({max(args.max_new // 2, 1), args.max_new})))
    arrivals = sched.generate_workload(wl, seed=args.seed,
                                       vocab_size=cfg.vocab_size)
    # the store holds one snapshot per session — admission pressure is the
    # QUEUE's problem (a burst beyond the slot count waits, it never raises
    # EngineFull), store pressure would be silent eviction, so size it out
    n_sessions = sched.n_sessions_for(wl)
    fork_template_uid, fork_seeds, prefix = None, {}, None
    if args.fork_prefix is not None:
        frng = np.random.default_rng(args.seed + 1)
        prefix = frng.integers(0, cfg.vocab_size,
                               args.fork_prefix).astype(np.int32)
        if args.no_fork:
            # A/B arm: the same shared prefix, prefilled per session
            arrivals = [a._replace(prompt=np.concatenate([prefix, a.prompt]))
                        if a.kind == "fresh" else a for a in arrivals]
        else:
            # template homes at row n_sessions (no workload uid maps there);
            # each fresh arrival becomes a RESUME of its forked child, which
            # diverges at the first token of its original prompt
            fork_template_uid = n_sessions
            n_sessions += 1
            fork_seeds = {a.uid: int(a.prompt[0]) for a in arrivals
                          if a.kind == "fresh"}
            arrivals = [a._replace(kind="resume", prompt=None)
                        if a.kind == "fresh" else a for a in arrivals]
    injector = None
    if args.fault_rate > 0:
        from repro.faults import FaultInjector, FaultSpec
        injector = FaultInjector(FaultSpec(
            rate=args.fault_rate,
            seed=args.seed if args.fault_seed is None else args.fault_seed,
            recover=not args.no_recovery))
    tracer = None
    if args.trace_out:
        from repro import movement as MV
        from repro.obs import Tracer
        tracer = Tracer()
        MV.set_tracer(tracer)       # host-side plan executes -> exec marks
    if args.replicas > 1:
        cluster = Cluster(cfg, params, n_replicas=args.replicas,
                          slots=args.slots, max_len=args.max_len,
                          n_sessions=n_sessions, faults=injector)
        s = sched.ClusterScheduler(cluster, policy=policy,
                                   arrivals=arrivals,
                                   migrate=not args.no_migrate,
                                   snapshot_every=(args.snapshot_every
                                                   if injector else 0),
                                   tracer=tracer)
        eng = cluster
    else:
        engine = Engine(cfg, params, slots=args.slots, max_len=args.max_len,
                        n_sessions=n_sessions)
        s = sched.Scheduler(engine, policy=policy, arrivals=arrivals,
                            tracer=tracer)
        eng = engine

    if fork_template_uid is not None:
        # prefill the shared prefix ONCE (max_new=1 auto-suspends at the
        # prefill token) and alias every workload session off its snapshot
        # — zero device dispatches for the whole fan-out
        uids = sorted(fork_seeds)
        if args.replicas > 1:
            cluster.submit(Request(uid=fork_template_uid, prompt=prefix,
                                   max_new=1), replica=0)
            for uid in uids:
                cluster.fork(fork_template_uid, uid,
                             seed_token=fork_seeds[uid])
        else:
            engine.submit(Request(uid=fork_template_uid, prompt=prefix,
                                  max_new=1))
            engine.fork_many(fork_template_uid, uids,
                             seed_tokens=[fork_seeds[u] for u in uids])

    t0 = time.time()
    summary = s.run()
    dt = time.time() - t0
    eng_stats = eng.stats

    out = {
        "policy": policy,
        "replicas": args.replicas,
        **summary,
        **{k: eng_stats[k] for k in ("decoded_tokens", "suspends", "resumes",
                                     "decode_dispatches", "host_transfers")},
        "villa_hit_rate": round(eng.hit_rate(), 3),
        "decode_compile_count": eng.compile_counts()["decode"],
        "ticks": s.tick_count,
        "tokens_per_s": round(eng_stats["decoded_tokens"] / max(dt, 1e-9),
                              1),
        "seconds": round(dt, 1),
    }
    if args.fork_prefix is not None:
        out["fork"] = {
            "enabled": not args.no_fork,
            "prefix_len": args.fork_prefix,
            "prefills": eng_stats["prefills"],
            "forks": eng_stats["forks"],
            "bytes_not_copied": eng_stats["bytes_not_copied"],
            "demotions": eng_stats["demotions"],
            "evictions": eng_stats["evictions"],
        }
    if args.replicas > 1:
        out["migrations"] = eng_stats["migrations"]
        out["migrated_bytes"] = eng_stats["migrated_bytes"]
    if injector is not None:
        out["fault_ledger"] = injector.summary()
        out["verify_failed"] = eng.verify_failure_count()
        out["at_rest_corrupt"] = int(eng.scrub())
    if tracer is not None:
        from repro import movement as MV
        from repro.obs import write_chrome_trace
        MV.set_tracer(None)         # don't leak into later runs in-process
        write_chrome_trace(tracer, args.trace_out)
        roll = tracer.rollup()
        # replaces the summary's rollup-only "trace" key with the launcher
        # digest: per-phase span counts, per-leg ns split, top-5 spans
        out["trace"] = {
            "spans": roll["spans"],
            "per_phase": {k: v["count"]
                          for k, v in roll["per_phase"].items()},
            "legs": roll["legs"],
            "top_spans_ns": tracer.top_spans(5),
            "chrome_trace": args.trace_out,
        }
    print(json.dumps(out, allow_nan=False))
    return out


if __name__ == "__main__":
    main()
