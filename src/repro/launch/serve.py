"""Serving launcher: continuous batching + VILLA session tiering demo.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
      --requests 12 --resumes 24
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import lm
from repro.serve.engine import Engine, Request


def main(argv=None) -> dict:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-len", type=int, default=96)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--resumes", type=int, default=16)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params = lm.init_lm(cfg, jax.random.key(args.seed))
    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len,
                 n_sessions=max(args.requests, 8))
    rng = np.random.default_rng(args.seed)

    t0 = time.time()
    # phase 1: serve fresh requests
    pending = [Request(uid=i,
                       prompt=rng.integers(0, cfg.vocab_size,
                                           args.prompt_len).astype(np.int32),
                       max_new=args.max_new)
               for i in range(args.requests)]
    while pending or eng.active:
        while pending and eng.free_slots():
            eng.submit(pending.pop(0))
        eng.step()
    # phase 2: resume sessions with a skewed (hot) distribution — the
    # VILLA policy should promote the frequently-resumed sessions.  Resumes
    # drain in waves: every wave of distinct uids is ONE batched
    # tiered-store dispatch (engine.resume_many / villa_cache.access_many).
    hot = max(args.requests // 4, 1)
    left = args.resumes
    while left > 0:
        wave = []
        wave_max = min(len(eng.free_slots()), left, args.requests)
        while len(wave) < wave_max:
            uid = int(rng.integers(0, hot)) if rng.random() < 0.8 \
                else int(rng.integers(0, args.requests))
            if uid not in wave:
                wave.append(uid)
        eng.resume_many(wave, extra_new=4)
        left -= len(wave)
        while eng.active:
            eng.step()
    dt = time.time() - t0
    out = {**eng.stats, "villa_hit_rate": round(eng.hit_rate(), 3),
           "tokens_per_s": round(eng.stats["decoded_tokens"] / dt, 1),
           "decode_compile_count": eng.compile_counts()["decode"],
           "seconds": round(dt, 1)}
    print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
