"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16)."""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 (data, model) per pod; 2x16x16 (pod, data, model) across pods.

    A function (not a module-level constant) so importing this module never
    touches jax device state; the dry-run sets XLA_FLAGS for 512 host
    devices *before* calling this.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(jax.devices())} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = data * model
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())}")
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(data, model), ("data", "model"))
