"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs`` covers the batch inputs; ``state_specs`` / ``cache_specs``
cover train state and KV caches via ``jax.eval_shape`` over the real
constructors — weak-type-correct and shardable, nothing materialised.
Modality frontends are STUBS per the task spec: [audio]/[vlm] get
precomputed frame/patch embeddings (enc_embeds) and M-RoPE position ids.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig
from repro.models import lm
from repro.train.step import ParallelConfig, init_train_state

SDS = jax.ShapeDtypeStruct

# Encoder length for enc-dec decode shapes (speech frames after frontend).
ENC_LEN_DECODE = 4096


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str,
                act_dtype=jnp.bfloat16) -> Dict[str, Any]:
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {
            "tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32),
        }
        if cfg.mrope:
            specs["positions"] = SDS((3, B, S), jnp.int32)
        if cfg.encdec:
            specs["enc_embeds"] = SDS((B, S, cfg.d_model), act_dtype)
        if shape.kind == "prefill":
            del specs["labels"]
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"tokens": SDS((B, 1), jnp.int32),
            "pos": SDS((), jnp.int32)}


def state_specs(cfg: ModelConfig, pcfg: ParallelConfig, param_dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_train_state(cfg, jax.random.key(0), pcfg, param_dtype))


def cache_specs(cfg: ModelConfig, shape: ShapeConfig | str,
                cache_dtype=jnp.bfloat16):
    if isinstance(shape, str):
        shape = SHAPES[shape]
    enc_len = ENC_LEN_DECODE if cfg.encdec else 0
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, max_len=shape.seq_len,
                              enc_len=enc_len, dtype=cache_dtype))


def param_specs(cfg: ModelConfig, param_dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: lm.init_lm(cfg, jax.random.key(0), param_dtype))
