import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: device count locks at first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * build the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  * lower jit(train_step | serve_step) over ShapeDtypeStruct stand-ins
    (no allocation),
  * compile; print memory_analysis() (proves it fits) and cost_analysis(),
  * parse collective traffic from the optimized HLO,
  * write the JSON artifact that EXPERIMENTS.md Sec Roofline reads.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all            # every applicable cell
Variants (hillclimbing levers): --no-fsdp --sp --cache-dtype int8
  --capacity-factor F --moe-groups N --no-remat --variant NAME
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time


def run_cell(args) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import SHAPES, get_config
    from repro.configs.base import applicable_shapes
    from repro.launch import specs as SP
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import roofline_terms
    from repro.roofline.hlo import analyze
    from repro.train.step import (ParallelConfig, make_prefill_step,
                                  make_serve_step, make_train_step)

    cfg = get_config(args.arch)
    if args.capacity_factor:
        cfg = dataclasses.replace(cfg, capacity_factor=args.capacity_factor)
    if args.no_remat:
        cfg = dataclasses.replace(cfg, remat=False)
    if args.attn_block:
        cfg = dataclasses.replace(cfg, attn_block=args.attn_block)
    if args.scan_chunk:
        cfg = dataclasses.replace(cfg, scan_chunk=args.scan_chunk)
    shape = SHAPES[args.shape]
    if args.shape not in applicable_shapes(cfg):
        return {"arch": args.arch, "shape": args.shape, "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention "
                          "(DESIGN.md Sec. 4)"}

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    n_chips = mesh.devices.size
    pcfg = ParallelConfig(fsdp=not args.no_fsdp,
                          tensor_parallel=not args.no_tp,
                          sequence_parallel=args.sp,
                          grad_compress=args.grad_compress,
                          moe_groups=args.moe_groups)
    cache_dtype = {"bf16": jnp.bfloat16, "int8": jnp.int8,
                   "f32": jnp.float32}[args.cache_dtype]

    t0 = time.time()
    if shape.kind == "train":
        state_shapes = SP.state_specs(cfg, pcfg, param_dtype=jnp.bfloat16)
        batch_shapes = SP.input_specs(cfg, shape)
        _, compile_step, _ = make_train_step(cfg, mesh, pcfg)
        jitted = compile_step(state_shapes, batch_shapes)
        lowered = jitted.lower(state_shapes, batch_shapes)
    elif shape.kind == "prefill":
        param_shapes = SP.param_specs(cfg, param_dtype=jnp.bfloat16)
        cache_shapes = SP.cache_specs(cfg, shape, cache_dtype=cache_dtype)
        batch = SP.input_specs(cfg, shape)
        _, compile_step = make_prefill_step(cfg, mesh, pcfg)
        jitted = compile_step(param_shapes, cache_shapes, batch)
        lowered = jitted.lower(param_shapes, cache_shapes, batch)
    else:
        param_shapes = SP.param_specs(cfg, param_dtype=jnp.bfloat16)
        cache_shapes = SP.cache_specs(cfg, shape, cache_dtype=cache_dtype)
        inp = SP.input_specs(cfg, shape)
        _, compile_step = make_serve_step(cfg, mesh, pcfg)
        jitted = compile_step(param_shapes, cache_shapes, inp["tokens"])
        lowered = jitted.lower(param_shapes, cache_shapes, inp["tokens"],
                               inp["pos"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:                                   # noqa: BLE001
        mem_info = {"error": str(e)}

    t0 = time.time()
    hlo = compiled.as_text()
    hc = analyze(hlo)
    t_analyze = time.time() - t0
    terms = roofline_terms(hc, n_chips, cfg, shape)

    art = {
        "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
        "variant": args.variant, "status": "ok", "n_chips": n_chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "analyze_s": round(t_analyze, 1),
        "cost_analysis_raw": {k: cost.get(k) for k in
                              ("flops", "bytes accessed", "transcendentals")},
        "hlo_cost": {k: v for k, v in hc.items() if k != "collectives"},
        "memory": mem_info,
        "collectives": hc["collectives"],
        "roofline": terms,
        "parallel": dataclasses.asdict(pcfg),
        "cache_dtype": args.cache_dtype,
    }
    return art


def _parser():
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", choices=["single", "multi"], default="single")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out-dir", default="experiments/dryrun")
    p.add_argument("--variant", default="baseline")
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--no-tp", action="store_true")
    p.add_argument("--sp", action="store_true")
    p.add_argument("--grad-compress", action="store_true")
    p.add_argument("--no-remat", action="store_true")
    p.add_argument("--moe-groups", type=int, default=0)
    p.add_argument("--capacity-factor", type=float, default=0.0)
    p.add_argument("--attn-block", type=int, default=0)
    p.add_argument("--scan-chunk", type=int, default=0)
    p.add_argument("--cache-dtype", default="bf16",
                   choices=["bf16", "int8", "f32"])
    p.add_argument("--timeout", type=int, default=3000)
    return p


def main() -> None:
    args = _parser().parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    if args.all:
        # one subprocess per cell: isolates compiles, survives hangs
        from repro.configs import ARCH_NAMES, get_config
        from repro.configs.base import applicable_shapes
        cells = [(a, s, m)
                 for a in ARCH_NAMES
                 for s in applicable_shapes(get_config(a))
                 for m in ("single", "multi")]
        for a, s, m in cells:
            out = os.path.join(args.out_dir, f"{a}_{s}_{m}_{args.variant}.json")
            if os.path.exists(out):
                print(f"[skip] {out}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m,
                   "--out-dir", args.out_dir, "--variant", args.variant]
            print(f"[run ] {a} {s} {m}", flush=True)
            r = subprocess.run(cmd, timeout=args.timeout)
            if r.returncode != 0:
                with open(out, "w") as f:
                    json.dump({"arch": a, "shape": s, "mesh": m,
                               "variant": args.variant, "status": "failed",
                               "returncode": r.returncode}, f,
                              allow_nan=False)
        return

    art = run_cell(args)
    name = f"{args.arch}_{args.shape}_{args.mesh}_{args.variant}.json"
    path = os.path.join(args.out_dir, name)
    with open(path, "w") as f:
        json.dump(art, f, indent=1, allow_nan=False)
    print(json.dumps({k: art[k] for k in
                      ("arch", "shape", "mesh", "status") if k in art},
                     allow_nan=False))
    if art.get("status") == "ok":
        print("memory:", art["memory"])
        print("hlo flops=%.3e bytes=%.3e link_bytes=%.3e" % (
            art["hlo_cost"]["flops"], art["hlo_cost"]["bytes"],
            art["hlo_cost"]["link_bytes_total"]))
        r = art["roofline"]
        print("roofline: compute=%.4fs memory=%.4fs collective=%.4fs "
              "dominant=%s useful=%.3f frac=%.3f" % (
                  r["compute_s"], r["memory_s"], r["collective_s"],
                  r["dominant"], r["useful_flops_ratio"],
                  r["roofline_fraction"]))


if __name__ == "__main__":
    main()
