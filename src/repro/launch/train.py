"""Training launcher: local mesh, checkpoint/restart, deterministic data.

CPU-runnable end-to-end (reduced configs); the same step factory and
shardings drive the production mesh in the dry-run.  Fault tolerance:
crash-and-rerun resumes from the newest intact checkpoint with the data
pipeline replaying the exact token stream (stateless ``batch_at(step)``).

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 20
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import manager as ckpt
from repro.configs import get_config, get_reduced
from repro.data.pipeline import DataConfig, batch_at
from repro.launch.mesh import make_local_mesh
from repro.optim.adamw import OptConfig
from repro.train.step import (ParallelConfig, TrainState, init_train_state,
                              make_train_step)


def main(argv=None) -> dict:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--data", type=int, default=1)
    p.add_argument("--model", type=int, default=1)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=0)
    p.add_argument("--grad-compress", action="store_true")
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_local_mesh(args.data, args.model)
    pcfg = ParallelConfig(fsdp=args.data > 1,
                          grad_compress=args.grad_compress)
    ocfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                     total_steps=args.steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)

    state = init_train_state(cfg, jax.random.key(args.seed), pcfg)
    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state = ckpt.restore(state, args.ckpt_dir)
        start_step = int(state.step)
        print(f"[resume] from step {start_step}")

    _, compile_step, state_shardings = make_train_step(cfg, mesh, pcfg, ocfg)
    b0 = batch_at(dcfg, 0)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          (state, b0))
    step_fn = compile_step(*shapes)

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = batch_at(dcfg, step)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        if args.ckpt_dir and args.ckpt_every and \
                (step + 1) % args.ckpt_every == 0:
            ckpt.save(state, args.ckpt_dir, step + 1)
    dt = time.time() - t0
    result = {"first_loss": losses[0] if losses else None,
              "last_loss": losses[-1] if losses else None,
              "steps": len(losses), "seconds": round(dt, 1)}
    print(json.dumps(result, allow_nan=False))
    return result


if __name__ == "__main__":
    main()
