"""repro — LISA (Low-Cost Inter-Linked Subarrays) as a JAX/TPU framework.

Faithful DRAM-substrate reproduction + the paper's connectivity insight as a
first-class distributed-runtime feature.  See DESIGN.md.
"""
import jax as _jax

if not hasattr(_jax, "shard_map"):
    # jax < 0.5 ships shard_map under experimental only; the distributed
    # modules (core/lisa/rbm, train/pipeline, ...) target the stable name.
    from jax.experimental.shard_map import shard_map as _shard_map
    _jax.shard_map = _shard_map

if not hasattr(_jax.lax, "axis_size"):
    # jax < 0.5 has no lax.axis_size; psum of a literal 1 folds to the static
    # mesh-axis size under shard_map, which is all the callers need.
    _jax.lax.axis_size = lambda axis_name: _jax.lax.psum(1, axis_name)

if not hasattr(_jax.lax, "pvary"):
    # pvary only adjusts newer jax's replication tracking; on jax < 0.5
    # shard_map has no varying-axis bookkeeping, so it is the identity.
    _jax.lax.pvary = lambda x, axis_name: x

__version__ = "1.0.0"
