"""repro — LISA (Low-Cost Inter-Linked Subarrays) as a JAX/TPU framework.

Faithful DRAM-substrate reproduction + the paper's connectivity insight as a
first-class distributed-runtime feature.  See DESIGN.md.
"""
__version__ = "1.0.0"
