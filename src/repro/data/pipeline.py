"""Deterministic synthetic LM data pipeline.

Stateless: ``batch_at(step)`` is a pure function of (seed, step), so restart
after a failure reproduces the exact token stream with no data-loader state
in the checkpoint — the fault-tolerance property the launcher relies on.

The synthetic language has learnable structure (a repeated-segment copy task
over a Markov backbone) so small models show clear loss decrease within a
few hundred steps — the end-to-end example trains on it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    repeat_len: int = 16       # copy-task period (structure to learn)


def batch_at(cfg: DataConfig, step: int | jax.Array) -> Dict[str, jax.Array]:
    """Produce the global batch for ``step`` (tokens, labels)."""
    key = jax.random.fold_in(jax.random.key(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    R = min(cfg.repeat_len, S)
    n_rep = -(-S // R)
    base = jax.random.randint(k1, (B, R), 0, V, jnp.int32)
    tokens = jnp.tile(base, (1, n_rep))[:, :S]
    # sprinkle noise so it's not trivially memorisable
    noise = jax.random.bernoulli(k2, 0.05, (B, S))
    rand = jax.random.randint(jax.random.fold_in(k2, 1), (B, S), 0, V, jnp.int32)
    tokens = jnp.where(noise, rand, tokens)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    return {"tokens": tokens, "labels": labels}


def host_shard(batch: Dict[str, jax.Array], host_id: int, n_hosts: int
               ) -> Dict[str, jax.Array]:
    """Slice the per-host shard (multi-host launchers feed jax.make_array_
    from_process_local_data with this)."""
    def cut(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return {k: cut(v) for k, v in batch.items()}
