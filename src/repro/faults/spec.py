"""Deterministic fault model: what goes wrong, when, and under which seed.

Chang's thesis (PAPERS.md) spends whole chapters on DRAM latency/reliability
variation and the ECC-style machinery controllers carry to survive it; LISA's
RBM hop chains multiply the surfaces where a transfer can be corrupted.  This
module is the *model* half of the chaos subsystem: a frozen
:class:`FaultSpec` plus a :class:`FaultInjector` whose every draw comes from
a counter-based seeded RNG (``np.random.default_rng((seed, counter))``) —
never wall-clock, never global RNG state — so an entire chaos run replays
bit-identically from ``(spec, workload)`` and CI can gate on exact counters.

The injector is also the host-side *ledger* of the zero-silent-corruption
invariant: every fired fault must end in exactly one bucket —

    ``retry_fixed``   a movement retry re-copied the leg clean
    ``recovered``     a snapshot restore repaired the session pre-resume
    ``detected``      the checksum verify caught it at resume (served lost)
    ``corrupted``     still at rest, counted by the end-of-run scrub

``fired == retry_fixed + new_corrupt + merged`` and ``new_corrupt ==
recovered + detected + destroyed + len(corrupted)`` hold at every step
(``destroyed``: the corrupt copy died with its replica); the chaos bench
asserts both against the device-side verify counter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

# fault-mode name -> traced int32 code.  "none" is the null fault; modes
# register themselves via repro.faults.inject.register_fault (the fifth
# instance of the PR 1 registry pattern), which assigns the next code at
# import time so codes are deterministic per registration order.
FAULT_CODES: Dict[str, int] = {"none": 0}

# the uniform traced fault operand: (mode, index, xor) int32.  Passing this
# when no fault fires keeps jitted signatures identical -> zero recompiles.
NULL_FAULT = np.zeros(3, np.int32)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One chaos scenario, fully determined by its fields (all seeded).

    ``rate`` is the per-opportunity fault probability (movement waves and
    per-tick storage draws); ``replica_failures`` / ``degrade_fast`` are
    scheduled ``(tick, replica)`` events.  ``recover`` arms retries and
    snapshot-based repair; off, corruptions land and must still be detected.
    """
    rate: float = 0.0
    seed: int = 0
    kinds: Tuple[str, ...] = ("flip_byte",)
    recover: bool = True
    max_retries: int = 3
    backoff_base_ns: float = 500.0
    backoff_cap_ns: float = 8000.0
    replica_failures: Tuple[Tuple[int, int], ...] = ()
    degrade_fast: Tuple[Tuple[int, int], ...] = ()

    def __post_init__(self):
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"fault rate must be in [0, 1], got {self.rate}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        unknown = [k for k in self.kinds if k == "none"]
        if unknown or not self.kinds:
            raise ValueError(f"kinds must name registered fault modes, "
                             f"got {self.kinds}")


class FaultInjector:
    """Seeded, replayable fault source + corruption ledger.

    Draw ``i`` uses ``np.random.default_rng((seed, i))`` — a fresh
    SeedSequence per opportunity, so injection sites can be added or
    reordered without perturbing unrelated draws beyond the counter shift,
    and nothing ever touches wall-clock or global RNG state
    (repro-lint's ``wallclock-in-virtual-clock`` rule stays green).
    """

    def __init__(self, spec: FaultSpec):
        # validate mode names against the registry (import registers modes)
        from repro.faults import inject as _inject  # noqa: F401
        for k in spec.kinds:
            if k not in FAULT_CODES:
                raise ValueError(f"unknown fault kind {k!r} "
                                 f"(known: {sorted(FAULT_CODES)})")
        self.spec = spec
        self._counter = 0
        self.corrupted: Dict[int, int] = {}      # uid -> fire counter
        self.counters: Dict[str, int] = {
            "fired": 0, "movement_fired": 0, "storage_fired": 0,
            "retries": 0, "retry_fixed": 0, "merged": 0,
            "new_corrupt": 0, "detected": 0, "recovered": 0,
            "destroyed": 0,
        }

    # -- draws ------------------------------------------------------------

    def _rng(self) -> np.random.Generator:
        rng = np.random.default_rng((self.spec.seed, self._counter))
        self._counter += 1
        return rng

    def draw_movement(self, n_bytes: int, n_pages: int) -> np.ndarray:
        """One fault opportunity on a movement wave of ``n_bytes`` payload
        laid out as ``n_pages`` pages; returns the traced (3,) int32 fault
        operand (NULL_FAULT when the draw does not fire)."""
        if self.spec.rate <= 0.0:
            return NULL_FAULT
        rng = self._rng()
        if rng.random() >= self.spec.rate:
            return NULL_FAULT
        kind = self.spec.kinds[int(rng.integers(len(self.spec.kinds)))]
        self.counters["fired"] += 1
        self.counters["movement_fired"] += 1
        if kind == "flip_byte":
            return np.array([FAULT_CODES[kind],
                             int(rng.integers(n_bytes)),
                             int(rng.integers(1, 256))], np.int32)
        return np.array([FAULT_CODES[kind],
                         int(rng.integers(n_pages)), 0], np.int32)

    def draw_storage(self, n_candidates: int, n_pages: int,
                     page_bytes: int) -> Optional[Tuple[int, int, int, int]]:
        """One per-tick at-rest corruption opportunity over ``n_candidates``
        suspended sessions; returns ``(candidate, page, byte, xor)`` or
        ``None``.  Only flips bytes (a zeroed page of an all-zero payload
        would be undetectable by ANY checksum — byte flips always land)."""
        if self.spec.rate <= 0.0 or n_candidates <= 0:
            return None
        rng = self._rng()
        if rng.random() >= self.spec.rate:
            return None
        self.counters["fired"] += 1
        self.counters["storage_fired"] += 1
        return (int(rng.integers(n_candidates)), int(rng.integers(n_pages)),
                int(rng.integers(page_bytes)), int(rng.integers(1, 256)))

    # -- ledger -----------------------------------------------------------

    def note_corrupt(self, uid: int) -> bool:
        """Record that ``uid``'s at-rest pages are now corrupt; returns
        True for a NEW incident (already-corrupt sessions merge)."""
        if uid in self.corrupted:
            self.counters["merged"] += 1
            return False
        self.corrupted[uid] = self.counters["fired"]
        self.counters["new_corrupt"] += 1
        return True

    def is_corrupt(self, uid: int) -> bool:
        return uid in self.corrupted

    def consume_corrupt(self, uid: int, outcome: str) -> None:
        """Close out a corrupt session: ``outcome`` is ``"detected"`` (served
        corrupt, caught by the resume-time verify) or ``"recovered"``
        (snapshot restore repaired it before service)."""
        if self.corrupted.pop(uid, None) is not None:
            self.counters[outcome] += 1

    def discard_corrupt(self, uid: int) -> None:
        """The corrupt copy itself was destroyed (replica failure) — the
        incident resolves with the session, not via the verify path."""
        if self.corrupted.pop(uid, None) is not None:
            self.counters["destroyed"] += 1

    # -- recovery pricing & scheduled events ------------------------------

    def backoff_ns(self, attempt: int) -> float:
        """Bounded exponential backoff for retry ``attempt`` (1-based)."""
        return float(min(self.spec.backoff_base_ns * (2 ** (attempt - 1)),
                         self.spec.backoff_cap_ns))

    def replica_failures_at(self, tick: int) -> List[int]:
        return [r for (t, r) in self.spec.replica_failures if t == tick]

    def degrade_at(self, tick: int) -> List[int]:
        return [r for (t, r) in self.spec.degrade_fast if t == tick]

    def summary(self) -> Dict[str, int]:
        out = dict(self.counters)
        out["at_rest_corrupt"] = len(self.corrupted)
        return out
