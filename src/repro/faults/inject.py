"""Fault modes + the fault-wrapping layer over the movement backends.

Two pieces, both registry-shaped:

1. **Fault-mode registry** — the fifth instance of the PR 1 registry
   pattern (CopyMechanism, movement backends, sched policies, lint rules,
   now fault modes).  Each mode is a *traced* transform
   ``fn(data, index, xor) -> data`` applied under ``jnp.where`` gating, so
   a jitted movement body compiled once serves every per-call fault via the
   uniform ``(mode, index, xor)`` int32 operand (``NULL_FAULT`` when
   inactive — identical signatures, zero recompiles).

2. **Backend wrappers** — :func:`install_fault_backends` interposes on the
   ``hop_chain`` and ``page_scatter`` legs through the registry's
   sanctioned :func:`~repro.movement.registry.wrap_backend` API.  A wrapper
   consumes the env's ``fault`` operand exactly once (first wrapped leg in
   the plan) and applies it to the payload: in-flight corruption on the hop
   chain, landing corruption on the scatter.  Plans that never carry a
   ``fault`` key trace byte-identical graphs to the unwrapped backends.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax.numpy as jnp

from repro.movement import registry as MR
from repro.faults.spec import FAULT_CODES, NULL_FAULT  # noqa: F401

FaultMode = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]

_FAULT_MODES: Dict[str, FaultMode] = {}


def register_fault(name: str) -> Callable[[FaultMode], FaultMode]:
    """Decorator: register one traced fault mode and assign its code.

    Same contract as the movement-backend registry: re-registering the SAME
    function (module reload) replaces silently; a different function under
    a taken name raises.  Codes are handed out in registration order, so
    they are deterministic per import order.
    """
    def deco(fn: FaultMode) -> FaultMode:
        old = _FAULT_MODES.get(name)
        if old is not None and (old.__module__, old.__qualname__) != (
                fn.__module__, fn.__qualname__):
            raise ValueError(f"fault mode {name!r} already registered by "
                             f"{old.__module__}.{old.__qualname__}")
        _FAULT_MODES[name] = fn
        FAULT_CODES.setdefault(name, len(FAULT_CODES))
        return fn
    return deco


def get_fault(name: str) -> FaultMode:
    try:
        return _FAULT_MODES[name]
    except KeyError:
        raise ValueError(f"unknown fault mode {name!r} "
                         f"(known: {sorted(_FAULT_MODES)})") from None


def fault_kinds() -> Tuple[str, ...]:
    return tuple(sorted(_FAULT_MODES))


def apply_fault(data: jnp.ndarray, fault) -> jnp.ndarray:
    """Apply the traced ``(mode, index, xor)`` operand to ``data``.

    Every registered mode is staged under a ``jnp.where`` on its code, so
    the graph is identical whichever (or no) fault fires at runtime.
    """
    fault = jnp.asarray(fault, jnp.int32)
    mode = fault[0]
    out = data
    for name, fn in _FAULT_MODES.items():
        out = jnp.where(mode == FAULT_CODES[name],
                        fn(data, fault[1], fault[2]), out)
    return out


@register_fault("flip_byte")
def _flip_byte(data: jnp.ndarray, index, xor) -> jnp.ndarray:
    """XOR one byte of the flat payload (xor != 0 => always detectable)."""
    flat = data.reshape(-1)
    t = jnp.clip(index, 0, flat.shape[0] - 1)
    return flat.at[t].set(flat[t] ^ xor.astype(data.dtype)).reshape(data.shape)


@register_fault("drop_page")
def _drop_page(data: jnp.ndarray, index, xor) -> jnp.ndarray:
    """Zero one leading-axis page of a pages-major payload (a lost RBM
    transfer).  Undetectable iff the page was already all-zero — which is
    why the bench gates inject ``flip_byte``; this mode is exercised by the
    property tests on nonzero payloads."""
    t = jnp.clip(index, 0, data.shape[0] - 1)
    return data.at[t].set(jnp.zeros_like(data[0]))


# ---------------------------------------------------------------------------
# the wrapping layer
# ---------------------------------------------------------------------------

# legs that carry payload bytes: corrupt post-hop (in flight) or pre-scatter
# (at landing).  The env's "fault" operand is consumed by the FIRST wrapped
# leg the plan executes, so a gather->hop->scatter chain applies it once.
WRAP_KINDS: Tuple[str, ...] = ("hop_chain", "page_scatter")
_PRE_KINDS = frozenset({"page_scatter"})


def _make_wrapper(kind: str, inner: MR.Backend) -> MR.Backend:
    def fault_wrapped(leg, env):
        fault = env.get("fault")
        if fault is None:
            return inner(leg, env)
        env = dict(env)
        del env["fault"]
        if kind in _PRE_KINDS:
            env["data"] = apply_fault(env["data"], fault)
            return inner(leg, env)
        env = dict(inner(leg, env))
        env["data"] = apply_fault(env["data"], fault)
        return env
    fault_wrapped.__qualname__ = f"fault_wrapped_{kind}"
    return fault_wrapped


def install_fault_backends() -> None:
    """Interpose the fault wrappers (idempotent).  Must run before the
    first trace of any jitted body that should honor a ``fault`` operand —
    :class:`repro.serve.cluster.Cluster` installs at construction when
    built with ``faults=``."""
    for kind in WRAP_KINDS:
        if kind not in MR.wrapped_kinds():
            MR.wrap_backend(kind, lambda inner, k=kind: _make_wrapper(k,
                                                                      inner))


def uninstall_fault_backends() -> None:
    """Restore the original backends (tests)."""
    for kind in WRAP_KINDS:
        MR.unwrap_backend(kind)
