"""Session snapshots and snapshot-backed recovery for the replica fleet.

The recovery source for replica death is a periodic *session snapshot*: the
suspended pages PLUS their checksum sidecar rows, staged device→host
through the same priced host-staging movement plan the checkpoint manager
uses — snapshot traffic is byte-accounted like every other transfer.
Restore is the reverse plan: host→device staging, an ``adopt_session``
registration, a slow-pool row write and a fast-tag invalidation (the fast
tier may hold the pre-failure — possibly corrupt — bytes).

Snapshots can also persist to disk in the checkpoint manager's atomic
``step_<N>`` format (:func:`save_snapshots` / :func:`load_snapshots`),
protected by the manager's crash-consistency trailer — a torn snapshot
directory is rejected, never restored as garbage state.

Everything here is host-driven bookkeeping around device buffers; nothing
runs inside the tick loop's jitted bodies, and the host reads go through
movement plans (the ``host_stage`` leg), keeping the serving modules free
of raw host-sync idioms.
"""
from __future__ import annotations

import os
from typing import Dict, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import movement as MV
from repro.checkpoint import manager as CM


class SessionSnapshot(NamedTuple):
    """One suspended session, host-resident: enough to re-admit it
    anywhere (pages are dtype-preserving uint8; ``sums`` is the checksum
    sidecar row computed at suspend time, so a restored session is
    verify-clean by construction).

    A FORKED family snapshots its shared physical row ONCE: the
    lowest-uid alias carries the pages, every other alias is a meta-only
    entry with ``alias_of`` naming the carrier and ``pages``/``sums``
    None.  Restore re-attaches aliases to the carrier's restored row by
    bookkeeping alone — one staged copy, one repair, the whole family
    healed."""
    uid: int
    pos: int
    tok: int
    pages: Optional[np.ndarray]       # (n_pages, P, d) uint8; None for alias
    sums: Optional[np.ndarray]        # (n_pages,) uint32; None for alias
    alias_of: Optional[int] = None    # carrier uid when this is an alias


def _zero_cost() -> MV.MovementCost:
    return MV.MovementCost(0, 0, 0.0, 0.0, 0.0, 0.0)


def _add(a: MV.MovementCost, b: MV.MovementCost) -> MV.MovementCost:
    return MV.MovementCost(a.bytes + b.bytes, max(a.hops, b.hops),
                           a.ns_lisa + b.ns_lisa,
                           a.ns_memcpy + b.ns_memcpy,
                           a.uj_lisa + b.uj_lisa,
                           a.uj_memcpy + b.uj_memcpy)


def snapshot_sessions(cluster) -> Tuple[Dict[int, "SessionSnapshot"],
                                        MV.MovementCost]:
    """Snapshot every suspended session in the fleet to host memory.

    One host-staging movement plan per replica with live sessions (the
    pages and sidecar rows of all its sessions travel as one batched
    transfer over the modeled channel).  Returns ``(snaps, total_cost)``;
    the scheduler records the cost as a ``snapshot_wave`` decision —
    write-behind traffic that overlaps decode, so it is priced but not
    charged to the critical-path clock.
    """
    snaps: Dict[int, SessionSnapshot] = {}
    total = _zero_cost()
    for eng in cluster.replicas:
        # ACTIVE sessions keep a stale session_pos entry; their store row is
        # a leftover the next suspend overwrites — snapshotting it would
        # capture out-of-date (possibly sidecar-inconsistent) bytes, so only
        # truly suspended sessions are snapshot candidates.
        active = {req.uid for req in eng.active.values()}
        uids = sorted(u for u in eng.session_pos if u not in active)
        if not uids:
            continue
        # fork-aware: stage each PHYSICAL row once.  The lowest-uid alias
        # of a shared row is its carrier; the rest become meta-only alias
        # entries — a 64-way fork family costs ONE row of snapshot traffic,
        # not 64.
        phys_of = {u: (eng.forks.resolve(u) if u in eng.forks
                       else u % eng.n_sessions) for u in uids}
        carrier_of: Dict[int, int] = {}
        for u in uids:                       # sorted: lowest uid carries
            carrier_of.setdefault(phys_of[u], u)
        carriers = sorted(carrier_of.values())
        idxs = jnp.asarray([phys_of[u] for u in carriers], jnp.int32)
        leaves = [eng.sessions.slow[idxs], eng.session_sums[idxs]]
        p = MV.plan(MV.Transfer(MV.Tier("device"), MV.Tier("host"),
                                MV.Layout.tree(leaves)))
        pages, sums = MV.execute(p, data=leaves)["data"]
        total = _add(total, p.cost)
        for j, uid in enumerate(carriers):
            snaps[uid] = SessionSnapshot(uid, eng.session_pos[uid],
                                         eng.session_tok[uid],
                                         pages[j], sums[j])
        for uid in uids:
            if uid in snaps:
                continue
            snaps[uid] = SessionSnapshot(
                uid, eng.session_pos[uid], eng.session_tok[uid],
                None, None, alias_of=carrier_of[phys_of[uid]])
    return snaps, total


def restore_session(cluster, snap: SessionSnapshot,
                    replica: int) -> Optional[MV.MovementCost]:
    """Re-admit one snapshot onto ``replica`` via the priced channel.

    Stages pages + sidecar host→device, registers the session
    (``adopt_session`` — collisions evict explicitly, like any suspend),
    overwrites the slow-pool row, and invalidates any stale fast-tier
    residency so the next resume reads the restored bytes.  Returns the
    staging cost (the scheduler charges it to the virtual clock as a
    ``recover_wave`` — recovery IS on the critical path).

    An ALIAS snapshot (``alias_of`` set) restores for free: its carrier
    already staged the shared row, so the alias re-attaches to the
    carrier's restored row by fork-table bookkeeping alone.  The carrier
    must be restored on ``replica`` FIRST (the scheduler orders owners
    before aliases); returns None if it is not — the caller writes the
    alias off as lost."""
    eng = cluster.replicas[replica]
    if snap.alias_of is not None:
        if (snap.alias_of not in eng.session_pos
                or snap.alias_of not in eng.forks):
            return None
        home = cluster.residence.get(snap.uid)
        if (home is not None
                and snap.uid in cluster.replicas[home].session_pos):
            cluster.replicas[home].drop_session(snap.uid)
        eng.adopt_alias(snap.uid, snap.pos, snap.tok, snap.alias_of)
        cluster.residence[snap.uid] = replica
        return _zero_cost()
    leaves = [np.asarray(snap.pages), np.asarray(snap.sums)]
    p = MV.plan(MV.Transfer(MV.Tier("host"), MV.Tier("device"),
                            MV.Layout.tree(leaves)))
    pages_dev, sums_dev = MV.execute(p, data=leaves)["data"]
    home = cluster.residence.get(snap.uid)
    if home is not None and snap.uid in cluster.replicas[home].session_pos:
        cluster.replicas[home].drop_session(snap.uid)
    idx = eng.adopt_session(snap.uid, snap.pos, snap.tok)
    eng.sessions = eng.sessions._replace(
        slow=eng.sessions.slow.at[idx].set(pages_dev))
    eng.session_sums = eng.session_sums.at[idx].set(sums_dev)
    cluster._invalidate_fast(eng, [idx])
    cluster.residence[snap.uid] = replica
    return p.cost


def repair_row(cluster, snap: SessionSnapshot,
               replica: int) -> Optional[MV.MovementCost]:
    """Heal the PHYSICAL row behind a (possibly shared) snapshot in place.

    Stages the carrier's pages + sidecar host→device and overwrites the row
    ``snap.uid`` currently resolves to — fork table, refcounts and every
    alias's host metadata untouched.  This is the pre-resume repair for a
    corrupt SHARED row: a shared row's bytes are immutable while shared
    (divergence write-breaks onto a fresh row first), so the carrier's
    snapshot matches the row by construction and one staged copy heals the
    whole family — :func:`restore_session` would instead re-admit the
    carrier, demoting the still-corrupt row to the siblings.  Returns the
    staging cost, or None when ``snap`` carries no pages or its uid no
    longer owns a row on ``replica``."""
    eng = cluster.replicas[replica]
    if snap.pages is None or snap.uid not in eng.session_pos:
        return None
    idx = (eng.forks.resolve(snap.uid) if snap.uid in eng.forks
           else snap.uid % eng.n_sessions)
    leaves = [np.asarray(snap.pages), np.asarray(snap.sums)]
    p = MV.plan(MV.Transfer(MV.Tier("host"), MV.Tier("device"),
                            MV.Layout.tree(leaves)))
    pages_dev, sums_dev = MV.execute(p, data=leaves)["data"]
    eng.sessions = eng.sessions._replace(
        slow=eng.sessions.slow.at[idx].set(pages_dev))
    eng.session_sums = eng.session_sums.at[idx].set(sums_dev)
    cluster._invalidate_fast(eng, [idx])
    return p.cost


# ---------------------------------------------------------------------------
# disk persistence (the checkpoint manager's atomic format + crc trailer)
# ---------------------------------------------------------------------------

def save_snapshots(snaps: Dict[int, SessionSnapshot], ckpt_dir: str,
                   step: int, keep_last: int = 3) -> str:
    """Persist a snapshot set through :func:`repro.checkpoint.manager.save`
    (atomic rename + crc trailer): a crash mid-save can never produce a
    restorable-but-torn snapshot directory."""
    tree = {}
    for s in snaps.values():
        alias = -1 if s.alias_of is None else s.alias_of
        entry = {"meta": np.array([s.pos, s.tok, alias], np.int64)}
        if s.pages is not None:
            # alias entries persist meta-only: the carrier's row is the
            # one copy of the shared bytes on disk, exactly as in memory
            entry["pages"] = s.pages
            entry["sums"] = s.sums
        tree[f"u{s.uid}"] = entry
    return CM.save(tree, ckpt_dir, step, keep_last=keep_last)


def load_snapshots(ckpt_dir: str,
                   step: Optional[int] = None) -> Dict[int, SessionSnapshot]:
    """Load a persisted snapshot set, trailer-verified first: a torn or
    truncated directory raises :class:`repro.checkpoint.manager.
    CorruptCheckpoint` instead of yielding garbage sessions."""
    if step is None:
        step = CM.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no snapshots in {ckpt_dir}")
    CM.verify_checkpoint(ckpt_dir, step)
    data = np.load(os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz"))
    out: Dict[int, SessionSnapshot] = {}
    uids = sorted({int(k.split("/")[0][1:]) for k in data.files})
    for uid in uids:
        meta = [int(x) for x in data[f"u{uid}/meta"]]
        # length-2 metas predate fork-aware snapshots — accept them
        pos, tok = meta[0], meta[1]
        alias = meta[2] if len(meta) > 2 else -1
        has_pages = f"u{uid}/pages" in data.files
        out[uid] = SessionSnapshot(
            uid, pos, tok,
            data[f"u{uid}/pages"] if has_pages else None,
            data[f"u{uid}/sums"] if has_pages else None,
            alias_of=None if alias < 0 else alias)
    return out
