"""Session snapshots and snapshot-backed recovery for the replica fleet.

The recovery source for replica death is a periodic *session snapshot*: the
suspended pages PLUS their checksum sidecar rows, staged device→host
through the same priced host-staging movement plan the checkpoint manager
uses — snapshot traffic is byte-accounted like every other transfer.
Restore is the reverse plan: host→device staging, an ``adopt_session``
registration, a slow-pool row write and a fast-tag invalidation (the fast
tier may hold the pre-failure — possibly corrupt — bytes).

Snapshots can also persist to disk in the checkpoint manager's atomic
``step_<N>`` format (:func:`save_snapshots` / :func:`load_snapshots`),
protected by the manager's crash-consistency trailer — a torn snapshot
directory is rejected, never restored as garbage state.

Everything here is host-driven bookkeeping around device buffers; nothing
runs inside the tick loop's jitted bodies, and the host reads go through
movement plans (the ``host_stage`` leg), keeping the serving modules free
of raw host-sync idioms.
"""
from __future__ import annotations

import os
from typing import Dict, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import movement as MV
from repro.checkpoint import manager as CM


class SessionSnapshot(NamedTuple):
    """One suspended session, host-resident: enough to re-admit it
    anywhere (pages are dtype-preserving uint8; ``sums`` is the checksum
    sidecar row computed at suspend time, so a restored session is
    verify-clean by construction)."""
    uid: int
    pos: int
    tok: int
    pages: np.ndarray       # (n_pages, P, d) uint8
    sums: np.ndarray        # (n_pages,) uint32


def _zero_cost() -> MV.MovementCost:
    return MV.MovementCost(0, 0, 0.0, 0.0, 0.0, 0.0)


def _add(a: MV.MovementCost, b: MV.MovementCost) -> MV.MovementCost:
    return MV.MovementCost(a.bytes + b.bytes, max(a.hops, b.hops),
                           a.ns_lisa + b.ns_lisa,
                           a.ns_memcpy + b.ns_memcpy,
                           a.uj_lisa + b.uj_lisa,
                           a.uj_memcpy + b.uj_memcpy)


def snapshot_sessions(cluster) -> Tuple[Dict[int, "SessionSnapshot"],
                                        MV.MovementCost]:
    """Snapshot every suspended session in the fleet to host memory.

    One host-staging movement plan per replica with live sessions (the
    pages and sidecar rows of all its sessions travel as one batched
    transfer over the modeled channel).  Returns ``(snaps, total_cost)``;
    the scheduler records the cost as a ``snapshot_wave`` decision —
    write-behind traffic that overlaps decode, so it is priced but not
    charged to the critical-path clock.
    """
    snaps: Dict[int, SessionSnapshot] = {}
    total = _zero_cost()
    for eng in cluster.replicas:
        # ACTIVE sessions keep a stale session_pos entry; their store row is
        # a leftover the next suspend overwrites — snapshotting it would
        # capture out-of-date (possibly sidecar-inconsistent) bytes, so only
        # truly suspended sessions are snapshot candidates.
        active = {req.uid for req in eng.active.values()}
        uids = sorted(u for u in eng.session_pos if u not in active)
        if not uids:
            continue
        idxs = jnp.asarray([u % eng.n_sessions for u in uids], jnp.int32)
        leaves = [eng.sessions.slow[idxs], eng.session_sums[idxs]]
        p = MV.plan(MV.Transfer(MV.Tier("device"), MV.Tier("host"),
                                MV.Layout.tree(leaves)))
        pages, sums = MV.execute(p, data=leaves)["data"]
        total = _add(total, p.cost)
        for j, uid in enumerate(uids):
            snaps[uid] = SessionSnapshot(uid, eng.session_pos[uid],
                                         eng.session_tok[uid],
                                         pages[j], sums[j])
    return snaps, total


def restore_session(cluster, snap: SessionSnapshot,
                    replica: int) -> MV.MovementCost:
    """Re-admit one snapshot onto ``replica`` via the priced channel.

    Stages pages + sidecar host→device, registers the session
    (``adopt_session`` — collisions evict explicitly, like any suspend),
    overwrites the slow-pool row, and invalidates any stale fast-tier
    residency so the next resume reads the restored bytes.  Returns the
    staging cost (the scheduler charges it to the virtual clock as a
    ``recover_wave`` — recovery IS on the critical path)."""
    eng = cluster.replicas[replica]
    leaves = [np.asarray(snap.pages), np.asarray(snap.sums)]
    p = MV.plan(MV.Transfer(MV.Tier("host"), MV.Tier("device"),
                            MV.Layout.tree(leaves)))
    pages_dev, sums_dev = MV.execute(p, data=leaves)["data"]
    home = cluster.residence.get(snap.uid)
    if home is not None and snap.uid in cluster.replicas[home].session_pos:
        cluster.replicas[home].drop_session(snap.uid)
    idx = eng.adopt_session(snap.uid, snap.pos, snap.tok)
    eng.sessions = eng.sessions._replace(
        slow=eng.sessions.slow.at[idx].set(pages_dev))
    eng.session_sums = eng.session_sums.at[idx].set(sums_dev)
    cluster._invalidate_fast(eng, [idx])
    cluster.residence[snap.uid] = replica
    return p.cost


# ---------------------------------------------------------------------------
# disk persistence (the checkpoint manager's atomic format + crc trailer)
# ---------------------------------------------------------------------------

def save_snapshots(snaps: Dict[int, SessionSnapshot], ckpt_dir: str,
                   step: int, keep_last: int = 3) -> str:
    """Persist a snapshot set through :func:`repro.checkpoint.manager.save`
    (atomic rename + crc trailer): a crash mid-save can never produce a
    restorable-but-torn snapshot directory."""
    tree = {f"u{s.uid}": {"pages": s.pages, "sums": s.sums,
                          "meta": np.array([s.pos, s.tok], np.int64)}
            for s in snaps.values()}
    return CM.save(tree, ckpt_dir, step, keep_last=keep_last)


def load_snapshots(ckpt_dir: str,
                   step: Optional[int] = None) -> Dict[int, SessionSnapshot]:
    """Load a persisted snapshot set, trailer-verified first: a torn or
    truncated directory raises :class:`repro.checkpoint.manager.
    CorruptCheckpoint` instead of yielding garbage sessions."""
    if step is None:
        step = CM.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no snapshots in {ckpt_dir}")
    CM.verify_checkpoint(ckpt_dir, step)
    data = np.load(os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz"))
    out: Dict[int, SessionSnapshot] = {}
    uids = sorted({int(k.split("/")[0][1:]) for k in data.files})
    for uid in uids:
        pos, tok = (int(x) for x in data[f"u{uid}/meta"])
        out[uid] = SessionSnapshot(uid, pos, tok, data[f"u{uid}/pages"],
                                   data[f"u{uid}/sums"])
    return out
