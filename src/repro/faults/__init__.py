"""Deterministic chaos: seeded fault injection, detection and recovery.

The subsystem threads the whole serving stack (ISSUE 7 / DESIGN.md
Sec. 12):

  * :mod:`repro.faults.spec`    — :class:`FaultSpec` / :class:`FaultInjector`
    (counter-based seeded RNG, never wall-clock) and the host-side
    corruption ledger.
  * :mod:`repro.faults.inject`  — the fault-mode registry (flip_byte /
    drop_page, traced transforms) and the wrapping layer over the movement
    backend registry (hop_chain / page_scatter legs).
  * :mod:`repro.faults.recover` — session snapshots over priced movement
    plans and snapshot-backed restore (replica death, corrupt-at-rest
    repair), plus disk persistence via the checkpoint manager.

Detection itself lives in the substrate: every ``pack_pages`` leg emits a
per-page checksum sidecar and every ``unpack_pages`` leg verifies it
(:mod:`repro.movement.paging`), so the chaos layer only decides WHAT breaks
— the movement layer proves WHETHER it was caught.
"""
from repro.faults.inject import (
    NULL_FAULT,
    apply_fault,
    fault_kinds,
    get_fault,
    install_fault_backends,
    register_fault,
    uninstall_fault_backends,
)
from repro.faults.recover import (
    SessionSnapshot,
    load_snapshots,
    repair_row,
    restore_session,
    save_snapshots,
    snapshot_sessions,
)
from repro.faults.spec import FAULT_CODES, FaultInjector, FaultSpec

__all__ = [
    "FaultSpec", "FaultInjector", "FAULT_CODES", "NULL_FAULT",
    "register_fault", "get_fault", "fault_kinds", "apply_fault",
    "install_fault_backends", "uninstall_fault_backends",
    "SessionSnapshot", "snapshot_sessions", "restore_session",
    "repair_row",
    "save_snapshots", "load_snapshots",
]
