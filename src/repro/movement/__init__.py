"""One movement substrate: plan -> execute for every bulk transfer.

Public surface::

    from repro import movement as MV

    layout = MV.Layout.pages(MV.PageSpec.for_cache(cache))
    p = MV.plan(MV.Transfer(MV.Tier("compute"), MV.Tier("slow"),
                            layout, policy=villa_cfg), spec)
    store = MV.execute(p, cache=cache, slot=slot,
                       store=store, item=idx)["store"]
    p.cost.ns_lisa, p.cost.ns_memcpy      # Table-1 pricing, system scale

See :mod:`repro.movement.plan` for the lowering, DESIGN.md Sec. 8 for the
paper mapping.
"""
from repro.movement.paging import (
    PageSpec,
    pack_slot,
    page_checksums,
    unpack_into_slot,
    verify_pages,
)
from repro.movement.plan import (
    HopChainLeg,
    HostStageLeg,
    Layout,
    Leg,
    MovementCost,
    MovementPlan,
    PackLeg,
    PageAliasLeg,
    PageGatherLeg,
    PageScatterLeg,
    TierReadLeg,
    TierWriteLeg,
    TileCopyLeg,
    Tier,
    Transfer,
    UnpackLeg,
    ContendedCost,
    contend,
    fuse,
    leg_costs,
    plan,
    retry_cost,
    ring_plan,
)
from repro.movement.registry import (
    Env,
    backend_kinds,
    execute,
    get_backend,
    register_backend,
    set_tracer,
    unwrap_backend,
    wrap_backend,
    wrapped_kinds,
)
from repro.movement import backends as _backends  # noqa: F401  (registers)

__all__ = [
    "PageSpec", "pack_slot", "unpack_into_slot",
    "page_checksums", "verify_pages",
    "Tier", "Layout", "Transfer", "Leg", "MovementCost", "MovementPlan",
    "PackLeg", "UnpackLeg", "PageAliasLeg", "PageGatherLeg",
    "PageScatterLeg",
    "TierReadLeg", "TierWriteLeg", "TileCopyLeg", "HopChainLeg",
    "HostStageLeg", "plan", "ring_plan", "fuse", "retry_cost", "leg_costs",
    "ContendedCost", "contend",
    "Env", "register_backend", "get_backend", "backend_kinds", "execute",
    "wrap_backend", "unwrap_backend", "wrapped_kinds", "set_tracer",
]
