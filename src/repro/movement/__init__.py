"""One movement substrate: plan -> execute for every bulk transfer.

Public surface::

    from repro import movement as MV

    layout = MV.Layout.pages(MV.PageSpec.for_cache(cache))
    p = MV.plan(MV.Transfer(MV.Tier("compute"), MV.Tier("slow"),
                            layout, policy=villa_cfg), spec)
    store = MV.execute(p, cache=cache, slot=slot,
                       store=store, item=idx)["store"]
    p.cost.ns_lisa, p.cost.ns_memcpy      # Table-1 pricing, system scale

See :mod:`repro.movement.plan` for the lowering, DESIGN.md Sec. 8 for the
paper mapping.
"""
from repro.movement.paging import PageSpec, pack_slot, unpack_into_slot
from repro.movement.plan import (
    HopChainLeg,
    HostStageLeg,
    Layout,
    Leg,
    MovementCost,
    MovementPlan,
    PackLeg,
    PageGatherLeg,
    PageScatterLeg,
    TierReadLeg,
    TierWriteLeg,
    TileCopyLeg,
    Tier,
    Transfer,
    UnpackLeg,
    fuse,
    plan,
    ring_plan,
)
from repro.movement.registry import (
    Env,
    backend_kinds,
    execute,
    get_backend,
    register_backend,
)
from repro.movement import backends as _backends  # noqa: F401  (registers)

__all__ = [
    "PageSpec", "pack_slot", "unpack_into_slot",
    "Tier", "Layout", "Transfer", "Leg", "MovementCost", "MovementPlan",
    "PackLeg", "UnpackLeg", "PageGatherLeg", "PageScatterLeg",
    "TierReadLeg", "TierWriteLeg", "TileCopyLeg", "HopChainLeg",
    "HostStageLeg", "plan", "ring_plan", "fuse",
    "Env", "register_backend", "get_backend", "backend_kinds", "execute",
]
