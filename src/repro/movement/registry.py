"""Backend registry + executor for :class:`~repro.movement.plan.MovementPlan`.

This extends PR 1's ``CopyMechanism`` registry pattern (objects in a
registry, not string if/elif chains) from the DRAM *model* up to the real
array layer: each leg kind names a backend callable that performs the
movement on real arrays.  Default backends (:mod:`repro.movement.backends`)
cover pack/unpack staging, Pallas page gather/scatter, VMEM tile copies,
mesh hop chains and host staging; :mod:`repro.core.lisa.villa_cache`
registers the VILLA policy-mediated tier legs on import.

A backend has signature ``fn(leg, env) -> env``: ``env`` is a dict of named
operands (traced arrays are fine — execute composes under an enclosing
``jax.jit``), and each leg reads the keys it needs and returns an updated
env.  Conventional keys:

  ``data``      the payload moving through the legs
  ``cache``     a batched pytree (pack/unpack source/target), ``slot(s)``
  ``store``     a TieredStore (tier legs), ``item(s)`` its indices
  ``pool``      a page pool array, ``table`` its page table
  ``shardings`` optional placement for host->device staging
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

from repro.movement.plan import Leg, MovementPlan

Env = Dict[str, Any]
Backend = Callable[[Leg, Env], Env]

_BACKENDS: Dict[str, Backend] = {}


def register_backend(kind: str) -> Callable[[Backend], Backend]:
    """Decorator: register the movement backend for one leg kind.

    Re-registering the SAME backend (same module/qualname — a module
    reload) replaces it silently, so registering modules stay
    reload-safe; a different function under a taken kind still raises.
    Reload-safety holds under interposition too: while ``kind`` is
    wrapped, ownership is judged against the stored ORIGINAL, and a
    reload refreshes that original in place — the wrapper stays
    installed and the next :func:`unwrap_backend` restores the fresh fn.
    """
    def deco(fn: Backend) -> Backend:
        old = _WRAPPED.get(kind, _BACKENDS.get(kind))
        if old is not None and (old.__module__, old.__qualname__) != (
                fn.__module__, fn.__qualname__):
            raise ValueError(f"movement backend {kind!r} already registered "
                             f"by {old.__module__}.{old.__qualname__}")
        if kind in _WRAPPED:
            _WRAPPED[kind] = fn
        else:
            _BACKENDS[kind] = fn
        return fn
    return deco


def get_backend(kind: str) -> Backend:
    try:
        return _BACKENDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown movement backend {kind!r} (known: "
            f"{sorted(_BACKENDS)}); import the module that registers it "
            f"(tier legs live in repro.core.lisa.villa_cache)") from None


def backend_kinds() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


# Sanctioned interposition: a wrapper layer (fault injection, tracing) may
# wrap a registered backend without violating the one-owner contract above.
# Originals are kept so the wrap is reversible and never stacks silently.
_WRAPPED: Dict[str, Backend] = {}


def wrap_backend(kind: str,
                 make: Callable[[Backend], Backend]) -> Backend:
    """Replace backend ``kind`` with ``make(original)``; returns the wrapper.

    Raises if ``kind`` is unknown or already wrapped (wrappers must not
    stack — unwrap first).  The original is restored by
    :func:`unwrap_backend`.
    """
    if kind in _WRAPPED:
        raise ValueError(f"movement backend {kind!r} is already wrapped; "
                         f"unwrap_backend({kind!r}) first")
    original = get_backend(kind)
    wrapper = make(original)
    _WRAPPED[kind] = original
    _BACKENDS[kind] = wrapper
    return wrapper


def unwrap_backend(kind: str) -> None:
    """Restore the original backend for ``kind`` (no-op if not wrapped)."""
    original = _WRAPPED.pop(kind, None)
    if original is not None:
        _BACKENDS[kind] = original


def wrapped_kinds() -> Tuple[str, ...]:
    return tuple(sorted(_WRAPPED))


# Optional execution tracing (repro.obs): when a tracer is installed,
# host-side executes mark each leg as an instant on the tracer's current
# lane cursor (cat="exec").  Pricing spans stay the scheduler's job — exec
# marks record WHICH backends actually ran, so plan-vs-execution drift is
# visible in the same timeline.  Under an active jax trace (execute
# composing inside jit) nothing is recorded: a span per compile would
# misattribute one-time tracing work as steady-state movement.
_TRACER: Any = None


def set_tracer(tracer: Any) -> None:
    """Install (or with ``None`` remove) the execution tracer."""
    global _TRACER
    _TRACER = tracer


def _tracing_clean() -> bool:
    try:
        from jax.core import trace_state_clean
        return trace_state_clean()
    except ImportError:                          # pragma: no cover - version
        return False


def execute(plan: MovementPlan, env: Env | None = None, **operands) -> Env:
    """Run every leg of ``plan`` through its registered backend.

    Traceable: called inside ``jax.jit`` this stages pure jax ops, so a
    whole plan (e.g. a batched resume wave) lowers to ONE dispatch.
    Returns the final env; callers read their result keys (``data``,
    ``cache``, ``store``, ``pool``, ...) from it.
    """
    env = dict(env or {})
    env.update(operands)
    tr = _TRACER
    mark = (tr is not None and getattr(tr, "enabled", False)
            and _tracing_clean())
    for leg in plan.legs:
        if mark:
            tr.instant(leg.kind, cat="exec",
                       attrs={"nbytes": leg.nbytes, "batch": leg.batch,
                              "hops": leg.hops})
        env = get_backend(leg.kind)(leg, env)
    return env
