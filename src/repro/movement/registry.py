"""Backend registry + executor for :class:`~repro.movement.plan.MovementPlan`.

This extends PR 1's ``CopyMechanism`` registry pattern (objects in a
registry, not string if/elif chains) from the DRAM *model* up to the real
array layer: each leg kind names a backend callable that performs the
movement on real arrays.  Default backends (:mod:`repro.movement.backends`)
cover pack/unpack staging, Pallas page gather/scatter, VMEM tile copies,
mesh hop chains and host staging; :mod:`repro.core.lisa.villa_cache`
registers the VILLA policy-mediated tier legs on import.

A backend has signature ``fn(leg, env) -> env``: ``env`` is a dict of named
operands (traced arrays are fine — execute composes under an enclosing
``jax.jit``), and each leg reads the keys it needs and returns an updated
env.  Conventional keys:

  ``data``      the payload moving through the legs
  ``cache``     a batched pytree (pack/unpack source/target), ``slot(s)``
  ``store``     a TieredStore (tier legs), ``item(s)`` its indices
  ``pool``      a page pool array, ``table`` its page table
  ``shardings`` optional placement for host->device staging
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

from repro.movement.plan import Leg, MovementPlan

Env = Dict[str, Any]
Backend = Callable[[Leg, Env], Env]

_BACKENDS: Dict[str, Backend] = {}


def register_backend(kind: str) -> Callable[[Backend], Backend]:
    """Decorator: register the movement backend for one leg kind.

    Re-registering the SAME backend (same module/qualname — a module
    reload) replaces it silently, so registering modules stay
    reload-safe; a different function under a taken kind still raises.
    """
    def deco(fn: Backend) -> Backend:
        old = _BACKENDS.get(kind)
        if old is not None and (old.__module__, old.__qualname__) != (
                fn.__module__, fn.__qualname__):
            raise ValueError(f"movement backend {kind!r} already registered "
                             f"by {old.__module__}.{old.__qualname__}")
        _BACKENDS[kind] = fn
        return fn
    return deco


def get_backend(kind: str) -> Backend:
    try:
        return _BACKENDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown movement backend {kind!r} (known: "
            f"{sorted(_BACKENDS)}); import the module that registers it "
            f"(tier legs live in repro.core.lisa.villa_cache)") from None


def backend_kinds() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def execute(plan: MovementPlan, env: Env | None = None, **operands) -> Env:
    """Run every leg of ``plan`` through its registered backend.

    Traceable: called inside ``jax.jit`` this stages pure jax ops, so a
    whole plan (e.g. a batched resume wave) lowers to ONE dispatch.
    Returns the final env; callers read their result keys (``data``,
    ``cache``, ``store``, ``pool``, ...) from it.
    """
    env = dict(env or {})
    env.update(operands)
    for leg in plan.legs:
        env = get_backend(leg.kind)(leg, env)
    return env
