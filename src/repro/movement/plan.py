"""`Transfer` -> `plan()` -> `MovementPlan`: one movement substrate.

LISA's claim is that a single low-cost substrate (interlinked subarrays)
serves *many* applications — RISC bulk copy, VILLA caching, LIP precharging
— through one shared mechanism.  This module is that substrate at the
system level: every bulk transfer in the repo (serving suspend/resume,
tier promotion, checkpoint staging, pipeline stage hops, dense bulk copies)
is expressed as a :class:`Transfer` between *tiers*, lowered by
:func:`plan` against a :class:`~repro.core.dram.spec.DramSpec` topology
into a typed :class:`MovementPlan` of legs, and executed through the
backend registry (:mod:`repro.movement.registry`).

The lowering mirrors the paper's structure:

  * page gather/scatter legs  — LISA-RISC row movement (the Pallas kernels
    ``villa_gather`` / ``villa_scatter`` with scalar-prefetched tables);
  * tier read/write legs      — VILLA policy-mediated movement (hot-marking
    and promotion decide *what* moves; the page legs move it);
  * hop-chain legs            — inter-device ``ppermute`` chains over a mesh
    axis (``rbm.rbm_hop`` / ``rbm.lisa_copy``), cost linear in hops;
  * tile-copy legs            — intra-device HBM->HBM movement through VMEM
    (``rbm_copy``, LIP double buffering);
  * host-staging legs         — the off-chip channel (checkpoint save /
    restore), the "memcpy" path every in-fabric leg is priced against;
  * pack/unpack legs          — dtype-preserving uint8 page staging
    (:mod:`repro.movement.paging`); zero-cost relabeling, not movement.

Every plan carries a :class:`MovementCost` — true payload bytes, hop count,
and modeled latency/energy under both the LISA hop-chain mechanism and the
channel memcpy mechanism, priced through the spec's ``CopyMechanism``
registry — so callers account movement the same way the DRAM model does
(Table 1 at system granularity).  Batched waves are expressed with
``Layout(batch=k)`` (or :func:`fuse`) and lower to ONE dispatch.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Any, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.dram.spec import DDR3_1600, DramSpec
from repro.core.dram.villa import VillaConfig
from repro.movement.paging import PageSpec

if TYPE_CHECKING:                       # pragma: no cover
    from repro.core.lisa.topology import MeshTopology

# repro.core.lisa.topology is imported lazily (function scope): its package
# __init__ pulls in villa_cache, which itself registers backends with this
# movement package — a module-level import here would be circular.

TIER_KINDS = ("compute", "fast", "slow", "device", "host", "stage")


@dataclasses.dataclass(frozen=True)
class Tier:
    """One end of a transfer.

    kind:  "compute" — live working state on device (KV cache, activations)
           "fast"    — VILLA fast tier (hot working set)
           "slow"    — VILLA slow/bulk tier (paged session pool)
           "device"  — whole-device dense storage (bulk arrays)
           "host"    — host memory across the off-chip channel
           "stage"   — a position on a named mesh axis (pipeline stage /
                       mesh neighbor); ``index`` optional (None = shift mode)
    """
    kind: str
    index: Optional[int] = None
    axis: Optional[str] = None

    def __post_init__(self):
        if self.kind not in TIER_KINDS:
            raise ValueError(f"unknown tier kind {self.kind!r} "
                             f"(known: {TIER_KINDS})")


@dataclasses.dataclass(frozen=True)
class Layout:
    """Static shape/byte description of the payload (dtype-preserving:
    ``nbytes`` is always true bytes, never a float32 upcast)."""
    kind: str                           # "pages" | "dense" | "tree"
    nbytes: int                         # true payload bytes PER ITEM
    batch: int = 1                      # items moving as one fused wave
    page_spec: Optional[PageSpec] = None
    shape: Tuple[int, ...] = ()
    dtype_name: str = ""

    @classmethod
    def pages(cls, page_spec: PageSpec, batch: int = 1) -> "Layout":
        """A paged pytree snapshot (one cache slot) staged via PageSpec."""
        return cls(kind="pages", nbytes=page_spec.total_bytes, batch=batch,
                   page_spec=page_spec)

    @classmethod
    def raw_pages(cls, n_pages: int, page_rows: int, page_lanes: int,
                  dtype, batch: int = 1) -> "Layout":
        """A block of already-paged data (no pack/unpack staging needed)."""
        nbytes = n_pages * page_rows * page_lanes * np.dtype(dtype).itemsize
        return cls(kind="pages", nbytes=nbytes, batch=batch,
                   shape=(n_pages, page_rows, page_lanes),
                   dtype_name=np.dtype(dtype).name)

    @classmethod
    def dense(cls, shape: Sequence[int], dtype, batch: int = 1) -> "Layout":
        shape = tuple(int(s) for s in shape)
        nbytes = math.prod(shape) * np.dtype(dtype).itemsize
        return cls(kind="dense", nbytes=nbytes, batch=batch, shape=shape,
                   dtype_name=np.dtype(dtype).name)

    @classmethod
    def tree(cls, leaves: Sequence[Any]) -> "Layout":
        """An arbitrary list of array leaves (checkpoint staging).  Plain
        Python / numpy scalar leaves (step counters, hyperparameters) are
        sized via numpy, like the host-staging backend stages them."""
        nbytes = 0
        for l in leaves:
            if l is None:
                continue
            if hasattr(l, "shape") and hasattr(l, "dtype"):
                nbytes += int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
            else:
                nbytes += np.asarray(l).nbytes
        return cls(kind="tree", nbytes=nbytes)


@dataclasses.dataclass(frozen=True)
class Transfer:
    """A bulk-movement request: source/destination tier + layout + policy.

    ``policy`` (a :class:`VillaConfig`) routes compute<->slow transfers
    through the VILLA tier policy (hot-marking, promotion) instead of raw
    page movement.  ``preserve_dtype`` documents the staging contract: paged
    lowering bitcasts to uint8 pages and restores bit-exactly (the only
    supported mode for paged layouts — no silent upcasts on any path).

    ``kind`` defaults to a data-moving transfer; ``kind="fork"`` requests
    the CoW alias lowering instead (repro/fork): same-replica forks lower
    to one ``page_alias`` leg — host bookkeeping priced as a RowClone FPM,
    with the payload recorded as bytes NOT copied — and cross-replica
    forks materialize over the priced migration route.
    """
    src: Tier
    dst: Tier
    layout: Layout
    policy: Optional[VillaConfig] = None
    preserve_dtype: bool = True
    kind: str = "move"


# ---------------------------------------------------------------------------
# Typed legs.  Each leg kind names a registry backend (registry.py); the
# static fields are everything the backend needs beyond traced operands.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Leg:
    """Base leg: ``kind`` selects the backend, ``nbytes`` (per item) and
    ``hops`` drive the pricing, ``batch`` fuses a wave into one dispatch."""
    kind: str = "leg"
    nbytes: int = 0
    hops: int = 0
    batch: int = 1


@dataclasses.dataclass(frozen=True)
class PackLeg(Leg):
    """Bitcast a pytree slot into uint8 pages (zero-cost relabeling)."""
    kind: str = "pack_pages"
    page_spec: Optional[PageSpec] = None


@dataclasses.dataclass(frozen=True)
class UnpackLeg(Leg):
    """Restore uint8 pages into a pytree slot (inverse of PackLeg)."""
    kind: str = "unpack_pages"
    page_spec: Optional[PageSpec] = None


@dataclasses.dataclass(frozen=True)
class PageGatherLeg(Leg):
    """Gather whole pages by a page table (Pallas ``villa_gather``).
    ``pool_key``/``table_key`` name the env operands, so a two-pool plan
    (tier promotion) can bind each leg to its own pool."""
    kind: str = "page_gather"
    pool_key: str = "pool"
    table_key: str = "table"


@dataclasses.dataclass(frozen=True)
class PageScatterLeg(Leg):
    """Scatter whole pages by a page table (Pallas ``villa_scatter``)."""
    kind: str = "page_scatter"
    pool_key: str = "pool"
    table_key: str = "table"


@dataclasses.dataclass(frozen=True)
class TierReadLeg(Leg):
    """VILLA policy-mediated read: promotes hot items to the fast tier."""
    kind: str = "tier_read"
    policy: Optional[VillaConfig] = None


@dataclasses.dataclass(frozen=True)
class TierWriteLeg(Leg):
    """VILLA write-through: slow tier + fast slot if resident."""
    kind: str = "tier_write"
    policy: Optional[VillaConfig] = None


@dataclasses.dataclass(frozen=True)
class TileCopyLeg(Leg):
    """Intra-device bulk copy through VMEM tiles (Pallas ``rbm_copy``)."""
    kind: str = "tile_copy"
    tile_rows: int = 256
    lanes: int = 128


@dataclasses.dataclass(frozen=True)
class HopChainLeg(Leg):
    """Inter-device movement over a mesh axis as a ppermute hop chain.

    ``src``/``dst`` set: point-to-point chain (``rbm.lisa_copy``, ``hops``
    sequential single-pair permutes; ``wraparound`` mirrors the topology so
    the priced route IS the executed route).  Both None: neighbor-shift
    mode (``rbm.rbm_hop`` by ``step`` — the pipeline stage hop), one hop."""
    kind: str = "hop_chain"
    axis: Optional[str] = None
    step: int = 1
    src: Optional[int] = None
    dst: Optional[int] = None
    wraparound: bool = True


@dataclasses.dataclass(frozen=True)
class HostStageLeg(Leg):
    """Cross the off-chip channel: device_get / device_put per leaf."""
    kind: str = "host_stage"
    to_host: bool = True


@dataclasses.dataclass(frozen=True)
class PageAliasLeg(Leg):
    """A zero-copy page alias (fork fast path): the backend is the host
    identity — the ForkPageTable repoints the logical row, no bytes move.
    Priced as a RowClone FPM (``rowclone`` at ``max(hops, 1)``) against the
    memcpy a real per-session copy would have cost; ``nbytes * batch`` is
    the bytes-NOT-copied credit."""
    kind: str = "page_alias"


# ---------------------------------------------------------------------------
# Cost model.
# ---------------------------------------------------------------------------

class MovementCost(NamedTuple):
    """Modeled cost of a plan under both mechanisms (ns / uJ, Table-1
    pricing at system granularity).  ``bytes`` is the true total payload
    (batch included); ``hops`` the largest hop distance any leg crosses."""
    bytes: int
    hops: int
    ns_lisa: float
    ns_memcpy: float
    uj_lisa: float
    uj_memcpy: float

    @property
    def advantage(self) -> float:
        """Modeled memcpy/LISA latency ratio (the Table 1 gap)."""
        return self.ns_memcpy / self.ns_lisa if self.ns_lisa else 1.0

    def scaled(self, k: int) -> "MovementCost":
        return self._replace(bytes=self.bytes * k, ns_lisa=self.ns_lisa * k,
                             ns_memcpy=self.ns_memcpy * k,
                             uj_lisa=self.uj_lisa * k,
                             uj_memcpy=self.uj_memcpy * k)


def retry_cost(cost: MovementCost, retries: int) -> MovementCost:
    """The EXTRA *movement* cost of ``retries`` re-executions of an
    already-charged plan.

    A checksum-failed leg re-issues the whole transfer, so k retries price
    exactly ``cost.scaled(k)`` — cost-additivity the chaos property tests
    pin.  Retry *backoff* is deliberately NOT here: it is mechanism-
    independent waiting, not movement, and folding it into both clocks
    skewed the reported lisa-vs-memcpy ratio with the fault rate (the more
    chaos, the closer the ratio drifted to 1).  Callers charge backoff to
    the virtual clock as its own latency bucket
    (:class:`repro.sched.metrics.Decision.backoff_ns`), keeping
    ``advantage = ns_memcpy / ns_lisa`` fault-rate-invariant."""
    if retries <= 0:
        return MovementCost(0, 0, 0.0, 0.0, 0.0, 0.0)
    return cost.scaled(retries)


class ContendedCost(NamedTuple):
    """A priced movement *and* when it actually ran: ``cost`` is the
    isolated Table-1 bill (unchanged by load), ``start_ns``/``end_ns`` the
    service window a :class:`~repro.core.dram.bank.RequestMultiplexer`
    granted it.  The gap between ``end - ready`` and the isolated service
    time is queue/refresh contention — the load-dependent part of latency
    the bank model adds (DESIGN.md Sec. 15)."""
    cost: MovementCost
    ready_ns: float
    start_ns: float
    end_ns: float

    @property
    def stall_ns(self) -> float:
        """Time spent waiting on bank occupancy or refresh, beyond the
        isolated service time."""
        return self.start_ns - self.ready_ns


def contend(cost: MovementCost, mux, bank: int, ready_ns: float,
            mechanism: str = "lisa") -> ContendedCost:
    """Submit an isolated ``MovementCost`` through a bank multiplexer and
    return it alongside its queued/contended completion window.  The
    active mechanism's ns is the service time; pricing is untouched —
    contention decides *when*, Table 1 decides *how much*."""
    service = cost.ns_lisa if mechanism == "lisa" else cost.ns_memcpy
    start, end = mux.submit(bank, ready_ns, service)
    return ContendedCost(cost=cost, ready_ns=ready_ns, start_ns=start,
                         end_ns=end)


_FREE_LEGS = ("pack_pages", "unpack_pages")      # relabeling, not movement
_CHANNEL_LEGS = ("host_stage",)                  # channel is the only path


def _price_leg(leg: Leg, spec: DramSpec) -> MovementCost:
    if isinstance(leg, PageAliasLeg):
        # Fork fast path: no bytes cross any channel — the lisa arm prices
        # the in-DRAM RowClone alias, the memcpy arm prices the per-session
        # copy the alias avoided.  bytes records what was NOT copied.
        rows = leg.batch * max(1, math.ceil(leg.nbytes / spec.row_bytes))
        h = max(leg.hops, 1)
        return MovementCost(leg.batch * leg.nbytes, leg.hops,
                            rows * spec.copy_latency("rowclone", h),
                            rows * spec.copy_latency("memcpy"),
                            rows * spec.copy_energy("rowclone", h),
                            rows * spec.copy_energy("memcpy"))
    if leg.kind in _FREE_LEGS or leg.nbytes == 0:
        return MovementCost(0, leg.hops, 0.0, 0.0, 0.0, 0.0)
    if isinstance(leg, HopChainLeg):
        if leg.hops == 0:                        # already local: a free move
            return MovementCost(0, 0, 0.0, 0.0, 0.0, 0.0)
        from repro.core.lisa.topology import ici_dram_spec
        spec = ici_dram_spec(leg.nbytes)         # mesh legs: ICI constants
    rows = leg.batch * max(1, math.ceil(leg.nbytes / spec.row_bytes))
    h = max(leg.hops, 1)
    ns_mem = rows * spec.copy_latency("memcpy")
    uj_mem = rows * spec.copy_energy("memcpy")
    if leg.kind in _CHANNEL_LEGS:
        # No in-fabric alternative: both mechanisms pay the channel.
        return MovementCost(leg.batch * leg.nbytes, leg.hops,
                            ns_mem, ns_mem, uj_mem, uj_mem)
    return MovementCost(leg.batch * leg.nbytes, leg.hops,
                        rows * spec.copy_latency("lisa", h), ns_mem,
                        rows * spec.copy_energy("lisa", h), uj_mem)


def _sum_costs(costs: Sequence[MovementCost]) -> MovementCost:
    return MovementCost(
        bytes=sum(c.bytes for c in costs),
        hops=max((c.hops for c in costs), default=0),
        ns_lisa=sum(c.ns_lisa for c in costs),
        ns_memcpy=sum(c.ns_memcpy for c in costs),
        uj_lisa=sum(c.uj_lisa for c in costs),
        uj_memcpy=sum(c.uj_memcpy for c in costs))


def leg_costs(plan: "MovementPlan",
              spec: DramSpec = DDR3_1600) -> Tuple[MovementCost, ...]:
    """Per-leg :class:`MovementCost` breakdown of ``plan`` under ``spec``.

    This re-runs the exact ``_price_leg`` arithmetic that produced
    ``plan.cost`` (same spec, same order), so a left-to-right sum over the
    returned tuple reproduces the plan total bit-for-bit — the contract the
    observability layer's per-leg span attribution relies on.
    """
    return tuple(_price_leg(leg, spec) for leg in plan.legs)


class MovementPlan(NamedTuple):
    """A lowered transfer: typed legs + the priced cost.  Execute with
    :func:`repro.movement.registry.execute`."""
    transfer: Transfer
    legs: Tuple[Leg, ...]
    cost: MovementCost

    def describe(self) -> str:
        t = self.transfer
        legs = " -> ".join(
            f"{l.kind}[{l.batch}x{l.nbytes}B"
            + (f",h={l.hops}" if l.hops else "") + "]" for l in self.legs)
        return (f"{t.src.kind}->{t.dst.kind}: {legs} "
                f"| {self.cost.bytes}B, lisa={self.cost.ns_lisa:.0f}ns, "
                f"memcpy={self.cost.ns_memcpy:.0f}ns "
                f"({self.cost.advantage:.1f}x)")


# ---------------------------------------------------------------------------
# The lowering.
# ---------------------------------------------------------------------------

def plan(transfer: Transfer, spec: DramSpec = DDR3_1600, *,
         topo: Optional["MeshTopology"] = None) -> MovementPlan:
    """Lower a :class:`Transfer` against a spec topology into a typed plan.

    In-device legs are priced by ``spec``'s mechanism registry (hop-chain
    vs channel, the Table 1 model); mesh legs by the ICI analogue
    (:func:`~repro.core.lisa.topology.ici_dram_spec`).  ``topo`` supplies
    hop distances for point-to-point stage transfers.
    """
    src, dst, lay = transfer.src, transfer.dst, transfer.layout
    pair = (src.kind, dst.kind)
    n, b = lay.nbytes, lay.batch
    legs: Tuple[Leg, ...]

    if transfer.kind == "fork":
        # Session fork (repro/fork).  Same replica: ONE page_alias leg —
        # the ForkPageTable repoints the child onto the parent's physical
        # row, zero device dispatches, priced as a RowClone FPM with the
        # per-session copy it avoided on the memcpy arm.  Cross-replica:
        # the alias cannot span slow pools, so the fork MATERIALIZES over
        # the same priced migration route a session move takes.
        if pair != ("slow", "slow"):
            raise ValueError(f"fork transfers alias slow-tier pages "
                             f"(slow->slow); got {pair[0]}->{pair[1]}")
        if transfer.policy is not None:
            raise ValueError("fork transfers are not policy-mediated "
                             "(aliasing never touches the fast tier)")
        if src.index is None or dst.index is None \
                or src.index == dst.index:
            legs = (PageAliasLeg(nbytes=n, batch=b, hops=0),)
        else:
            if src.axis is None or src.axis != dst.axis:
                raise ValueError(
                    "cross-replica forks need matching mesh axis names "
                    f"(got {src.axis!r} -> {dst.axis!r})")
            if topo is None:
                raise ValueError(
                    "cross-replica forks materialize over the migration "
                    "route: pass plan(..., topo=MeshTopology(n_replicas)) "
                    "so the copy is priced over the executed ring")
            legs = (PageGatherLeg(nbytes=0, batch=b, pool_key="src_pool",
                                  table_key="src_table"),
                    HopChainLeg(nbytes=n,
                                hops=topo.hops(src.index, dst.index),
                                batch=b, axis=src.axis, src=src.index,
                                dst=dst.index, wraparound=topo.wraparound),
                    PageScatterLeg(nbytes=0, batch=b, pool_key="dst_pool",
                                   table_key="dst_table"))
        cost = _sum_costs([_price_leg(leg, spec) for leg in legs])
        return MovementPlan(transfer=transfer, legs=legs, cost=cost)

    if transfer.policy and pair not in (("compute", "slow"),
                                        ("slow", "compute")):
        # The VILLA policy itself decides fast-tier placement (hot marking
        # + promotion), and no other tier pair is policy-mediated at all —
        # silently planning a policy-free leg would bypass the TieredStore
        # without any signal to the caller.
        raise ValueError(
            "policy-routed transfers address the slow tier (compute<->slow "
            "with policy=): the policy decides what gets promoted to fast, "
            f"and {pair[0]}->{pair[1]} has no policy-mediated lowering — "
            "drop policy= or retarget the transfer")
    if pair == ("compute", "slow") and transfer.policy:
        # With a PageSpec the payload is a pytree slot staged through uint8
        # pages first; raw paged items go straight to the tier policy.
        pack = (PackLeg(nbytes=0, batch=b, page_spec=lay.page_spec),) \
            if lay.page_spec is not None else ()
        legs = pack + (TierWriteLeg(nbytes=n, hops=1, batch=b,
                                    policy=transfer.policy),)
    elif pair == ("slow", "compute") and transfer.policy:
        unpack = (UnpackLeg(nbytes=0, batch=b, page_spec=lay.page_spec),) \
            if lay.page_spec is not None else ()
        legs = (TierReadLeg(nbytes=n, hops=1, batch=b,
                            policy=transfer.policy),) + unpack
    elif pair in (("compute", "slow"), ("compute", "fast")):
        legs = (PageScatterLeg(nbytes=n, hops=1, batch=b),)
    elif pair in (("slow", "compute"), ("fast", "compute")):
        legs = (PageGatherLeg(nbytes=n, hops=1, batch=b),)
    elif pair in (("slow", "fast"), ("fast", "slow")):
        # Tier promotion / demotion: gather the pages out of the source
        # pool, scatter them into the DESTINATION pool (distinct env keys —
        # binding both legs to one pool would make the move a no-op).  The
        # pair is ONE copy in the cost model (the paper prices a slow<->fast
        # row move once, not per read/write phase): the gather leg carries
        # the payload bytes, the scatter leg is priced free.
        legs = (PageGatherLeg(nbytes=n, hops=1, batch=b,
                              pool_key="src_pool", table_key="src_table"),
                PageScatterLeg(nbytes=0, hops=1, batch=b,
                               pool_key="dst_pool", table_key="dst_table"))
    elif pair == ("slow", "slow"):
        # Cross-replica session migration: the suspended snapshot's pages
        # leave the source replica's slow pool, cross the mesh as a hop
        # chain, and land in the destination replica's slow pool.  The
        # gather/scatter legs are staging (free — the paper prices one row
        # move per migration, not per pool access); the hop-chain leg
        # carries the payload and is priced over the ICI route, so the
        # whole migration is ONE copy under the Table-1 model.
        if src.axis is None or src.axis != dst.axis:
            raise ValueError("cross-replica slow->slow transfers need "
                             "matching mesh axis names (got "
                             f"{src.axis!r} -> {dst.axis!r})")
        if src.index is None or dst.index is None:
            raise ValueError("cross-replica slow->slow transfers name both "
                             "replica indices (src.index / dst.index)")
        if topo is None:
            raise ValueError(
                "cross-replica transfers need the mesh topology: pass "
                "plan(..., topo=MeshTopology(n_replicas)) so the migration "
                "is priced over the same ring the hop chain executes on")
        legs = (PageGatherLeg(nbytes=0, batch=b, pool_key="src_pool",
                              table_key="src_table"),
                HopChainLeg(nbytes=n, hops=topo.hops(src.index, dst.index),
                            batch=b, axis=src.axis, src=src.index,
                            dst=dst.index, wraparound=topo.wraparound),
                PageScatterLeg(nbytes=0, batch=b, pool_key="dst_pool",
                               table_key="dst_table"))
    elif pair == ("device", "host"):
        legs = (HostStageLeg(nbytes=n, batch=b, to_host=True),)
    elif pair == ("host", "device"):
        legs = (HostStageLeg(nbytes=n, batch=b, to_host=False),)
    elif pair == ("stage", "stage"):
        if src.axis is None or src.axis != dst.axis:
            raise ValueError("stage transfer needs matching mesh axis names "
                             f"(got {src.axis!r} -> {dst.axis!r})")
        if src.index is None or dst.index is None:
            legs = (HopChainLeg(nbytes=n, hops=1, batch=b, axis=src.axis),)
        else:
            if topo is None:
                # Guessing the axis size would let the priced hop count
                # diverge from the route lisa_copy actually takes.
                raise ValueError(
                    "point-to-point stage transfers need the mesh topology: "
                    "pass plan(..., topo=MeshTopology(axis_size)) so hops "
                    "are priced over the same ring the chain executes on")
            legs = (HopChainLeg(nbytes=n,
                                hops=topo.hops(src.index, dst.index),
                                batch=b, axis=src.axis,
                                src=src.index, dst=dst.index,
                                wraparound=topo.wraparound),)
    elif pair == ("device", "device"):
        legs = (TileCopyLeg(nbytes=n, hops=1, batch=b),)
    else:
        raise ValueError(f"no lowering for transfer {src.kind!r} -> "
                         f"{dst.kind!r} (layout {lay.kind!r})")

    if lay.kind == "pages" and not transfer.preserve_dtype:
        raise ValueError("paged transfers are dtype-preserving by "
                         "construction; preserve_dtype=False is not a "
                         "supported paged mode")

    cost = _sum_costs([_price_leg(leg, spec) for leg in legs])
    return MovementPlan(transfer=transfer, legs=legs, cost=cost)


def ring_plan(axis: str, axis_size: int, layout: Layout,
              kind: str = "all_gather") -> MovementPlan:
    """A ring collective as a movement plan: one neighbor-shift hop-chain
    leg per ring step ((n-1) for all_gather/reduce_scatter, 2(n-1) for
    all_reduce — the paper's hop chain run twice), each carrying one
    shard's bytes.  Matches ``topology.ring_collective_us`` by
    construction; ``rbm.ring_scan`` is the executing schedule.
    """
    steps = {"all_gather": axis_size - 1,
             "reduce_scatter": axis_size - 1,
             "all_reduce": 2 * (axis_size - 1)}[kind]
    transfer = Transfer(Tier("stage", axis=axis), Tier("stage", axis=axis),
                        layout)
    legs = tuple(HopChainLeg(nbytes=layout.nbytes, hops=1,
                             batch=layout.batch, axis=axis)
                 for _ in range(max(steps, 0)))
    cost = _sum_costs([_price_leg(leg, DDR3_1600) for leg in legs]
                      or [MovementCost(0, 0, 0.0, 0.0, 0.0, 0.0)])
    return MovementPlan(transfer=transfer, legs=legs, cost=cost)


#: Leg kinds whose backends execute a whole wave in one dispatch (scanned
#: policy access / vmapped pack / scanned unpack).  Other kinds would
#: silently move one item while the fused cost reports k — refuse them.
_WAVE_KINDS = frozenset(
    {"pack_pages", "unpack_pages", "tier_read", "tier_write", "page_alias"})


def fuse(plans: Sequence[MovementPlan]) -> MovementPlan:
    """Fuse identical single-item plans into one batched wave (k items, one
    dispatch).  All plans must be equal and every leg wave-capable
    (:data:`_WAVE_KINDS`); cost scales linearly."""
    if not plans:
        raise ValueError("cannot fuse an empty plan list")
    first, k = plans[0], len(plans)
    if any(p != first for p in plans[1:]):
        raise ValueError("fuse() requires identical plans (same transfer, "
                         "legs and spec pricing)")
    unsupported = sorted({l.kind for l in first.legs} - _WAVE_KINDS)
    if unsupported:
        raise ValueError(
            f"fuse() cannot batch {unsupported} legs (their backends run "
            f"one item per dispatch); batch at the caller — e.g. a longer "
            f"page table for gather/scatter — or fuse only policy-staged "
            f"plans (legs in {sorted(_WAVE_KINDS)})")
    if k == 1:
        return first
    lay = dataclasses.replace(first.transfer.layout,
                              batch=first.transfer.layout.batch * k)
    return MovementPlan(
        transfer=dataclasses.replace(first.transfer, layout=lay),
        legs=tuple(dataclasses.replace(l, batch=l.batch * k)
                   for l in first.legs),
        cost=first.cost.scaled(k))
