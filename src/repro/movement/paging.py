"""Byte-paged, dtype-preserving layout staging for movement plans.

A snapshot of any pytree slice (e.g. one slot of a batched KV cache) is
staged as fixed-size *pages* of raw bytes (default 8x128 = 1 KB — one DRAM
row in the paper's geometry).  Every leaf is bitcast to uint8, so int8 stays
1 byte/elem and bf16 stays 2 — no float32 upcast anywhere on a movement
path, and restore is bit-exact by construction.  This is the ``pack_pages``
/ ``unpack_pages`` leg pair of a :class:`~repro.movement.plan.MovementPlan`.

Everything here is shape-static and traceable: ``pack_slot`` /
``unpack_into_slot`` take a *traced* slot index, so a plan containing these
legs still lowers to ONE jitted dispatch with donated buffers.

(This module is the substrate-level home of what used to live in
``repro.serve.paged_store``; the serving module now delegates here.)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp


def _to_bytes(x: jax.Array) -> jax.Array:
    """Bitcast any leaf to a flat uint8 vector (dtype-preserving, bit-exact)."""
    return jax.lax.bitcast_convert_type(x, jnp.uint8).reshape(-1)


def _from_bytes(b: jax.Array, shape: Tuple[int, ...], dtype) -> jax.Array:
    itemsize = jnp.dtype(dtype).itemsize
    if itemsize == 1:
        return jax.lax.bitcast_convert_type(b.reshape(shape), dtype)
    return jax.lax.bitcast_convert_type(b.reshape(shape + (itemsize,)), dtype)


@dataclasses.dataclass(frozen=True)
class PageSpec:
    """Static byte layout of one snapshot (one slot slice of a pytree)."""
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    leaf_dtypes: Tuple[Any, ...]
    leaf_offsets: Tuple[int, ...]       # byte offset of each leaf
    total_bytes: int                    # sum of leaf bytes (true, not upcast)
    page_rows: int = 8
    page_lanes: int = 128

    @property
    def page_bytes(self) -> int:
        return self.page_rows * self.page_lanes

    @property
    def n_pages(self) -> int:
        return -(-self.total_bytes // self.page_bytes)

    @classmethod
    def for_cache(cls, cache, *, page_rows: int = 8,
                  page_lanes: int = 128) -> "PageSpec":
        """Layout for one slot of a batched cache (leaves (reps, slots, ...))."""
        leaves = jax.tree_util.tree_leaves(cache)
        shapes, dtypes, offsets = [], [], []
        off = 0
        for leaf in leaves:
            shape = leaf.shape[:1] + leaf.shape[2:]      # drop the slot dim
            shapes.append(shape)
            dtypes.append(leaf.dtype)
            offsets.append(off)
            off += math.prod(shape) * leaf.dtype.itemsize
        return cls(tuple(shapes), tuple(dtypes), tuple(offsets), off,
                   page_rows, page_lanes)


def pack_slot(spec: PageSpec, cache, slot) -> jax.Array:
    """Snapshot cache[:, slot] into (n_pages, P, d) uint8 pages (traceable)."""
    leaves = jax.tree_util.tree_leaves(cache)
    parts: List[jax.Array] = []
    for leaf in leaves:
        one = jax.lax.dynamic_index_in_dim(leaf, slot, axis=1, keepdims=False)
        parts.append(_to_bytes(one))
    flat = jnp.concatenate(parts)
    pad = spec.n_pages * spec.page_bytes - spec.total_bytes
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(spec.n_pages, spec.page_rows, spec.page_lanes)


def page_checksums(pages: jax.Array) -> jax.Array:
    """Per-page position-weighted byte checksum (uint32, traceable).

    ``pages`` is (..., P, d) uint8 — any leading batch dims, last two dims
    one page.  Each page's checksum is ``sum(byte[i] * (2*i + 1)) mod 2^32``.
    The weights are odd, hence units mod 2^32, so ANY single-byte change
    (delta in [-255, 255] \\ {0}) shifts the sum by ``delta * w_i != 0`` —
    every single-byte corruption is detected, by construction.  Computed
    in-graph: a pack leg pays a few uint32 FLOPs per byte and no host sync.
    """
    pb = pages.shape[-2] * pages.shape[-1]
    flat = pages.reshape(pages.shape[:-2] + (pb,)).astype(jnp.uint32)
    w = 2 * jnp.arange(pb, dtype=jnp.uint32) + 1
    return jnp.sum(flat * w, axis=-1, dtype=jnp.uint32)


def verify_pages(pages: jax.Array, sums: jax.Array) -> jax.Array:
    """Count of pages whose recomputed checksum mismatches ``sums``.

    ``pages`` (..., n_pages, P, d) against ``sums`` (..., n_pages); returns
    an int32 scalar (traceable — the verdict rides whatever sync the caller
    already performs, never forcing one of its own).
    """
    return jnp.sum((page_checksums(pages) != sums).astype(jnp.int32))


def row_page_table(spec: PageSpec, row) -> jax.Array:
    """The flat-pool page table addressing one store row's pages.

    Pool rows are ``spec.n_pages`` consecutive pages once the pool is
    reshaped flat, so row ``r`` (a traced index is fine) is pages
    ``r * n_pages + [0, n_pages)``.  Fork-aware callers pass the PHYSICAL
    row a :class:`repro.fork.ForkPageTable` resolved, so every alias of a
    shared row gathers the same bytes.
    """
    return jnp.asarray(row, jnp.int32) * spec.n_pages + jnp.arange(
        spec.n_pages, dtype=jnp.int32)


def unpack_into_slot(spec: PageSpec, cache, slot, pages: jax.Array):
    """Restore pages into cache[:, slot]; inverse of :func:`pack_slot`."""
    flat = pages.reshape(-1)
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    out = []
    for leaf, shape, dtype, off in zip(leaves, spec.leaf_shapes,
                                       spec.leaf_dtypes, spec.leaf_offsets):
        nbytes = math.prod(shape) * jnp.dtype(dtype).itemsize
        piece = _from_bytes(jax.lax.slice(flat, (off,), (off + nbytes,)),
                            shape, dtype)
        out.append(jax.lax.dynamic_update_slice_in_dim(
            leaf, jnp.expand_dims(piece, 1), slot, axis=1))
    return jax.tree_util.tree_unflatten(treedef, out)
