"""Default movement backends: the real-array layer of the substrate.

Registered on ``import repro.movement``.  Each backend is the thinnest
possible adapter from a typed leg to the underlying movement engine:

  pack_pages / unpack_pages  ->  repro.movement.paging (uint8 bitcast legs)
  page_gather / page_scatter ->  Pallas kernels (scalar-prefetched tables,
                                 LIP double buffering, input/output aliasing)
  tile_copy                  ->  Pallas rbm_copy (HBM->HBM through VMEM)
  hop_chain                  ->  ppermute hop chains over a mesh axis
                                 (rbm.rbm_hop shift / rbm.lisa_copy chain)
  host_stage                 ->  device_get / device_put across the channel

The VILLA tier legs (``tier_read`` / ``tier_write``) are registered by
:mod:`repro.core.lisa.villa_cache`, which owns the caching policy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lisa import rbm
from repro.kernels.rbm_copy import rbm_copy, villa_gather, villa_scatter
from repro.movement import paging
from repro.movement.plan import HopChainLeg, Leg, PackLeg, TileCopyLeg, \
    UnpackLeg
from repro.movement.registry import Env, register_backend


@register_backend("pack_pages")
def _pack_pages(leg: PackLeg, env: Env) -> Env:
    # Plural env keys declare a wave (see _unpack_pages): a fused suspend
    # wave packs every slot in one vmapped dispatch.
    env = dict(env)
    if leg.batch > 1 or "slots" in env:
        env["data"] = jax.vmap(
            lambda s: paging.pack_slot(leg.page_spec, env["cache"], s))(
                env["slots"])
    else:
        env["data"] = paging.pack_slot(leg.page_spec, env["cache"],
                                       env["slot"])
    # The detection sidecar: every pack leg emits per-page checksums
    # alongside the payload (ECC computed at the subarray boundary).  Pure
    # in-graph uint32 arithmetic — no extra dispatch, no host sync.
    env["sums"] = paging.page_checksums(env["data"])
    return env


@register_backend("unpack_pages")
def _unpack_pages(leg: UnpackLeg, env: Env) -> Env:
    # A wave is declared by the plural env keys, so a fused plan of batch 1
    # (a one-element resume wave) still takes the batched path.
    env = dict(env)
    expected = env.get("sums")
    if expected is not None:
        # Verify at unpack against the checksums the caller carried from
        # pack time.  ``verify_fail`` counts ITEMS with any corrupt page
        # (one incident per session) and stays on-device: the verdict rides
        # the caller's existing sync, never adding one.
        cs = paging.page_checksums(env["data"])
        mismatch = cs != jnp.asarray(expected, jnp.uint32)
        if mismatch.ndim > 1:          # wave: (k, n_pages) -> per-item any
            env["verify_fail"] = jnp.sum(
                jnp.any(mismatch, axis=-1).astype(jnp.int32))
        else:
            env["verify_fail"] = jnp.any(mismatch).astype(jnp.int32)
    if leg.batch > 1 or "slots" in env:
        def body(cache, xs):
            slot, pages = xs
            return paging.unpack_into_slot(leg.page_spec, cache, slot,
                                           pages), None
        env["cache"], _ = jax.lax.scan(body, env["cache"],
                                       (env["slots"], env["data"]))
    else:
        env["cache"] = paging.unpack_into_slot(leg.page_spec, env["cache"],
                                               env["slot"], env["data"])
    return env


@register_backend("page_gather")
def _page_gather(leg, env: Env) -> Env:
    env = dict(env)
    env["data"] = villa_gather(env[leg.pool_key], env[leg.table_key])
    return env


@register_backend("page_scatter")
def _page_scatter(leg, env: Env) -> Env:
    env = dict(env)
    env[leg.pool_key] = villa_scatter(env[leg.pool_key], env[leg.table_key],
                                      env["data"])
    return env


@register_backend("tile_copy")
def _tile_copy(leg: TileCopyLeg, env: Env) -> Env:
    env = dict(env)
    env["data"] = rbm_copy(env["data"], tile_rows=leg.tile_rows,
                           lanes=leg.lanes)
    return env


@register_backend("hop_chain")
def _hop_chain(leg: HopChainLeg, env: Env) -> Env:
    env = dict(env)
    if env.get("local_fabric"):
        # Single-process replica fleet (serve/cluster.py): the replica pools
        # share one address space, so the payload the gather leg staged is
        # already reachable by the scatter leg — the hop chain contributes
        # the PRICED mesh route (the plan's cost is the ICI hop model) and
        # is an identity on the bytes here.  On a real mesh the same leg
        # executes the ppermute chain below (pinned by the shard_map tests).
        return env
    if leg.src is None or leg.dst is None:
        env["data"] = rbm.rbm_hop(env["data"], leg.axis, leg.step)
    else:
        env["data"] = rbm.lisa_copy(env["data"], leg.src, leg.dst, leg.axis,
                                    wraparound=leg.wraparound)
    return env


@register_backend("page_alias")
def _page_alias(leg: Leg, env: Env) -> Env:
    # Zero-copy fork fast path (repro/fork): the physical bytes stay where
    # they are — the ForkPageTable repointed the child's logical row on the
    # host BEFORE this plan executed.  The leg exists so the alias is a
    # first-class priced movement (RowClone FPM on the lisa arm, the
    # avoided per-session copy on the memcpy arm); executing it dispatches
    # NOTHING (pinned by repro.analysis.testlib in tests/test_fork.py).
    return env


@register_backend("host_stage")
def _host_stage(leg: Leg, env: Env) -> Env:
    env = dict(env)
    leaves = env["data"]
    if leg.to_host:
        env["data"] = [None if l is None else np.asarray(jax.device_get(l))
                       for l in leaves]
    else:
        shardings = env.get("shardings") or [None] * len(leaves)
        env["data"] = [
            None if a is None else
            (jax.device_put(a, s) if s is not None else jnp.asarray(a))
            for a, s in zip(leaves, shardings)]
    return env
