"""Chrome-trace (``trace_events``) export of a :class:`~repro.obs.Tracer`.

The output is the JSON-object flavor Perfetto / ``chrome://tracing``
accept: ``{"traceEvents": [...], "displayTimeUnit": "ns"}`` with ``ph:"X"``
complete events (``ts``/``dur`` in microseconds — the format's unit) and
``ph:"i"`` instants.  Modeled ns live unrounded in each event's ``args``
(``ns`` plus the lisa/memcpy cost split), so the trace stays exact even
though the viewer renders microseconds.

Byte stability is a contract: events are emitted in span-recording order
(deterministic under a fixed seed), keys are sorted, separators are
compact, and ``allow_nan=False`` keeps the artifact strict JSON — two
same-seed runs produce byte-identical files (``tests/test_obs.py`` pins
this).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from repro.obs.tracer import Tracer

__all__ = ["trace_events", "chrome_trace", "write_chrome_trace"]

#: pid for the whole modeled timeline (one "process": the virtual clock).
_PID = 0

_LANE0 = "scheduler"


def _lane_name(lane: int, n_lanes: int) -> str:
    if lane == 0:
        return _LANE0
    return f"replica-{lane - 1}"


def trace_events(tracer: Tracer) -> List[Dict[str, Any]]:
    """The ``traceEvents`` list: metadata rows naming each lane, then one
    event per span in recording order."""
    lanes = sorted({s.lane for s in tracer.spans})
    evs: List[Dict[str, Any]] = []
    for lane in lanes:
        evs.append({"ph": "M", "pid": _PID, "tid": lane,
                    "name": "thread_name",
                    "args": {"name": _lane_name(lane, len(lanes))}})
    for s in tracer.spans:
        args = dict(s.attrs)
        args["ns"] = s.ns
        ev: Dict[str, Any] = {
            "name": s.name, "cat": s.cat or "span",
            "pid": _PID, "tid": s.lane,
            "ts": s.t0_ns / 1e3, "args": args,
        }
        if s.instant:
            ev["ph"] = "i"
            ev["s"] = "t"
        else:
            ev["ph"] = "X"
            ev["dur"] = s.ns / 1e3
        evs.append(ev)
    return evs


def chrome_trace(tracer: Tracer) -> str:
    """The full trace as a strict-JSON string (byte-stable per docstring)."""
    payload = {"traceEvents": trace_events(tracer),
               "displayTimeUnit": "ns",
               "otherData": {"clock": "modeled-virtual-ns",
                             "mechanism": tracer.mechanism}}
    return json.dumps(payload, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)


def write_chrome_trace(tracer: Tracer, path: str) -> str:
    """Write the trace to ``path``; returns the path."""
    with open(path, "w") as f:
        f.write(chrome_trace(tracer))
        f.write("\n")
    return path
