"""One observable timeline: virtual-clock span tracing + attribution.

Public surface::

    from repro import obs

    tr = obs.Tracer(mechanism="lisa")
    s = Scheduler(engine, cfg, tracer=tr)   # spans in modeled ns
    s.run()                                  # summary() gains a trace block
    obs.write_chrome_trace(tr, "trace.json") # open in Perfetto

Spans record the SAME numbers the Decision ledger charges (per-leg
movement splits, fault retries, recovery restores), on per-replica lanes —
see DESIGN.md Sec. 14 for the span <-> DRAM-command-timeline mapping and
:mod:`repro.obs.tracer` for the lane/cursor model.  Everything here is
host bookkeeping over the virtual clock: zero device dispatches, no
wall-clock reads (repro-lint enforced).
"""
from repro.obs.tracer import NULL_TRACER, NullTracer, Span, Tracer
from repro.obs.export import chrome_trace, trace_events, write_chrome_trace

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Span",
    "chrome_trace", "trace_events", "write_chrome_trace",
]
