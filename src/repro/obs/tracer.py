"""Virtual-clock span tracing: one observable timeline for the substrate.

Every nanosecond the scheduler's virtual clock charges comes from somewhere
— a decode dispatch, a movement-plan leg, a fault retry's backoff, a
recovery restore.  The :class:`Tracer` records that attribution as spans in
MODELED ns (the same numbers the :class:`~repro.sched.metrics.Decision`
ledger charges), laid out on per-replica lanes, with parent/child nesting
inside each lane.  It is pure host bookkeeping: no device syncs, no
``time.time`` (repro-lint's wallclock rule covers this package), zero
device dispatches (pinned by ``tests/test_obs.py``).

Timeline model
--------------
  * lane 0              — the scheduler lane (tick / decode / prefill and,
                          for the single-engine scheduler, movement waves);
  * lane 1 + r          — replica ``r``'s movement lane (cluster waves run
                          per-replica; the clock advances by the slowest
                          lane, exactly what the spans show);
  * last lane           — the write-behind lane (snapshot waves: priced,
                          never clock-charged).

Each lane keeps a monotone cursor in modeled ns.  ``emit`` places a
complete span at the cursor and advances it; ``begin_span``/``end_span``
bracket children (the repro-lint ``unclosed-span`` rule checks every
``begin_span`` has a matching ``end_span`` in the same function — or use
the :meth:`Tracer.span` context manager).  Parentage is per lane: a span
begun while another is open on the same lane becomes its child.

Movement spans carry the full lisa-vs-memcpy :class:`MovementCost` split in
their attrs; per-leg child spans partition the per-move totals exactly
(last leg residual-corrected), so summing leg attrs in emission order
reproduces the Decision ledger bit-for-bit — the additivity contract
``tests/test_obs.py`` pins.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER"]

#: Attr value types that survive strict-JSON export unchanged.
_JSONABLE = (str, int, float, bool, type(None))


class Span:
    """One interval (or instant) on a lane, in modeled ns."""

    __slots__ = ("name", "cat", "lane", "t0_ns", "t1_ns", "parent",
                 "attrs", "index", "instant")

    def __init__(self, name: str, cat: str, lane: int, t0_ns: float,
                 parent: Optional["Span"], index: int,
                 instant: bool = False):
        self.name = name
        self.cat = cat
        self.lane = lane
        self.t0_ns = float(t0_ns)
        self.t1_ns = float(t0_ns)
        self.parent = parent
        self.attrs: Dict[str, Any] = {}
        self.index = index
        self.instant = instant

    @property
    def ns(self) -> float:
        return self.t1_ns - self.t0_ns

    def __repr__(self) -> str:                   # pragma: no cover - debug
        return (f"Span({self.name!r}, lane={self.lane}, "
                f"t0={self.t0_ns:.0f}, ns={self.ns:.0f})")


class Tracer:
    """Span recorder over the virtual clock (see module docstring).

    ``mechanism`` names which cost arm ("lisa" | "memcpy") drives span
    DURATIONS — matching the scheduler's charging mechanism — while attrs
    always carry both arms.  All state is plain host Python: recording a
    span never touches a device.
    """

    enabled = True

    def __init__(self, mechanism: str = "lisa"):
        if mechanism not in ("lisa", "memcpy"):
            raise ValueError(f"unknown mechanism {mechanism!r} "
                             "(known: lisa, memcpy)")
        self.mechanism = mechanism
        self.spans: List[Span] = []
        self._stacks: Dict[int, List[Span]] = {}
        self._cursor: Dict[int, float] = {}
        self._attribution: Dict[str, Dict[str, Any]] = {}

    # ---- clock cursors -----------------------------------------------------

    def now(self, lane: int = 0) -> float:
        """The lane's cursor: where the next span on it starts."""
        return self._cursor.get(lane, 0.0)

    def seek(self, lane: int, t_ns: float) -> None:
        """Advance the lane cursor to ``t_ns`` (monotone: never rewinds).
        Seeking also registers the lane, so :meth:`seek_all` covers it."""
        cur = self._cursor.get(lane)
        if cur is None or t_ns > cur:
            self._cursor[lane] = float(t_ns)

    def seek_all(self, t_ns: float) -> None:
        """Advance every known lane cursor to ``t_ns`` (tick barrier)."""
        for lane in self._cursor:
            if t_ns > self._cursor[lane]:
                self._cursor[lane] = float(t_ns)

    # ---- span recording ----------------------------------------------------

    def _clean_attrs(self, attrs: Optional[Dict[str, Any]]) -> \
            Dict[str, Any]:
        if not attrs:
            return {}
        return {k: (v if isinstance(v, _JSONABLE) else str(v))
                for k, v in attrs.items()}

    def begin_span(self, name: str, lane: int = 0, cat: str = "phase",
                   attrs: Optional[Dict[str, Any]] = None,
                   t0_ns: Optional[float] = None) -> Span:
        """Open a span at the lane cursor (or explicit ``t0_ns``).  MUST be
        paired with :meth:`end_span` in the same function (repro-lint
        ``unclosed-span``), or use :meth:`span`."""
        t0 = self.now(lane) if t0_ns is None else float(t0_ns)
        self.seek(lane, t0)
        stack = self._stacks.setdefault(lane, [])
        parent = stack[-1] if stack else None
        s = Span(name, cat, lane, t0, parent, len(self.spans))
        s.attrs.update(self._clean_attrs(attrs))
        extra = self._attribution.get(name)
        if extra:
            s.attrs.update(self._clean_attrs(extra))
        self.spans.append(s)
        stack.append(s)
        return s

    def end_span(self, span: Span, t1_ns: Optional[float] = None,
                 attrs: Optional[Dict[str, Any]] = None) -> Span:
        """Close ``span``.  ``t1_ns`` defaults to the lane cursor (i.e. the
        span covers everything emitted inside it); the cursor advances to
        the close time."""
        stack = self._stacks.get(span.lane, [])
        if not stack or stack[-1] is not span:
            raise RuntimeError(
                f"end_span({span.name!r}): span is not the innermost open "
                f"span on lane {span.lane} — close children first")
        stack.pop()
        t1 = self.now(span.lane) if t1_ns is None else float(t1_ns)
        if t1 < span.t0_ns:
            raise RuntimeError(f"end_span({span.name!r}): t1 {t1} precedes "
                               f"t0 {span.t0_ns} (modeled time is monotone)")
        span.t1_ns = t1
        span.attrs.update(self._clean_attrs(attrs))
        self.seek(span.lane, t1)
        return span

    @contextlib.contextmanager
    def span(self, name: str, lane: int = 0, cat: str = "phase",
             attrs: Optional[Dict[str, Any]] = None,
             t0_ns: Optional[float] = None) -> Iterator[Span]:
        """Context-managed begin/end pair (always balanced)."""
        s = self.begin_span(name, lane=lane, cat=cat, attrs=attrs,
                            t0_ns=t0_ns)
        try:
            yield s
        finally:
            self.end_span(s)

    def emit(self, name: str, ns: float, lane: int = 0, cat: str = "phase",
             attrs: Optional[Dict[str, Any]] = None) -> Span:
        """A complete leaf span of duration ``ns`` at the lane cursor; the
        cursor advances past it (sequential within the lane)."""
        s = self.begin_span(name, lane=lane, cat=cat, attrs=attrs)
        self.end_span(s, t1_ns=s.t0_ns + float(ns))
        return s

    def instant(self, name: str, lane: int = 0, cat: str = "event",
                attrs: Optional[Dict[str, Any]] = None,
                t_ns: Optional[float] = None) -> Span:
        """A zero-duration event mark (fork / CoW break / eviction /
        fault incident) at the lane cursor."""
        t0 = self.now(lane) if t_ns is None else float(t_ns)
        stack = self._stacks.get(lane, [])
        s = Span(name, cat, lane, t0, stack[-1] if stack else None,
                 len(self.spans), instant=True)
        s.attrs.update(self._clean_attrs(attrs))
        self.spans.append(s)
        return s

    # ---- movement attribution ---------------------------------------------

    def move_span(self, wave_kind: str, lane: int,
                  totals: Sequence[float],
                  leg_items: Sequence[Tuple[str, Sequence[float],
                                            Dict[str, Any]]],
                  attrs: Optional[Dict[str, Any]] = None) -> Span:
        """One priced movement (a wave member) and its per-leg children.

        ``totals`` is the 4-tuple ``(ns_lisa, ns_memcpy, uj_lisa,
        uj_memcpy)`` the Decision ledger charges for this move.  Each item
        of ``leg_items`` is ``(leg_kind, (ns_l, ns_m, uj_l, uj_m), extra)``
        — already scaled to this move.  The LAST leg is residual-corrected
        against ``totals`` so a left-to-right sum over the emitted leg
        attrs reproduces ``totals`` exactly (every current plan carries its
        cost on one leg, which makes the residual exact, not approximate).
        """
        mech = 0 if self.mechanism == "lisa" else 1
        base = {"ns_lisa": totals[0], "ns_memcpy": totals[1],
                "uj_lisa": totals[2], "uj_memcpy": totals[3],
                "wave": wave_kind}
        if attrs:
            base.update(attrs)
        mv = self.begin_span("move", lane=lane, cat="move", attrs=base)
        acc = [0.0, 0.0, 0.0, 0.0]
        last = len(leg_items) - 1
        for i, (kind, vals, extra) in enumerate(leg_items):
            if i == last:
                vals = tuple(totals[j] - acc[j] for j in range(4))
            else:
                for j in range(4):
                    acc[j] += vals[j]
            leg_attrs = {"ns_lisa": vals[0], "ns_memcpy": vals[1],
                         "uj_lisa": vals[2], "uj_memcpy": vals[3],
                         "wave": wave_kind}
            leg_attrs.update(extra)
            self.emit(kind, vals[mech], lane=lane, cat="leg",
                      attrs=leg_attrs)
        self.end_span(mv)
        return mv

    # ---- roofline binding --------------------------------------------------

    def bind_attribution(self, mapping: Dict[str, Dict[str, Any]]) -> None:
        """Attach roofline attribution to span names: every subsequent span
        named ``k`` gains ``mapping[k]``'s entries as attrs (e.g. decode
        spans gain the dominant HLO kernel + its byte/flop share), so the
        trace answers "which kernel owns this tick's time"."""
        for name, extra in mapping.items():
            self._attribution[name] = dict(extra)

    # ---- aggregation -------------------------------------------------------

    def rollup(self) -> Dict[str, Any]:
        """Aggregated per-phase / per-leg totals (merged into
        ``Metrics.summary()``).  Keys sorted for stable artifacts."""
        per_phase: Dict[str, Dict[str, Any]] = {}
        legs: Dict[str, Dict[str, Any]] = {}
        for s in self.spans:
            key = s.cat or s.name
            d = per_phase.setdefault(key, {"count": 0, "ns": 0.0})
            d["count"] += 1
            d["ns"] += s.ns
            if s.cat == "leg":
                l = legs.setdefault(
                    s.name, {"count": 0, "ns_lisa": 0.0, "ns_memcpy": 0.0})
                l["count"] += 1
                l["ns_lisa"] += float(s.attrs.get("ns_lisa", 0.0))
                l["ns_memcpy"] += float(s.attrs.get("ns_memcpy", 0.0))
        return {
            "spans": len(self.spans),
            "per_phase": {k: {"count": v["count"], "ns": round(v["ns"], 2)}
                          for k, v in sorted(per_phase.items())},
            "legs": {k: {"count": v["count"],
                         "ns_lisa": round(v["ns_lisa"], 2),
                         "ns_memcpy": round(v["ns_memcpy"], 2)}
                     for k, v in sorted(legs.items())},
        }

    def top_spans(self, n: int = 5) -> List[Dict[str, Any]]:
        """The ``n`` longest non-instant spans by modeled ns (stable
        tie-break by emission index)."""
        ranked = sorted((s for s in self.spans if not s.instant),
                        key=lambda s: (-s.ns, s.index))
        return [{"name": s.name, "cat": s.cat, "lane": s.lane,
                 "t0_ns": round(s.t0_ns, 2), "ns": round(s.ns, 2)}
                for s in ranked[:n]]


class NullTracer:
    """Disabled tracer: every call is a cheap no-op so instrumented code
    reads straight-line (no ``if tracer`` guards at call sites)."""

    enabled = False
    mechanism = "lisa"
    spans: List[Span] = []

    _SPAN = Span("null", "", 0, 0.0, None, -1)

    def now(self, lane: int = 0) -> float:
        return 0.0

    def seek(self, lane: int, t_ns: float) -> None:
        pass

    def seek_all(self, t_ns: float) -> None:
        pass

    def begin_span(self, name: str, lane: int = 0, cat: str = "phase",
                   attrs: Optional[Dict[str, Any]] = None,
                   t0_ns: Optional[float] = None) -> Span:
        return self._SPAN

    def end_span(self, span: Span, t1_ns: Optional[float] = None,
                 attrs: Optional[Dict[str, Any]] = None) -> Span:
        return self._SPAN

    @contextlib.contextmanager
    def span(self, name: str, lane: int = 0, cat: str = "phase",
             attrs: Optional[Dict[str, Any]] = None,
             t0_ns: Optional[float] = None) -> Iterator[Span]:
        yield self._SPAN

    def emit(self, name: str, ns: float, lane: int = 0, cat: str = "phase",
             attrs: Optional[Dict[str, Any]] = None) -> Span:
        return self._SPAN

    def instant(self, name: str, lane: int = 0, cat: str = "event",
                attrs: Optional[Dict[str, Any]] = None,
                t_ns: Optional[float] = None) -> Span:
        return self._SPAN

    def move_span(self, wave_kind: str, lane: int,
                  totals: Sequence[float],
                  leg_items: Sequence[Tuple[str, Sequence[float],
                                            Dict[str, Any]]],
                  attrs: Optional[Dict[str, Any]] = None) -> Span:
        return self._SPAN

    def bind_attribution(self, mapping: Dict[str, Dict[str, Any]]) -> None:
        pass

    def rollup(self) -> Dict[str, Any]:
        return {"spans": 0, "per_phase": {}, "legs": {}}

    def top_spans(self, n: int = 5) -> List[Dict[str, Any]]:
        return []


#: Shared disabled tracer: ``self.trace = tracer or NULL_TRACER``.
NULL_TRACER = NullTracer()
