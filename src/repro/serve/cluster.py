"""Multi-replica serving: N engines on a mesh, with live session migration.

LISA links adjacent subarrays so a row can hop across the chip at full
internal bandwidth instead of draining through the narrow channel (PAPER.md
Sec. 3).  A serving fleet has the same shape one level up: each replica is a
"subarray" holding sessions (suspended KV snapshots in its VILLA tiered
store), the ICI mesh is the inter-subarray link fabric, and the host/PCIe
path is the narrow channel.  This module is that analogy made executable:

  * **replica placement ↔ subarray distance** — replicas sit on a
    :class:`~repro.core.lisa.topology.MeshTopology` ring; moving a session
    from replica ``i`` to ``j`` costs ``hops(i, j)`` ICI hops, priced by the
    same :func:`~repro.core.lisa.topology.ici_dram_spec` Table-1 model that
    prices every other movement in the repo.
  * **live migration ↔ RBM hop chain** — a migration is a
    :class:`~repro.movement.plan.MovementPlan` (page gather out of the
    source replica's slow pool → ``hop_chain`` across the mesh → page
    scatter into the destination pool), planned per route and priced as ONE
    copy.  It is loss-free and bit-exact: the pages are dtype-preserving
    uint8, and the session's host bookkeeping (position, seed token)
    travels with them.
  * **migration waves ↔ fused row moves** — a rebalance burst groups
    sessions by route; each route is ONE jitted gather+scatter dispatch
    (one long page table), never one dispatch per session — the cluster
    dual of ``suspend_many`` / ``resume_many``.

Every replica shares the first engine's jitted entry points
(:meth:`Engine.adopt_jits`), so a fleet compiles each hot path once.  The
cluster exposes an engine-shaped surface over *global* slot ids
(``replica * slots_per_replica + local_slot``) — the scheduler
(:class:`repro.sched.scheduler.ClusterScheduler`) drives it exactly like an
engine, plus the placement axis.

The cluster is single-process: replicas are separate device buffers in one
address space, so the hop-chain leg of a migration plan is executed as the
priced route (``local_fabric`` mode) while the gather/scatter legs carry
the bytes.  The same plan executes a real ``ppermute`` chain under
``shard_map`` on a multi-device mesh (pinned by tests/test_cluster.py's
forced-host 4-device test).
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import movement as MV
from repro.configs.base import ModelConfig
from repro.core.dram.spec import DDR3_1600, DramSpec
from repro.core.dram.villa import VillaConfig
from repro.core.lisa.topology import MeshTopology
from repro.faults.inject import install_fault_backends
from repro.faults.spec import NULL_FAULT, FaultInjector
from repro.serve.engine import Engine, EngineFull, Request, UnknownSession


class Cluster:
    """N identically-configured :class:`Engine` replicas on a mesh ring."""

    def __init__(self, cfg: ModelConfig, params, *, n_replicas: int,
                 slots: int = 4, max_len: int = 128, n_sessions: int = 64,
                 villa: Optional[VillaConfig] = None,
                 spec: DramSpec = DDR3_1600,
                 topo: Optional[MeshTopology] = None, axis: str = "replica",
                 faults: Optional[FaultInjector] = None):
        if n_replicas < 1:
            raise ValueError(f"need at least one replica (got {n_replicas})")
        # Chaos mode: interpose the fault wrappers BEFORE any jitted body
        # traces, so migration waves honor their traced ``fault`` operand.
        # Without an injector the same bodies run with NULL_FAULT — one
        # compilation serves clean and chaos runs alike.
        self.faults = faults
        if faults is not None:
            install_fault_backends()
        self.cfg = cfg
        self.n_replicas = n_replicas
        self.slots_per_replica = slots
        self.slots = n_replicas * slots
        self.max_len = max_len
        self.spec = spec
        self.axis = axis
        self.topo = topo or MeshTopology(n_replicas)
        if self.topo.size != n_replicas:
            raise ValueError(f"topology size {self.topo.size} != "
                             f"n_replicas {n_replicas}")
        self.replicas: List[Engine] = []
        for r in range(n_replicas):
            eng = Engine(cfg, params, slots=slots, max_len=max_len,
                         n_sessions=n_sessions, villa=villa, spec=spec,
                         replica_id=r)
            if self.replicas:
                # one compile serves the whole fleet
                eng.adopt_jits(self.replicas[0])
            self.replicas.append(eng)
        e0 = self.replicas[0]
        self.villa_cfg = e0.villa_cfg
        self.page_spec = e0.page_spec
        self.n_sessions = e0.n_sessions
        self.plan_suspend = e0.plan_suspend
        self.plan_resume = e0.plan_resume
        self.snapshot_bytes = e0.snapshot_bytes
        # uid -> replica whose slow pool holds the suspended snapshot
        self.residence: Dict[int, int] = {}
        self.cluster_stats = {"migrations": 0, "migration_waves": 0,
                              "migrated_bytes": 0,
                              "modeled_migration_ns_lisa": 0.0,
                              "modeled_migration_ns_memcpy": 0.0,
                              "migration_retries": 0, "replica_failures": 0,
                              "retry_ns_lisa": 0.0, "retry_ns_memcpy": 0.0,
                              "retry_backoff_ns": 0.0,
                              "fork_materializations": 0}
        self._route_plans: Dict[Tuple, MV.MovementPlan] = {}
        self._migrate_exec = None       # built lazily (n_replicas > 1 only)
        self._fault_events: List[Dict[str, object]] = []
        self.tracer = None              # set by attach_tracer (repro.obs)

    # ---- global slot ids ---------------------------------------------------
    def _gslot(self, replica: int, slot: int) -> int:
        return replica * self.slots_per_replica + slot

    def replica_of(self, gslot: int) -> int:
        return gslot // self.slots_per_replica

    def _local(self, gslot: int) -> int:
        return gslot % self.slots_per_replica

    # ---- engine-shaped aggregate views --------------------------------------
    @property
    def active(self) -> Dict[int, Request]:
        out: Dict[int, Request] = {}
        for r, eng in enumerate(self.replicas):
            for s, req in eng.active.items():
                out[self._gslot(r, s)] = req
        return out

    @property
    def session_pos(self) -> Dict[int, int]:
        return {uid: self.replicas[r].session_pos[uid]
                for uid, r in self.residence.items()
                if uid in self.replicas[r].session_pos}

    def free_slots(self) -> List[int]:
        return [self._gslot(r, s) for r, eng in enumerate(self.replicas)
                for s in eng.free_slots()]

    def free_by_replica(self) -> List[int]:
        return [len(eng.free_slots()) for eng in self.replicas]

    @property
    def stats(self) -> Dict[str, object]:
        out: Dict[str, object] = dict(self.cluster_stats)
        for eng in self.replicas:
            for k, v in eng.stats.items():
                out[k] = out.get(k, 0) + v
        return out

    def attach_tracer(self, tracer) -> None:
        """Attach a :class:`repro.obs.Tracer` fleet-wide: replica ``r``'s
        session lifecycle events land on trace lane ``1 + r`` (the
        scheduler's per-replica lane convention)."""
        self.tracer = tracer
        for r, eng in enumerate(self.replicas):
            eng.attach_tracer(tracer, lane=1 + r)

    def fast_resident_uids(self) -> frozenset:
        out: set = set()
        for eng in self.replicas:
            out |= eng.fast_resident_uids()
        return frozenset(out)

    def fast_occupancy(self) -> List[float]:
        """Per-replica VILLA fast-tier occupancy (fraction of fast slots
        holding a live snapshot) — a placement signal: an overfull fast
        tier means inbound sessions will resume at slow-tier timings."""
        out = []
        for eng in self.replicas:
            tags = np.asarray(eng.sessions.policy.tags)
            live = sum(1 for t in tags if t >= 0 and int(t) in eng.store_uid)
            out.append(live / max(len(tags), 1))
        return out

    def hit_rate(self) -> float:
        hits = sum(int(eng.sessions.hits) for eng in self.replicas)
        acc = sum(int(eng.sessions.accesses) for eng in self.replicas)
        return hits / acc if acc else 0.0

    def compile_counts(self) -> Dict[str, int]:
        out = self.replicas[0].compile_counts()     # jits are fleet-shared
        fn = self._migrate_exec
        out["migrate"] = (fn._cache_size()
                         if fn is not None and hasattr(fn, "_cache_size")
                         else (0 if fn is None else -1))
        return out

    # ---- decode ------------------------------------------------------------
    def step_begin(self):
        """ONE fused decode dispatch per replica with live work (issued
        async, back to back — the replicas decode in parallel)."""
        handles = [eng.step_begin() for eng in self.replicas]
        return None if all(h is None for h in handles) else handles

    def step_end(self, handles) -> List[Tuple[int, Request]]:
        if handles is None:
            return []
        completed: List[Tuple[int, Request]] = []
        for r, (eng, h) in enumerate(zip(self.replicas, handles)):
            for s, req in eng.step_end(h):
                self.residence[req.uid] = r      # auto-suspended here
                completed.append((self._gslot(r, s), req))
        return completed

    def step(self) -> List[Tuple[int, Request]]:
        return self.step_end(self.step_begin())

    # ---- admission / suspension ---------------------------------------------
    def submit(self, req: Request, replica: Optional[int] = None) -> int:
        """Prefill-admit a fresh request onto ``replica`` (the scheduler's
        placement decision; default = first replica with a free slot)."""
        if replica is None:
            replica = next((r for r, eng in enumerate(self.replicas)
                            if eng.free_slots()), None)
            if replica is None:
                raise EngineFull(f"all {self.slots} cluster slots busy")
        eng = self.replicas[replica]
        slot = eng.submit(req)
        if slot not in eng.active:               # completed at prefill
            self.residence[req.uid] = replica
        return self._gslot(replica, slot)

    def suspend(self, gslot: int) -> None:
        self.suspend_many([gslot])

    def suspend_many(self, gslots: Sequence[int]) -> None:
        """Suspend a wave of global slots: grouped by replica, ONE fused
        dispatch per replica involved (never one per session)."""
        by_rep: Dict[int, List[int]] = {}
        for g in gslots:
            by_rep.setdefault(self.replica_of(g), []).append(self._local(g))
        for r, slots in by_rep.items():
            eng = self.replicas[r]
            uids = [eng.active[s].uid for s in slots]
            if len(slots) == 1:
                eng.suspend(slots[0])
            else:
                eng.suspend_many(slots)
            for uid in uids:
                self.residence[uid] = r

    # ---- resume (with implicit migration) ------------------------------------
    def resume(self, uid: int, extra_new: int,
               replica: Optional[int] = None) -> int:
        return self.resume_many([uid], extra_new,
                                None if replica is None else [replica])[0]

    def resume_many(self, uids: Sequence[int], extra_new,
                    replicas: Optional[Sequence[int]] = None) -> List[int]:
        """Resume a wave of sessions, each on its target replica (default:
        where it resides).  Sessions whose target differs from their
        residence are MIGRATED first — grouped by route, one hop-chain
        plan dispatch per route — then each replica's resumes run as one
        fused ``resume_many`` wave.  Returns global slots in input order."""
        if not uids:
            return []
        extras = ([int(extra_new)] * len(uids)
                  if isinstance(extra_new, (int, np.integer))
                  else [int(e) for e in extra_new])
        if len(extras) != len(uids):
            raise ValueError(f"extra_new sequence has {len(extras)} entries "
                             f"for {len(uids)} uids")
        targets = (list(replicas) if replicas is not None
                   else [self._home(u) for u in uids])
        if len(targets) != len(uids):
            raise ValueError(f"replicas sequence has {len(targets)} entries "
                             f"for {len(uids)} uids")
        moves = [(u, t) for u, t in zip(uids, targets)
                 if self._home(u) != t]
        if moves:
            self.migrate_many(moves)
        by_rep: Dict[int, List[int]] = {}
        for i, t in enumerate(targets):
            by_rep.setdefault(t, []).append(i)
        gslots = [0] * len(uids)
        for r, idxs in by_rep.items():
            eng = self.replicas[r]
            slots = eng.resume_many([uids[i] for i in idxs],
                                    [extras[i] for i in idxs])
            for i, s in zip(idxs, slots):
                gslots[i] = self._gslot(r, s)
        return gslots

    def _home(self, uid: int) -> int:
        if uid not in self.residence:
            raise UnknownSession(
                f"uid {uid} has no suspended session on any replica")
        return self.residence[uid]

    # ---- live migration -------------------------------------------------------
    def migration_plan(self, src: int, dst: int,
                       k: int = 1) -> MV.MovementPlan:
        """The priced route plan for ``k`` sessions moving src -> dst:
        page gather -> mesh hop chain -> page scatter, ONE copy under the
        Table-1 model (the hop leg carries the payload at ICI pricing; the
        memcpy alternative is the two-leg PCIe host path)."""
        key = (src, dst, k)
        if key not in self._route_plans:
            self._route_plans[key] = MV.plan(
                MV.Transfer(MV.Tier("slow", index=src, axis=self.axis),
                            MV.Tier("slow", index=dst, axis=self.axis),
                            MV.Layout.pages(self.page_spec, batch=k)),
                self.spec, topo=self.topo)
        return self._route_plans[key]

    def hop_ns(self, src: int, dst: int, mechanism: str = "lisa") -> float:
        """Modeled one-session migration latency over the src->dst route
        under ``mechanism`` — the scheduler's placement-cost input."""
        if src == dst:
            return 0.0
        c = self.migration_plan(src, dst).cost
        return c.ns_lisa if mechanism == "lisa" else c.ns_memcpy

    def _build_migrate_exec(self):
        """The jitted route executor, shared by every route: gather the
        sessions' pages out of the source pool, scatter them into the
        destination pool (donated).  The hop-chain leg between them is the
        priced mesh route (identity in single-process ``local_fabric``
        mode); routes differ only in pricing, so ONE compilation per wave
        width serves every route."""
        exec_plan = self.migration_plan(0, 1 % self.n_replicas)
        P, d = self.page_spec.page_rows, self.page_spec.page_lanes

        @partial(jax.jit, donate_argnums=(1,))
        def body(src_slow, dst_slow, src_table, dst_table, fault):
            # ``fault`` is the traced (mode, index, xor) chaos operand —
            # NULL_FAULT on clean runs — consumed by the fault-wrapped
            # hop-chain backend when chaos mode installed the wrappers, and
            # simply unused otherwise: one compilation either way.
            env = MV.execute(exec_plan,
                             src_pool=src_slow.reshape(-1, P, d),
                             src_table=src_table,
                             dst_pool=dst_slow.reshape(-1, P, d),
                             dst_table=dst_table, local_fabric=True,
                             fault=fault)
            return env["dst_pool"].reshape(dst_slow.shape)

        return body

    def migrate(self, uid: int, dst: int) -> None:
        self.migrate_many([(uid, dst)])

    def migrate_many(self, moves: Sequence[Tuple[int, int]]) -> None:
        """Migrate a burst of suspended sessions, each ``(uid, dst_replica)``.

        Sessions are grouped by (src, dst) route; each route executes as
        ONE jitted page gather+scatter over a fused page table (the wave
        idiom of ``suspend_many``/``resume_many``), priced by one hop-chain
        plan of batch k.  Bit-exact and loss-free: uint8 pages plus the
        host bookkeeping (position, seed token) move together."""
        if not moves:
            return
        uids = [u for u, _ in moves]
        if len(set(uids)) != len(uids):
            raise ValueError(f"duplicate uids in migration wave: {uids}")
        active_uids = {r.uid for r in self.active.values()}
        routes: Dict[Tuple[int, int], List[int]] = {}
        for uid, dst in moves:
            if not 0 <= dst < self.n_replicas:
                raise ValueError(f"unknown destination replica {dst}")
            if uid in active_uids:
                raise ValueError(f"uid {uid} is active; suspend it before "
                                 f"migrating its session")
            src = self._home(uid)
            if src == dst:
                raise ValueError(f"uid {uid} already resides on replica "
                                 f"{dst}; migration needs a real route")
            routes.setdefault((src, dst), []).append(uid)
        if self._migrate_exec is None:
            self._migrate_exec = self._build_migrate_exec()

        spp = self.page_spec.n_pages
        page_bytes = self.page_spec.page_bytes
        arange = np.arange(spp, dtype=np.int32)
        for (src, dst), route_uids in routes.items():
            s_eng, d_eng = self.replicas[src], self.replicas[dst]
            metas = [s_eng.session_meta(u) for u in route_uids]
            src_idx = [s_eng.drop_session(u) for u in route_uids]
            dst_idx = [d_eng.adopt_session(u, p, t)
                       for u, (p, t) in zip(route_uids, metas)]
            self._invalidate_fast(d_eng, dst_idx)
            src_table = jnp.asarray(
                np.concatenate([i * spp + arange for i in src_idx]))
            dst_table = jnp.asarray(
                np.concatenate([i * spp + arange for i in dst_idx]))
            k = len(route_uids)

            def run_route(dst_slow, fault):
                with warnings.catch_warnings():
                    warnings.filterwarnings(
                        "ignore",
                        message="Some donated buffers were not usable")
                    return self._migrate_exec(
                        s_eng.sessions.slow, dst_slow, src_table, dst_table,
                        jnp.asarray(fault))

            inj = self.faults
            fault = (inj.draw_movement(k * spp * page_bytes, k * spp)
                     if inj is not None else NULL_FAULT)
            new_slow = run_route(d_eng.sessions.slow, fault)
            if inj is not None and int(fault[0]) != 0:
                # The injector KNOWS it corrupted this wave (host-
                # deterministic — no mid-loop device read needed): retry the
                # whole route from the intact source pages, each retry a
                # fresh draw, bounded by max_retries with exponential
                # backoff.  The retries and backoff are real latency —
                # the scheduler prices them into the virtual clock via
                # drain_fault_events().
                cost1 = self.migration_plan(src, dst, k).cost
                retries, backoff_total = 0, 0.0
                while (inj.spec.recover and int(fault[0]) != 0
                       and retries < inj.spec.max_retries):
                    retries += 1
                    inj.counters["retries"] += 1
                    backoff_total += inj.backoff_ns(retries)
                    fault = inj.draw_movement(k * spp * page_bytes, k * spp)
                    new_slow = run_route(new_slow, fault)
                corrupt_uid = None
                if int(fault[0]) != 0:          # landed corrupt (no/lost
                    mode, index = int(fault[0]), int(fault[1])  # recovery)
                    page = (index // page_bytes if mode == 1 else index)
                    corrupt_uid = route_uids[min(page // spp, k - 1)]
                    inj.note_corrupt(corrupt_uid)
                elif retries:
                    inj.counters["retry_fixed"] += 1
                self.cluster_stats["migration_retries"] += retries
                self.cluster_stats["retry_ns_lisa"] += (
                    retries * cost1.ns_lisa)
                self.cluster_stats["retry_ns_memcpy"] += (
                    retries * cost1.ns_memcpy)
                self.cluster_stats["retry_backoff_ns"] += backoff_total
                self._fault_events.append({
                    "kind": "migration", "src": src, "dst": dst, "k": k,
                    "retries": retries, "backoff_ns": backoff_total,
                    "corrupt_uid": corrupt_uid,
                    "uids": tuple(route_uids)})
            d_eng.sessions = d_eng.sessions._replace(slow=new_slow)
            # the checksum sidecar rows travel with the pages — computed at
            # suspend time on the SOURCE, so corruption in flight is exactly
            # what the destination's resume-time verify will catch
            d_eng.session_sums = d_eng.session_sums.at[
                jnp.asarray(dst_idx)].set(
                    s_eng.session_sums[jnp.asarray(src_idx)])
            for uid in route_uids:
                self.residence[uid] = dst
            cost = self.migration_plan(src, dst, k).cost
            self.cluster_stats["migrations"] += k
            self.cluster_stats["migration_waves"] += 1
            self.cluster_stats["migrated_bytes"] += cost.bytes
            self.cluster_stats["modeled_migration_ns_lisa"] += cost.ns_lisa
            self.cluster_stats["modeled_migration_ns_memcpy"] += (
                cost.ns_memcpy)

    # ---- zero-copy forking (cluster semantics) -------------------------------
    def fork(self, parent_uid: int, child_uid: int,
             replica: Optional[int] = None,
             seed_token: Optional[int] = None) -> None:
        """Fork ``child_uid`` off a suspended parent.

        Same replica (default): a zero-copy ALIAS fork — the child
        refcounts the parent's physical row on that replica's fork table,
        zero device dispatches (``Engine.fork``).

        Different replica: the alias cannot span pools (refcounts are
        per-replica), so the fork MATERIALIZES — the parent's snapshot row
        is copied over the existing priced migration route (page gather ->
        mesh hop chain -> page scatter, ONE dispatch) into an exclusive row
        on the destination; the parent and its refcounts are untouched.
        The copy is drawn with NULL_FAULT deliberately: materialization is
        a fresh admission, not an in-flight session move — chaos targets
        migrations of live state, and a corrupted fork would be detected at
        the child's first resume anyway (the checksum sidecar travels).
        """
        src = self._home(parent_uid)
        dst = src if replica is None else replica
        if not 0 <= dst < self.n_replicas:
            raise ValueError(f"unknown replica {dst}")
        if child_uid in self.residence or any(
                r.uid == child_uid for r in self.active.values()):
            raise ValueError(f"child uid {child_uid} already in use")
        if dst == src:
            self.replicas[src].fork(parent_uid, child_uid, seed_token)
            self.residence[child_uid] = src
            return
        s_eng, d_eng = self.replicas[src], self.replicas[dst]
        pos, tok = s_eng.session_meta(parent_uid)
        if parent_uid in {r.uid for r in self.active.values()}:
            raise ValueError(f"parent uid {parent_uid} is active; suspend "
                             f"it before forking")
        src_phys = s_eng.forks.resolve(parent_uid)
        seed = tok if seed_token is None else int(seed_token)
        dst_idx = d_eng.adopt_session(child_uid, pos, seed)
        self._invalidate_fast(d_eng, [dst_idx])
        if self._migrate_exec is None:
            self._migrate_exec = self._build_migrate_exec()
        spp = self.page_spec.n_pages
        arange = np.arange(spp, dtype=np.int32)
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            new_slow = self._migrate_exec(
                s_eng.sessions.slow, d_eng.sessions.slow,
                jnp.asarray(src_phys * spp + arange),
                jnp.asarray(dst_idx * spp + arange),
                jnp.asarray(NULL_FAULT))
        d_eng.sessions = d_eng.sessions._replace(slow=new_slow)
        d_eng.session_sums = d_eng.session_sums.at[dst_idx].set(
            s_eng.session_sums[src_phys])
        self.residence[child_uid] = dst
        cost = self._fork_route_plan(src, dst).cost
        self.cluster_stats["fork_materializations"] += 1
        self.cluster_stats["migrated_bytes"] += cost.bytes
        self.cluster_stats["modeled_migration_ns_lisa"] += cost.ns_lisa
        self.cluster_stats["modeled_migration_ns_memcpy"] += cost.ns_memcpy

    def _fork_route_plan(self, src: int, dst: int) -> MV.MovementPlan:
        """The priced cross-replica ``fork``-kind plan (gather -> hop chain
        -> scatter: a materialization is a real copy, priced like the
        migration route it rides)."""
        key = ("fork", src, dst)
        if key not in self._route_plans:
            self._route_plans[key] = MV.plan(
                MV.Transfer(MV.Tier("slow", index=src, axis=self.axis),
                            MV.Tier("slow", index=dst, axis=self.axis),
                            MV.Layout.pages(self.page_spec), kind="fork"),
                self.spec, topo=self.topo)
        return self._route_plans[key]

    def shared_uids(self) -> frozenset:
        """Fleet union of per-replica shared uids (fork-aware scheduling
        input: worst victims, preferred placements)."""
        out: set = set()
        for eng in self.replicas:
            out |= eng.shared_uids()
        return frozenset(out)

    def drain_fault_events(self) -> List[Dict[str, object]]:
        """Hand the scheduler the chaos events since the last drain (retry
        latency to charge, corrupt sessions to repair or write off)."""
        out, self._fault_events = self._fault_events, []
        return out

    # ---- chaos surface ------------------------------------------------------
    def fail_replica(self, r: int) -> Tuple[List[Tuple[int, Request]],
                                            Dict[int, Tuple[int, int]]]:
        """Chaos: replica ``r`` dies.  Its slots, fast-tier tags and
        in-flight sessions are gone; its suspended snapshots are
        unreachable.  Returns what the scheduler needs for recovery:
        the ``(gslot, request)`` pairs that were in flight, and the
        ``{uid: (pos, tok)}`` bookkeeping of the suspended sessions that
        died with the pools.  The replica itself restarts empty (capacity
        returns; state does not) — re-admission goes through snapshots or
        re-prefill, never through the lost buffers."""
        if not 0 <= r < self.n_replicas:
            raise ValueError(f"unknown replica {r}")
        eng = self.replicas[r]
        inflight = [(self._gslot(r, s), eng.active[s])
                    for s in sorted(eng.active)]
        suspended = {uid: (eng.session_pos[uid], eng.session_tok[uid])
                     for uid in sorted(eng.session_pos)}
        eng.active.clear()
        eng.session_pos.clear()
        eng.session_tok.clear()
        eng.store_uid.clear()
        eng.forks.clear()       # aliases died with the rows they shared
        st = eng.sessions
        eng.sessions = st._replace(policy=st.policy._replace(
            tags=jnp.full_like(st.policy.tags, -1)))
        for uid in [u for u, home in self.residence.items() if home == r]:
            del self.residence[uid]
        self.cluster_stats["replica_failures"] += 1
        return inflight, suspended

    def degrade_fast(self, r: int) -> None:
        """Chaos: replica ``r``'s VILLA fast tier degrades to slow-only
        (pricing reroutes; data-path correctness is untouched)."""
        self.replicas[r].degrade_fast()

    def verify_failure_count(self) -> int:
        """Fleet total of the device-side resume-verify counters (one
        explicit sync per replica — bench/test surface, not the tick
        loop)."""
        return sum(eng.verify_failure_count() for eng in self.replicas)

    def scrub(self) -> int:
        """End-of-run audit: device-side checksum scrub of every live
        suspended snapshot across the fleet; returns the corrupt-session
        count."""
        return sum(int(eng.verify_store()) for eng in self.replicas)

    @staticmethod
    def _invalidate_fast(eng: Engine, idxs: Sequence[int]) -> None:
        """Drop stale fast-tier residency for store indices an inbound
        migration is about to overwrite.  A local suspend writes through to
        both pools, but a migration scatters into the slow pool only — a
        fast slot still tagged with the (evicted) index would serve the
        OLD session's bytes on the next resume."""
        tags = np.asarray(eng.sessions.policy.tags)
        stale = [i for i, t in enumerate(tags) if int(t) in idxs]
        if stale:
            policy = eng.sessions.policy._replace(
                tags=eng.sessions.policy.tags.at[np.asarray(stale)].set(-1))
            eng.sessions = eng.sessions._replace(policy=policy)
