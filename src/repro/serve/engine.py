"""Continuous-batching decode engine with LISA-VILLA session caching.

The serving data path is device-resident (the serving-layer analogue of the
paper's "move data over wide internal paths, not the narrow channel"):

  * ``step`` — ONE jitted dispatch and ONE device→host transfer per decode
    step, regardless of how ragged the slot positions are: per-slot positions
    and the active mask are traced data (``models/lm.decode_step_batched``),
    greedy sampling runs in-graph, and the KV cache is donated so XLA updates
    it in place instead of copying it every token.
  * suspend / resume — planned movement: each is a ``movement.Transfer``
    between the compute tier and the VILLA slow tier, lowered once at engine
    construction by ``movement.plan`` into pack + tier legs and executed
    inside the jitted bodies by ``movement.execute``.  Snapshots live as
    dtype-preserving uint8 *pages* (``serve/paged_store``); the tier legs
    run the paper's exact promotion policy and move pages through the Pallas
    RBM kernels (scalar-prefetched page tables, LIP double buffering).
    ``resume_many`` executes ONE fused wave plan (``movement.fuse``) — a
    whole burst of resumes is still a single dispatch.
  * prefill — lengths are bucketed (next power of two) where the architecture
    permits, bounding compilation count; pads carry sentinel positions so
    they stay causally invisible forever.

The movement is also *accounted*: every plan carries a ``MovementCost``
priced by the engine's :class:`~repro.core.dram.spec.DramSpec` under the
``lisa`` vs ``memcpy`` mechanisms, and each suspend/resume charges its
plan's cost — the serving-level view of Table 1's gap.

Pure-JAX state; greedy sampling; CPU-runnable at reduced configs.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import movement as MV
from repro.configs.base import ModelConfig
from repro.core.dram.spec import DDR3_1600, DramSpec
from repro.core.dram.villa import VillaConfig
from repro.core.lisa import villa_cache as VC
from repro.models import lm
from repro.serve import paged_store as PS

POS_SENTINEL = 2**30     # matches the cache init sentinel in models/lm.py


def _quiet(fn, *args):
    """Run one donated-buffer dispatch without the CPU backend's 'donated
    buffers were not usable' warning (CPU XLA cannot honor donation; the
    hint is still correct on TPU).  Scoped per call so other code keeps the
    diagnostic for its own donation mistakes."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return fn(*args)


class EngineFull(RuntimeError):
    """No free slot: the caller should drain a slot (or queue) and retry."""


class UnknownSession(KeyError):
    """resume() of a uid that was never suspended (or has been evicted)."""


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    generated: Optional[List[int]] = None
    # scheduling metadata: when the request entered the system, its priority
    # class (0 = most urgent) and its latency SLO.  Round-trips through
    # repro.sched — `Scheduler.submit_request` admits by these fields, and
    # scheduler-placed requests carry them back out.  Defaults make plain
    # engine use unchanged.
    arrival_ns: float = 0.0
    priority: int = 0
    slo_ns: float = float("inf")


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 128, n_sessions: int = 64,
                 villa: Optional[VillaConfig] = None,
                 spec: DramSpec = DDR3_1600, replica_id: int = 0):
        self.cfg = cfg
        self.params = params
        self.spec = spec
        self.slots = slots
        self.max_len = max_len
        self.n_sessions = n_sessions
        # which replica of a serving fleet this engine is (0 for standalone
        # use); the cluster layer (serve/cluster.py) keys session residence
        # and migration routes on it
        self.replica_id = replica_id
        self.active: Dict[int, Request] = {}        # slot -> request
        self.pos = np.zeros(slots, np.int32)

        self.cache = lm.init_cache(cfg, slots, max_len=max_len)
        # ONE jitted decode for the whole ragged batch; the cache buffer is
        # donated — XLA writes the new KV in place instead of copying it.
        self._decode = jax.jit(partial(lm.decode_step_batched, cfg),
                               donate_argnums=(1,))
        self._decode_legacy = None      # built on first step_unbatched()

        # Prefill-length bucketing is sound when every layer's cache slot for
        # token t is position-addressed (full attention / MLA): right-padded
        # tokens carry sentinel positions and stay causally invisible, and
        # later decodes overwrite exactly the pad slots.  Ring-buffer windows,
        # scan states (mamba/rwkv), enc-dec and m-rope address by array index
        # or consume pads statefully — those fall back to exact lengths.
        self._can_bucket = (not cfg.encdec and not cfg.mrope and
                            all(k in ("attn_full", "mla")
                                for k in cfg.layer_kinds()))
        self._prefill = jax.jit(self._prefill_insert, donate_argnums=(1,))

        # Session store: suspended KV snapshots as dtype-preserving uint8
        # pages in a VILLA tiered store (movement via the RBM page kernels).
        self.page_spec = PS.PageSpec.for_cache(self.cache)
        self.villa_cfg = villa or VillaConfig(
            n_counters=n_sessions, n_hot=max(n_sessions // 4, 2),
            n_slots=max(n_sessions // 4, 2), epoch_len=8)
        self.sessions = PS.make_session_store(self.page_spec, n_sessions,
                                              self.villa_cfg)
        self.session_pos: Dict[int, int] = {}       # uid -> next position
        self.session_tok: Dict[int, int] = {}       # uid -> last emitted token
        self.store_uid: Dict[int, int] = {}         # phys row -> owner uid
        # CoW alias ledger (repro/fork): logical uids -> physical store
        # rows, refcounted.  Forked sessions alias ONE row until a writer
        # diverges; all alias mutation goes through its API (the
        # `unrefcounted-alias` lint rule).  store_uid tracks one
        # representative owner per physical row (the last writer).
        self.forks = PS.make_fork_table()
        # Detection sidecar: per-page checksums of every suspended snapshot,
        # written by the pack leg at suspend time and verified at unpack on
        # resume.  ``verify_failed`` accumulates ON DEVICE — the verdict
        # rides whichever sync a caller already performs (bench/test
        # surface), never adding one to the tick loop.
        self.session_sums = jnp.zeros(
            (n_sessions, self.page_spec.n_pages), jnp.uint32)
        self.verify_failed = jnp.zeros((), jnp.int32)
        self.fast_degraded = False
        self._suspend = jax.jit(self._suspend_fn, donate_argnums=(1, 2))
        self._suspend_many = jax.jit(self._suspend_many_fn,
                                     donate_argnums=(1, 2))
        self._resume = jax.jit(self._resume_fn, donate_argnums=(0, 1, 3))
        self._resume_many = jax.jit(self._resume_many_fn,
                                    donate_argnums=(0, 1, 3))
        # shared-row demotion: device-clone one slow row (store + checksum
        # sidecar) so a shared snapshot can yield its index without being
        # destroyed
        self._clone = jax.jit(self._clone_fn, donate_argnums=(0, 1))

        # Every suspend/resume is a planned movement between the compute
        # tier and the VILLA slow tier, lowered ONCE here against the spec;
        # the jitted bodies execute the plans, and each call charges its
        # plan's modeled MovementCost (lisa hop chain vs channel memcpy).
        _layout = MV.Layout.pages(self.page_spec)
        self.plan_suspend = MV.plan(MV.Transfer(
            MV.Tier("compute"), MV.Tier("slow"), _layout,
            policy=self.villa_cfg), spec)
        self.plan_resume = MV.plan(MV.Transfer(
            MV.Tier("slow"), MV.Tier("compute"), _layout,
            policy=self.villa_cfg), spec)
        # Fork fast path: a same-replica ``fork``-kind transfer lowers to
        # ONE page_alias leg — host bookkeeping priced as RowClone FPM on
        # the lisa arm vs the per-session copy it avoids on the memcpy arm
        # (cost.bytes = bytes NOT copied).  A shared-row demotion moves one
        # row's real bytes within the pool, priced under the same alias
        # mechanism (an in-subarray RowClone of one row).
        self.plan_fork = MV.plan(MV.Transfer(
            MV.Tier("slow"), MV.Tier("slow"), _layout, kind="fork"), spec)
        self.plan_demote = self.plan_fork
        self._wave_plans: Dict[tuple, MV.MovementPlan] = {}
        self.snapshot_bytes = self.page_spec.total_bytes
        self.stats = {"decoded_tokens": 0, "prefills": 0, "suspends": 0,
                      "resumes": 0,
                      "decode_dispatches": 0, "host_transfers": 0,
                      "evictions": 0, "demotions": 0,
                      "forks": 0, "bytes_not_copied": 0,
                      "modeled_move_ns_lisa": 0.0,
                      "modeled_move_ns_memcpy": 0.0}
        # observability (repro.obs): fork/CoW/demotion/eviction events are
        # marked as trace instants when a tracer is attached — host
        # bookkeeping only, zero device dispatches
        self.tracer = None
        self.trace_lane = 0

    def attach_tracer(self, tracer, lane: Optional[int] = None) -> None:
        """Attach a :class:`repro.obs.Tracer`; session lifecycle events
        (fork / demotion / eviction) become instants on ``lane`` (the
        scheduler's replica lane convention: ``1 + replica_id``; the
        single-engine scheduler passes nothing and events share lane 0)."""
        self.tracer = tracer
        self.trace_lane = lane if lane is not None else 0

    # ---- jitted bodies (traced slot/store indices; donated buffers) -------
    def _prefill_insert(self, params, cache, tokens, positions, true_len,
                        slot):
        """Prefill one request and insert its KV into ``slot``: one dispatch,
        returns (next_token scalar, cache).  ``tokens`` may be right-padded
        to a bucket length; pads carry sentinel positions."""
        cache1 = lm.init_cache(self.cfg, 1, max_len=self.max_len)
        logits, cache1 = lm.prefill(self.cfg, params, tokens, cache1,
                                    positions=positions)
        nxt = jnp.argmax(logits[0, true_len - 1]).astype(jnp.int32)
        cache = jax.tree.map(
            lambda full, p: jax.lax.dynamic_update_slice_in_dim(
                full, p.astype(full.dtype), slot, axis=1), cache, cache1)
        return nxt, cache

    def _suspend_fn(self, cache, store, sums, slot, idx):
        env = MV.execute(self.plan_suspend, cache=cache, slot=slot,
                         store=store, item=idx)
        # the pack leg emitted per-page checksums; persist them in the
        # sidecar row for this store index (donated: updated in place)
        return env["store"], sums.at[idx].set(env["sums"])

    def _resume_fn(self, cache, store, sums, failed, slot, idx):
        env = MV.execute(self.plan_resume, cache=cache, store=store,
                         slot=slot, item=idx, sums=sums[idx])
        return env["cache"], env["store"], failed + env["verify_fail"]

    def _wave_plan(self, single: MV.MovementPlan, k: int) -> MV.MovementPlan:
        """A whole wave as ONE fused plan (k identical transfers -> one
        vmapped pack / batched tier access / scanned unpack: one
        dispatch)."""
        key = (id(single), k)
        if key not in self._wave_plans:
            self._wave_plans[key] = MV.fuse([single] * k)
        return self._wave_plans[key]

    def _suspend_many_fn(self, cache, store, sums, slots, idxs):
        env = MV.execute(self._wave_plan(self.plan_suspend, slots.shape[0]),
                         cache=cache, slots=slots, store=store, items=idxs)
        return env["store"], sums.at[idxs].set(env["sums"])

    def _resume_many_fn(self, cache, store, sums, failed, slots, idxs):
        env = MV.execute(self._wave_plan(self.plan_resume, slots.shape[0]),
                         cache=cache, store=store, slots=slots, items=idxs,
                         sums=sums[idxs])
        return env["cache"], env["store"], failed + env["verify_fail"]

    def _clone_fn(self, store, sums, src, dst):
        """Shared-row demotion body: clone slow row src -> dst (pages AND
        checksum sidecar) in one dispatch; the fork table repoints the
        aliases right after."""
        return (VC.clone_item(store, src, dst),
                sums.at[dst].set(sums[src]))

    # ---- scheduling -------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def _take_slot(self) -> int:
        free = self.free_slots()
        if not free:
            raise EngineFull(
                f"all {self.slots} slots busy; suspend or finish a request "
                f"first (active uids: {[r.uid for r in self.active.values()]})")
        return free[0]

    def _bucket_len(self, n: int) -> int:
        if not self._can_bucket:
            return n
        return min(max(16, 1 << (n - 1).bit_length()), self.max_len)

    def submit(self, req: Request) -> int:
        slot = self._take_slot()
        n = len(req.prompt)
        if n > self.max_len:
            raise ValueError(f"prompt length {n} exceeds max_len={self.max_len}")
        req.generated = []
        lb = self._bucket_len(n)
        toks = np.zeros(lb, np.int32)
        toks[:n] = req.prompt
        if self.cfg.mrope:      # (3,B,S) layout — unbucketed, default arange
            positions = None
        else:
            pos_arr = np.full(lb, POS_SENTINEL, np.int32)
            pos_arr[:n] = np.arange(n)
            positions = jnp.asarray(pos_arr)[None]
        nxt, self.cache = _quiet(
            self._prefill, self.params, self.cache, jnp.asarray(toks)[None],
            positions, jnp.int32(n), jnp.int32(slot))
        req.generated.append(int(nxt))
        self.stats["prefills"] += 1
        self.active[slot] = req
        self.pos[slot] = n
        if len(req.generated) >= req.max_new:
            # a max_new=1 request is completed by the prefill token itself —
            # suspend now instead of letting the next step_end overshoot the
            # budget by one decoded token
            self.suspend(slot)
        return slot

    def step_begin(self):
        """Issue the tick's ONE fused decode dispatch and return the
        in-flight device handle (None when idle).  The dispatch is async:
        the host is free to plan the next scheduling wave while the device
        decodes — the serving analogue of LISA-LIP's linked precharge
        (:mod:`repro.sched.scheduler` overlaps exactly this way).  Pair
        with :meth:`step_end`."""
        if not self.active:
            return None
        toks = np.zeros(self.slots, np.int32)
        mask = np.zeros(self.slots, bool)
        for s, req in self.active.items():
            toks[s] = req.generated[-1]
            mask[s] = True
        nxt_dev, self.cache = _quiet(
            self._decode, self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.pos), jnp.asarray(mask))
        self.stats["decode_dispatches"] += 1
        return nxt_dev

    def step_end(self, handle) -> List:
        """Sync one in-flight decode (the tick's ONE device→host transfer),
        run token bookkeeping, and suspend completed requests — a burst
        completes as ONE fused ``suspend_many`` wave.  Returns the
        ``(slot, request)`` pairs that completed this step."""
        if handle is None:
            return []
        nxt = np.asarray(handle)                # the one device→host transfer
        self.stats["host_transfers"] += 1
        for s in self.active:
            self.active[s].generated.append(int(nxt[s]))
            self.pos[s] += 1
            self.stats["decoded_tokens"] += 1
        done = [s for s, req in self.active.items()
                if len(req.generated) >= req.max_new]
        completed = [(s, self.active[s]) for s in done]
        if len(done) == 1:
            self.suspend(done[0])
        elif done:                        # burst completion: ONE fused wave
            self.suspend_many(done)
        return completed

    def step(self) -> List:
        """Decode one token for every active slot: ONE jitted dispatch and
        ONE device→host transfer, however ragged the slot positions are.
        Equivalent to ``step_end(step_begin())`` with nothing overlapped."""
        return self.step_end(self.step_begin())

    def step_unbatched(self) -> None:
        """A/B-ONLY path — never serve production traffic with it.  Kept
        solely so benchmarks can compare against the pre-batching design:
        splits slots into uniform-position groups — one dispatch per group
        plus one sync per slot.  Equivalent to :meth:`step` ONLY at uniform
        positions: with ragged positions each group's cache write lands in
        every batch row and corrupts the other slots (the latent bug the
        active-mask path fixes).  The drift guard for the real path is
        tests/test_decode_consistency.py::
        test_batched_ragged_decode_parity_with_unbatched, which pins
        ``decode_step_batched`` at ragged positions to per-request
        ``decode_step`` truth (tokens AND cache state)."""
        if not self.active:
            return
        if self._decode_legacy is None:
            self._decode_legacy = jax.jit(partial(lm.decode_step, self.cfg))
        groups: Dict[int, List[int]] = {}
        for s in self.active:
            groups.setdefault(int(self.pos[s]), []).append(s)
        for pos, ss in groups.items():
            toks = np.zeros((self.slots, 1), np.int32)
            for s in ss:
                toks[s, 0] = self.active[s].generated[-1]
            logits, self.cache = self._decode_legacy(
                self.params, self.cache, jnp.asarray(toks), jnp.int32(pos))
            self.stats["decode_dispatches"] += 1
            for s in ss:
                nxt = int(jnp.argmax(logits[s, 0]))
                self.stats["host_transfers"] += 1
                self.active[s].generated.append(nxt)
                self.pos[s] += 1
                self.stats["decoded_tokens"] += 1
        for s, req in list(self.active.items()):
            if len(req.generated) >= req.max_new:
                self.suspend(s)

    def adopt_jits(self, other: "Engine") -> None:
        """Share ``other``'s jitted entry points and wave-plan cache.

        A replica fleet (serve/cluster.py) runs N engines with identical
        config and geometry; without sharing, each replica would recompile
        the same decode/prefill/suspend/resume programs.  After adoption
        every hot path compiles ONCE for the whole fleet — the serving-
        layer analogue of one shared row-buffer program driving many
        subarrays."""
        if (self.cfg is not other.cfg or self.slots != other.slots
                or self.max_len != other.max_len
                or self.n_sessions != other.n_sessions
                or self.page_spec != other.page_spec
                or self.villa_cfg != other.villa_cfg
                or self.spec != other.spec):
            raise ValueError(
                "adopt_jits needs an identically-configured engine (same "
                "cfg object, slots, max_len, n_sessions, page layout, "
                "villa config and DramSpec — the shared suspend/resume "
                "programs bake in the tier policy and movement pricing)")
        self._decode = other._decode
        self._prefill = other._prefill
        self._suspend = other._suspend
        self._suspend_many = other._suspend_many
        self._resume = other._resume
        self._resume_many = other._resume_many
        self._clone = other._clone
        self._wave_plans = other._wave_plans

    # ---- VILLA session tiering (fork-aware row allocation) ----------------
    def _claim_row(self, uid: int) -> int:
        """Free the home index (uid % n_sessions) for ``uid``'s next write
        and return it.  An EXCLUSIVE occupant is destroy-evicted (legacy
        collision semantics); a SHARED occupant is *demoted* — its bytes
        device-cloned to a free row and every alias repointed — never
        destroyed.  Also the ``alloc`` callback of
        :meth:`~repro.fork.ForkPageTable.write_break`."""
        idx = uid % self.n_sessions
        owner = self.store_uid.get(idx)
        if owner is not None and owner != uid:
            if self.forks.refs.get(idx, 0) > 1:
                self._demote_row(idx)
            else:
                self._evict_row(idx)
        elif owner == uid and idx in self.forks.refs:
            # uid's own home is the shared row it is detaching from:
            # demote it (uid's alias moves along; write_break re-resolves)
            self._demote_row(idx)
        assert idx not in self.forks.refs, (idx, self.forks.refs)
        return idx

    def _evict_row(self, idx: int) -> None:
        """Destroy the exclusive snapshot occupying ``idx``."""
        old = self.store_uid.pop(idx)
        self.session_pos.pop(old, None)
        self.session_tok.pop(old, None)
        if old in self.forks and self.forks.resolve(old) == idx:
            self.forks.release(old)
        self.stats["evictions"] += 1
        if self.tracer is not None:
            self.tracer.instant("evict", lane=self.trace_lane, cat="fork",
                                attrs={"uid": old, "row": idx})

    def _demote_row(self, src: int) -> None:
        """Migrate a SHARED row out of the way: device-clone its pages and
        checksum sidecar to a free row, repoint every alias as one unit
        (refcount preserved).  Shared snapshots are never destroyed by a
        collision — the fork-aware eviction contract."""
        free = [i for i in range(self.n_sessions)
                if i not in self.forks.refs and i not in self.store_uid]
        if not free:
            raise RuntimeError(
                f"store full: cannot demote shared row {src} "
                f"(aliases {self.forks.aliases(src)}); drop a session first")
        dst = free[0]
        self.sessions, self.session_sums = _quiet(
            self._clone, self.sessions, self.session_sums,
            jnp.int32(src), jnp.int32(dst))
        self.forks.repoint(src, dst)
        self.store_uid[dst] = self.store_uid.pop(src)
        self.stats["demotions"] += 1
        self._charge_move(self.plan_demote)
        if self.tracer is not None:
            self.tracer.instant(
                "cow_demote", lane=self.trace_lane, cat="fork",
                attrs={"src_row": src, "dst_row": dst,
                       "ns_lisa": self.plan_demote.cost.ns_lisa,
                       "ns_memcpy": self.plan_demote.cost.ns_memcpy})

    def _own_row(self, uid: int, idx: int) -> None:
        """Post-write bookkeeping: a fresh uid binds its claimed row; any
        row ``uid`` no longer backs is handed to a surviving alias so
        ``store_uid`` always names a live alias of every owned row."""
        if uid not in self.forks:
            self.forks.bind(uid, idx)
        for phys in [p for p, o in self.store_uid.items()
                     if o == uid and p != idx]:
            alts = [a for a in self.forks.aliases(phys) if a != uid]
            if alts:
                self.store_uid[phys] = alts[0]
            else:
                del self.store_uid[phys]
        self.store_uid[idx] = uid

    def _release_row(self, uid: int) -> Optional[int]:
        """Drop ``uid``'s alias claim; returns the physical row iff it was
        the last alias (now reclaimable), else None.  Ownership of a still-
        shared row passes to a surviving alias."""
        phys = self.forks.resolve(uid)
        freed = self.forks.release(uid)
        if freed is not None:
            self.store_uid.pop(freed, None)
        elif self.store_uid.get(phys) == uid:
            self.store_uid[phys] = self.forks.aliases(phys)[0]
        return freed

    # ---- session residence metadata (migration support) -------------------
    def session_meta(self, uid: int) -> tuple:
        """(next position, last emitted token) of a suspended session —
        the host-side bookkeeping a migration must carry along with the
        snapshot pages."""
        if uid not in self.session_pos:
            raise UnknownSession(f"uid {uid} has no suspended session on "
                                 f"replica {self.replica_id}")
        return self.session_pos[uid], self.session_tok[uid]

    def adopt_session(self, uid: int, pos: int, tok: int) -> int:
        """Register an inbound migrated session and return the store index
        its pages must be scattered into (an EXCLUSIVE row — an inbound
        snapshot is materialized bytes, not an alias).  Collisions evict or
        demote explicitly, exactly like a local suspend."""
        if uid in self.forks:
            self._release_row(uid)      # stale claim: re-adoption replaces
        idx = self._claim_row(uid)
        self.forks.bind(uid, idx)
        self.store_uid[idx] = uid
        self.session_pos[uid] = int(pos)
        self.session_tok[uid] = int(tok)
        return idx

    def adopt_alias(self, uid: int, pos: int, tok: int,
                    owner_uid: int) -> int:
        """Register a session as an ALIAS of an already-resident owner
        (snapshot restore of a forked family: the owner's row was restored
        ONCE; each alias re-attaches by bookkeeping alone — zero device
        work, one repair heals every alias).  Returns the shared row."""
        if uid in self.forks:
            self._release_row(uid)
        phys = self.forks.fork_child(owner_uid, uid)
        self.session_pos[uid] = int(pos)
        self.session_tok[uid] = int(tok)
        return phys

    def drop_session(self, uid: int) -> int:
        """Forget a suspended session (its pages migrated away); returns
        the PHYSICAL row the snapshot occupied — for a forked alias that is
        the shared row, which survives for the other aliases.  The bytes in
        the pool are left as-is; an exclusive row is dead until a new
        session claims it."""
        pos = self.session_pos.pop(uid, None)
        if pos is None:
            raise UnknownSession(f"uid {uid} has no suspended session on "
                                 f"replica {self.replica_id}")
        self.session_tok.pop(uid, None)
        if uid not in self.forks:
            return uid % self.n_sessions      # pre-fork legacy bookkeeping
        idx = self.forks.resolve(uid)
        self._release_row(uid)
        return idx

    def _suspend_bookkeep(self, slot: int) -> int:
        """Pop the request off ``slot`` and record its session state;
        returns the uid (row allocation is the caller's CoW write-break)."""
        req = self.active.pop(slot)
        self.session_pos[req.uid] = int(self.pos[slot])
        self.session_tok[req.uid] = req.generated[-1] if req.generated else 0
        self.stats["suspends"] += 1
        return req.uid

    def suspend(self, slot: int) -> None:
        if slot not in self.active:
            raise ValueError(f"slot {slot} has no active request to suspend "
                             f"(active slots: {sorted(self.active)})")
        uid = self._suspend_bookkeep(slot)
        # CoW write-break BEFORE the scatter: the `unrefcounted-alias` lint
        # rule requires the refcount API in any function that drives the
        # _suspend scatter.
        idx = (self.forks.write_break(uid, alloc=self._claim_row)
               if uid in self.forks else self._claim_row(uid))
        self._own_row(uid, idx)
        self.sessions, self.session_sums = _quiet(
            self._suspend, self.cache, self.sessions, self.session_sums,
            jnp.int32(slot), jnp.int32(idx))
        self._charge_move(self.plan_suspend)

    def suspend_many(self, slots: Sequence[int]) -> None:
        """Suspend a wave of slots in ONE dispatch (the dual of
        :meth:`resume_many`): one vmapped page pack + one batched
        write-through through the fused suspend plan."""
        if not slots:
            return
        bad = [s for s in slots if s not in self.active]
        if bad or len(set(slots)) != len(slots):
            raise ValueError(f"suspend wave needs distinct active slots "
                             f"(got {list(slots)}; active: "
                             f"{sorted(self.active)})")
        uids = [self._suspend_bookkeep(s) for s in slots]
        # per-uid CoW write-break (host bookkeeping; the scatter below stays
        # ONE fused dispatch for the whole wave)
        idxs = []
        for uid in uids:
            idx = (self.forks.write_break(uid, alloc=self._claim_row)
                   if uid in self.forks else self._claim_row(uid))
            self._own_row(uid, idx)
            idxs.append(idx)
        self.sessions, self.session_sums = _quiet(
            self._suspend_many, self.cache, self.sessions, self.session_sums,
            jnp.asarray(slots, jnp.int32), jnp.asarray(idxs, jnp.int32))
        self._charge_move(self._wave_plan(self.plan_suspend, len(slots)))

    def _check_resumable(self, uid: int, extra_new: int) -> int:
        for slot, r in self.active.items():
            if r.uid == uid:
                raise ValueError(
                    f"uid {uid} is already active in slot {slot}; suspend it "
                    f"before resuming it again (a second resume would fork a "
                    f"stale snapshot and corrupt suspend bookkeeping)")
        if uid not in self.session_pos:
            raise UnknownSession(
                f"uid {uid} has no suspended session (never suspended, or "
                f"evicted by a store-index collision)")
        pos = self.session_pos[uid]
        if pos + extra_new - 1 > self.max_len:
            # decode step k writes the cache at position pos+k: past max_len
            # the scatter is silently dropped (JAX OOB semantics) and later
            # tokens would attend over a hole — refuse instead of corrupting
            raise ValueError(
                f"uid {uid} is at position {pos}: decoding {extra_new - 1} "
                f"more tokens would write past max_len={self.max_len}; "
                f"clamp extra_new to the context envelope (repro.sched "
                f"truncates follow-ups this way)")
        # the PHYSICAL row: a forked child resumes by gathering straight
        # from the parent's shared row (read-through aliasing)
        return self.forks.resolve(uid)

    def _activate(self, slot: int, uid: int, extra_new: int) -> None:
        req = Request(uid=uid, prompt=np.zeros(0, np.int32), max_new=extra_new)
        req.generated = [self.session_tok[uid]]
        self.active[slot] = req
        self.pos[slot] = self.session_pos[uid]
        if len(req.generated) >= req.max_new:
            # extra_new <= 1: the restored seed token already meets the
            # budget — suspend instead of overshooting by one decode (the
            # resume-path mirror of submit()'s max_new=1 guard)
            self.suspend(slot)

    def resume(self, uid: int, extra_new: int) -> int:
        """Bring a suspended session back: the tiered-store access promotes
        hot sessions to the fast tier (paper policy) — hit rate is the
        serving-level VILLA metric.  One jitted dispatch, no host sync."""
        idx = self._check_resumable(uid, extra_new)
        slot = self._take_slot()
        self.cache, self.sessions, self.verify_failed = _quiet(
            self._resume, self.cache, self.sessions, self.session_sums,
            self.verify_failed, jnp.int32(slot), jnp.int32(idx))
        self._activate(slot, uid, extra_new)
        self.stats["resumes"] += 1
        self._charge_move(self.plan_resume)
        return slot

    def resume_many(self, uids: Sequence[int], extra_new) -> List[int]:
        """Resume a wave of sessions in ONE dispatch: the page tables of all
        sessions drive one batched tiered-store access.  ``extra_new`` is an
        int applied to every session, or a per-uid sequence (the scheduler
        resumes jobs owing different token counts in one fused wave —
        ``extra_new`` is host bookkeeping, never traced, so ragged budgets
        share the single dispatch)."""
        if not uids:
            return []
        if len(set(uids)) != len(uids):
            raise ValueError(f"duplicate uids in resume wave: {list(uids)}")
        extras = ([int(extra_new)] * len(uids)
                  if isinstance(extra_new, (int, np.integer))
                  else [int(e) for e in extra_new])
        if len(extras) != len(uids):
            raise ValueError(f"extra_new sequence has {len(extras)} entries "
                             f"for {len(uids)} uids")
        idxs = [self._check_resumable(u, e) for u, e in zip(uids, extras)]
        free = self.free_slots()
        if len(free) < len(uids):
            raise EngineFull(f"{len(uids)} resumes requested but only "
                             f"{len(free)} slots free")
        slots = free[:len(uids)]
        self.cache, self.sessions, self.verify_failed = _quiet(
            self._resume_many, self.cache, self.sessions, self.session_sums,
            self.verify_failed, jnp.asarray(slots, jnp.int32),
            jnp.asarray(idxs, jnp.int32))
        for slot, uid, extra in zip(slots, uids, extras):
            self._activate(slot, uid, extra)
            self.stats["resumes"] += 1
        self._charge_move(self._wave_plan(self.plan_resume, len(uids)))
        return slots

    def _charge_move(self, plan: MV.MovementPlan) -> None:
        """Account one executed plan under both mechanisms: the running
        totals expose the modeled LISA-vs-memcpy gap at serving
        granularity."""
        self.stats["modeled_move_ns_lisa"] += plan.cost.ns_lisa
        self.stats["modeled_move_ns_memcpy"] += plan.cost.ns_memcpy

    # ---- zero-copy session forking (RowClone analogue) --------------------
    def fork_many(self, parent_uid: int, child_uids: Sequence[int],
                  seed_tokens: Optional[Sequence[int]] = None) -> None:
        """Fork N children off a SUSPENDED parent: each child aliases the
        parent's physical snapshot row (refcount += 1) and inherits its
        position — pure host bookkeeping, ZERO device dispatches (pinned by
        repro.analysis.testlib).  The shared prefix is prefilled once, ever.

        ``seed_tokens`` overrides each child's first decode input (the
        divergence point); default is the parent's last emitted token.  The
        real copy is deferred: a child's first post-fork decode scatters
        only its slot cache, and its next suspend write-breaks onto a row
        of its own — still one fused dispatch per wave.

        Charges one ``fork``-kind movement plan per child (RowClone FPM on
        the lisa arm vs the avoided full-snapshot copy on the memcpy arm)
        and credits ``stats["bytes_not_copied"]``.
        """
        if not child_uids:
            return
        if parent_uid not in self.session_pos:
            raise UnknownSession(
                f"uid {parent_uid} has no suspended session to fork "
                f"(suspend the parent first — fork aliases its snapshot)")
        for slot, r in self.active.items():
            if r.uid == parent_uid:
                raise ValueError(
                    f"parent uid {parent_uid} is active in slot {slot}; "
                    f"suspend it before forking (the snapshot row must be "
                    f"quiescent)")
        if len(set(child_uids)) != len(child_uids):
            raise ValueError(f"duplicate child uids: {list(child_uids)}")
        taken = [c for c in child_uids
                 if c == parent_uid or c in self.session_pos
                 or c in self.forks
                 or any(r.uid == c for r in self.active.values())]
        if taken:
            raise ValueError(f"child uids already in use: {taken}")
        seeds = (list(seed_tokens) if seed_tokens is not None
                 else [self.session_tok[parent_uid]] * len(child_uids))
        if len(seeds) != len(child_uids):
            raise ValueError(f"{len(seeds)} seed tokens for "
                             f"{len(child_uids)} children")
        for child, seed in zip(child_uids, seeds):
            self.forks.fork_child(parent_uid, child)
            self.session_pos[child] = self.session_pos[parent_uid]
            self.session_tok[child] = int(seed)
        fplan = self._wave_plan(self.plan_fork, len(child_uids))
        self._charge_move(fplan)
        self.stats["forks"] += len(child_uids)
        self.stats["bytes_not_copied"] += fplan.cost.bytes
        if self.tracer is not None:
            self.tracer.instant(
                "fork", lane=self.trace_lane, cat="fork",
                attrs={"parent": parent_uid, "children": len(child_uids),
                       "bytes_not_copied": fplan.cost.bytes,
                       "ns_lisa": fplan.cost.ns_lisa,
                       "ns_memcpy": fplan.cost.ns_memcpy})

    def fork(self, parent_uid: int, child_uid: int,
             seed_token: Optional[int] = None) -> None:
        """Fork ONE child — see :meth:`fork_many`."""
        self.fork_many(parent_uid, [child_uid],
                       None if seed_token is None else [seed_token])

    def reseed(self, uid: int, token: int) -> None:
        """Override a suspended session's next decode input (host
        bookkeeping only): the benchmark's fork-OFF arm drives identical
        divergence tokens through independent sessions this way."""
        if uid not in self.session_pos:
            raise UnknownSession(f"uid {uid} has no suspended session")
        for slot, r in self.active.items():
            if r.uid == uid:
                raise ValueError(f"uid {uid} is active in slot {slot}")
        self.session_tok[uid] = int(token)

    def shared_uids(self) -> frozenset:
        """uids whose snapshot row is aliased by at least one other session
        (host dicts only — no device read).  The scheduler treats these as
        the WORST eviction victims and their replicas as preferred fork
        placements."""
        return frozenset(u for u, p in self.forks.phys_of.items()
                         if self.forks.refs[p] > 1)

    def fast_resident_uids(self) -> frozenset:
        """uids whose snapshots are resident in the VILLA fast tier right
        now (one small device→host read of the policy tags).  The scheduler
        consults this for occupancy-aware cost scoring: a resident resume is
        a fast-subarray read, a resident suspend pays the write-through to
        both pools.  A degraded fast tier reports empty — every movement is
        priced at slow-tier cost."""
        if self.fast_degraded:
            return frozenset()
        tags = np.asarray(self.sessions.policy.tags)
        out = set()
        for t in tags:
            t = int(t)
            if t < 0:
                continue
            if t in self.forks.refs:
                # a resident SHARED row makes every alias fast-resident —
                # they all read the same physical pages
                out.update(self.forks.aliases(t))
            elif t in self.store_uid:
                out.add(self.store_uid[t])
        return frozenset(out)

    def hit_rate(self) -> float:
        return float(VC.hit_rate(self.sessions))

    # ---- chaos surface ----------------------------------------------------
    def degrade_fast(self) -> None:
        """Take the VILLA fast tier offline for pricing purposes: tags are
        dropped (nothing to write back — the store is write-through, so the
        slow tier is already current, the fault-model analogue of
        LISA-VILLA's dirty-line writeback being a no-op) and
        :meth:`fast_resident_uids` reports empty from now on, rerouting
        every scheduler cost estimate to slow-tier prices.  Data-path
        correctness is untouched; only the pricing surface degrades."""
        self.fast_degraded = True
        st = self.sessions
        self.sessions = st._replace(policy=st.policy._replace(
            tags=jnp.full_like(st.policy.tags, -1)))

    def corrupt_stored(self, idx: int, page: int, byte: int,
                       xor: int) -> None:
        """Chaos hook: XOR one byte of suspended snapshot ``idx`` at rest —
        in the slow pool AND, if the snapshot is fast-resident, in the fast
        copy (both tiers hold the same rotted bits, as one failing subarray
        would).  The checksum sidecar is deliberately NOT updated: the next
        resume's unpack verify must catch this.  Pure device ops, no host
        sync."""
        P, d = self.page_spec.page_rows, self.page_spec.page_lanes
        row, lane = byte // d, byte % d
        if not (0 <= page < self.page_spec.n_pages and 0 <= row < P):
            raise ValueError(f"corrupt_stored target out of range: "
                             f"page={page}, byte={byte}")
        x = jnp.uint8(xor)
        st = self.sessions
        slow = st.slow.at[idx, page, row, lane].set(
            st.slow[idx, page, row, lane] ^ x)
        tags = st.policy.tags
        hit = jnp.any(tags == idx)
        f = jnp.argmax(tags == idx)
        fast = jnp.where(hit, st.fast.at[f, page, row, lane].set(
            st.fast[f, page, row, lane] ^ x), st.fast)
        self.sessions = st._replace(slow=slow, fast=fast)

    def verify_store(self) -> jax.Array:
        """Scrub: recompute every LIVE suspended snapshot's checksums
        against the sidecar; returns the ON-DEVICE int32 count of corrupt
        PHYSICAL rows.  A shared row is checked ONCE however many sessions
        alias it — one corruption, one detection, and the one repair that
        follows heals every alias.  Callers (the chaos bench's end-of-run
        audit, tests) sync it explicitly — the tick loop never calls
        this."""
        idxs = sorted(i for i, u in self.store_uid.items()
                      if u in self.session_pos
                      or any(a in self.session_pos
                             for a in self.forks.aliases(i)))
        if not idxs:
            return jnp.zeros((), jnp.int32)
        ii = jnp.asarray(idxs, jnp.int32)
        cs = PS.page_checksums(self.sessions.slow[ii])
        mismatch = jnp.any(cs != self.session_sums[ii], axis=-1)
        return jnp.sum(mismatch.astype(jnp.int32))

    def verify_failure_count(self) -> int:
        """Sync the device-side resume-verify counter (bench/test surface —
        one explicit read, outside the tick loop)."""
        return int(self.verify_failed)

    def compile_counts(self) -> Dict[str, int]:
        """Jit-cache sizes of the hot-path entry points (compilations seen).
        -1 when the jax build exposes no cache-size probe — asserters should
        treat -1 as 'unknown', not as a regression."""
        out = {}
        for name, fn in [("decode", self._decode), ("prefill", self._prefill),
                         ("suspend", self._suspend), ("resume", self._resume),
                         ("suspend_many", self._suspend_many),
                         ("resume_many", self._resume_many),
                         ("clone", self._clone)]:
            out[name] = fn._cache_size() if hasattr(fn, "_cache_size") else -1
        return out
