"""Continuous-batching decode engine with LISA-VILLA session caching.

Slots hold active requests (one batched KV cache across slots); finished or
paused sessions are *suspended* into a tiered store driven by the paper's
exact VILLA policy — hot sessions (frequent resumes: chat turns, shared
prefixes) live in the fast tier, cold ones in the bulk tier.  Suspension /
resumption moves whole KV snapshots: exactly the bulk data movement LISA
accelerates (on TPU the move is `kernels/rbm_copy`; on the mesh it is a
`core.lisa.rbm.lisa_copy` hop chain between replicas).

The movement itself is also *accounted*: the engine takes a
:class:`~repro.core.dram.spec.DramSpec` and, per suspend/resume, charges the
modeled cost of moving the KV snapshot under the ``lisa`` vs ``memcpy``
mechanisms from the registry — the serving-level view of Table 1's gap.

Pure-JAX state; greedy sampling; CPU-runnable at reduced configs.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dram.spec import DDR3_1600, DramSpec
from repro.core.dram.villa import VillaConfig
from repro.core.lisa import villa_cache as VC
from repro.models import lm


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    generated: Optional[List[int]] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 128, n_sessions: int = 64,
                 villa: Optional[VillaConfig] = None,
                 spec: DramSpec = DDR3_1600):
        self.cfg = cfg
        self.params = params
        self.spec = spec
        self.slots = slots
        self.max_len = max_len
        self.active: Dict[int, Request] = {}        # slot -> request
        self.pos = np.zeros(slots, np.int32)

        self.cache = lm.init_cache(cfg, slots, max_len=max_len)
        self._decode = jax.jit(partial(lm.decode_step, cfg))
        self._prefill1 = jax.jit(partial(self._prefill_one))

        # session store: suspended KV snapshots, VILLA-tiered
        flat, self._cache_def = jax.tree_util.tree_flatten(
            self._slot_slice(self.cache, 0))
        self._leaf_shapes = [l.shape for l in flat]
        self._leaf_dtypes = [l.dtype for l in flat]
        sizes = [int(np.prod(s)) for s in self._leaf_shapes]
        self._leaf_sizes = sizes
        self.villa_cfg = villa or VillaConfig(
            n_counters=n_sessions, n_hot=max(n_sessions // 4, 2),
            n_slots=max(n_sessions // 4, 2), epoch_len=8)
        slow = jnp.zeros((n_sessions, sum(sizes)), jnp.float32)
        self.sessions = VC.make_store(slow, self.villa_cfg)
        self.session_pos: Dict[int, int] = {}
        # Modeled cost of moving one KV snapshot (float32 bytes -> DRAM
        # rows), under the in-DRAM hop chain vs the channel path.
        snapshot_rows = max(1, math.ceil(sum(sizes) * 4 / spec.row_bytes))
        self._move_ns = {
            "lisa": snapshot_rows * spec.copy_latency("lisa", 1),
            "memcpy": snapshot_rows * spec.copy_latency("memcpy"),
        }
        self.stats = {"decoded_tokens": 0, "suspends": 0, "resumes": 0,
                      "modeled_move_ns_lisa": 0.0,
                      "modeled_move_ns_memcpy": 0.0}

    # ---- cache <-> flat session snapshots --------------------------------
    def _slot_slice(self, cache, slot):
        return jax.tree.map(lambda x: x[:, slot], cache)   # leading dim = reps

    def _snapshot(self, slot) -> jax.Array:
        leaves = jax.tree_util.tree_flatten(self._slot_slice(self.cache, slot))[0]
        return jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                                for l in leaves])

    def _restore_snapshot(self, slot, vec: jax.Array) -> None:
        leaves = []
        off = 0
        for shape, dtype, size in zip(self._leaf_shapes, self._leaf_dtypes,
                                      self._leaf_sizes):
            leaves.append(vec[off:off + size].reshape(shape).astype(dtype))
            off += size
        piece = jax.tree_util.tree_unflatten(self._cache_def, leaves)
        self.cache = jax.tree.map(
            lambda full, p: full.at[:, slot].set(p), self.cache, piece)

    def _prefill_one(self, params, cache1, tokens):
        return lm.prefill(self.cfg, params, tokens, cache1)

    # ---- scheduling -------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def submit(self, req: Request) -> int:
        slot = self.free_slots()[0]
        req.generated = []
        # fresh single-slot cache WITH the position sentinel (2**30) intact —
        # zeros would unmask unwritten slots (kv_pos=0 passes the causal mask)
        cache1 = lm.init_cache(self.cfg, 1, max_len=self.max_len)
        logits, cache1 = self._prefill1(self.params, cache1,
                                        jnp.asarray(req.prompt)[None])
        self.cache = jax.tree.map(
            lambda full, p: full.at[:, slot:slot + 1].set(p),
            self.cache, cache1)
        nxt = int(jnp.argmax(logits[0, -1]))
        req.generated.append(nxt)
        self.active[slot] = req
        self.pos[slot] = len(req.prompt)
        return slot

    def step(self) -> None:
        """Decode one token for every active slot (uniform position per
        micro-group: slots at different positions run in position groups)."""
        if not self.active:
            return
        groups: Dict[int, List[int]] = {}
        for s in self.active:
            groups.setdefault(int(self.pos[s]), []).append(s)
        for pos, ss in groups.items():
            toks = np.zeros((self.slots, 1), np.int32)
            for s in ss:
                toks[s, 0] = self.active[s].generated[-1]
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks),
                                              jnp.int32(pos))
            for s in ss:
                nxt = int(jnp.argmax(logits[s, 0]))
                self.active[s].generated.append(nxt)
                self.pos[s] += 1
                self.stats["decoded_tokens"] += 1
        for s, req in list(self.active.items()):
            if len(req.generated) >= req.max_new:
                self.suspend(s)

    # ---- VILLA session tiering --------------------------------------------
    def suspend(self, slot: int) -> None:
        req = self.active.pop(slot)
        vec = self._snapshot(slot)
        self.sessions = VC.write(self.sessions, req.uid % len(
            self.sessions.slow), vec)
        self.session_pos[req.uid] = int(self.pos[slot])
        self.stats["suspends"] += 1
        self._charge_move()

    def resume(self, uid: int, extra_new: int) -> int:
        """Bring a suspended session back: the tiered store access promotes
        hot sessions to the fast tier (paper policy) — hit rate is the
        serving-level VILLA metric."""
        self.sessions, vec, hit = VC.access(
            self.sessions, uid % len(self.sessions.slow), self.villa_cfg)
        slot = self.free_slots()[0]
        self._restore_snapshot(slot, vec)
        req = Request(uid=uid, prompt=np.zeros(0, np.int32),
                      max_new=extra_new)
        req.generated = [0]
        self.active[slot] = req
        self.pos[slot] = self.session_pos[uid]
        self.stats["resumes"] += 1
        self._charge_move()
        return slot

    def _charge_move(self) -> None:
        """Account one whole-snapshot movement under both mechanisms: the
        running totals expose the modeled LISA-vs-memcpy gap at serving
        granularity."""
        self.stats["modeled_move_ns_lisa"] += self._move_ns["lisa"]
        self.stats["modeled_move_ns_memcpy"] += self._move_ns["memcpy"]

    def hit_rate(self) -> float:
        return float(VC.hit_rate(self.sessions))
