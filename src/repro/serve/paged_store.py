"""Paged, dtype-preserving KV-snapshot layout for the serving engine.

A suspended session's KV cache is stored as fixed-size *pages* of raw bytes
(default 8x128 = 1 KB — one DRAM row in the paper's geometry), bit-exact
and without any float32 upcast.  The staging itself (``PageSpec`` /
``pack_slot`` / ``unpack_into_slot``) is the movement substrate's paging
layer (:mod:`repro.movement.paging`) — this module is the serving-layer
view of it plus the session-store constructor.

The page pool lives in a :class:`~repro.core.lisa.villa_cache.TieredStore`
whose items are page blocks; all tier movement (suspend, resume, hot-tier
promotion) lowers through ``movement.plan`` to page gather/scatter legs run
by the Pallas RBM kernels (scalar-prefetched page tables, LIP double
buffering).  The engine's suspend and resume are each ONE jitted dispatch
with donated buffers: every function here takes *traced* slot indices.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.dram.villa import VillaConfig
from repro.core.lisa import villa_cache as VC
from repro.fork import ForkPageTable
from repro.movement.paging import (  # noqa: F401  (serving-layer re-exports)
    PageSpec,
    pack_slot,
    page_checksums,
    row_page_table,
    unpack_into_slot,
    verify_pages,
)


def make_session_store(spec: PageSpec, n_sessions: int,
                       cfg: VillaConfig) -> VC.TieredStore:
    """A VILLA tiered store over uint8 page blocks: slow tier holds every
    session's pages; the fast tier caches hot (frequently resumed) ones."""
    slow = jnp.zeros((n_sessions, spec.n_pages, spec.page_rows,
                      spec.page_lanes), jnp.uint8)
    return VC.make_store(slow, cfg)


def make_fork_table() -> ForkPageTable:
    """The store's CoW alias ledger (one per store/replica): logical uids
    -> physical slow-pool rows, refcounted so N forked sessions alias one
    row until a writer diverges.  All alias mutation goes through its API
    (the `unrefcounted-alias` lint rule enforces this for serving code)."""
    return ForkPageTable()
