"""Fault-tolerant checkpointing: atomic sharded save / restore / auto-resume.

Layout: <dir>/step_<N>/arrays.npz + meta.json, written to a tmp dir and
renamed (atomic on POSIX) so a crash mid-save never corrupts the latest
checkpoint.  Leaves are keyed by tree path, so restore works against any
structurally-equal target — and ``restore(..., shardings=...)`` lays the
arrays out on a *different* mesh, which is the elastic-rescale path
(checkpoint from a 256-chip run restores onto 128 or 512 chips; the
cross-device movement is exactly the bulk transfer LISA accelerates).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(tree: Any, ckpt_dir: str, step: int, keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_path_str(p): np.asarray(jax.device_get(l)) for p, l in flat
              if l is not None}
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_arrays": len(arrays)}, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "meta.json")):
            out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(tree_like: Any, ckpt_dir: str, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like`` (shapes/dtypes template).

    ``shardings``: optional matching tree of NamedShardings — arrays are
    placed directly onto that (possibly different) mesh: elastic rescale.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (p, leaf), sh in zip(flat, shard_flat):
        key = _path_str(p)
        if leaf is None:
            leaves.append(None)
            continue
        arr = data[key]
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        treedef, [l for (_, leaf), l in zip(flat, leaves)])
