"""Fault-tolerant checkpointing: atomic sharded save / restore / auto-resume.

Layout: <dir>/step_<N>/arrays.npz + meta.json, written to a tmp dir and
renamed (atomic on POSIX) so a crash mid-save never corrupts the latest
checkpoint.  Leaves are keyed by tree path, so restore works against any
structurally-equal target — and ``restore(..., shardings=...)`` lays the
arrays out on a *different* mesh, which is the elastic-rescale path
(checkpoint from a 256-chip run restores onto 128 or 512 chips; the
cross-device movement is exactly the bulk transfer LISA accelerates).

Device<->host staging is a planned movement: both directions lower through
``movement.plan`` to a host-staging leg — the off-chip channel, the
"memcpy" path the in-fabric legs are priced against — so checkpoint traffic
is byte-accounted by the same substrate as every other bulk transfer
(``last_move_cost()`` exposes the most recent plan's cost).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from typing import Any, Optional

import jax
import numpy as np

from repro import movement as MV

_LAST_COST: Optional[MV.MovementCost] = None


class CorruptCheckpoint(RuntimeError):
    """A checkpoint failed integrity verification: torn write, truncation,
    or on-disk bit rot.  Raised by :func:`verify_checkpoint` (and hence
    :func:`restore`) instead of silently restoring garbage state."""


def last_move_cost() -> Optional[MV.MovementCost]:
    """MovementCost of the most recent save/restore staging (None before
    any staging ran): checkpoint bytes over the modeled channel."""
    return _LAST_COST


def _stage(leaves, to_host: bool, shardings=None):
    """Move a list of leaves across the channel via one host-staging plan."""
    global _LAST_COST
    src, dst = (("device", "host") if to_host else ("host", "device"))
    p = MV.plan(MV.Transfer(MV.Tier(src), MV.Tier(dst),
                            MV.Layout.tree(leaves)))
    _LAST_COST = p.cost
    return MV.execute(p, data=leaves, shardings=shardings)["data"]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _file_crc(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                break
            crc = zlib.crc32(chunk, crc)
    return crc & 0xFFFFFFFF


def save(tree: Any, ckpt_dir: str, step: int, keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = [(p, l) for p, l in jax.tree_util.tree_flatten_with_path(tree)[0]
            if l is not None]
    staged = _stage([l for _, l in flat], to_host=True)
    arrays = {_path_str(p): a for (p, _), a in zip(flat, staged)}
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        npz = os.path.join(tmp, "arrays.npz")
        np.savez(npz, **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_arrays": len(arrays)}, f,
                      allow_nan=False)
        # Integrity trailer: crc + size of the payload, written last inside
        # the tmp dir so the atomic rename publishes data and trailer
        # together — a torn copy of this directory is always detectable.
        with open(os.path.join(tmp, "trailer.json"), "w") as f:
            json.dump({"crc32": _file_crc(npz),
                       "size": os.path.getsize(npz)}, f, allow_nan=False)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "meta.json")):
            out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def verify_checkpoint(ckpt_dir: str, step: int) -> None:
    """Check a checkpoint's integrity trailer; raise
    :class:`CorruptCheckpoint` on any mismatch.

    Catches the failure modes the atomic rename alone cannot: a partial
    copy of the directory (rsync interrupted mid-``arrays.npz``), a
    truncated payload, or flipped bits at rest.  A missing trailer is
    itself treated as corruption — an attacker-free analogue of "fail
    closed"."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    npz = os.path.join(d, "arrays.npz")
    trailer_path = os.path.join(d, "trailer.json")
    if not os.path.exists(npz):
        raise CorruptCheckpoint(f"{d}: missing arrays.npz")
    if not os.path.exists(trailer_path):
        raise CorruptCheckpoint(f"{d}: missing integrity trailer")
    try:
        with open(trailer_path) as f:
            trailer = json.load(f)
        crc, size = int(trailer["crc32"]), int(trailer["size"])
    except (ValueError, KeyError, TypeError, json.JSONDecodeError) as e:
        raise CorruptCheckpoint(f"{d}: unreadable trailer ({e})") from e
    actual_size = os.path.getsize(npz)
    if actual_size != size:
        raise CorruptCheckpoint(
            f"{d}: arrays.npz truncated or padded "
            f"({actual_size} bytes, trailer says {size})")
    actual_crc = _file_crc(npz)
    if actual_crc != crc:
        raise CorruptCheckpoint(
            f"{d}: arrays.npz checksum mismatch "
            f"(crc32 {actual_crc:#010x}, trailer says {crc:#010x})")


def restore(tree_like: Any, ckpt_dir: str, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like`` (shapes/dtypes template).

    ``shardings``: optional matching tree of NamedShardings — arrays are
    placed directly onto that (possibly different) mesh: elastic rescale.

    Integrity-verified first: a torn, truncated, or bit-rotted checkpoint
    raises :class:`CorruptCheckpoint` rather than restoring garbage.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    verify_checkpoint(ckpt_dir, step)
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    hosted = [None if leaf is None else data[_path_str(p)]
              for (p, leaf) in flat]
    leaves = _stage(hosted, to_host=False, shardings=shard_flat)
    return jax.tree_util.tree_unflatten(treedef, leaves)
