"""seamless-m4t-medium [audio]: 12L enc + 12L dec, d_model=1024 16H
d_ff=4096 vocab=256206 — enc-dec, multimodal.  Audio frontend is a STUB:
input_specs() provides precomputed frame embeddings.  [arXiv:2308.11596; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    activation="swiglu", rope_theta=1e4,
    encdec=True, n_enc_layers=12, frontend="audio",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=512, remat=False, attn_block=32,
    scan_chunk=8)
