"""rwkv6-7b [ssm]: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 —
Finch: data-dependent decay.  [arXiv:2404.05892; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab_size=65536,
    attn_kind="none", ssm_kind="rwkv6",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=128, n_heads=2, n_kv_heads=2, head_dim=64,
    d_ff=256, vocab_size=512, remat=False, attn_block=32, scan_chunk=8)
