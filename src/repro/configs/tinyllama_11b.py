"""tinyllama-1.1b [dense]: 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000 — llama2-arch small.  [arXiv:2401.02385; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=5632, vocab_size=32000,
    activation="swiglu", rope_theta=1e4,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=176, vocab_size=512, remat=False, attn_block=32, scan_chunk=8)
