"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE every other
layer.  [arXiv:2403.19887; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    activation="swiglu", rope_theta=1e4,
    ssm_kind="mamba", attn_period=8, attn_offset=4,
    d_state=16, d_conv=4, expand=2,
    n_experts=16, top_k=2, moe_d_ff=14336, moe_every=2, moe_offset=1,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, n_experts=4, top_k=2, moe_d_ff=128,
    d_state=4, capacity_factor=8.0, remat=False, attn_block=32, scan_chunk=8)
