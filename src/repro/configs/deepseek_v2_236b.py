"""deepseek-v2-236b [moe]: 60L d_model=5120 128H, MLA kv_lora=512,
d_ff=1536/expert, 2 shared + 160 routed top-6, vocab=102400.
[arXiv:2405.04434; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=192,
    d_ff=12288, vocab_size=102400,
    attn_kind="mla", activation="swiglu", rope_theta=1e4,
    kv_lora_rank=512, q_lora_rank=1536,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536, moe_every=1,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=48,
    d_ff=128, vocab_size=512, kv_lora_rank=32, q_lora_rank=48,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=64,
    capacity_factor=8.0, remat=False, attn_block=32, scan_chunk=8)
