"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=49152, vocab_size=152064,
    qkv_bias=True, activation="swiglu", rope_theta=1e6,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512, remat=False, attn_block=32, scan_chunk=8)
