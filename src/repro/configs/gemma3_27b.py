"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5 local : 1 global sliding-window mix, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab_size=262144,
    activation="geglu", rope_theta=1e4,
    window=1024, swa_period=6,              # 5 local : 1 global
    tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, window=16, swa_period=4, remat=False,
    attn_block=32, scan_chunk=8)
