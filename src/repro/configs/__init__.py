"""Assigned-architecture configs (--arch <id>)."""
from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES,
                                applicable_shapes, param_count)

from repro.configs import (gemma3_27b, qwen15_110b, tinyllama_11b, gemma_7b,
                           jamba_v01_52b, qwen2_vl_72b, rwkv6_7b, olmoe_1b_7b,
                           deepseek_v2_236b, seamless_m4t_medium)

_MODULES = {
    "gemma3-27b": gemma3_27b,
    "qwen1.5-110b": qwen15_110b,
    "tinyllama-1.1b": tinyllama_11b,
    "gemma-7b": gemma_7b,
    "jamba-v0.1-52b": jamba_v01_52b,
    "qwen2-vl-72b": qwen2_vl_72b,
    "rwkv6-7b": rwkv6_7b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "seamless-m4t-medium": seamless_m4t_medium,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _MODULES[name].REDUCED
