"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024 (per
expert) vocab=50304, MoE 64e top-8.  [arXiv:2409.02060; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304,
    activation="swiglu", rope_theta=1e4,
    n_experts=64, top_k=8, moe_d_ff=1024, moe_every=1,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=64, vocab_size=512, n_experts=8, top_k=2, moe_d_ff=64,
    capacity_factor=8.0, remat=False, attn_block=32, scan_chunk=8)
