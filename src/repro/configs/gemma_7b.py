"""gemma-7b [dense]: 28L d_model=3072 16H (MHA kv=16) d_ff=24576
vocab=256000 — GeGLU, head_dim=256.  [arXiv:2403.08295; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000,
    activation="geglu", rope_theta=1e4, tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=512, remat=False, attn_block=32, scan_chunk=8)
