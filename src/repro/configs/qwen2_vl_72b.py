"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution.  Vision frontend is a STUB:
input_specs() provides precomputed patch embeddings / 3-part position ids.
[arXiv:2409.12191; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True, activation="swiglu", rope_theta=1e6, mrope=True,
    frontend="vision",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512, remat=False, attn_block=32, scan_chunk=8)
