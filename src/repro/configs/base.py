"""Model / shape configuration for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    attn_kind: str = "gqa"        # gqa | mla | none
    qkv_bias: bool = False
    activation: str = "swiglu"    # swiglu | geglu
    rope_theta: float = 1e4
    mrope: bool = False           # qwen2-vl M-RoPE
    window: int = 0               # sliding-window size (local layers)
    swa_period: int = 0           # gemma3: every `period`-th layer is global
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1            # MoE at layers with (i % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25  # GShard-style expert capacity
    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM / hybrid
    ssm_kind: str = ""            # "" | mamba | rwkv6
    attn_period: int = 0          # jamba: 1 attention layer per `attn_period`
    attn_offset: int = 4
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # enc-dec / multimodal
    encdec: bool = False
    n_enc_layers: int = 0
    frontend: str = ""            # "" | audio | vision — stub embeddings
    # numerics / training
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    remat: bool = True
    scan_chunk: int = 128         # ssm/rwkv time-scan chunk
    attn_block: int = 512         # chunked-attention KV block

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid / sliding-window mixes."""
        return bool(self.ssm_kind) or self.swa_period > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Mixer kind per decoder layer."""
        kinds = []
        for i in range(self.n_layers):
            if self.ssm_kind == "rwkv6":
                kinds.append("rwkv")
            elif self.ssm_kind == "mamba":
                if self.attn_period and i % self.attn_period == self.attn_offset:
                    kinds.append("attn_full")
                else:
                    kinds.append("mamba")
            elif self.attn_kind == "mla":
                kinds.append("mla")
            elif self.swa_period and (i % self.swa_period != self.swa_period - 1):
                kinds.append("attn_local")
            else:
                kinds.append("attn_full")
        return tuple(kinds)

    def mlp_kinds(self) -> Tuple[str, ...]:
        kinds = []
        for i in range(self.n_layers):
            if self.ssm_kind == "rwkv6":
                kinds.append("rwkv_cm")
            elif self.n_experts and i % self.moe_every == self.moe_offset:
                kinds.append("moe")
            else:
                kinds.append("dense")
        return tuple(kinds)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """long_500k only for sub-quadratic archs (DESIGN.md Sec. 4)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return tuple(names)


# ---------------------------------------------------------------------------
# Parameter counting (for roofline MODEL_FLOPS = 6*N*D).
# ---------------------------------------------------------------------------

def _attn_params(cfg: ModelConfig) -> int:
    if cfg.attn_kind == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        return (cfg.d_model * cfg.q_lora_rank
                + cfg.q_lora_rank * cfg.n_heads * qk
                + cfg.d_model * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
                + cfg.n_heads * cfg.v_head_dim * cfg.d_model)
    hd = cfg.head_dim
    return (cfg.d_model * cfg.n_heads * hd + 2 * cfg.d_model * cfg.n_kv_heads * hd
            + cfg.n_heads * hd * cfg.d_model)


def _mamba_params(cfg: ModelConfig) -> int:
    d_in = cfg.expand * cfg.d_model
    dt_rank = math.ceil(cfg.d_model / 16)
    return (cfg.d_model * 2 * d_in + d_in * cfg.d_conv
            + d_in * (dt_rank + 2 * cfg.d_state) + dt_rank * d_in
            + d_in * cfg.d_state + 2 * d_in + d_in * cfg.d_model)


def _rwkv_params(cfg: ModelConfig) -> int:
    return 5 * cfg.d_model * cfg.d_model + 2 * 64 * cfg.d_model \
        + 2 * cfg.d_model * cfg.d_ff + cfg.d_model * cfg.d_model


def _dense_mlp_params(cfg: ModelConfig) -> int:
    return 3 * cfg.d_model * cfg.d_ff


def _moe_params(cfg: ModelConfig, active_only: bool) -> int:
    e = cfg.top_k if active_only else cfg.n_experts
    routed = 3 * cfg.d_model * cfg.moe_d_ff * e
    shared = 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_shared_experts
    return routed + shared + cfg.d_model * cfg.n_experts


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Decoder (+encoder) parameter count; embeddings counted once."""
    total = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    for kind, mkind in zip(cfg.layer_kinds(), cfg.mlp_kinds()):
        if kind in ("attn_full", "attn_local"):
            total += _attn_params(cfg)
        elif kind == "mla":
            total += _attn_params(cfg)
        elif kind == "mamba":
            total += _mamba_params(cfg)
        if kind == "rwkv":
            total += _rwkv_params(cfg)
        elif mkind == "dense":
            total += _dense_mlp_params(cfg)
        elif mkind == "moe":
            total += _moe_params(cfg, active_only)
    if cfg.encdec:
        total += cfg.n_enc_layers * (_attn_params(cfg) + _dense_mlp_params(cfg))
        # decoder cross-attention
        total += cfg.n_layers * _attn_params(cfg)
    return total
