"""AdamW with global-norm clipping and warmup+cosine schedule (pure JAX).

Moments are fp32 regardless of parameter dtype (bf16 training keeps fp32
optimizer state — the standard large-scale recipe); the update is computed in
fp32 and cast back, so bf16 parameters don't lose small updates to rounding
inside the moment math.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Dict
    v: Dict
    count: jax.Array


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(sum(leaves))


def update(cfg: OptConfig, grads, state: OptState, params
           ) -> Tuple[Dict, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.beta1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
        step_ = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step_ + decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_m, new_v, count), {
        "grad_norm": gnorm, "lr": lr}
