"""Zero-copy session forking: refcounted CoW page aliasing (RowClone).

See :mod:`repro.fork.table` for the ledger and DESIGN.md Sec. 13 for the
paper mapping (alias = RowClone FPM, materialize = PSM via LISA hops,
CoW trigger = first post-fork activate).
"""
from repro.fork.table import ForkPageTable

__all__ = ["ForkPageTable"]
