"""Refcounted copy-on-write page aliasing — the RowClone analogue's ledger.

A :class:`ForkPageTable` is pure host bookkeeping over the uint8 page
substrate: sessions (logical uids) map onto *physical* slow-pool rows, and
N sessions may alias ONE physical row after a fork.  The table never
touches device memory — it decides *which* row a movement plan reads or
writes, so the fork fast path is zero device dispatches (RowClone FPM: a
row copy that never crosses the channel), and the real copy is deferred
until a writer diverges (:meth:`write_break`, the CoW detach — RowClone
PSM / a LISA hop chain when the copy crosses subarrays).

Invariants (the refcount-conservation property, asserted by
:meth:`check_conserved` and the hypothesis stream test):

  * every mapped uid resolves to exactly one physical row;
  * ``set(phys_of.values()) == set(refs.keys())`` — no orphan refcounts,
    no unaccounted rows;
  * ``sum(refs.values()) == len(phys_of)`` — each alias is counted once;
  * a row's refcount hits zero exactly when its last alias releases
    (:meth:`release` returns the freed row then, and only then).

All mutation of alias structure goes through this API; the
`unrefcounted-alias` repro-lint rule fails any serving code path that
scatters into or frees fork-owned rows around it.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple


class ForkPageTable:
    """Host-side refcounted logical->physical page-row map for one store."""

    def __init__(self) -> None:
        self.phys_of: Dict[int, int] = {}    # uid -> physical row
        self.refs: Dict[int, int] = {}       # physical row -> alias count

    # ---- reads -------------------------------------------------------------
    def __contains__(self, uid: int) -> bool:
        return uid in self.phys_of

    def __len__(self) -> int:
        return len(self.phys_of)

    def resolve(self, uid: int) -> int:
        """The physical row backing ``uid`` (KeyError if unmapped)."""
        return self.phys_of[uid]

    def refcount(self, uid: int) -> int:
        """Aliases of the row backing ``uid`` (0 if unmapped)."""
        phys = self.phys_of.get(uid)
        return 0 if phys is None else self.refs[phys]

    def shared(self, uid: int) -> bool:
        """True when ``uid``'s row is aliased by at least one other uid."""
        return self.refcount(uid) > 1

    def aliases(self, phys: int) -> Tuple[int, ...]:
        """All uids aliasing physical row ``phys``, sorted."""
        return tuple(sorted(u for u, p in self.phys_of.items() if p == phys))

    def shared_rows(self) -> Dict[int, int]:
        """``{phys: refcount}`` for every row with refcount > 1."""
        return {p: n for p, n in self.refs.items() if n > 1}

    # ---- mutation (the refcount API the lint rule guards) ------------------
    def bind(self, uid: int, phys: int) -> None:
        """Claim ``phys`` exclusively for ``uid`` (a fresh suspend home).

        ``uid`` must be unmapped and ``phys`` unowned: rebinding a live
        alias or stealing an owned row would silently leak or double-count
        — both raise.
        """
        if uid in self.phys_of:
            raise ValueError(f"uid {uid} already mapped to row "
                             f"{self.phys_of[uid]}; release it first")
        if phys in self.refs:
            raise ValueError(f"row {phys} already owned by "
                             f"{self.aliases(phys)}")
        self.phys_of[uid] = phys
        self.refs[phys] = 1

    def fork_child(self, parent_uid: int, child_uid: int) -> int:
        """Alias ``child_uid`` onto the parent's row: refcount += 1, zero
        allocation, zero device work.  Returns the shared physical row."""
        if child_uid in self.phys_of:
            raise ValueError(f"child uid {child_uid} already mapped")
        phys = self.phys_of[parent_uid]
        self.phys_of[child_uid] = phys
        self.refs[phys] += 1
        return phys

    def write_break(self, uid: int,
                    alloc: Optional[Callable[[int], int]] = None) -> int:
        """CoW detach: return a row ``uid`` may WRITE exclusively.

        Exclusive already -> its current row (the fast path, no copy).
        Shared -> detach: the other aliases keep the old row (refcount -= 1)
        and ``uid`` claims ``alloc(uid)``, a fresh row the caller provides
        (the caller owns placement and performs any data copy — this table
        only does bookkeeping).  ``alloc`` is required exactly when shared.
        """
        phys = self.phys_of[uid]
        if self.refs[phys] == 1:
            return phys
        if alloc is None:
            raise ValueError(f"uid {uid} shares row {phys} with "
                             f"{self.aliases(phys)}; an alloc callback is "
                             f"required to detach")
        new_phys = alloc(uid)
        if new_phys in self.refs:
            raise ValueError(f"alloc returned owned row {new_phys}")
        # alloc may itself have DEMOTED the shared row to free its index
        # (when uid's home row IS the shared row): re-resolve before
        # decrementing so the bookkeeping follows the repoint.
        phys = self.phys_of[uid]
        self.refs[phys] -= 1
        self.phys_of[uid] = new_phys
        self.refs[new_phys] = 1
        return new_phys

    def repoint(self, old_phys: int, new_phys: int) -> Tuple[int, ...]:
        """Move EVERY alias of ``old_phys`` onto ``new_phys`` (a shared-row
        demotion: the caller migrated the bytes; aliases follow as one
        unit, refcount preserved).  Returns the moved uids."""
        if new_phys in self.refs:
            raise ValueError(f"row {new_phys} already owned by "
                             f"{self.aliases(new_phys)}")
        moved = self.aliases(old_phys)
        if not moved:
            raise KeyError(f"row {old_phys} has no aliases")
        for u in moved:
            self.phys_of[u] = new_phys
        self.refs[new_phys] = self.refs.pop(old_phys)
        return moved

    def release(self, uid: int) -> Optional[int]:
        """Drop ``uid``'s alias; returns the physical row iff this was the
        LAST alias (the row is now free to destroy), else None."""
        phys = self.phys_of.pop(uid)
        self.refs[phys] -= 1
        if self.refs[phys] == 0:
            del self.refs[phys]
            return phys
        return None

    def clear(self) -> None:
        """Forget everything (replica failure: the rows died with it)."""
        self.phys_of.clear()
        self.refs.clear()

    # ---- invariants --------------------------------------------------------
    def check_conserved(self) -> None:
        """Assert the conservation identities; raises AssertionError with
        the full state on any violation (used by the property tests after
        every step of a random fork/write/evict/release stream)."""
        targets = set(self.phys_of.values())
        assert targets == set(self.refs), (
            f"alias targets {sorted(targets)} != refcounted rows "
            f"{sorted(self.refs)}")
        assert sum(self.refs.values()) == len(self.phys_of), (
            f"refcounts {self.refs} don't sum to {len(self.phys_of)} aliases")
        for p, n in self.refs.items():
            assert n >= 1, (p, n)
            assert len(self.aliases(p)) == n, (p, n, self.aliases(p))
