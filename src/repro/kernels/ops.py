"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode; on TPU they
compile to Mosaic.  ``use_pallas_attention()`` lets the model stack swap the
pure-jnp chunked attention for the kernel on real hardware.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rbm_copy import (rbm_copy as _copy, villa_gather as _gather,
                                    villa_scatter as _scatter)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_vjp(q, k, v, causal, window, block_q, block_k, interpret):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret)


def _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret), (q, k, v)


def _flash_bwd(causal, window, block_q, block_k, interpret, res, g):
    # Backward via the jnp oracle (flash-recompute): on TPU this is where a
    # dedicated bwd kernel slots in.  The oracle applies the same causal +
    # window masking as the forward kernel, and block_q/block_k are pure
    # tiling (no semantic effect), so gradients are block-size invariant —
    # guarded by test_kernels.py::test_flash_attention_windowed_causal_
    # grad_equivalence and ..._grad_block_size_invariant.
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.flash_attention_ref(
        q_, k_, v_, causal=causal, window=window), q, k, v)
    return vjp(g)


_flash_vjp.defvjp(_flash_fwd, _flash_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128, interpret=None):
    return _flash_vjp(q, k, v, causal, window, block_q, block_k, interpret)


@partial(jax.jit, static_argnames=("tile_rows", "lanes", "interpret"))
def rbm_copy(x, *, tile_rows: int = 256, lanes: int = 128, interpret=None):
    return _copy(x, tile_rows=tile_rows, lanes=lanes, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def villa_gather(pages, table, *, interpret=None):
    return _gather(pages, table, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def villa_scatter(pages, table, updates, *, interpret=None):
    """NOTE: ``pages`` is DONATED (it aliases the output, the whole point of
    the in-place row-buffer write) — on backends that honor donation the
    caller must not reuse it afterwards; pass ``pages + 0`` to keep a copy."""
    return _scatter(pages, table, updates, interpret=interpret)


# Oracles re-exported for benchmarks/tests.
flash_attention_ref = jax.jit(ref.flash_attention_ref,
                              static_argnames=("causal", "window"))
rbm_copy_ref = jax.jit(ref.rbm_copy_ref)
villa_gather_ref = jax.jit(ref.villa_gather_ref)
villa_scatter_ref = jax.jit(ref.villa_scatter_ref)
