"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """Exact softmax attention.  q: (B,H,S,D), k/v: (B,K,T,D), H = K*G."""
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    qr = q.reshape(B, K, G, S, D).astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qr, k.astype(jnp.float32)) * D ** -0.5
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    valid = jnp.ones((S, T), bool)
    if causal:
        valid &= k_pos <= q_pos + (T - S)       # q block at sequence tail
    if window > 0:
        valid &= k_pos > q_pos + (T - S) - window
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)


def rbm_copy_ref(x: jax.Array) -> jax.Array:
    """Bulk copy oracle: identity (the kernel must move every byte)."""
    return x + 0


def villa_gather_ref(pages: jax.Array, table: jax.Array) -> jax.Array:
    """Tiered-cache page gather oracle.  pages: (N, P, d), table: (n,)."""
    return jnp.take(pages, table, axis=0)


def villa_scatter_ref(pages: jax.Array, table: jax.Array,
                      updates: jax.Array) -> jax.Array:
    """Tiered-cache page scatter oracle: pages with updates written by table."""
    return pages.at[table].set(updates)
