"""Pallas TPU bulk-copy kernel: RBM at the VMEM level.

A row-buffer movement is a wide, latency-optimal transfer between adjacent
storage arrays.  The TPU analogue at the kernel level is a tiled HBM->HBM
copy staged through VMEM: the Pallas grid pipeline keeps *two* tile buffers
in flight — while tile i computes (stores), tile i+1's DMA is already running
("precharging" the idle buffer: LISA-LIP, DESIGN.md Sec. 5.4).

Tiles are (rows x 128-lane) MXU/VPU-aligned.  ``rbm_copy`` is the movement
engine used by the serving tier-promotion path and checkpoint resharding when
running on real TPUs; on CPU it validates in interpret mode against the
identity oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def rbm_copy(x: jax.Array, *, tile_rows: int = 256, lanes: int = 128,
             interpret: Optional[bool] = None) -> jax.Array:
    """Copy ``x`` (any shape) through VMEM tiles of (tile_rows, lanes)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    flat = x.reshape(-1)
    n = flat.size
    per_tile = tile_rows * lanes
    n_tiles = -(-n // per_tile)
    pad = n_tiles * per_tile - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    tiled = flat.reshape(n_tiles * tile_rows, lanes)

    out = pl.pallas_call(
        _copy_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile_rows, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(tiled.shape, x.dtype),
        interpret=interpret,
    )(tiled)
    return out.reshape(-1)[:n].reshape(x.shape)


def _gather_kernel(table_ref, pages_ref, out_ref):
    # pages_ref block is selected by the scalar-prefetched table entry;
    # the body is a pure VMEM move.
    out_ref[...] = pages_ref[...]


def villa_gather(pages: jax.Array, table: jax.Array, *,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Gather whole pages by a page table: out[j] = pages[table[j]].

    pages: (N, P, d) — P*d must tile to (8, 128) multiples for real TPUs.
    The page table is scalar-prefetched so the grid pipeline can launch the
    DMA for page j+1 while page j is being written (LIP again) — this is the
    VILLA fast-tier read path.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    from jax.experimental.pallas import tpu as pltpu
    N, P, d = pages.shape
    n_out = table.shape[0]

    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_out,),
            in_specs=[pl.BlockSpec((1, P, d), lambda j, table: (table[j], 0, 0))],
            out_specs=pl.BlockSpec((1, P, d), lambda j, table: (j, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_out, P, d), pages.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), pages)
    return out


def _scatter_kernel(table_ref, pages_ref, upd_ref, out_ref):
    # out block j is routed to pages[table[j]] by the scalar-prefetched
    # table; the body is a pure VMEM store of the staged update tile.
    out_ref[...] = upd_ref[...]


def villa_scatter(pages: jax.Array, table: jax.Array, updates: jax.Array, *,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Scatter whole pages by a page table: out = pages; out[table[j]] = updates[j].

    pages: (N, P, d), updates: (n, P, d) — the VILLA fast-tier *write* path,
    dual of :func:`villa_gather`.  The grid runs over updates only: page j+1's
    DMA is in flight while page j stores (LIP double buffering, DESIGN.md
    Sec. 5.4), and untouched pages never move — ``pages`` is aliased into the
    output (the donated row buffer), so cost is O(touched pages), not O(N).
    Duplicate table entries resolve in grid order (last write wins).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    from jax.experimental.pallas import tpu as pltpu
    N, P, d = pages.shape
    n_upd = updates.shape[0]

    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_upd,),
            in_specs=[pl.BlockSpec((1, P, d), lambda j, table: (0, 0, 0)),
                      pl.BlockSpec((1, P, d), lambda j, table: (j, 0, 0))],
            out_specs=pl.BlockSpec((1, P, d), lambda j, table: (table[j], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, P, d), pages.dtype),
        input_output_aliases={1: 0},    # pages buffer IS the output
        interpret=interpret,
    )(table.astype(jnp.int32), pages, updates)
