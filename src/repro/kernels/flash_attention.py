"""Pallas TPU flash-attention kernel (blockwise online softmax).

LISA mapping: KV blocks stream through VMEM like row buffers through the
LISA links — the Pallas grid pipeline double-buffers the next KV block's DMA
against the current block's MXU work (the LISA-LIP idle-resource-recruitment
property, DESIGN.md Sec. 5.4).

Layout: q (B, H, S, D), k/v (B, K, T, D) with H = K*G (GQA: the index map
routes each q-head block to its kv head — no KV broadcast in HBM).
Causal and sliding-window masks are applied from block coordinates; fully
masked blocks skip their FLOPs via ``pl.when``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, seq_q: int, seq_kv: int,
            causal: bool, window: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level mask decision: q rows are at the *tail* of the kv sequence
    # (cache layout), so q_pos = ki_offset + (seq_kv - seq_q).
    q_off = qi * block_q + (seq_kv - seq_q)
    k_off = ki * block_k
    fully_masked = False
    if causal:
        fully_masked = k_off > q_off + block_q - 1
    if window > 0:
        fully_masked = fully_masked | (k_off + block_k - 1 <= q_off - window)

    @pl.when(jnp.logical_not(jnp.asarray(fully_masked)))
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

        q_pos = q_off + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_off + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        valid = jnp.ones((block_q, block_k), bool)
        if causal:
            valid &= k_pos <= q_pos
        if window > 0:
            valid &= k_pos > q_pos - window
        valid &= k_pos < seq_kv                            # kv padding
        s = jnp.where(valid, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_prev * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + p @ v

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,H,S,D); k/v: (B,K,T,D).  Returns (B,H,S,D)."""
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    nq = -(-S // block_q)
    nk = -(-T // block_k)
    if S % block_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, nq * block_q - S), (0, 0)))
    if T % block_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, nk * block_k - T), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, nk * block_k - T), (0, 0)))

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, seq_q=S, seq_kv=T,
        causal=causal, window=window, scale=D ** -0.5)

    qs = q.reshape(B * H, nq * block_q, D)
    ks = k.reshape(B * K, nk * block_k, D)
    vs = v.reshape(B * K, nk * block_k, D)

    # GQA routing: q-head block bh -> kv row (batch * K + head // G).
    kv_row = lambda bh: (bh // H) * K + (bh % H) // G

    from jax.experimental.pallas import tpu as pltpu

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki: (kv_row(bh), ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki: (kv_row(bh), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # running max
            pltpu.VMEM((block_q, 1), jnp.float32),     # running sum
            pltpu.VMEM((block_q, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qs, ks, vs)
    return out.reshape(B, H, nq * block_q, D)[:, :, :S]
