"""Pipeline parallelism over a mesh axis via LISA hop transfers (GPipe).

Stage-to-stage activation movement is a planned movement: a stage->stage
``movement.Transfer`` lowers to a single neighbor hop-chain leg (the
``ppermute`` shift = the RBM primitive, executed by the ``hop_chain``
backend), exactly the paper's adjacent-subarray path: stage s computes a
microbatch, its output hops one link to stage s+1 while stage s starts the
next microbatch — the classic GPipe schedule with n_stages + n_micro - 1
slots.  The plan's ``MovementCost`` prices each hop with the ICI analogue
of Table 1's linear model.

Implementation: `shard_map` over the pipeline axis; every device holds its
stage's parameters (stacked layer group), the schedule runs a fori_loop over
slots with a rotating microbatch buffer.  Used for the optional PP config
(DESIGN.md §3) and exercised by tests/test_pipeline.py on 4 host devices;
on the production mesh the natural pipeline axis is "pod".
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import movement as MV


def gpipe(stage_fn: Callable, axis_name: str):
    """Build a pipelined forward: ``stage_fn(params_stage, x) -> y``.

    Returns ``run(params_stacked, micro_in) -> micro_out`` to be called
    INSIDE shard_map over ``axis_name``:
      params_stacked: this device's stage params (leading stage dim removed
                      by shard_map's in_spec).
      micro_in: (n_micro, mb, ...) microbatches, replicated; microbatch m
                enters stage 0 at slot m, exits stage S-1 at slot m + S - 1.
    """

    def run(stage_params, micro_in):
        n_stages = jax.lax.axis_size(axis_name)
        idx = jax.lax.axis_index(axis_name)
        n_micro = micro_in.shape[0]
        n_slots = n_stages + n_micro - 1

        micro_in = jax.lax.pvary(micro_in, (axis_name,))
        out_shape = jax.eval_shape(stage_fn, stage_params, micro_in[0])
        # Stage-to-stage hop as a movement plan: one neighbor-shift
        # hop-chain leg, planned once per activation shape at trace time.
        hop_plan = MV.plan(MV.Transfer(
            MV.Tier("stage", axis=axis_name), MV.Tier("stage", axis=axis_name),
            MV.Layout.dense(out_shape.shape, out_shape.dtype)))
        outputs = jnp.zeros((n_micro,) + out_shape.shape, out_shape.dtype)
        outputs = jax.lax.pvary(outputs, (axis_name,))
        carry_in = jnp.zeros_like(micro_in[0])

        def slot(t, state):
            carry_in, outputs = state
            m = t - idx                       # microbatch index at this stage
            active = (m >= 0) & (m < n_micro)
            x = jnp.where(idx == 0,
                          micro_in[jnp.clip(m, 0, n_micro - 1)], carry_in)
            y = stage_fn(stage_params, x)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # RBM hop: activations move one link toward the next stage
            carry_next = MV.execute(hop_plan, data=y)["data"]
            done = active & (idx == n_stages - 1)
            outputs = jax.lax.cond(
                done,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(m, 0, n_micro - 1), 0),
                lambda o: o, outputs)
            return carry_next, outputs

        _, outputs = jax.lax.fori_loop(0, n_slots, slot,
                                       (carry_in, outputs))
        # results live on the last stage; hop them back to stage 0 owners
        # (one wraparound link) so every stage returns the same outputs
        return jax.lax.psum(outputs, axis_name)

    return run


def pipeline_transformer(mesh: Mesh, axis_name: str, layer_fn: Callable,
                         n_layers_per_stage: int):
    """Convenience: stage = scan over this stage's layer slice."""

    def stage_fn(stage_params, x):
        def body(h, p):
            return layer_fn(p, h), None
        out, _ = jax.lax.scan(body, x, stage_params)
        return out

    run = gpipe(stage_fn, axis_name)

    def pipelined(params_stacked, micro_in):
        # params_stacked: (n_stages, n_layers_per_stage, ...) pytree;
        # shard_map keeps the (length-1) stage dim — squeeze it per device.
        def body(p, m):
            return run(jax.tree.map(lambda a: a[0], p), m)
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(axis_name), P()),
            out_specs=P())(params_stacked, micro_in)

    return pipelined
