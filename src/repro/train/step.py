"""Train / serve step factories: jit with explicit in/out shardings.

``make_train_step`` builds the full SPMD training step (fwd + bwd + AdamW)
with FSDP x TP x (optional SP / PP-over-pod) sharding; ``make_serve_step``
builds the decode step over a sharded KV cache.  Both are what the multi-pod
dry-run lowers and compiles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.lisa import compression as COMP
from repro.models import lm
from repro.models.sharding import use_sharding
from repro.optim import adamw
from repro.train import shardings as SH


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    fsdp: bool = True
    tensor_parallel: bool = True      # False: pure DPxFSDP over all axes
    sequence_parallel: bool = False   # shard layer-boundary activations on S
    grad_compress: bool = False       # int8 error-feedback DP all-reduce
    moe_groups: int = 0
    aux_weight: float = 0.01
    z_weight: float = 1e-4


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState
    step: jax.Array
    err_fb: Any                        # error-feedback residuals (or None)


def init_train_state(cfg: ModelConfig, key: jax.Array, pcfg: ParallelConfig,
                     dtype=jnp.float32) -> TrainState:
    params = lm.init_lm(cfg, key, dtype)
    return TrainState(
        params=params, opt=adamw.init(params),
        step=jnp.zeros((), jnp.int32),
        err_fb=COMP.init_error(params) if pcfg.grad_compress else None)


def sharding_rules(pcfg: ParallelConfig) -> Dict[str, Any]:
    rules: Dict[str, Any] = {}
    if pcfg.sequence_parallel:
        rules["seq_sp"] = "model"
    if not pcfg.tensor_parallel:
        # pure DP x FSDP: batch over every mesh axis, no compute sharding of
        # heads/ff/vocab (weights stay fully sharded and are gathered on use;
        # EP stays on "model" — it is DP-compatible).
        rules.update(batch=("pod", "data", "model"), heads=None,
                     kv_heads=None, ff=None, vocab=None, inner=None)
    return rules


def loss_fn(cfg: ModelConfig, pcfg: ParallelConfig, params, batch
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux, _ = lm.forward(
        cfg, params, batch["tokens"], positions=batch.get("positions"),
        enc_embeds=batch.get("enc_embeds"), moe_groups=pcfg.moe_groups)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, batch["labels"][..., None],
                              axis=-1)[..., 0]
    ce = (logz - tgt).mean()
    zloss = jnp.square(logz).mean()
    loss = ce + pcfg.aux_weight * aux + pcfg.z_weight * zloss
    return loss, {"ce": ce, "aux": aux, "zloss": zloss}


def make_train_step(cfg: ModelConfig, mesh: Mesh, pcfg: ParallelConfig,
                    ocfg: Optional[adamw.OptConfig] = None,
                    donate: bool = True):
    ocfg = ocfg or adamw.OptConfig()

    def step_fn(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        with use_sharding(mesh, sharding_rules(pcfg)):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, pcfg, p, batch), has_aux=True
            )(state.params)
            if pcfg.grad_compress:
                # int8 error-feedback re-quantisation of the DP-reduced
                # gradient (jit's psum already averaged over data; the
                # quantised payload is what a LISA ring would carry).
                def q(g, e):
                    qv, s, ne = COMP.compress(g, e)
                    return COMP.decompress(qv, s).astype(g.dtype), ne
                pairs = jax.tree.map(q, grads, state.err_fb)
                grads = jax.tree.map(lambda t: t[0], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
                err_fb = jax.tree.map(lambda t: t[1], pairs,
                                      is_leaf=lambda x: isinstance(x, tuple))
            else:
                err_fb = state.err_fb
            params, opt, om = adamw.update(ocfg, grads, state.opt, state.params)
            metrics = dict(metrics, loss=loss, **om)
            return TrainState(params, opt, state.step + 1, err_fb), metrics

    def state_shardings(state_shapes: TrainState) -> TrainState:
        ps = SH.tree_shardings(state_shapes.params, mesh, SH.param_spec,
                               fsdp=pcfg.fsdp)
        return TrainState(
            params=ps,
            opt=adamw.OptState(
                m=jax.tree.map(lambda _, s: s, state_shapes.opt.m, ps),
                v=jax.tree.map(lambda _, s: s, state_shapes.opt.v, ps),
                count=NamedSharding(mesh, P())),
            step=NamedSharding(mesh, P()),
            err_fb=None if state_shapes.err_fb is None else jax.tree.map(
                lambda _, s: s, state_shapes.err_fb, ps))

    def compile_step(state_shapes, batch_shapes):
        ss = state_shardings(state_shapes)
        dp = ("pod", "data") if pcfg.tensor_parallel else \
            ("pod", "data", "model")
        bs = SH.batch_specs(mesh, batch_shapes, dp_axes=dp)
        rep = NamedSharding(mesh, P())       # prefix spec: all metric leaves
        return jax.jit(step_fn, in_shardings=(ss, bs), out_shardings=(ss, rep),
                       donate_argnums=(0,) if donate else ())

    return step_fn, compile_step, state_shardings


def _logits_sharding(cfg: ModelConfig, mesh: Mesh, batch: int) -> NamedSharding:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = SH._fit_spec([dp if dp else None, None, "model"],
                        (batch, 1, cfg.vocab_size), mesh)
    return NamedSharding(mesh, spec)


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, pcfg: ParallelConfig):
    """Inference-prefill: causal forward + KV-cache population."""
    def step_fn(params, cache, batch):
        with use_sharding(mesh, sharding_rules(pcfg)):
            logits, _, new_cache = lm.forward(
                cfg, params, batch["tokens"],
                positions=batch.get("positions"),
                enc_embeds=batch.get("enc_embeds"),
                cache=cache, mode="prefill", moe_groups=pcfg.moe_groups)
        return logits, new_cache

    def compile_step(param_shapes, cache_shapes, batch_shapes):
        ps = SH.tree_shardings(param_shapes, mesh, SH.param_spec,
                               fsdp=pcfg.fsdp)
        cs = SH.tree_shardings(cache_shapes, mesh, SH.cache_spec)
        bs = SH.batch_specs(mesh, batch_shapes)
        lg = _logits_sharding(cfg, mesh, batch_shapes["tokens"].shape[0])
        return jax.jit(step_fn, in_shardings=(ps, cs, bs),
                       out_shardings=(lg, cs), donate_argnums=(1,))

    return step_fn, compile_step


def make_serve_step(cfg: ModelConfig, mesh: Mesh, pcfg: ParallelConfig):
    def step_fn(params, cache, tokens, pos):
        with use_sharding(mesh, sharding_rules(pcfg)):
            logits, new_cache = lm.decode_step(cfg, params, cache, tokens,
                                               pos, moe_groups=pcfg.moe_groups)
        return logits, new_cache

    def compile_step(param_shapes, cache_shapes, token_shapes):
        ps = SH.tree_shardings(param_shapes, mesh, SH.param_spec,
                               fsdp=pcfg.fsdp)
        cs = SH.tree_shardings(cache_shapes, mesh, SH.cache_spec)
        ts = SH.batch_specs(mesh, token_shapes)
        rep = NamedSharding(mesh, P())
        lg = _logits_sharding(
            cfg, mesh, jax.tree.leaves(token_shapes)[0].shape[0])
        return jax.jit(step_fn, in_shardings=(ps, cs, ts, rep),
                       out_shardings=(lg, cs), donate_argnums=(1,))

    return step_fn, compile_step
