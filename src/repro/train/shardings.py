"""Parameter / cache / batch PartitionSpecs (FSDP x TP, path-based rules).

TP (Megatron) over "model": attention heads, FFN hidden, experts, vocab.
FSDP (ZeRO-3) over "data" (+"pod"): the remaining large dim of every matrix,
gathered per-layer on use.  Stacked layer dims (leading axes added by
scan-over-layers) are never sharded — rules match the *trailing* dims.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex on the param path, spec for the trailing dims) — ORDERED: the first
# match wins, so expert (3-D) rules must precede the generic 2-D matmul rules
# they would otherwise be shadowed by.
_PARAM_RULES = [
    # MoE experts (E over model = EP; fsdp over d_model)
    (r"mlp/wi_gate$|mlp/wi_up$", ("model", "fsdp", None)),
    (r"mlp/wo$", ("model", None, "fsdp")),
    (r"router$", (None, None)),
    # embeddings / head
    (r"embed$", ("model", "fsdp")),                  # (V, M)
    (r"head$", ("fsdp", "model")),                   # (M, V)
    # attention (column-parallel in, row-parallel out)
    (r"wq$|wk$|wv$", ("fsdp", "model")),
    (r"wo$", ("model", "fsdp")),
    (r"bq$|bk$|bv$", ("model",)),
    # MLA
    (r"q_a$|kv_a$", ("fsdp", None)),
    (r"q_b$|kv_b$", (None, "model")),
    # dense MLP
    (r"wi_gate$|wi_up$", ("fsdp", "model")),
    # Mamba
    (r"in_proj$", ("fsdp", "model")),
    (r"conv_w$", (None, "model")),
    (r"conv_b$|dt_bias$|D$", ("model",)),
    (r"x_proj$", ("model", None)),
    (r"dt_w$", (None, "model")),
    (r"A_log$", ("model", None)),
    (r"out_proj$", ("model", "fsdp")),
    # RWKV
    (r"w1$", ("fsdp", None)),
    (r"w2$", (None, "model")),
    (r"u$", ("model", None)),
    (r"cm_wk$", ("fsdp", "model")),
    (r"cm_wv$", ("model", "fsdp")),
    (r"cm_wr$", ("fsdp", "model")),
]

_CACHE_RULES = [
    (r"k_scale$|v_scale$", (("pod", "data"), None, "kv_model")),
    (r"cc_scale$|cr_scale$", (("pod", "data"), None)),
    (r"/k$|/v$|enc_k$|enc_v$", (("pod", "data"), None, "kv_model", None)),
    (r"/pos$|enc_pos$", (("pod", "data"), None)),
    (r"/cc$|/cr$", (("pod", "data"), None, None)),
    (r"conv$", (("pod", "data"), None, "model")),
    (r"ssm$", (("pod", "data"), "model", None)),
    (r"att_shift$|ffn_shift$", (("pod", "data"), None)),
    (r"wkv$", (("pod", "data"), "model", None, None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def _resolve(axis, mesh_axes, fsdp: bool, divisor_ok) -> Any:
    if axis == "fsdp":
        if not fsdp:
            return None
        cand = tuple(a for a in ("pod", "data") if a in mesh_axes)
        return cand if cand else None
    if axis == "kv_model":
        return "model" if "model" in mesh_axes else None
    if isinstance(axis, tuple):
        kept = tuple(a for a in axis if a in mesh_axes)
        return kept if kept else None
    if isinstance(axis, str):
        return axis if axis in mesh_axes else None
    return None


def _fit_spec(spec, shape, mesh: Mesh):
    """Drop axes that don't divide the dim (e.g. kv heads < |model|)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for ax, dim in zip(spec, shape):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else ax
        total = 1
        kept = []
        for a in axes:
            if dim % (total * sizes[a]) == 0:
                kept.append(a)
                total *= sizes[a]
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def param_spec(path, leaf, mesh: Mesh, fsdp: bool = True) -> P:
    s = _path_str(path)
    for pat, trailing in _PARAM_RULES:
        if re.search(pat, s):
            resolved = [_resolve(a, mesh.axis_names, fsdp, None)
                        for a in trailing]
            lead = leaf.ndim - len(resolved)
            spec = [None] * lead + resolved
            return _fit_spec(spec, leaf.shape, mesh)
    return P(*([None] * leaf.ndim))


def cache_spec(path, leaf, mesh: Mesh) -> P:
    s = _path_str(path)
    for pat, trailing in _CACHE_RULES:
        if re.search(pat, s):
            resolved = [_resolve(a, mesh.axis_names, True, None)
                        for a in trailing]
            lead = leaf.ndim - len(resolved)
            spec = [None] * lead + resolved
            return _fit_spec(spec, leaf.shape, mesh)
    return P(*([None] * leaf.ndim))


def tree_shardings(tree, mesh: Mesh, spec_fn, **kw):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, spec_fn(p, l, mesh, **kw)), tree)


def batch_specs(mesh: Mesh, batch_tree, dp_axes=("pod", "data")):
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    def spec(path, leaf):
        s = [dp if dp else None] + [None] * (leaf.ndim - 1)
        # M-RoPE positions are (3, B, S): batch is dim 1
        if _path_str(path).endswith("positions") and leaf.ndim == 3:
            s = [None, dp if dp else None, None]
        return NamedSharding(mesh, _fit_spec(s, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(spec, batch_tree)
