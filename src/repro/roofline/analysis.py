"""Three-term roofline from the compiled dry-run artifact.

    compute    = HLO_FLOPs / peak_FLOP/s          (per chip: SPMD program)
    memory     = HLO_bytes / HBM_bw
    collective = sum(ring link_bytes per op) / ICI link bw

FLOPs/bytes/collective traffic come from the loop-aware HLO cost model
(``repro.roofline.hlo``) because ``compiled.cost_analysis()`` counts while
bodies once (scan-based models undercount by the trip count); the raw
cost_analysis numbers are recorded alongside for reference.

All terms are per-chip (the SPMD program is per-device), so the task
formula's "chips x" denominators cancel against global numerators.
MODEL_FLOPS / (HLO_FLOPs x chips) measures how much compiled compute is
useful — it catches remat recompute, MoE dispatch overhead, and attention
FLOPs that 6*N*D does not credit.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, param_count

PEAK_BF16_FLOPS = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_LINK_BW = 50e9           # bytes/s per link


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N*D (train) / 2*N_active*D (inference), D = tokens processed."""
    n_active = param_count(cfg, active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # one decode step


def roofline_terms(hlo_cost: Dict, n_chips: int, cfg: ModelConfig,
                   shape: ShapeConfig) -> Dict:
    flops = float(hlo_cost["flops"])
    byts = float(hlo_cost["bytes"])
    byts_k = float(hlo_cost.get("bytes_kernel_adjusted", byts))
    coll_bytes = float(hlo_cost["link_bytes_total"])

    compute_s = flops / PEAK_BF16_FLOPS
    memory_s = byts / HBM_BW                 # pure-XLA lowering
    memory_s_kernel = byts_k / HBM_BW        # Pallas kernels for attn/ssm/rwkv
    collective_s = coll_bytes / ICI_LINK_BW

    mf = model_flops(cfg, shape)
    hlo_global = flops * n_chips

    def _frac(mem):
        bound = max(compute_s, mem, collective_s)
        return mf / (bound * n_chips * PEAK_BF16_FLOPS) if bound > 0 else 0.0

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    terms_k = {"compute_s": compute_s, "memory_s": memory_s_kernel,
               "collective_s": collective_s}
    return {
        **terms,
        "memory_s_kernel": memory_s_kernel,
        "dominant": max(terms, key=terms.get),
        "dominant_kernel": max(terms_k, key=terms_k.get),
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": mf / hlo_global if hlo_global else 0.0,
        "collective_link_bytes": coll_bytes,
        # useful global FLOPs over what the binding term allows at peak
        "roofline_fraction": _frac(memory_s),
        "roofline_fraction_kernel": _frac(memory_s_kernel),
    }
