"""Weighted byte/flop attribution over the optimized HLO — the "profile"
the perf loop reads (no real-TPU timings exist on this container; this is
the lowered-IR profile the task prescribes).

  PYTHONPATH=src python -m repro.roofline.attribution /tmp/some_hlo.txt
"""
from __future__ import annotations

import sys
from collections import defaultdict
from typing import Dict

from repro.roofline import hlo as H


def attribute(hlo_text: str, top: int = 20) -> Dict[str, float]:
    hc = H.HloCost(hlo_text)
    acc: Dict[str, float] = defaultdict(float)

    def visit(name: str, weight: float) -> None:
        comp = hc.comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                m = H._WHILE_PARTS.search(op.rest)
                if m:
                    tm = H._TRIP.search(op.rest)
                    visit(m.group(2),
                          weight * (int(tm.group(1)) if tm else 1))
                continue
            if oc in H._BYTES_SKIP_OPS or oc.endswith("-done"):
                continue
            if oc == "fusion":
                m = H._CALLS.search(op.rest)
                callee = hc.comps.get(m.group(1)) if m else None
                rb = H._bytes_of_type(op.type_text)
                opb = sum(H._bytes_of_type(hc._type_of(comp, o))
                          for o in op.operand_names)
                dus = H._dus_update_bytes(callee) if callee is not None else None
                b = (max(opb - dus[1], 0) + 2 * dus[0]) if dus else rb + opb
            elif oc == "dynamic-slice":
                b = 2 * H._bytes_of_type(op.type_text)
            elif oc == "dynamic-update-slice":
                upd = op.operand_names[1] if len(op.operand_names) > 1 else None
                ub = H._bytes_of_type(hc._type_of(comp, upd)) if upd else 0
                b = 2 * ub if ub else H._bytes_of_type(op.type_text)
            else:
                b = H._bytes_of_type(op.type_text) + sum(
                    H._bytes_of_type(hc._type_of(comp, o))
                    for o in op.operand_names)
            if hc._in_kernel_region(op):
                key = "PALLAS_KERNEL_REGION"
            else:
                nm = H._OPNAME.search(op.rest)
                key = nm.group(1) if nm else f"<none> {oc} in {name[:30]}"
            acc[key] += b * weight

    visit(hc.entry.name, 1.0)
    return dict(sorted(acc.items(), key=lambda kv: -kv[1])[:top])


def main() -> None:
    with open(sys.argv[1]) as f:
        txt = f.read()
    for k, v in attribute(txt).items():
        print(f"{v:.3e}  {k[:150]}")


if __name__ == "__main__":
    main()
