"""Loop-aware cost model over post-optimization HLO text.

``compiled.cost_analysis()`` visits while-loop bodies ONCE, so for scan-based
models (layers, attention KV blocks, SSM chunks) it undercounts FLOPs, bytes
and collective traffic by the trip count.  This parser rebuilds the costs
from the optimized HLO:

  * computations are parsed into op lists with def-use type tables;
  * ``while`` trip counts are recovered from the loop-condition constant
    (jax scans lower to ``lt(i, N)``);
  * dot FLOPs = 2 * |result| * |contracted dims| from the printed dnums;
  * bytes follow the fusion model: every top-level op reads its operands
    from and writes its result to HBM; fused computations' internals are
    free (that is exactly what fusion means on TPU);
  * collectives record operand bytes + replica-group size, weighted by the
    product of enclosing trip counts.

Everything is exact for the dot-dominated programs we lower; elementwise /
reduce FLOPs are ignored (orders of magnitude below the matmuls).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_TYPED = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*"
    r"(?P<type>\((?:[^()]|\([^()]*\))*\)|"        # tuple (may hold /*index=N*/)
    r"[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s*(?P<op>[\w\-]+)\((?P<rest>.*)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND = re.compile(r"%([\w\.\-]+)")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_PARTS = re.compile(r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONSTANT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_BYTES_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "call", "custom-call",
}

_FRAME_ID = re.compile(r"stack_frame_id=(\d+)")
_TABLE_ROW = re.compile(r"^(\d+)\s+(.*)$")
_LOC_ROW = re.compile(r"function_name_id=(\d+)")
_FRAME_ROW = re.compile(r"file_location_id=(\d+)\s+parent_frame_id=(\d+)")

# Regions that run as Pallas kernels on real TPUs: their HLO fusion-boundary
# tensors stay in VMEM inside the kernel, so the kernel-adjusted memory term
# excludes them (see repro/kernels/*).  Model code marks them with
# jax.named_scope("pallas_kernel_region"), which survives jvp/transpose/remat
# in op_name metadata; stack-frame function names are the fallback.
KERNEL_SCOPE = "pallas_kernel_region"
KERNEL_FNS = ("chunked_attention", "_wkv_scan", "_ssm_scan")
_OPNAME = re.compile(r'op_name="([^"]*)"')


def parse_stack_tables(hlo: str):
    """FileNames/FunctionNames/FileLocations/StackFrames -> frame_id -> set
    of function names on the frame chain."""
    section = None
    fn_names: Dict[int, str] = {}
    loc_fn: Dict[int, int] = {}
    frames: Dict[int, tuple] = {}
    for line in hlo.splitlines():
        s = line.strip()
        if s in ("FileNames", "FunctionNames", "FileLocations", "StackFrames"):
            section = s
            continue
        if section is None:
            continue
        m = _TABLE_ROW.match(s)
        if not m:
            if s and not s[0].isdigit():
                section = None
            continue
        idx, rest = int(m.group(1)), m.group(2)
        if section == "FunctionNames":
            fn_names[idx] = rest.strip().strip('"')
        elif section == "FileLocations":
            lm = _LOC_ROW.search(rest)
            if lm:
                loc_fn[idx] = int(lm.group(1))
        elif section == "StackFrames":
            fm = _FRAME_ROW.search(rest)
            if fm:
                frames[idx] = (int(fm.group(1)), int(fm.group(2)))

    chains: Dict[int, frozenset] = {}

    def chain(fid: int, depth: int = 0) -> frozenset:
        if fid in chains:
            return chains[fid]
        if fid not in frames or depth > 64:
            return frozenset()
        loc, parent = frames[fid]
        names = {fn_names.get(loc_fn.get(loc, -1), "")}
        if parent != fid and parent in frames:
            names |= chain(parent, depth + 1)
        out = frozenset(n for n in names if n)
        chains[fid] = out
        return out

    return {fid: chain(fid) for fid in frames}


def _bytes_of_type(t: str) -> int:
    total = 0
    for dt, dims in _TYPED.findall(t):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims_of_type(t: str) -> List[int]:
    m = _TYPED.search(t)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Op:
    name: str
    type_text: str
    opcode: str
    rest: str          # operands + attrs (everything after the open paren)

    @property
    def operand_names(self) -> List[str]:
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return _OPERAND.findall(self.rest[:i])
        return _OPERAND.findall(self.rest)


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]
    _types: Optional[Dict[str, str]] = None

    @property
    def types(self) -> Dict[str, str]:
        # lazy: ops are appended after construction by split_computations
        if self._types is None or len(self._types) != len(self.ops):
            self._types = {o.name: o.type_text for o in self.ops}
        return self._types


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if line.rstrip().endswith("{") else None
        if hdr:
            cur = Computation(hdr.group(2), bool(hdr.group(1)), [])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            cur.ops.append(Op(m.group("name"), m.group("type"),
                              m.group("op"), m.group("rest")))
    return comps


def _trip_count(cond: Computation) -> int:
    consts = [int(c) for o in cond.ops for c in _CONSTANT.findall(o.rest + o.type_text)]
    # also match "constant(N)" appearing as its own op line
    for o in cond.ops:
        if o.opcode == "constant":
            m = re.match(r"(\d+)", o.rest)
            if m:
                consts.append(int(m.group(1)))
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


def _dot_flops(op: Op, comp: Computation) -> float:
    result_dims = _dims_of_type(op.type_text)
    out = 1.0
    for d in result_dims:
        out *= d
    lhs = op.operand_names[0] if op.operand_names else None
    lhs_dims = _dims_of_type(comp.types.get(lhs, "")) if lhs else []
    contracted = 1.0
    m = _CONTRACT.search(op.rest)
    if m and m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                contracted *= lhs_dims[idx]
    return 2.0 * out * contracted


def _dus_update_bytes(callee: "Computation"):
    """(update_bytes, buffer_bytes) of a dynamic-update-slice inside a fused
    computation, or None."""
    for o in callee.ops:
        if o.opcode == "dynamic-update-slice" and len(o.operand_names) > 1:
            ub = _bytes_of_type(callee.types.get(o.operand_names[1], ""))
            bb = _bytes_of_type(callee.types.get(o.operand_names[0], ""))
            if ub:
                return ub, bb
    return None


def _group_size(rest: str) -> int:
    gm = _GROUPS_LIST.search(rest)
    if gm:
        return len(gm.group(1).split(","))
    gm = _GROUPS_IOTA.search(rest)
    if gm:
        return int(gm.group(2))
    return 0


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    kernel_bytes: float = 0.0     # bytes inside Pallas-kernel source regions
    transcendentals: float = 0.0
    collectives: Dict[str, Dict] = dataclasses.field(
        default_factory=lambda: {k: {"count": 0.0, "operand_bytes": 0.0,
                                     "result_bytes": 0.0, "link_bytes": 0.0}
                                 for k in COLLECTIVE_KINDS})

    @property
    def bytes_kernel_adjusted(self) -> float:
        """Memory traffic with kernel regions VMEM-resident (TPU path)."""
        return self.bytes - self.kernel_bytes

    def add(self, other: "Cost", weight: float = 1.0,
            include_bytes: bool = True) -> None:
        self.flops += other.flops * weight
        self.transcendentals += other.transcendentals * weight
        if include_bytes:
            self.bytes += other.bytes * weight
            self.kernel_bytes += other.kernel_bytes * weight
        for k, rec in other.collectives.items():
            mine = self.collectives[k]
            for f in ("count", "operand_bytes", "result_bytes", "link_bytes"):
                mine[f] += rec[f] * weight


def link_bytes(kind: str, operand_bytes: float, group_size: int) -> float:
    """Bytes crossing one device's link under a ring schedule."""
    n = max(group_size, 2)
    if kind == "all-gather":
        return operand_bytes * (n - 1)              # operand = local shard
    if kind == "reduce-scatter":
        return operand_bytes * (n - 1) / n          # operand = full array
    if kind == "all-reduce":
        return 2 * operand_bytes * (n - 1) / n
    if kind == "all-to-all":
        return operand_bytes * (n - 1) / n
    if kind == "collective-permute":
        return operand_bytes
    return operand_bytes


class HloCost:
    def __init__(self, hlo: str, kernel_fns: tuple = KERNEL_FNS):
        self.comps = split_computations(hlo)
        self._memo: Dict[str, Cost] = {}
        entries = [c for c in self.comps.values() if c.is_entry]
        if not entries:
            raise ValueError("no ENTRY computation found")
        self.entry = entries[0]
        # module-global name -> type fallback (HLO names are unique)
        self.global_types: Dict[str, str] = {}
        for c in self.comps.values():
            self.global_types.update(c.types)
        self.kernel_fns = kernel_fns
        self.frame_chains = parse_stack_tables(hlo) if kernel_fns else {}

    def _type_of(self, comp: Computation, name: str) -> str:
        return comp.types.get(name) or self.global_types.get(name, "")

    def _in_kernel_region(self, op: Op) -> bool:
        nm = _OPNAME.search(op.rest)
        if nm and KERNEL_SCOPE in nm.group(1):
            return True
        if not self.frame_chains:
            return False
        m = _FRAME_ID.search(op.rest)
        if not m:
            return False
        chain = self.frame_chains.get(int(m.group(1)), frozenset())
        # names carry closure suffixes ("chunked_attention.<locals>.step")
        return any(fn in name for name in chain for fn in self.kernel_fns)

    def cost(self) -> Cost:
        return self._cost_of(self.entry.name)

    def _cost_of(self, name: str, in_kernel: bool = False) -> Cost:
        key = (name, in_kernel)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = Cost()
        self._memo[key] = total
        if comp is None:
            return total

        def charge(b, op):
            total.bytes += b
            if in_kernel or self._in_kernel_region(op):
                total.kernel_bytes += b

        for op in comp.ops:
            oc = op.opcode
            base_kind = oc[:-6] if oc.endswith("-start") else oc
            if base_kind in COLLECTIVE_KINDS:
                ob = sum(_bytes_of_type(self._type_of(comp, o))
                         for o in op.operand_names)
                gs = _group_size(op.rest)
                rec = total.collectives[base_kind]
                rec["count"] += 1
                rec["operand_bytes"] += ob
                rec["result_bytes"] += _bytes_of_type(op.type_text)
                rec["link_bytes"] += link_bytes(base_kind, ob, gs)
                charge(ob + _bytes_of_type(op.type_text), op)
                continue
            if oc.endswith("-done") or oc.endswith("-update"):
                continue
            if oc == "while":
                m = _WHILE_PARTS.search(op.rest)
                if m:
                    cond, body = m.group(1), m.group(2)
                    tm = _TRIP.search(op.rest)
                    if tm:
                        trips = int(tm.group(1))
                    elif cond in self.comps:
                        trips = _trip_count(self.comps[cond])
                    else:
                        trips = 1
                    child_k = in_kernel or self._in_kernel_region(op)
                    total.add(self._cost_of(body, child_k), weight=trips)
                continue
            if oc == "conditional":
                m = _BRANCHES.search(op.rest)
                if m:
                    branches = _OPERAND.findall(m.group(1)) or \
                        [b.strip().lstrip("%") for b in m.group(1).split(",")]
                    costs = [self._cost_of(b) for b in branches
                             if b in self.comps]
                    if costs:
                        biggest = max(costs, key=lambda c: c.flops + c.bytes)
                        total.add(biggest)
                continue
            if oc == "fusion":
                m = _CALLS.search(op.rest)
                callee = self.comps.get(m.group(1)) if m else None
                if callee is not None:
                    total.add(self._cost_of(callee.name), include_bytes=False)
                # fusion reads operands, writes result (HBM boundary);
                # in-place dynamic-update-slice fusions only touch the slice,
                # not the aliased buffer.
                rb = _bytes_of_type(op.type_text)
                opb = sum(_bytes_of_type(self._type_of(comp, o))
                          for o in op.operand_names)
                dus = _dus_update_bytes(callee) if callee is not None else None
                if dus is not None:
                    upd_b, buf_b = dus
                    b = max(opb - buf_b, 0) + 2 * upd_b
                else:
                    b = rb + opb
                charge(b, op)
                continue
            if oc in ("call", "custom-call"):
                m = _CALLS.search(op.rest)
                if m and m.group(1) in self.comps:
                    total.add(self._cost_of(m.group(1)))
                if oc == "custom-call":
                    total.bytes += _bytes_of_type(op.type_text) + sum(
                        _bytes_of_type(self._type_of(comp, o))
                        for o in op.operand_names)
                continue
            if oc in ("dot", "convolution"):
                total.flops += _dot_flops(op, comp)
            if oc in ("exponential", "tanh", "logistic", "log", "rsqrt",
                      "sqrt", "power", "cosine", "sine"):
                total.transcendentals += float(
                    max(1, _bytes_of_type(op.type_text) // 4))
            if oc in _BYTES_SKIP_OPS:
                continue
            if oc == "dynamic-slice":
                b = 2 * _bytes_of_type(op.type_text)       # read + write slice
            elif oc == "dynamic-update-slice":
                upd = (op.operand_names[1]
                       if len(op.operand_names) > 1 else None)
                ub = _bytes_of_type(self._type_of(comp, upd)) if upd else 0
                b = 2 * ub if ub else _bytes_of_type(op.type_text)
            else:
                b = _bytes_of_type(op.type_text) + sum(
                    _bytes_of_type(self._type_of(comp, o))
                    for o in op.operand_names)
            charge(b, op)
        return total


def analyze(hlo: str) -> Dict:
    cost = HloCost(hlo).cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "kernel_region_bytes": cost.kernel_bytes,
        "bytes_kernel_adjusted": cost.bytes_kernel_adjusted,
        "transcendentals": cost.transcendentals,
        "collectives": cost.collectives,
        "link_bytes_total": sum(r["link_bytes"]
                                for r in cost.collectives.values()),
    }


# Backwards-compatible line-level parse (used by tests for cross-checking).
def parse_collectives(hlo: str) -> Dict[str, Dict]:
    cost = HloCost(hlo).cost()
    return cost.collectives
