"""Render EXPERIMENTS.md tables from the dry-run artifacts.

  PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

NOTE = {
    # one sentence per (dominant term) on what would move it down
    "compute_s": "compute-bound: gains come from larger per-chip tiles "
                 "(less TP for this size) and bf16 everywhere",
    "memory_s": "memory-bound: cut activation traffic (fused kernels, bf16 "
                "cotangents, less remat) or raise arithmetic intensity "
                "(bigger per-chip batch)",
    "collective_s": "collective-bound: reshard (less FSDP gather / EP "
                    "all-to-all payload), overlap rings with compute, or "
                    "compress payloads",
}


def load(dir_: str, variant: str = "baseline"):
    """Collect the ok dry-run cells.  Files open under a context manager
    (the old ``json.load(open(f))`` leaked the handle until GC), and a
    cell that fails to parse is SKIPPED with a warning rather than taking
    the whole report down — one corrupt artifact should cost one row."""
    cells = []
    for f in sorted(glob.glob(os.path.join(dir_, f"*_{variant}.json"))):
        try:
            with open(f) as fh:
                a = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"report: skipping {f}: {e}", file=sys.stderr)
            continue
        if not isinstance(a, dict):
            print(f"report: skipping {f}: not a JSON object",
                  file=sys.stderr)
            continue
        if a.get("status") == "ok":
            cells.append(a)
    return cells


def fmt_table(cells, mesh="single"):
    rows = []
    hdr = ("| arch | shape | compute s | memory s (xla/kernel) | coll s | "
           "dominant | MODEL_FLOPS | useful | frac | bottleneck note |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for a in cells:
        if a["mesh"] != mesh:
            continue
        r = a["roofline"]
        dom = r["dominant_kernel"]
        rows.append(
            f"| {a['arch']} | {a['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.3f} / {r['memory_s_kernel']:.3f} | "
            f"{r['collective_s']:.4f} | {dom.replace('_s','')} | "
            f"{r['model_flops']:.2e} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction_kernel']:.4f} | {NOTE[dom]} |")
    return "\n".join(rows)


def fmt_dryrun_summary(cells):
    rows = ["| arch | shape | mesh | chips | compile s | HLO GF/chip | "
            "HBM GB/chip | link GB/chip | collectives (ag/ar/rs/a2a/cp) | "
            "args GB/chip | temp GB/chip |",
            "|" + "---|" * 11]
    for a in cells:
        c = a["collectives"]
        counts = "/".join(str(int(c[k]["count"])) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        mem = a["memory"]
        arg = (mem.get("argument_size_bytes") or 0) / 1e9
        tmp = (mem.get("temp_size_bytes") or 0) / 1e9
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | {a['n_chips']} | "
            f"{a['compile_s']:.0f} | {a['hlo_cost']['flops']/1e9:.1f} | "
            f"{a['hlo_cost']['bytes']/1e9:.1f} | "
            f"{a['hlo_cost']['link_bytes_total']/1e9:.2f} | {counts} | "
            f"{arg:.2f} | {tmp:.2f} |")
    return "\n".join(rows)


def pick_hillclimb(cells):
    """worst roofline fraction / most collective-bound / paper-representative."""
    singles = [a for a in cells if a["mesh"] == "single"
               and a["kind"] == "train"]
    worst = min(singles, key=lambda a: a["roofline"]["roofline_fraction_kernel"])
    coll = max(cells, key=lambda a: (a["roofline"]["collective_s"]
                                     / max(a["roofline"]["compute_s"], 1e-9)))
    return worst, coll


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="experiments/dryrun")
    p.add_argument("--variant", default="baseline")
    p.add_argument("--what", default="roofline",
                   choices=["roofline", "dryrun", "pick"])
    p.add_argument("--mesh", default="single")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="also write the loaded cells as strict JSON")
    args = p.parse_args()
    cells = load(args.dir, args.variant)
    if args.json:
        # strict JSON: a NaN in any cell fails HERE, not in a consumer
        with open(args.json, "w") as fh:
            json.dump({"schema": "dryrun-cells/v1", "n": len(cells),
                       "cells": cells}, fh, indent=2, sort_keys=True,
                      allow_nan=False)
            fh.write("\n")
    if args.what == "roofline":
        print(fmt_table(cells, args.mesh))
    elif args.what == "dryrun":
        print(fmt_dryrun_summary(cells))
    else:
        worst, coll = pick_hillclimb(cells)
        print("worst fraction:", worst["arch"], worst["shape"],
              worst["roofline"]["roofline_fraction_kernel"])
        print("most collective-bound:", coll["arch"], coll["shape"],
              coll["mesh"],
              coll["roofline"]["collective_s"] / max(
                  coll["roofline"]["compute_s"], 1e-9))


if __name__ == "__main__":
    main()
