"""RWKV-6 ("Finch") blocks: attention-free time-mix with *data-dependent
decay* (the defining RWKV-6 feature) + squared-ReLU channel-mix.

State is O(1) in context length: per block a (B, H, D, D) wkv matrix plus two
token-shift vectors — which is why rwkv6 runs the long_500k decode shape.
Training runs a chunked, rematerialised scan like the Mamba block.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.sharding import constrain

HEAD_DIM = 64
DECAY_LORA = 64


class RWKVState(NamedTuple):
    att_shift: jax.Array   # (B, d_model) — previous token (time-mix)
    ffn_shift: jax.Array   # (B, d_model) — previous token (channel-mix)
    wkv: jax.Array         # (B, H, D, D) fp32 — key-value state


def init_rwkv_params(key: jax.Array, d_model: int, d_ff: int,
                     dtype=jnp.float32) -> Dict:
    H = d_model // HEAD_DIM
    ks = jax.random.split(key, 12)
    return {
        # time-mix token-shift interpolation weights
        "mu_w": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_k": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_v": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_r": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_g": jnp.full((d_model,), 0.5, jnp.float32),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(xw@w1)@w2))
        "w0": jnp.full((d_model,), -6.0, jnp.float32),
        "w1": dense_init(ks[0], (d_model, DECAY_LORA), dtype=dtype),
        "w2": dense_init(ks[1], (DECAY_LORA, d_model),
                         scale=DECAY_LORA ** -0.5, dtype=dtype),
        "u": dense_init(ks[2], (H, HEAD_DIM), scale=1.0, dtype=jnp.float32),
        "wk": dense_init(ks[3], (d_model, d_model), dtype=dtype),
        "wv": dense_init(ks[4], (d_model, d_model), dtype=dtype),
        "wr": dense_init(ks[5], (d_model, d_model), dtype=dtype),
        "wg": dense_init(ks[6], (d_model, d_model), dtype=dtype),
        "wo": dense_init(ks[7], (d_model, d_model), dtype=dtype),
        "ln_x": jnp.ones((d_model,), jnp.float32),
        # channel-mix
        "cm_mu_k": jnp.full((d_model,), 0.5, jnp.float32),
        "cm_mu_r": jnp.full((d_model,), 0.5, jnp.float32),
        "cm_wk": dense_init(ks[8], (d_model, d_ff), dtype=dtype),
        "cm_wv": dense_init(ks[9], (d_ff, d_model), dtype=dtype),
        "cm_wr": dense_init(ks[10], (d_model, d_model), dtype=dtype),
    }


def _shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """Token shift: x[t-1] (zeros / carried state at t=0)."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None, :].astype(x.dtype), x], 1)[:, :-1]


def _wkv_scan(r, k, v, w, u, chunk: int, state: jax.Array | None):
    """RWKV-6 recurrence.  r,k,v: (B,S,H,D); w: (B,S,H,D) decay in (0,1).

    out_t = r_t . (S_{t-1} + u * k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    B, S, H, D = r.shape

    def inner(s, inp):
        r_t, k_t, v_t, w_t = inp                                # (B,H,D)
        kv = k_t[..., :, None] * v_t[..., None, :]              # (B,H,Dk,Dv)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, out

    @jax.checkpoint
    def run_chunk(s, inp):
        return jax.lax.scan(inner, s, inp)

    if state is None:
        state = jnp.zeros((B, H, D, D), jnp.float32)

    if S == 1:
        s, out = inner(state, (r[:, 0], k[:, 0], v[:, 0], w[:, 0]))
        return out[:, None], s

    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk
    resh = lambda a: jnp.moveaxis(a.reshape(B, nc, chunk, H, D), (1, 2), (0, 1))
    # TPU path: a chunked GLA-style wkv kernel (VMEM-resident state); marked
    # for the roofline's kernel-adjusted memory accounting.
    with jax.named_scope("pallas_kernel_region"):
        s, ys = jax.lax.scan(lambda s, i: run_chunk(s, i), state,
                             (resh(r), resh(k), resh(v), resh(w)))
    return jnp.moveaxis(ys.reshape(nc * chunk, B, H, D), 0, 1), s


def rwkv_time_mix(params: Dict, x: jax.Array, *, chunk: int = 128,
                  state: RWKVState | None = None
                  ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array] | None]:
    B, S, M = x.shape
    H = M // HEAD_DIM
    prev = state.att_shift if state is not None else None
    xs = _shift(x, prev)
    mix = lambda mu: (x + (xs - x) * mu).astype(x.dtype)

    xw, xk, xv, xr, xg = (mix(params[f"mu_{n}"]) for n in "wkvrg")
    # data-dependent per-channel decay (the Finch contribution)
    dd = params["w0"] + jnp.tanh(xw @ params["w1"]).astype(jnp.float32) \
        @ params["w2"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(jnp.clip(dd, -20.0, 1.0)))             # (B,S,M)

    k = (xk @ params["wk"]).reshape(B, S, H, HEAD_DIM)
    v = (xv @ params["wv"]).reshape(B, S, H, HEAD_DIM)
    r = (xr @ params["wr"]).reshape(B, S, H, HEAD_DIM)
    g = jax.nn.silu(xg @ params["wg"])
    k = constrain(k, "batch", None, "heads", None)

    out, new_wkv = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32),
                             w.reshape(B, S, H, HEAD_DIM), params["u"],
                             chunk, state.wkv if state is not None else None)
    # per-head group-norm (RWKV uses GN over heads)
    mean = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 64e-5)
    out = out.reshape(B, S, M) * params["ln_x"]
    y = (out.astype(x.dtype) * g) @ params["wo"]
    if state is None:
        return y, None
    return y, (x[:, -1, :], new_wkv)


def rwkv_channel_mix(params: Dict, x: jax.Array,
                     state: RWKVState | None = None
                     ) -> Tuple[jax.Array, jax.Array | None]:
    prev = state.ffn_shift if state is not None else None
    xs = _shift(x, prev)
    xk = x + (xs - x) * params["cm_mu_k"]
    xr = x + (xs - x) * params["cm_mu_r"]
    k = jnp.square(jax.nn.relu(xk.astype(x.dtype) @ params["cm_wk"]))
    k = constrain(k, "batch", None, "ff")
    v = k @ params["cm_wv"]
    y = jax.nn.sigmoid(xr.astype(x.dtype) @ params["cm_wr"]) * v
    return y, (x[:, -1, :] if state is not None else None)


def init_rwkv_state(batch: int, d_model: int, dtype=jnp.float32) -> RWKVState:
    H = d_model // HEAD_DIM
    return RWKVState(
        att_shift=jnp.zeros((batch, d_model), dtype),
        ffn_shift=jnp.zeros((batch, d_model), dtype),
        wkv=jnp.zeros((batch, H, HEAD_DIM, HEAD_DIM), jnp.float32),
    )
