"""Composable LM assembly for all assigned architectures.

A model is a sequence of *stages*; each stage scans a repeating *group* of
layers (the smallest period of the per-layer kind sequence), so heterogeneous
stacks (gemma3's 5-local:1-global, jamba's 7-mamba:1-attn with alternating
MoE) compile to small HLO with stacked parameters, exactly like uniform
stacks.

Three modes share one code path:
  train    — causal over the sequence, no cache
  prefill  — train math + cache writes (tail-slice for windowed layers)
  decode   — single token, attends over the cache

Caches: full KV / ring-buffer window KV / MLA compressed / Mamba state /
RWKV state / enc-dec cross-KV.  All functional (pytrees in, pytrees out).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import rwkv as R
from repro.models import ssm as S
from repro.models.layers import embed, init_embed, init_mlp, init_rms, mlp, rms_norm, unembed
from repro.models.sharding import constrain

LayerSpec = Tuple[str, str]     # (mixer_kind, mlp_kind)


# ---------------------------------------------------------------------------
# Stage decomposition: smallest repeating pattern + tail.
# ---------------------------------------------------------------------------

def stages_of(cfg: ModelConfig) -> List[Tuple[int, Tuple[LayerSpec, ...]]]:
    kinds = list(zip(cfg.layer_kinds(), cfg.mlp_kinds()))
    n = len(kinds)
    for p in range(1, n + 1):
        if all(kinds[i] == kinds[i % p] for i in range(n)):
            reps, tail = n // p, n % p
            out = [(reps, tuple(kinds[:p]))]
            if tail:
                out.append((1, tuple(kinds[reps * p:])))
            return out
    return [(1, tuple(kinds))]


# ---------------------------------------------------------------------------
# Per-layer init.
# ---------------------------------------------------------------------------

def _init_mixer(cfg: ModelConfig, key: jax.Array, kind: str, dtype) -> Dict:
    if kind in ("attn_full", "attn_local"):
        return A.init_gqa_params(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, cfg.qkv_bias, dtype)
    if kind == "mla":
        return A.init_mla_params(key, cfg.d_model, cfg.n_heads, cfg.q_lora_rank,
                                 cfg.kv_lora_rank, cfg.qk_nope_dim,
                                 cfg.qk_rope_dim, cfg.v_head_dim, dtype)
    if kind == "mamba":
        return S.init_mamba_params(key, cfg.d_model, cfg.d_state, cfg.d_conv,
                                   cfg.expand, dtype)
    if kind == "rwkv":
        return R.init_rwkv_params(key, cfg.d_model, cfg.d_ff, dtype)
    raise ValueError(kind)


def _init_block(cfg: ModelConfig, key: jax.Array, spec: LayerSpec,
                cross: bool, dtype) -> Dict:
    mixer_kind, mlp_kind = spec
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": init_rms(cfg.d_model),
                         "mixer": _init_mixer(cfg, k1, mixer_kind, dtype)}
    if mlp_kind == "dense":
        p["ln2"] = init_rms(cfg.d_model)
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    elif mlp_kind == "moe":
        p["ln2"] = init_rms(cfg.d_model)
        p["mlp"] = MOE.init_moe_params(k2, cfg.d_model, cfg.n_experts,
                                       cfg.moe_d_ff, cfg.n_shared_experts,
                                       cfg.activation, dtype)
    elif mlp_kind == "rwkv_cm":
        p["ln2"] = init_rms(cfg.d_model)          # channel-mix params live in mixer
    if cross:
        p["ln_cross"] = init_rms(cfg.d_model)
        p["cross"] = A.init_cross_params(k3, cfg.d_model, cfg.n_heads,
                                         cfg.head_dim, dtype)
    return p


def _init_stage(cfg: ModelConfig, key: jax.Array, reps: int,
                group: Tuple[LayerSpec, ...], cross: bool, dtype) -> Dict:
    def one(k):
        ks = jax.random.split(k, len(group))
        return {f"b{j}": _init_block(cfg, ks[j], spec, cross, dtype)
                for j, spec in enumerate(group)}
    return jax.vmap(one)(jax.random.split(key, reps))


def init_lm(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_embed(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_rms(cfg.d_model),
    }
    for i, (reps, group) in enumerate(stages_of(cfg)):
        params[f"stage{i}"] = _init_stage(cfg, ks[2 + i], reps, group,
                                          cross=cfg.encdec, dtype=dtype)
    if not cfg.tie_embeddings:
        params["head"] = init_embed(ks[1], cfg.vocab_size, cfg.d_model,
                                    dtype).T
    if cfg.encdec:
        enc_spec: LayerSpec = ("attn_full", "dense")
        params["encoder"] = _init_stage(cfg, ks[6], cfg.n_enc_layers,
                                        (enc_spec,), cross=False, dtype=dtype)
        params["enc_norm"] = init_rms(cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# Cache construction.
# ---------------------------------------------------------------------------

def _block_cache(cfg: ModelConfig, spec: LayerSpec, reps: int, batch: int,
                 max_len: int, enc_len: int, dtype) -> Dict:
    mixer_kind, _ = spec
    c: Dict[str, Any] = {}
    if mixer_kind in ("attn_full", "attn_local"):
        L = max_len if (mixer_kind == "attn_full" or cfg.window == 0) \
            else min(max_len, cfg.window)
        c["k"] = jnp.zeros((reps, batch, L, cfg.n_kv_heads, cfg.head_dim), dtype)
        c["v"] = jnp.zeros((reps, batch, L, cfg.n_kv_heads, cfg.head_dim), dtype)
        c["pos"] = jnp.full((reps, batch, L), 2**30, jnp.int32)
        if dtype == jnp.int8:      # quantised KV: per-token-per-head scales
            c["k_scale"] = jnp.zeros((reps, batch, L, cfg.n_kv_heads),
                                     jnp.float32)
            c["v_scale"] = jnp.zeros((reps, batch, L, cfg.n_kv_heads),
                                     jnp.float32)
    elif mixer_kind == "mla":
        c["cc"] = jnp.zeros((reps, batch, max_len, cfg.kv_lora_rank), dtype)
        c["cr"] = jnp.zeros((reps, batch, max_len, cfg.qk_rope_dim), dtype)
        c["pos"] = jnp.full((reps, batch, max_len), 2**30, jnp.int32)
        if dtype == jnp.int8:
            c["cc_scale"] = jnp.zeros((reps, batch, max_len), jnp.float32)
            c["cr_scale"] = jnp.zeros((reps, batch, max_len), jnp.float32)
    elif mixer_kind == "mamba":
        st = S.init_mamba_state(batch, cfg.d_model, cfg.d_state, cfg.d_conv,
                                cfg.expand, dtype)
        c["conv"] = jnp.zeros((reps,) + st.conv.shape, dtype)
        c["ssm"] = jnp.zeros((reps,) + st.ssm.shape, jnp.float32)
    elif mixer_kind == "rwkv":
        st = R.init_rwkv_state(batch, cfg.d_model, dtype)
        c["att_shift"] = jnp.zeros((reps,) + st.att_shift.shape, dtype)
        c["ffn_shift"] = jnp.zeros((reps,) + st.ffn_shift.shape, dtype)
        c["wkv"] = jnp.zeros((reps,) + st.wkv.shape, jnp.float32)
    if cfg.encdec:
        c["enc_k"] = jnp.zeros((reps, batch, enc_len, cfg.n_heads, cfg.head_dim), dtype)
        c["enc_v"] = jnp.zeros((reps, batch, enc_len, cfg.n_heads, cfg.head_dim), dtype)
        c["enc_pos"] = jnp.full((reps, batch, enc_len), 2**30, jnp.int32)
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0, dtype=jnp.float32) -> Dict:
    cache: Dict[str, Any] = {}
    for i, (reps, group) in enumerate(stages_of(cfg)):
        cache[f"stage{i}"] = {
            f"b{j}": _block_cache(cfg, spec, reps, batch, max_len, enc_len, dtype)
            for j, spec in enumerate(group)}
    return cache


# ---------------------------------------------------------------------------
# Block application (one layer; train/prefill/decode).
# ---------------------------------------------------------------------------

def _apply_block(cfg: ModelConfig, spec: LayerSpec, p: Dict, x: jax.Array,
                 positions: jax.Array, mode: str,
                 cache: Optional[Dict], cache_index,
                 enc_out: Optional[jax.Array],
                 moe_groups: int) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    mixer_kind, mlp_kind = spec
    aux = jnp.zeros((), jnp.float32)
    B, Sq, _ = x.shape
    new_cache: Dict[str, Any] = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    window = cfg.window if mixer_kind == "attn_local" else 0
    tok_pos = positions[0] if cfg.mrope else positions

    if mixer_kind in ("attn_full", "attn_local"):
        kv = None
        idx = None
        kv_scales = None
        if cache is not None and mode == "decode":
            L = cache["k"].shape[1]
            idx = cache_index % L
            kv = (cache["k"], cache["v"], cache["pos"])
            if "k_scale" in cache:
                kv_scales = (cache["k_scale"], cache["v_scale"])
        y, newkv = A.gqa_block(
            p["mixer"], h, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta, mrope=cfg.mrope,
            window=window, block=cfg.attn_block, kv_cache=kv, cache_index=idx,
            kv_scales=kv_scales)
        if cache is not None and mode == "decode":
            new_cache.update(k=newkv[0], v=newkv[1], pos=newkv[2])
            if newkv[3] is not None:
                new_cache.update(k_scale=newkv[3][0], v_scale=newkv[3][1])
        elif cache is not None:  # prefill: recompute K/V tail into the cache
            L = cache["k"].shape[1]
            k_, v_ = h @ p["mixer"]["wk"], h @ p["mixer"]["wv"]
            if "bk" in p["mixer"]:
                k_, v_ = k_ + p["mixer"]["bk"], v_ + p["mixer"]["bv"]
            k_ = k_.reshape(B, Sq, cfg.n_kv_heads, cfg.head_dim)
            v_ = v_.reshape(B, Sq, cfg.n_kv_heads, cfg.head_dim)
            if cfg.mrope:
                k_ = A.apply_mrope(k_, positions, cfg.rope_theta)
            else:
                k_ = A.apply_rope(k_, positions, cfg.rope_theta)
            take = min(Sq, L)
            # Ring alignment: token t lands in slot t % L, so later decode
            # steps (slot = pos % L) overwrite the oldest entry first.
            roll = (Sq - take) % L
            upd = lambda c, t: jax.lax.dynamic_update_slice(
                c, jnp.roll(t[:, -take:], roll, axis=1), (0,) * c.ndim)
            new_cache.update(
                k=upd(cache["k"], k_), v=upd(cache["v"], v_),
                pos=upd(cache["pos"], tok_pos))
    elif mixer_kind == "mla":
        kv = None
        idx = None
        kv_scales = None
        if cache is not None and mode == "decode":
            idx = cache_index
            kv = (cache["cc"], cache["cr"], cache["pos"])
            if "cc_scale" in cache:
                kv_scales = (cache["cc_scale"], cache["cr_scale"])
        y, newkv = A.mla_block(
            p["mixer"], h, positions, n_heads=cfg.n_heads,
            q_lora=cfg.q_lora_rank, kv_lora=cfg.kv_lora_rank,
            qk_nope=cfg.qk_nope_dim, qk_rope=cfg.qk_rope_dim,
            v_dim=cfg.v_head_dim, rope_theta=cfg.rope_theta,
            block=cfg.attn_block, kv_cache=kv, cache_index=idx,
            kv_scales=kv_scales)
        if cache is not None and mode == "decode":
            new_cache.update(cc=newkv[0], cr=newkv[1], pos=newkv[2])
            if newkv[3] is not None:
                new_cache.update(cc_scale=newkv[3][0], cr_scale=newkv[3][1])
        elif cache is not None:
            _, _, c_kv, k_rope = A._mla_qkr(
                p["mixer"], h, positions, cfg.n_heads, cfg.qk_nope_dim,
                cfg.qk_rope_dim, cfg.kv_lora_rank, cfg.rope_theta)
            new_cache.update(
                cc=jax.lax.dynamic_update_slice(cache["cc"], c_kv, (0, 0, 0)),
                cr=jax.lax.dynamic_update_slice(cache["cr"], k_rope, (0, 0, 0)),
                pos=jax.lax.dynamic_update_slice(cache["pos"], tok_pos, (0, 0)))
    elif mixer_kind == "mamba":
        st = None
        if cache is not None:
            st = S.MambaState(conv=cache["conv"], ssm=cache["ssm"])
        y, new_st = S.mamba_block(p["mixer"], h, d_state=cfg.d_state,
                                  d_conv=cfg.d_conv, expand=cfg.expand,
                                  chunk=cfg.scan_chunk, state=st)
        if cache is not None:
            new_cache.update(conv=new_st.conv, ssm=new_st.ssm)
    elif mixer_kind == "rwkv":
        st = None
        if cache is not None:
            st = R.RWKVState(att_shift=cache["att_shift"],
                             ffn_shift=cache["ffn_shift"], wkv=cache["wkv"])
        y, new_st = R.rwkv_time_mix(p["mixer"], h, chunk=cfg.scan_chunk,
                                    state=st)
        if cache is not None:
            new_cache.update(att_shift=new_st[0], wkv=new_st[1])
    else:
        raise ValueError(mixer_kind)
    x = x + y

    if cfg.encdec:
        hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        if cache is not None and mode == "decode":
            enc_k, enc_v, enc_pos = cache["enc_k"], cache["enc_v"], cache["enc_pos"]
        else:
            enc_k, enc_v = A.encode_kv(p["cross"], enc_out, cfg.n_heads,
                                       cfg.head_dim)
            enc_pos = jnp.zeros(enc_out.shape[:2], jnp.int32)
            if cache is not None:
                new_cache.update(enc_k=enc_k, enc_v=enc_v, enc_pos=enc_pos)
        yc = A.cross_block(p["cross"], hc, (enc_k, enc_v),
                           enc_pos == 0, n_heads=cfg.n_heads,
                           head_dim=cfg.head_dim)
        x = x + yc
        if cache is not None and mode == "decode":
            new_cache.update(enc_k=enc_k, enc_v=enc_v, enc_pos=enc_pos)

    if mlp_kind == "dense":
        x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps),
                    cfg.activation)
    elif mlp_kind == "moe":
        y2, aux = MOE.moe_block(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps),
                                top_k=cfg.top_k, n_groups=moe_groups,
                                capacity_factor=cfg.capacity_factor,
                                activation=cfg.activation)
        x = x + y2
    elif mlp_kind == "rwkv_cm":
        st = None
        if cache is not None:
            st = R.RWKVState(att_shift=cache.get("att_shift"),
                             ffn_shift=cache["ffn_shift"], wkv=cache.get("wkv"))
        y2, new_shift = R.rwkv_channel_mix(
            p["mixer"], rms_norm(x, p["ln2"], cfg.norm_eps), state=st)
        x = x + y2
        if cache is not None:
            new_cache.update(ffn_shift=new_shift)

    x = constrain(x, "batch", "seq_sp", None)
    return x, (new_cache if cache is not None else None), aux


# ---------------------------------------------------------------------------
# Stage runner (scan over the repeating group).
# ---------------------------------------------------------------------------

def _run_stage(cfg: ModelConfig, reps: int, group: Tuple[LayerSpec, ...],
               params: Dict, x: jax.Array, positions: jax.Array, mode: str,
               cache: Optional[Dict], cache_index,
               enc_out: Optional[jax.Array], moe_groups: int):
    def body(carry, xs):
        xc, aux = carry
        p_group, c_group = xs
        new_c_group = {}
        for j, spec in enumerate(group):
            cj = c_group[f"b{j}"] if c_group is not None else None
            xc, ncj, aux_j = _apply_block(cfg, spec, p_group[f"b{j}"], xc,
                                          positions, mode, cj, cache_index,
                                          enc_out, moe_groups)
            if ncj is not None:
                new_c_group[f"b{j}"] = ncj
            aux = aux + aux_j
        return (xc, aux), (new_c_group if c_group is not None else 0)

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = (params, cache)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, (new_cache if cache is not None else None)


# ---------------------------------------------------------------------------
# Public API: forward / prefill / decode_step / encode.
# ---------------------------------------------------------------------------

def _default_positions(cfg: ModelConfig, B: int, Sq: int, offset=0):
    pos = jnp.arange(Sq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, Sq))
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[None], (3, B, Sq))
    return pos


def _encode(cfg: ModelConfig, params: Dict, enc_embeds: jax.Array):
    """Bidirectional encoder over frontend embeddings (audio/vision stub)."""
    B, T, _ = enc_embeds.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x = enc_embeds

    def body(carry, p_group):
        xc, _ = carry
        p = p_group["b0"]
        h = rms_norm(xc, p["ln1"], cfg.norm_eps)
        q = (h @ p["mixer"]["wq"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        k = (h @ p["mixer"]["wk"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        v = (h @ p["mixer"]["wv"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        q = A.apply_rope(q, pos, cfg.rope_theta)
        k = A.apply_rope(k, pos, cfg.rope_theta)
        out = A.chunked_attention(q, k, v, pos, pos, causal=False,
                                  block=cfg.attn_block)
        xc = xc + out.reshape(B, T, -1) @ p["mixer"]["wo"]
        xc = xc + mlp(p["mlp"], rms_norm(xc, p["ln2"], cfg.norm_eps),
                      cfg.activation)
        return (xc, jnp.zeros((), jnp.float32)), 0

    body = jax.checkpoint(body) if cfg.remat else body
    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            positions: Optional[jax.Array] = None,
            enc_embeds: Optional[jax.Array] = None,
            cache: Optional[Dict] = None, mode: str = "train",
            moe_groups: int = 0) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    """Returns (logits, aux_loss, new_cache)."""
    B, Sq = tokens.shape
    if positions is None:
        positions = _default_positions(cfg, B, Sq)
    x = embed(params["embed"], tokens) * math.sqrt(cfg.d_model)
    enc_out = _encode(cfg, params, enc_embeds) if cfg.encdec else None

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    for i, (reps, group) in enumerate(stages_of(cfg)):
        ci = cache[f"stage{i}"] if cache is not None else None
        x, aux, nci = _run_stage(cfg, reps, group, params[f"stage{i}"], x,
                                 positions, mode, ci, 0, enc_out, moe_groups)
        aux_total += aux
        if nci is not None:
            new_cache[f"stage{i}"] = nci
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"] if cfg.tie_embeddings else params["head"],
                     x, tied=cfg.tie_embeddings)
    return logits, aux_total, (new_cache if cache is not None else None)


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jax.Array, pos: jax.Array,
                moe_groups: int = 0) -> Tuple[jax.Array, Dict]:
    """One decode step.  tokens: (B, 1); pos: scalar int32 (uniform batch
    position; ragged continuous batching uses :func:`decode_step_batched`).
    Returns (logits (B,1,V), new_cache)."""
    B, Sq = tokens.shape
    positions = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32)[None, None], (B, Sq))
    if cfg.mrope:
        positions = jnp.broadcast_to(positions[None], (3, B, Sq))
    x = embed(params["embed"], tokens) * math.sqrt(cfg.d_model)

    new_cache: Dict[str, Any] = {}
    for i, (reps, group) in enumerate(stages_of(cfg)):
        x, _, nci = _run_stage(cfg, reps, group, params[f"stage{i}"], x,
                               positions, "decode", cache[f"stage{i}"],
                               jnp.asarray(pos, jnp.int32), None, moe_groups)
        new_cache[f"stage{i}"] = nci
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"] if cfg.tie_embeddings else params["head"],
                     x, tied=cfg.tie_embeddings)
    return logits, new_cache


def decode_step_batched(cfg: ModelConfig, params: Dict, cache: Dict,
                        tokens: jax.Array, pos: jax.Array, active: jax.Array,
                        moe_groups: int = 0) -> Tuple[jax.Array, Dict]:
    """One continuous-batching decode step: ONE dispatch for a ragged batch.

    tokens: (B,) int32 — last emitted token per slot; pos: (B,) int32 —
    per-slot positions (need not be uniform: each row reads/writes its own
    cache slot); active: (B,) bool — slots currently serving a request.
    Greedy sampling runs in-graph, so the only device→host traffic per step
    is the (B,) next-token vector.  Returns (next_tokens, new_cache);
    next_tokens is -1 for inactive slots, whose cache rows are left bit-exact
    (a suspended slot cannot be corrupted by a stale in-flight row).
    """
    B = tokens.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None]                                 # (B, 1)
    if cfg.mrope:
        positions = jnp.broadcast_to(positions[None], (3, B, 1))
    x = embed(params["embed"], tokens[:, None]) * math.sqrt(cfg.d_model)

    new_cache: Dict[str, Any] = {}
    for i, (reps, group) in enumerate(stages_of(cfg)):
        x, _, nci = _run_stage(cfg, reps, group, params[f"stage{i}"], x,
                               positions, "decode", cache[f"stage{i}"],
                               pos, None, moe_groups)
        new_cache[f"stage{i}"] = nci
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"] if cfg.tie_embeddings else params["head"],
                     x, tied=cfg.tie_embeddings)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    nxt = jnp.where(active, nxt, -1)
    # Cache leaves are (reps, batch, ...): inactive rows keep their old bits.
    keep = lambda o, n: jnp.where(
        active.reshape((1, B) + (1,) * (n.ndim - 2)), n, o)
    new_cache = jax.tree.map(keep, cache, new_cache)
    return nxt, new_cache


def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            cache: Dict, enc_embeds: Optional[jax.Array] = None,
            moe_groups: int = 0,
            positions: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """Prefill the cache.  ``positions`` defaults to arange; bucketed serving
    passes right-padded tokens with sentinel (2**30) positions for the pads,
    which keeps them causally invisible forever (see serve/engine)."""
    logits, _, new_cache = forward(cfg, params, tokens, positions=positions,
                                   cache=cache, enc_embeds=enc_embeds,
                                   mode="prefill", moe_groups=moe_groups)
    return logits, new_cache
