"""Shared model building blocks: norms, embeddings, MLPs, RoPE variants."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.sharding import constrain


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 *statistics* but application in the input dtype —
    keeps the (B,S,d) elementwise traffic and its cotangents in bf16
    (EXPERIMENTS.md §Perf iteration A5: −fp32 norm families)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def init_rms(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)


def dense_init(key: jax.Array, shape, scale: Optional[float] = None,
               dtype=jnp.float32) -> jax.Array:
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# MLP: SwiGLU / GeGLU gated feed-forward.
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, d_model: int, d_ff: int, dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "wi_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "wo": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def mlp(params: Dict, x: jax.Array, activation: str = "swiglu") -> jax.Array:
    gate = x @ params["wi_gate"]
    up = x @ params["wi_up"]
    gate = constrain(gate, "batch", None, "ff")
    if activation == "swiglu":
        h = jax.nn.silu(gate) * up
    elif activation == "geglu":
        h = jax.nn.gelu(gate, approximate=True) * up
    else:
        raise ValueError(f"unknown activation {activation}")
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE).
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float = 1e4,
                sections=(2, 1, 1)) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the frequency bands of each head are split
    into temporal/height/width sections, each rotated by its own position id.

    x: (B, S, H, D); positions: (3, B, S) — for text all three are equal.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = rope_freqs(d, theta)                                  # (half,)
    total = sum(sections)
    bounds = []
    acc = 0
    for s in sections:
        n = half * s // total
        bounds.append((acc, acc + n))
        acc += n
    bounds[-1] = (bounds[-1][0], half)
    ang_parts = []
    for (lo, hi), pos in zip(bounds, positions):
        ang_parts.append(pos[..., None].astype(jnp.float32) * freqs[lo:hi])
    ang = jnp.concatenate(ang_parts, -1)                          # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head.
# ---------------------------------------------------------------------------

def init_embed(key: jax.Array, vocab: int, d_model: int, dtype=jnp.float32) -> jax.Array:
    # std 1/sqrt(d): the embed-scale multiplier sqrt(d) restores unit variance
    # and tied logits stay O(1) at init (CE starts near ln V).
    return dense_init(key, (vocab, d_model), scale=d_model ** -0.5, dtype=dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    out = jnp.take(table, tokens, axis=0)
    return constrain(out, "batch", "seq", None)


def unembed(table_or_w: jax.Array, x: jax.Array, tied: bool) -> jax.Array:
    w = table_or_w.T if tied else table_or_w
    logits = x @ w
    return constrain(logits, "batch", "seq", "vocab")
