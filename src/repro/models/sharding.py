"""Logical-axis sharding annotations threaded through the model code.

Model code calls ``constrain(x, "batch", "seq", None)`` with *logical* axis
names; the active rule set (installed by the train/serve step factories via
``use_sharding``) maps logical names to mesh axes.  Outside any context the
calls are no-ops, so single-device smoke tests and the pure-jnp oracles run
the exact same model code.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# Megatron-style default: batch over (pod, data); heads/ff/experts/vocab over
# model; sequence sharded over model *between* layers (sequence parallelism)
# only when the rule set enables it.
DEFAULT_RULES: Mapping[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": None,          # layer-boundary sequence axis (SP off by default)
    "dmodel": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "vocab": "model",
    "state": None,
    "inner": "model",        # SSM/RWKV channel axis
}

_CTX: contextvars.ContextVar = contextvars.ContextVar("lisa_sharding", default=None)


@contextlib.contextmanager
def use_sharding(mesh: jax.sharding.Mesh, rules: Optional[Mapping[str, Axis]] = None):
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # Drop mesh axes that don't exist on this mesh (e.g. "pod" on single-pod).
    names = set(mesh.axis_names)

    def filt(ax: Axis) -> Axis:
        if ax is None:
            return None
        if isinstance(ax, str):
            return ax if ax in names else None
        kept = tuple(a for a in ax if a in names)
        return kept if kept else None

    token = _CTX.set((mesh, {k: filt(v) for k, v in merged.items()}))
    try:
        yield
    finally:
        _CTX.reset(token)


def spec_for(*logical: Optional[str]) -> Optional[P]:
    ctx = _CTX.get()
    if ctx is None:
        return None
    _, rules = ctx
    return P(*[rules.get(ax) if ax is not None else None for ax in logical])


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (no-op w/o context).

    Axes that do not evenly divide the dimension are dropped (e.g. 4 KV heads
    on a 16-way model axis -> replicated KV, Megatron-style) — forcing them
    produces SPMD full-rematerialization copies.
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fit(ax, dim):
        if ax is None:
            return None
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        kept, total = [], 1
        for a in axes:
            if dim % (total * sizes[a]) == 0:
                kept.append(a)
                total *= sizes[a]
        if not kept:
            return None
        return kept[0] if len(kept) == 1 else tuple(kept)

    spec = P(*[fit(rules.get(ax) if ax is not None else None, d)
               for ax, d in zip(logical, x.shape)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def active() -> bool:
    return _CTX.get() is not None
