"""Mamba (selective SSM) block — the Jamba hybrid's attention-free mixer.

Training uses a chunked, rematerialised time scan (memory O(S/chunk) state
carries instead of O(S) hidden-state history); decode is a single-step state
update with a rolling conv buffer — state size is constant in context length,
which is why the hybrid runs the long_500k shape.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.sharding import constrain


class MambaState(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, d_inner) — last inputs for causal conv
    ssm: jax.Array    # (B, d_inner, d_state)


def init_mamba_params(key: jax.Array, d_model: int, d_state: int = 16,
                      d_conv: int = 4, expand: int = 2, dtype=jnp.float32
                      ) -> Dict:
    d_inner = expand * d_model
    dt_rank = math.ceil(d_model / 16)
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                         (d_inner, d_state))
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_inner), dtype=dtype),
        "conv_w": dense_init(ks[1], (d_conv, d_inner), scale=d_conv ** -0.5,
                             dtype=dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], (d_inner, dt_rank + 2 * d_state), dtype=dtype),
        "dt_w": dense_init(ks[3], (dt_rank, d_inner), scale=dt_rank ** -0.5,
                           dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[4], (d_inner,), jnp.float32)
                             * (math.log(0.1) - math.log(0.001))
                             + math.log(0.001)), 1e-4))).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_inner, d_model), dtype=dtype),
    }


def _ssm_scan(dt, Bm, Cm, xs, A, chunk: int, h0=None):
    """Selective scan.  dt/xs: (B,S,D), Bm/Cm: (B,S,N), A: (D,N).

    Returns (y (B,S,D), h_final (B,D,N)).  Chunked + rematerialised: the
    outer scan carries only the inter-chunk state; inner steps recompute on
    the backward pass.
    """
    B, S, D = xs.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk

    def inner(h, inp):
        dt_t, b_t, c_t, x_t = inp                       # (B,D) (B,N) (B,N) (B,D)
        da = jnp.exp(dt_t[..., None] * A[None])         # (B,D,N)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    @jax.checkpoint
    def run_chunk(h, inp):
        return jax.lax.scan(inner, h, inp)

    resh = lambda a: jnp.moveaxis(
        a.reshape(B, nc, chunk, a.shape[-1]), (1, 2), (0, 1))   # (nc,chunk,B,·)
    if h0 is None:
        h0 = jnp.zeros((B, D, N), jnp.float32)
    # TPU path: a chunked selective-scan kernel (VMEM-resident h); marked for
    # the roofline's kernel-adjusted memory accounting.
    with jax.named_scope("pallas_kernel_region"):
        h, ys = jax.lax.scan(lambda h, i: run_chunk(h, i), h0,
                             (resh(dt), resh(Bm), resh(Cm), resh(xs)))
    return jnp.moveaxis(ys.reshape(nc * chunk, B, D), 0, 1), h


def _conv_causal(x: jax.Array, w: jax.Array, b: jax.Array,
                 history: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv over time.  x: (B,S,D), w: (K,D)."""
    K = w.shape[0]
    if history is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def mamba_block(params: Dict, x: jax.Array, *, d_state: int, d_conv: int,
                expand: int, chunk: int = 128,
                state: MambaState | None = None,
                ) -> Tuple[jax.Array, MambaState | None]:
    """x: (B,S,M).  Training: state=None.  Decode: pass/return MambaState."""
    B, S, M = x.shape
    d_inner = expand * M
    dt_rank = params["dt_w"].shape[0]

    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, "batch", None, "inner")

    if state is None:
        xc = _conv_causal(x_in, params["conv_w"], params["conv_b"])
        new_conv = None
    else:
        xc = _conv_causal(x_in, params["conv_w"], params["conv_b"],
                          history=state.conv)
        new_conv = jnp.concatenate([state.conv, x_in], axis=1)[:, -(d_conv - 1):]
    xc = jax.nn.silu(xc)

    x_db = xc @ params["x_proj"]
    dt_r = x_db[..., :dt_rank]
    Bm = x_db[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    Cm = x_db[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus((dt_r @ params["dt_w"]).astype(jnp.float32)
                         + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    if state is None:
        y, _ = _ssm_scan(dt, Bm, Cm, xc.astype(jnp.float32), A, chunk)
        new_state = None
    elif S == 1:
        da = jnp.exp(dt[:, 0, :, None] * A[None])                  # (B,D,N)
        h = da * state.ssm + (dt[:, 0] * xc[:, 0].astype(jnp.float32)
                              )[..., None] * Bm[:, 0, None, :]
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None, :]
        new_state = MambaState(conv=new_conv, ssm=h)
    else:                                       # prefill: scan from state
        y, h = _ssm_scan(dt, Bm, Cm, xc.astype(jnp.float32), A, chunk,
                         h0=state.ssm)
        new_state = MambaState(conv=new_conv, ssm=h)

    y = (y + params["D"] * xc.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"], new_state


def init_mamba_state(batch: int, d_model: int, d_state: int, d_conv: int,
                     expand: int, dtype=jnp.float32) -> MambaState:
    d_inner = expand * d_model
    return MambaState(
        conv=jnp.zeros((batch, d_conv - 1, d_inner), dtype),
        ssm=jnp.zeros((batch, d_inner, d_state), jnp.float32),
    )
