"""Mixture-of-Experts with sort-based capacity dispatch (EP-shardable).

Dispatch is gather/scatter (argsort by expert id within token groups) rather
than the GShard one-hot einsum — the einsum dispatch costs O(T*E*C*M) FLOPs,
which for DeepSeek-V2/OLMoE shapes *doubles* compiled FLOPs and wrecks the
MODEL_FLOPS/HLO_FLOPs roofline ratio.  Groups shard over the data axes,
experts over the model axis; with activations replicated over "model"
(Megatron TP), each expert shard gathers its own tokens locally and the
combine scatter-add reduces over "model" with the layer's existing psum.

Capacity-bounded: tokens over an expert's capacity are dropped (residual +
shared experts still apply), per GShard/Switch.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_mlp, mlp
from repro.models.sharding import constrain


def init_moe_params(key: jax.Array, d_model: int, n_experts: int,
                    d_ff_expert: int, n_shared: int, activation: str = "swiglu",
                    dtype=jnp.float32) -> Dict:
    kr, ke, ks = jax.random.split(key, 3)
    k1, k2, k3 = jax.random.split(ke, 3)
    p = {
        "router": dense_init(kr, (d_model, n_experts), scale=d_model ** -0.5,
                             dtype=jnp.float32),
        "wi_gate": dense_init(k1, (n_experts, d_model, d_ff_expert), dtype=dtype),
        "wi_up": dense_init(k2, (n_experts, d_model, d_ff_expert), dtype=dtype),
        "wo": dense_init(k3, (n_experts, d_ff_expert, d_model), dtype=dtype),
    }
    if n_shared > 0:
        p["shared"] = init_mlp(ks, d_model, n_shared * d_ff_expert, dtype=dtype)
    return p


def _dispatch_indices(expert_ids: jax.Array, n_experts: int, capacity: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """expert_ids: (T, k) -> (entry (E, C) flat indices into T*k, valid (E, C)).

    Tokens are ranked by (expert, arrival order); ranks >= capacity drop.
    """
    Tk = expert_ids.size
    flat = expert_ids.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    ends = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="right")
    pos = starts[:, None] + jnp.arange(capacity)[None, :]       # (E, C)
    valid = pos < ends[:, None]
    entry = jnp.take(order, jnp.clip(pos, 0, Tk - 1))
    return jnp.where(valid, entry, -1), valid


def moe_block(params: Dict, x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25, n_groups: int = 0,
              activation: str = "swiglu",
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, M) -> (y, aux_loss).  Router in fp32; top-k softmax gating
    (normalised over the selected experts, DeepSeek/Mixtral-style)."""
    B, S, M = x.shape
    T = B * S
    E = params["wi_gate"].shape[0]
    if n_groups <= 0:
        n_groups = max(min(T // 4096, 64), 1)
    while T % n_groups:
        n_groups -= 1
    G = T // n_groups
    k = top_k
    C = max(int(math.ceil(G * k / E * capacity_factor)), min(k, G))

    xt = x.reshape(n_groups, G, M)
    xt = constrain(xt, "batch", None, None)
    logits = xt.astype(jnp.float32) @ params["router"]          # (g, G, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (g, G, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    def per_group(xg, ids, gates):
        entry, valid = _dispatch_indices(ids, E, C)             # (E, C)
        token = jnp.clip(entry, 0) // k
        slot = jnp.clip(entry, 0) % k
        ein = jnp.take(xg, token, axis=0)                       # (E, C, M)
        w = jnp.where(valid, gates[token, slot], 0.0)           # (E, C)
        return ein, token, w

    ein, token, w = jax.vmap(per_group)(xt, expert_ids, gate_vals)
    ein = constrain(ein, "batch", "experts", None, None)        # (g, E, C, M)

    h_gate = jnp.einsum("gecm,emf->gecf", ein, params["wi_gate"])
    h_up = jnp.einsum("gecm,emf->gecf", ein, params["wi_up"])
    h_gate = constrain(h_gate, "batch", "experts", None, None)
    act = jax.nn.silu(h_gate) if activation == "swiglu" else jax.nn.gelu(
        h_gate, approximate=True)
    eout = jnp.einsum("gecf,efm->gecm", act * h_up, params["wo"])
    eout = eout * w[..., None].astype(eout.dtype)

    def combine(out_g, token_g):
        y = jnp.zeros((G, M), out_g.dtype)
        return y.at[token_g.reshape(-1)].add(out_g.reshape(-1, M))

    y = jax.vmap(combine)(eout, token).reshape(B, S, M)
    y = constrain(y, "batch", "seq", None)

    if "shared" in params:
        y = y + mlp(params["shared"], x, activation)

    # Switch-style load-balance loss: E * sum_e f_e * p_e.
    me = probs.mean(axis=(0, 1))                                # (E,)
    assign = jax.nn.one_hot(expert_ids, E).sum(-2)              # (g, G, E)
    fe = assign.mean(axis=(0, 1)) / k
    aux = E * jnp.sum(fe * me)
    return y, aux
