"""Attention variants: GQA (full / sliding-window), MLA (DeepSeek-V2),
with a chunked online-softmax core that keeps prefill memory linear in
sequence length (the pure-jnp twin of ``kernels/flash_attention.py``).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_mrope, apply_rope, dense_init, rms_norm
from repro.models.sharding import constrain

NEG_INF = -1e30


def _cache_write(buf: jax.Array, val: jax.Array, idx) -> jax.Array:
    """Write a one-token decode update into a cache buffer.

    ``idx`` scalar: uniform batch position (shared slot, legacy path).
    ``idx`` vector (B,): per-slot ragged positions — each batch row writes its
    own slot (continuous batching, one dispatch for the whole ragged batch).
    ``val``: (B, 1, ...) matching ``buf``: (B, T, ...).
    """
    val = val.astype(buf.dtype)
    if jnp.ndim(idx) == 1:
        return buf.at[jnp.arange(buf.shape[0]), idx].set(val[:, 0])
    return jax.lax.dynamic_update_slice(buf, val,
                                        (0, idx) + (0,) * (buf.ndim - 2))


# ---------------------------------------------------------------------------
# Chunked online-softmax attention core (flash-style, pure jnp).
# ---------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, kv_pos: jax.Array,
                      *, causal: bool = True, window: int = 0,
                      block: int = 512, k_scale=None, v_scale=None
                      ) -> jax.Array:
    """q: (B,S,H,Dk), k: (B,T,K,Dk), v: (B,T,K,Dv); H = K*G.

    Scans KV blocks with running (max, sum, acc) — memory O(S*block), never
    materialising the (S,T) score matrix.  ``window > 0`` masks keys older
    than ``q_pos - window + 1`` (sliding-window attention).  Invalid cache
    slots must carry ``kv_pos`` > any real position (they get causally
    masked).  ``k_scale``/``v_scale`` (B,T,K): int8-quantised KV cache;
    dequantisation happens inside the kernel region per block (the fused
    dequant-attention kernel on real TPUs).
    """
    B, S, H, Dk = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    Dv = v.shape[-1]
    scale = Dk ** -0.5
    # The whole body runs as the Pallas flash kernel on TPU
    # (kernels/flash_attention.py); the scope marks it for the roofline's
    # kernel-adjusted memory accounting (roofline/hlo.py).
    with jax.named_scope("pallas_kernel_region"):
        return _chunked_attention_body(q, k, v, q_pos, kv_pos, causal=causal,
                                       window=window, block=block,
                                       k_scale=k_scale, v_scale=v_scale)


def _chunked_attention_body(q, k, v, q_pos, kv_pos, *, causal, window, block,
                            k_scale=None, v_scale=None):
    B, S, H, Dk = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    Dv = v.shape[-1]
    scale = Dk ** -0.5

    block = min(block, T)
    nb = -(-T // block)
    pad = nb * block - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))

    qr = (q.reshape(B, S, K, G, Dk) * scale).astype(jnp.float32)
    kb = k.reshape(B, nb, block, K, Dk)
    vb = v.reshape(B, nb, block, K, Dv)
    pb = kv_pos.reshape(B, nb, block)
    sb = (k_scale.reshape(B, nb, block, K), v_scale.reshape(B, nb, block, K)) \
        if k_scale is not None else None

    def step(carry, blk):
        m, l, acc = carry
        if sb is not None:
            kj, vj, pj, ksj, vsj = blk
            kj = kj.astype(jnp.float32) * ksj[..., None]
            vj = vj.astype(jnp.float32) * vsj[..., None]
        else:
            kj, vj, pj = blk
        s = jnp.einsum("bskgd,btkd->bkgst", qr, kj.astype(jnp.float32))
        valid = jnp.ones((B, 1, 1, S, block), bool)
        if causal:
            valid &= pj[:, None, None, None, :] <= q_pos[:, None, None, :, None]
        if window > 0:
            valid &= pj[:, None, None, None, :] > (
                q_pos[:, None, None, :, None] - window)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, K, G, S, Dv), jnp.float32)
    xs = [jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
          jnp.moveaxis(pb, 1, 0)]
    if sb is not None:
        xs += [jnp.moveaxis(sb[0], 1, 0), jnp.moveaxis(sb[1], 1, 0)]
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), tuple(xs))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, K * G, S, Dv).swapaxes(1, 2).reshape(B, S, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block (RoPE / M-RoPE, optional sliding window, optional QKV bias).
# ---------------------------------------------------------------------------

def init_gqa_params(key: jax.Array, d_model: int, n_heads: int, n_kv: int,
                    head_dim: int, qkv_bias: bool = False,
                    dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim), dtype=dtype),
        "wk": dense_init(ks[1], (d_model, n_kv * head_dim), dtype=dtype),
        "wv": dense_init(ks[2], (d_model, n_kv * head_dim), dtype=dtype),
        "wo": dense_init(ks[3], (n_heads * head_dim, d_model), dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def gqa_block(params: Dict, x: jax.Array, positions: jax.Array, *,
              n_heads: int, n_kv: int, head_dim: int,
              rope_theta: float = 1e4, mrope: bool = False,
              window: int = 0, block: int = 512,
              kv_cache: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None,
              kv_scales: Optional[Tuple[jax.Array, jax.Array]] = None,
              ) -> Tuple[jax.Array, Optional[Tuple]]:
    """Self-attention.  Training: ``kv_cache=None`` (causal over ``x``).
    Decode: ``kv_cache=(k, v, kv_pos)`` ring/linear buffers; the new token's
    K/V is written at ``cache_index`` and attention runs over the cache.
    int8 caches quantise on write (per-token-per-head absmax scales in
    ``kv_scales``) and dequantise inside the attention kernel region.

    positions: (B,S) int32, or (3,B,S) when ``mrope``.
    """
    B, S, M = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)

    if mrope:
        q = apply_mrope(q, positions, rope_theta)
        k = apply_mrope(k, positions, rope_theta)
        tok_pos = positions[0]
    else:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
        tok_pos = positions

    if kv_cache is None:
        out = chunked_attention(q, k, v, tok_pos, tok_pos,
                                causal=True, window=window, block=block)
        new_cache = None
    else:
        ck, cv, cpos = kv_cache
        idx = cache_index
        new_scales = None
        if ck.dtype == jnp.int8:
            ks_buf, vs_buf = kv_scales
            k_s = jnp.maximum(jnp.abs(k).max(-1), 1e-6) / 127.0   # (B,S,K)
            v_s = jnp.maximum(jnp.abs(v).max(-1), 1e-6) / 127.0
            kq = jnp.clip(jnp.round(k / k_s[..., None]), -127, 127
                          ).astype(jnp.int8)
            vq = jnp.clip(jnp.round(v / v_s[..., None]), -127, 127
                          ).astype(jnp.int8)
            ck = _cache_write(ck, kq, idx)
            cv = _cache_write(cv, vq, idx)
            ks_buf = _cache_write(ks_buf, k_s, idx)
            vs_buf = _cache_write(vs_buf, v_s, idx)
            new_scales = (ks_buf, vs_buf)
        else:
            ck = _cache_write(ck, k, idx)
            cv = _cache_write(cv, v, idx)
        cpos = _cache_write(cpos, jnp.broadcast_to(tok_pos, (B, S)), idx)
        out = chunked_attention(
            q, ck, cv, tok_pos, cpos, causal=True, window=window, block=block,
            k_scale=new_scales[0] if new_scales else None,
            v_scale=new_scales[1] if new_scales else None)
        new_cache = (ck, cv, cpos, new_scales)

    y = out.reshape(B, S, n_heads * head_dim) @ params["wo"]
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention, DeepSeek-V2) with compressed decode cache.
# ---------------------------------------------------------------------------

def init_mla_params(key: jax.Array, d_model: int, n_heads: int,
                    q_lora: int, kv_lora: int, qk_nope: int, qk_rope: int,
                    v_dim: int, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)
    return {
        "q_a": dense_init(ks[0], (d_model, q_lora), dtype=dtype),
        "q_norm": jnp.zeros((q_lora,), jnp.float32),
        "q_b": dense_init(ks[1], (q_lora, n_heads * (qk_nope + qk_rope)), dtype=dtype),
        "kv_a": dense_init(ks[2], (d_model, kv_lora + qk_rope), dtype=dtype),
        "kv_norm": jnp.zeros((kv_lora,), jnp.float32),
        "kv_b": dense_init(ks[3], (kv_lora, n_heads * (qk_nope + v_dim)), dtype=dtype),
        "wo": dense_init(ks[4], (n_heads * v_dim, d_model), dtype=dtype),
    }


def _mla_qkr(params, x, positions, n_heads, qk_nope, qk_rope, kv_lora,
             rope_theta):
    B, S, _ = x.shape
    cq = rms_norm(x @ params["q_a"], params["q_norm"])
    q = (cq @ params["q_b"]).reshape(B, S, n_heads, qk_nope + qk_rope)
    q = constrain(q, "batch", None, "heads", None)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    ckv = x @ params["kv_a"]
    c_kv = rms_norm(ckv[..., :kv_lora], params["kv_norm"])  # (B,S,kv_lora)
    k_rope = apply_rope(ckv[..., kv_lora:][:, :, None, :], positions,
                        rope_theta)[:, :, 0, :]             # (B,S,qk_rope)
    return q_nope, q_rope, c_kv, k_rope


def mla_block(params: Dict, x: jax.Array, positions: jax.Array, *,
              n_heads: int, q_lora: int, kv_lora: int, qk_nope: int,
              qk_rope: int, v_dim: int, rope_theta: float = 1e4,
              block: int = 512,
              kv_cache: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None,
              kv_scales: Optional[Tuple[jax.Array, jax.Array]] = None,
              ) -> Tuple[jax.Array, Optional[Tuple]]:
    """Training path expands K/V per head; decode path uses the *absorbed*
    formulation over the compressed cache (c_kv, k_rope) — the cache is
    (kv_lora + qk_rope) per token instead of 2*H*D (the paper-relevant
    bulk-data saving: 576 vs 32768 floats/token for DeepSeek-V2).
    int8 caches quantise on write (per-token scales) and dequantise inside
    the kernel region."""
    B, S, M = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(
        params, x, positions, n_heads, qk_nope, qk_rope, kv_lora, rope_theta)

    w_kv = params["kv_b"].reshape(kv_lora, n_heads, qk_nope + v_dim)
    w_uk, w_uv = w_kv[..., :qk_nope], w_kv[..., qk_nope:]

    if kv_cache is None:
        kv = jnp.einsum("btc,chd->bthd", c_kv, w_kv)
        k_nope, v = kv[..., :qk_nope], kv[..., qk_nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, n_heads, qk_rope))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        out = chunked_attention(q, k, v, positions, positions,
                                causal=True, block=block)
        new_cache = None
    else:
        cc, cr, cpos = kv_cache                      # (B,T,kv_lora) (B,T,rope)
        idx = cache_index
        new_scales = None
        if cc.dtype == jnp.int8:
            cs_buf, rs_buf = kv_scales
            c_s = jnp.maximum(jnp.abs(c_kv).max(-1), 1e-6) / 127.0   # (B,S)
            r_s = jnp.maximum(jnp.abs(k_rope).max(-1), 1e-6) / 127.0
            c_q = jnp.clip(jnp.round(c_kv / c_s[..., None]), -127, 127
                           ).astype(jnp.int8)
            r_q = jnp.clip(jnp.round(k_rope / r_s[..., None]), -127, 127
                           ).astype(jnp.int8)
            cc = _cache_write(cc, c_q, idx)
            cr = _cache_write(cr, r_q, idx)
            cs_buf = _cache_write(cs_buf, c_s, idx)
            rs_buf = _cache_write(rs_buf, r_s, idx)
            new_scales = (cs_buf, rs_buf)
        else:
            cc = _cache_write(cc, c_kv, idx)
            cr = _cache_write(cr, k_rope, idx)
        cpos = _cache_write(cpos, jnp.broadcast_to(positions, (B, S)), idx)
        # Absorbed attention over the compressed cache — the fused
        # MLA-decode kernel on real TPUs (dequant inside the region).
        with jax.named_scope("pallas_kernel_region"):
            scale = (qk_nope + qk_rope) ** -0.5
            q_c = jnp.einsum("bshd,chd->bshc", q_nope, w_uk)
            s_nope = jnp.einsum("bshc,btc->bhst", q_c, cc.astype(q_c.dtype))
            s_rope = jnp.einsum("bshd,btd->bhst", q_rope,
                                cr.astype(q_rope.dtype))
            if new_scales is not None:      # undo per-token quantisation
                s_nope = s_nope * new_scales[0][:, None, None, :]
                s_rope = s_rope * new_scales[1][:, None, None, :]
            s = (s_nope + s_rope) * scale
            valid = cpos[:, None, None, :] <= positions[:, None, :, None]
            s = jnp.where(valid, s.astype(jnp.float32), NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            if new_scales is not None:
                p_eff = (p * new_scales[0][:, None, None, :]).astype(q_c.dtype)
            else:
                p_eff = p.astype(q_c.dtype)
            ctx = jnp.einsum("bhst,btc->bshc", p_eff, cc.astype(q_c.dtype))
            out = jnp.einsum("bshc,chd->bshd", ctx, w_uv)
        new_cache = (cc, cr, cpos, new_scales)

    y = out.reshape(B, S, n_heads * v_dim) @ params["wo"]
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec; seamless-m4t decoder).
# ---------------------------------------------------------------------------

def init_cross_params(key: jax.Array, d_model: int, n_heads: int,
                      head_dim: int, dtype=jnp.float32) -> Dict:
    return init_gqa_params(key, d_model, n_heads, n_heads, head_dim,
                           dtype=dtype)


def cross_block(params: Dict, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                enc_mask: Optional[jax.Array], *, n_heads: int, head_dim: int
                ) -> jax.Array:
    """enc_kv: precomputed (k, v) of shape (B, T, H, D) from encoder output."""
    B, S, M = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_heads, head_dim)
    k, v = enc_kv
    T = k.shape[1]
    kv_pos = jnp.zeros((B, T), jnp.int32)
    if enc_mask is not None:
        kv_pos = jnp.where(enc_mask, 0, 2**30)
    q_pos = jnp.full((B, S), 2**29, jnp.int32)     # attend to all valid enc
    out = chunked_attention(q, k, v, q_pos, kv_pos, causal=True, block=512)
    return out.reshape(B, S, n_heads * head_dim) @ params["wo"]


def encode_kv(params: Dict, enc_out: jax.Array, n_heads: int, head_dim: int
              ) -> Tuple[jax.Array, jax.Array]:
    B, T, _ = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(B, T, n_heads, head_dim)
    v = (enc_out @ params["wv"]).reshape(B, T, n_heads, head_dim)
    return k, v
