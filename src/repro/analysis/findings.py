"""Findings, waivers and the machine-readable ``repro-lint`` report.

A :class:`Finding` is one violated invariant at one source location (or one
audited entry point).  Findings from both analyzer layers — the AST
architecture linter (:mod:`repro.analysis.rules`) and the jaxpr/HLO dispatch
auditor (:mod:`repro.analysis.dispatch`) — share this shape, so CI gates on
ONE report.

Waivers are explicit, committed and line-addressed: the file (default
``LINT_WAIVERS`` at the repo root) holds one ``rule:path`` or
``rule:path:line`` pattern per line.  An empty waiver file is the intended
steady state — the acceptance bar for every PR that touches the hot path.

The report itself is strict JSON (``allow_nan=False``, sorted keys, no
timestamps): regenerating it on an unchanged tree is byte-stable, so the
artifact can be committed and schema-checked by ``benchmarks/run.py
--check`` exactly like the BENCH files.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence

REPORT_SCHEMA = "repro-lint-report/v1"
DEFAULT_WAIVER_FILE = "LINT_WAIVERS"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant: a lint rule (or audit contract) ``rule`` at
    ``path:line`` with a human-readable ``message``."""
    rule: str
    path: str               # repo-relative, posix separators
    line: int               # 1-based; 0 for whole-file / entry-point findings
    message: str
    severity: str = "error"

    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.line}"

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def load_waivers(path: Optional[str]) -> List[str]:
    """Waiver patterns from ``path``: one ``rule:path[:line]`` per line,
    ``#`` comments and blanks ignored.  A missing file is an empty list —
    same contract as an empty file."""
    if path is None or not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for raw in f:
            line = raw.split("#", 1)[0].strip()
            if line:
                out.append(line)
    return out


def is_waived(finding: Finding, waivers: Sequence[str]) -> bool:
    """A waiver matches a finding exactly (``rule:path:line``) or for a
    whole file (``rule:path``)."""
    return (finding.key() in waivers
            or f"{finding.rule}:{finding.path}" in waivers)


def split_waived(findings: Sequence[Finding], waivers: Sequence[str]):
    """-> (active, waived) partitions, both sorted for stable reports."""
    active = [f for f in findings if not is_waived(f, waivers)]
    waived = [f for f in findings if is_waived(f, waivers)]
    order = lambda f: (f.path, f.line, f.rule)          # noqa: E731
    return sorted(active, key=order), sorted(waived, key=order)


@dataclasses.dataclass
class Report:
    """The full ``repro-lint`` result: both layers, waivers applied."""
    roots: List[str]
    rules: List[str]
    findings: List[Finding] = dataclasses.field(default_factory=list)
    waived: List[Finding] = dataclasses.field(default_factory=list)
    waiver_file: str = DEFAULT_WAIVER_FILE
    files_scanned: int = 0
    audit: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def clean(self) -> bool:
        return not self.findings and not self.audit.get("findings")

    def to_dict(self) -> Dict[str, object]:
        return {
            "schema": REPORT_SCHEMA,
            "roots": list(self.roots),
            "rules": sorted(self.rules),
            "findings": [f.as_dict() for f in self.findings],
            "waived": [f.as_dict() for f in self.waived],
            "waiver_file": self.waiver_file,
            "counts": {
                "files_scanned": self.files_scanned,
                "findings": len(self.findings),
                "waived": len(self.waived),
            },
            "audit": self.audit,
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True,
                      allow_nan=False)
            f.write("\n")
