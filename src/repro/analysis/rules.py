"""AST architecture lint rules behind a registry.

This extends the repo's registry pattern a fourth time: PR 1 registered
``CopyMechanism`` objects (pricing a copy), PR 3 registered movement
*backends* (performing a copy), PR 4 registered scheduling *policies*
(choosing a copy), and this module registers lint *rules* — proving that no
code path exists that could perform movement any other way.  Same contract
as the others: re-registering the same class (module reload) replaces
silently, a different class under a taken id raises.

Each rule guards one paper invariant (DESIGN.md Sec. 11 has the mapping):

* ``movement-raw-backend`` — all bulk movement flows through
  ``movement.plan()``; raw kernel/collective calls outside the backend
  registry would bypass the Table-1 cost accounting (LISA's point is that
  the *mechanism* is priced, not assumed).
* ``host-sync-in-hot-loop`` — the tick loop and wave dispatch never sync
  the device beyond the one sanctioned transfer per step: a stray
  ``.item()`` is a trip across the narrow channel mid-wave.
* ``wallclock-in-virtual-clock`` — scheduling runs on the virtual clock;
  wall-clock reads or unseeded RNG would make the priced schedules (and the
  CI-gated BENCH numbers) nondeterministic.
* ``json-nan`` — every JSON artifact is strict JSON (``allow_nan=False``):
  a NaN that serializes as a bare ``NaN`` literal poisons downstream
  schema checks silently.
* ``import-time-registration`` — backends/policies register at import time
  only; a call-site registration would make dispatch depend on execution
  order.
* ``unchecked-unpack`` — page payloads re-enter a cache only through the
  checksum-verified unpack leg; a raw ``unpack_into_slot`` call outside the
  movement substrate that never consults the sidecar is a silent-corruption
  hole (chaos runs gate on zero of these).
* ``unrefcounted-alias`` — serving code that drives the snapshot scatter
  (``_suspend`` / ``_suspend_many``) must consult the fork table's refcount
  API in the same function: a bare scatter into a row that forked sessions
  may alias overwrites every alias's bytes without a copy-on-write detach.
* ``unclosed-span`` — a ``begin_span`` with no ``end_span`` in the same
  function leaves the span open on its lane forever: every later span on
  that lane nests under it, and the trace's parent/child additivity
  contract silently breaks.  Use the ``span()`` context manager or close
  in the function that opened.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple, Type

from repro.analysis.findings import Finding

_RULES: Dict[str, "LintRule"] = {}


def register_rule(cls: Type["LintRule"]) -> Type["LintRule"]:
    """Class decorator: register an instance under ``cls.id`` (the
    CopyMechanism/backend/policy registry contract)."""
    old = _RULES.get(cls.id)
    if old is not None and (type(old).__module__, type(old).__qualname__) != (
            cls.__module__, cls.__qualname__):
        raise ValueError(f"lint rule {cls.id!r} already registered by "
                         f"{type(old).__qualname__}")
    _RULES[cls.id] = cls()
    return cls


def get_rule(rule_id: str) -> "LintRule":
    try:
        return _RULES[rule_id]
    except KeyError:
        raise ValueError(f"unknown lint rule {rule_id!r} "
                         f"(known: {sorted(_RULES)})") from None


def rule_ids() -> Tuple[str, ...]:
    return tuple(sorted(_RULES))


def all_rules() -> Tuple["LintRule", ...]:
    return tuple(_RULES[k] for k in sorted(_RULES))


# ---------------------------------------------------------------------------
# shared AST plumbing
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """``np.asarray`` -> "np.asarray"; ``x.item`` -> "x.item"; None when the
    callee is not a plain name/attribute chain (e.g. a subscript)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _FuncStackVisitor(ast.NodeVisitor):
    """Generic walker tracking the enclosing-function name stack.  Decorator
    expressions are visited at the PARENT's depth (a module-level
    ``@register_backend(...)`` is import-time work, not function-body
    work)."""

    def __init__(self):
        self.stack: List[str] = []

    def _visit_func(self, node):
        for dec in node.decorator_list:
            self.visit(dec)
        self.stack.append(node.name)
        for child in node.body:
            self.visit(child)
        for default in list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]:
            self.visit(default)
        self.stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func


class LintRule:
    """Base rule: ``applies_to`` scopes by repo-relative path, ``check``
    returns findings for one parsed module."""

    id: str = "base"
    doc: str = ""

    def applies_to(self, relpath: str) -> bool:
        raise NotImplementedError

    def check(self, tree: ast.Module, relpath: str,
              source: str) -> List[Finding]:
        raise NotImplementedError

    def finding(self, relpath: str, node: ast.AST, msg: str) -> Finding:
        return Finding(rule=self.id, path=relpath,
                       line=getattr(node, "lineno", 0), message=msg)


# ---------------------------------------------------------------------------
# rule 1: movement only via plan()
# ---------------------------------------------------------------------------

@register_rule
class RawBackendRule(LintRule):
    """Raw movement primitives may be CALLED only where the architecture
    says the bytes move: the kernel package (definitions and their
    interpret/reference wrappers), the RBM hop primitives, and the one
    backend registry that executes ``MovementPlan`` legs.  Everywhere else
    movement must go through ``movement.plan()`` so it is priced."""

    id = "movement-raw-backend"
    doc = ("raw villa_gather/villa_scatter/rbm_copy/ppermute call outside "
           "the movement backend registry")

    RAW_CALLS = frozenset({"villa_gather", "villa_scatter", "rbm_copy",
                           "ppermute"})
    ALLOWED = ("src/repro/kernels/",)
    ALLOWED_FILES = frozenset({"src/repro/movement/backends.py",
                               "src/repro/core/lisa/rbm.py"})

    def applies_to(self, relpath: str) -> bool:
        return (relpath.startswith("src/repro/")
                and relpath not in self.ALLOWED_FILES
                and not any(relpath.startswith(p) for p in self.ALLOWED))

    def check(self, tree, relpath, source):
        findings: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name and name.split(".")[-1] in self.RAW_CALLS:
                findings.append(self.finding(
                    relpath, node,
                    f"raw movement call {name}() bypasses movement.plan(); "
                    f"route it through a registered backend so it is priced"))
        return findings


# ---------------------------------------------------------------------------
# rule 2: no host syncs in the tick loop / wave dispatch
# ---------------------------------------------------------------------------

@register_rule
class HostSyncRule(LintRule):
    """The serving hot path makes exactly ONE device→host transfer per
    decode step (``Engine.step_end``) plus the small sanctioned policy-tag
    reads the scheduler's cost scoring consults between dispatches.  Any
    other sync idiom in tick-loop or wave-dispatch code is a trip across
    the narrow channel the architecture exists to avoid.  The sanctioned
    readers are structural allowlist entries HERE (reviewed with the rule),
    never waiver-file lines — the waiver file stays empty."""

    id = "host-sync-in-hot-loop"
    doc = ("device sync (.item()/np.asarray/block_until_ready/device_get/"
           "float-on-buffer) inside tick-loop or wave-dispatch code")

    SCOPE = frozenset({
        "src/repro/sched/scheduler.py",
        "src/repro/sched/policy.py",
        "src/repro/sched/queue.py",
        "src/repro/serve/engine.py",
        "src/repro/serve/cluster.py",
    })
    # the documented one-transfer-per-step contract and the policy-tag reads
    SANCTIONED: Dict[str, Set[str]] = {
        "src/repro/serve/engine.py": {"step_end", "fast_resident_uids"},
        "src/repro/serve/cluster.py": {"fast_occupancy", "_invalidate_fast"},
    }
    ASARRAY = frozenset({"np.asarray", "numpy.asarray", "onp.asarray"})

    def applies_to(self, relpath: str) -> bool:
        return relpath in self.SCOPE

    def check(self, tree, relpath, source):
        rule, sanctioned = self, self.SANCTIONED.get(relpath, set())
        findings: List[Finding] = []

        class V(_FuncStackVisitor):
            def visit_Call(self, node):
                if not (set(self.stack) & sanctioned):
                    msg = rule._sync_idiom(node)
                    if msg:
                        findings.append(rule.finding(relpath, node, msg))
                self.generic_visit(node)

        V().visit(tree)
        return findings

    def _sync_idiom(self, node: ast.Call) -> Optional[str]:
        name = dotted_name(node.func)
        if name is None:
            return None
        leaf = name.split(".")[-1]
        if leaf == "item" and not node.args:
            return f"{name}() syncs the device mid-tick"
        if leaf == "block_until_ready":
            return f"{name}() blocks the dispatch pipeline"
        if leaf == "device_get":
            return f"{name}() is a device->host transfer in hot-loop code"
        arg_is_buffer = (node.args and isinstance(
            node.args[0], (ast.Name, ast.Attribute)))
        if name in self.ASARRAY and arg_is_buffer:
            return (f"{name}() on a live buffer forces a device->host "
                    f"transfer; only the sanctioned step_end/policy-tag "
                    f"reads may sync")
        if name == "float" and arg_is_buffer:
            return "float() on a live buffer syncs the device"
        return None


# ---------------------------------------------------------------------------
# rule 3: virtual-clock modules stay deterministic
# ---------------------------------------------------------------------------

@register_rule
class WallClockRule(LintRule):
    """Everything under ``sched/`` runs on the scheduler's virtual clock
    (modeled ns): wall-clock reads or unseeded RNG there would decouple the
    priced schedule from the deterministic BENCH gates."""

    id = "wallclock-in-virtual-clock"
    doc = "wall-clock read or unseeded RNG in a virtual-clock module"

    # obs/ records MODELED time only — a wall-clock read there would stamp
    # host time onto the virtual timeline and break byte-stable traces;
    # core/dram/bank.py holds the refresher/bank-machine clock model whose
    # refresh windows must be a pure function of virtual time
    SCOPE_PREFIX = ("src/repro/sched/", "src/repro/obs/",
                    "src/repro/core/dram/bank.py")
    WALL = frozenset({"time.time", "time.time_ns", "time.perf_counter",
                      "time.perf_counter_ns", "time.monotonic",
                      "time.monotonic_ns", "datetime.now",
                      "datetime.datetime.now", "datetime.utcnow"})

    def applies_to(self, relpath: str) -> bool:
        return relpath.startswith(self.SCOPE_PREFIX)

    def check(self, tree, relpath, source):
        findings: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None:
                continue
            if name in self.WALL:
                findings.append(self.finding(
                    relpath, node,
                    f"{name}() reads the wall clock inside the virtual-"
                    f"clock domain; charge modeled ns instead"))
            elif name.startswith("random."):
                findings.append(self.finding(
                    relpath, node,
                    f"{name}() uses the unseeded global RNG; thread a "
                    f"seeded np.random.default_rng(seed) through instead"))
            elif (name.endswith(".random.default_rng")
                  or name == "default_rng") and not (node.args
                                                     or node.keywords):
                findings.append(self.finding(
                    relpath, node,
                    "default_rng() without a seed is entropy-seeded; pass "
                    "the workload seed explicitly"))
            elif (name.split(".")[0] in ("np", "numpy")
                  and ".random." in name
                  and not name.endswith("default_rng")):
                findings.append(self.finding(
                    relpath, node,
                    f"{name}() draws from the global numpy RNG; use a "
                    f"seeded Generator"))
        return findings


# ---------------------------------------------------------------------------
# rule 4: strict JSON artifacts
# ---------------------------------------------------------------------------

@register_rule
class JsonNanRule(LintRule):
    """``json.dump``/``dumps`` must pass ``allow_nan=False``: Python's
    default emits bare ``NaN``/``Infinity`` literals, which are not JSON —
    a NaN metric must fail at WRITE time, not poison a consumer later.
    (``repro.sched.metrics`` reports empty classes as None for exactly this
    reason.)"""

    id = "json-nan"
    doc = "json.dump/json.dumps without allow_nan=False"

    def applies_to(self, relpath: str) -> bool:
        return True

    def check(self, tree, relpath, source):
        findings: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name not in ("json.dump", "json.dumps"):
                continue
            ok = any(kw.arg == "allow_nan"
                     and isinstance(kw.value, ast.Constant)
                     and kw.value.value is False for kw in node.keywords)
            if not ok:
                findings.append(self.finding(
                    relpath, node,
                    f"{name}() without allow_nan=False writes non-strict "
                    f"JSON (bare NaN/Infinity literals)"))
        return findings


# ---------------------------------------------------------------------------
# rule 5: registries are import-time only
# ---------------------------------------------------------------------------

@register_rule
class ImportTimeRegistrationRule(LintRule):
    """Backend/policy/rule registration must complete at import time — a
    registration inside a function body makes lookup depend on whether and
    when that function ran (the reload-safe registry contract assumes the
    module body IS the registration transaction)."""

    id = "import-time-registration"
    doc = "register_backend/register_policy/register_rule inside a function"

    REGISTRARS = frozenset({"register_backend", "register_policy",
                            "register_rule", "register_mechanism",
                            "register_fault"})

    def applies_to(self, relpath: str) -> bool:
        return relpath.startswith("src/repro/")

    def check(self, tree, relpath, source):
        rule = self
        findings: List[Finding] = []

        class V(_FuncStackVisitor):
            def visit_Call(self, node):
                name = dotted_name(node.func)
                if (name and name.split(".")[-1] in rule.REGISTRARS
                        and self.stack):
                    findings.append(rule.finding(
                        relpath, node,
                        f"{name}() called inside "
                        f"{'.'.join(self.stack)}(); registries are "
                        f"import-time only"))
                self.generic_visit(node)

        V().visit(tree)
        return findings


# ---------------------------------------------------------------------------
# rule 6: unpacked pages must be checksum-verified
# ---------------------------------------------------------------------------

@register_rule
class UncheckedUnpackRule(LintRule):
    """Outside the movement substrate (whose unpack backend verifies the
    sidecar itself), a function that calls ``unpack_into_slot`` directly
    must also consult the checksum surface — ``page_checksums`` /
    ``verify_pages``, or pass the ``sums=`` operand through an
    ``execute(...)`` env.  A bare unpack re-materializes page bytes into a
    live cache with no way to notice in-flight or at-rest corruption: the
    exact hole the chaos bench's zero-silent-corruption gate closes."""

    id = "unchecked-unpack"
    doc = ("unpack_into_slot call outside movement/ in a function that "
           "never consults the checksum sidecar")

    SCOPE_EXCLUDE = "src/repro/movement/"
    VERIFIERS = frozenset({"page_checksums", "verify_pages"})

    def applies_to(self, relpath: str) -> bool:
        return (relpath.startswith("src/repro/")
                and not relpath.startswith(self.SCOPE_EXCLUDE))

    def check(self, tree, relpath, source):
        rule = self
        findings: List[Finding] = []

        class V(_FuncStackVisitor):
            def __init__(self):
                super().__init__()
                self.unpacks: List[Tuple[Tuple[str, ...], ast.Call]] = []
                self.verified: Set[Tuple[str, ...]] = set()

            def visit_Call(self, node):
                name = dotted_name(node.func)
                leaf = name.split(".")[-1] if name else ""
                key = tuple(self.stack)
                if leaf == "unpack_into_slot":
                    self.unpacks.append((key, node))
                elif leaf in rule.VERIFIERS or any(
                        kw.arg == "sums" for kw in node.keywords):
                    self.verified.add(key)
                self.generic_visit(node)

        v = V()
        v.visit(tree)
        for key, node in v.unpacks:
            if key not in v.verified:
                findings.append(rule.finding(
                    relpath, node,
                    "unpack_into_slot() without a checksum verify in the "
                    "same function; route through the movement unpack leg "
                    "(which verifies the sidecar) or call verify_pages()"))
        return findings


# ---------------------------------------------------------------------------
# rule 7: snapshot scatters respect the fork table's refcounts
# ---------------------------------------------------------------------------

@register_rule
class UnrefcountedAliasRule(LintRule):
    """Forked sessions alias ONE physical store row (``repro.fork``); the
    row is written by the ``_suspend`` / ``_suspend_many`` scatter
    dispatches.  A serving function that drives that scatter — calling
    the dispatch directly or handing it to a wrapper like ``_quiet`` —
    without touching the fork table's refcount API (``write_break`` /
    ``bind`` / ``fork_child`` / ``release``) in the same function would
    overwrite a possibly-shared row with one writer's bytes and silently
    corrupt every other alias: the copy-on-write detach MUST gate the
    scatter.  (Benchmarks drive ``eng._suspend`` raw for A/B timing — the
    scope is the serving and fork packages, where the alias ledger is
    live.)"""

    id = "unrefcounted-alias"
    doc = ("_suspend/_suspend_many scatter in serving code with no fork-"
           "table refcount call (write_break/bind/fork_child/release) in "
           "the same function")

    SCOPE_PREFIXES = ("src/repro/serve/", "src/repro/fork/")
    MUTATORS = frozenset({"_suspend", "_suspend_many"})
    VERIFIERS = frozenset({"write_break", "bind", "fork_child", "release"})

    def applies_to(self, relpath: str) -> bool:
        return any(relpath.startswith(p) for p in self.SCOPE_PREFIXES)

    def check(self, tree, relpath, source):
        rule = self
        findings: List[Finding] = []

        class V(_FuncStackVisitor):
            def __init__(self):
                super().__init__()
                self.scatters: List[Tuple[Tuple[str, ...], ast.Call]] = []
                self.verified: Set[Tuple[str, ...]] = set()

            def visit_Call(self, node):
                key = tuple(self.stack)
                name = dotted_name(node.func)
                leaf = name.split(".")[-1] if name else ""
                if leaf in rule.MUTATORS:
                    self.scatters.append((key, node))
                else:
                    # the dispatch handed to a wrapper: _quiet(self._suspend,
                    # ...) drives the same scatter
                    for a in node.args:
                        an = dotted_name(a)
                        if an and an.split(".")[-1] in rule.MUTATORS:
                            self.scatters.append((key, node))
                            break
                if leaf in rule.VERIFIERS:
                    self.verified.add(key)
                self.generic_visit(node)

        v = V()
        v.visit(tree)
        for key, node in v.scatters:
            if key not in v.verified:
                findings.append(rule.finding(
                    relpath, node,
                    "snapshot scatter without a fork-table refcount call in "
                    "the same function; a forked alias may share this row — "
                    "CoW-detach via write_break() before writing"))
        return findings


# ---------------------------------------------------------------------------
# rule 8: tracer spans close where they open
# ---------------------------------------------------------------------------

@register_rule
class UnclosedSpanRule(LintRule):
    """A span opened with ``begin_span`` and never closed stays on its
    lane's stack forever: every later span on that lane silently nests
    under it, its duration covers the rest of the run, and the trace's
    parent/child additivity contract (tests/test_obs.py) breaks without an
    error.  So the pairing is STRUCTURAL, like ``unrefcounted-alias``'s
    scatter/refcount pairing: a function that calls ``begin_span`` must
    also call ``end_span`` (on any span) in the same function — or use the
    ``span()`` context manager, which cannot leak.  ``emit`` / ``instant``
    / ``move_span`` are self-closing and need no pairing."""

    id = "unclosed-span"
    doc = ("begin_span with no end_span in the same function; use the "
           "span() context manager or close where you open")

    OPENER = "begin_span"
    CLOSER = "end_span"

    def applies_to(self, relpath: str) -> bool:
        # everywhere the tracer may be driven; obs/tracer.py itself defines
        # the pairing (span()/emit/move_span all open AND close)
        return relpath.startswith("src/repro/") or \
            relpath.startswith("benchmarks/")

    def check(self, tree, relpath, source):
        rule = self
        findings: List[Finding] = []

        class V(_FuncStackVisitor):
            def __init__(self):
                super().__init__()
                self.opens: List[Tuple[Tuple[str, ...], ast.Call]] = []
                self.closed: Set[Tuple[str, ...]] = set()

            def visit_Call(self, node):
                name = dotted_name(node.func)
                leaf = name.split(".")[-1] if name else ""
                key = tuple(self.stack)
                if leaf == rule.OPENER:
                    self.opens.append((key, node))
                elif leaf == rule.CLOSER:
                    self.closed.add(key)
                self.generic_visit(node)

        v = V()
        v.visit(tree)
        for key, node in v.opens:
            if key not in v.closed:
                findings.append(rule.finding(
                    relpath, node,
                    "begin_span() with no end_span() in the same function "
                    "leaves the span open on its lane (all later spans nest "
                    "under it); use tracer.span() or close here"))
        return findings
