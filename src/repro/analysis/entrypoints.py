"""The registry of audited jitted entry points.

``default_targets()`` builds each registered hot-path program at reduced
audit geometry and pairs it with the contract its docstring promises:

========================  =========================================
entry point               contract
========================  =========================================
``decode``                donate cache (arg 1); zero host transfers
``prefill[bucket=k]``     donate cache (arg 1); one per bucket length
``suspend``               donate store+sums (args 1,2); uint8-preserving
``suspend_many``          donate store+sums (args 1,2); ONE dispatch/wave
``resume``                donate cache+store+fail (args 0,1,3); uint8-prsv
``resume_many``           donate cache+store+fail (args 0,1,3); ONE disp
``migrate``               donate dst pool (arg 1); uint8-preserving
``simulate_params``       pure simulator: no donation, no host transfer
========================  =========================================

The suspend/resume signatures carry the checksum sidecar (PR 7): suspends
also emit per-page sums; resumes also consume them and fold the verify
verdict into a donated failure counter — still zero extra host transfers.
The migrate executor takes the traced ``(mode, index, xor)`` fault operand
(NULL_FAULT on clean runs): one compilation serves clean and chaos runs.

Everything is traced/lowered statically — no engine loop runs, no tokens
decode.  The geometry is deliberately tiny (2 slots, max_len 32): the
contracts are shape-independent, so proving them at reduced geometry proves
the mechanism.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.dispatch import AuditTarget, EntryContract
from repro.faults.spec import NULL_FAULT

AUDIT_SLOTS = 2
AUDIT_MAX_LEN = 32
AUDIT_SESSIONS = 4
AUDIT_WAVE = 2          # wave width audited for *_many / migrate


def prefill_buckets(engine) -> List[int]:
    """The declared compile-key set: the image of ``_bucket_len`` over all
    admissible lengths."""
    return sorted({engine._bucket_len(n)
                   for n in range(1, engine.max_len + 1)})


def engine_targets(engine) -> List[AuditTarget]:
    """Audit targets for one constructed :class:`~repro.serve.engine.Engine`
    (its live jit objects — the audit sees exactly what serving runs)."""
    slots = engine.slots
    cache, sessions, params = engine.cache, engine.sessions, engine.params
    sums, failed = engine.session_sums, engine.verify_failed
    i32 = jnp.int32
    wave = min(AUDIT_WAVE, slots)
    targets = [
        AuditTarget(
            "decode", engine._decode,
            (params, cache, jnp.zeros(slots, i32), jnp.zeros(slots, i32),
             jnp.zeros(slots, bool)),
            EntryContract(donate=frozenset({1}), max_compiles=1)),
        AuditTarget(
            "suspend", engine._suspend,
            (cache, sessions, sums, i32(0), i32(0)),
            EntryContract(donate=frozenset({1, 2}), uint8_preserving=True)),
        AuditTarget(
            "suspend_many", engine._suspend_many,
            (cache, sessions, sums, jnp.arange(wave, dtype=i32),
             jnp.arange(wave, dtype=i32)),
            EntryContract(donate=frozenset({1, 2}), uint8_preserving=True)),
        AuditTarget(
            "resume", engine._resume,
            (cache, sessions, sums, failed, i32(0), i32(0)),
            EntryContract(donate=frozenset({0, 1, 3}),
                          uint8_preserving=True)),
        AuditTarget(
            "resume_many", engine._resume_many,
            (cache, sessions, sums, failed, jnp.arange(wave, dtype=i32),
             jnp.arange(wave, dtype=i32)),
            EntryContract(donate=frozenset({0, 1, 3}),
                          uint8_preserving=True)),
    ]
    buckets = prefill_buckets(engine)
    for lb in buckets:
        if engine.cfg.mrope:
            positions = None
        else:
            positions = jnp.zeros((1, lb), i32)
        targets.append(AuditTarget(
            f"prefill[bucket={lb}]", engine._prefill,
            (params, cache, jnp.zeros((1, lb), i32), positions,
             i32(lb), i32(0)),
            EntryContract(donate=frozenset({1}),
                          max_compiles=len(buckets))))
    return targets


def cluster_targets(cluster) -> List[AuditTarget]:
    """The migration route executor of a constructed cluster (>= 2
    replicas), audited at wave width :data:`AUDIT_WAVE`."""
    if cluster.n_replicas < 2:
        return []
    if cluster._migrate_exec is None:
        cluster._migrate_exec = cluster._build_migrate_exec()
    spp = cluster.page_spec.n_pages
    table = jnp.arange(AUDIT_WAVE * spp, dtype=jnp.int32)
    src = cluster.replicas[0].sessions.slow
    dst = cluster.replicas[1].sessions.slow
    fault = jnp.asarray(NULL_FAULT)
    return [AuditTarget(
        "migrate", cluster._migrate_exec, (src, dst, table, table, fault),
        EntryContract(donate=frozenset({1}), uint8_preserving=True))]


def controller_targets() -> List[AuditTarget]:
    """The DRAM controller simulator: ONE jit serves every copy-mechanism
    preset (mechanism parameters are traced data, never compile keys)."""
    from repro.core.dram import controller as DC
    from repro.core.dram import traces as DT
    from repro.core.dram.spec import DDR3_1600

    tcfg = DT.TraceConfig(n_requests=64)
    trace = DT.generate(jax.random.key(0), tcfg)
    mcfg = DC.MechanismConfig()
    p = DC.mechanism_params(mcfg, DDR3_1600)
    return [AuditTarget(
        "simulate_params", DC.simulate_params, (trace, p),
        EntryContract(donate=frozenset(), max_compiles=1),
        kwargs=dict(n_banks=tcfg.n_banks, n_cores=tcfg.n_cores,
                    villa_cfg=mcfg.villa, unroll=4))]


def default_targets(arch: str = "tinyllama-1.1b"):
    """(targets, engine) at reduced audit geometry — every registered
    jitted entry point in the serving stack plus the controller simulator."""
    from repro.configs import get_reduced
    from repro.models import lm
    from repro.serve.cluster import Cluster
    from repro.serve.engine import Engine

    cfg = get_reduced(arch)
    params = lm.init_lm(cfg, jax.random.key(0))
    engine = Engine(cfg, params, slots=AUDIT_SLOTS, max_len=AUDIT_MAX_LEN,
                    n_sessions=AUDIT_SESSIONS)
    cluster = Cluster(cfg, params, n_replicas=2, slots=1,
                      max_len=AUDIT_MAX_LEN, n_sessions=AUDIT_SESSIONS)
    targets = (engine_targets(engine) + cluster_targets(cluster)
               + controller_targets())
    return targets, engine
