"""The architecture-lint pass: walk the tree, apply every registered rule,
partition findings against the waiver file, return a :class:`Report`.

Paths in findings are always repo-relative posix paths — the report must be
byte-stable across machines so it can be committed and schema-checked.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.analysis import rules as R
from repro.analysis.findings import (DEFAULT_WAIVER_FILE, Finding, Report,
                                     load_waivers, split_waived)

DEFAULT_ROOTS = ("src/repro", "benchmarks")
_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "node_modules"}


def find_repo_root(start: Optional[str] = None) -> str:
    """Nearest ancestor holding ``src/repro`` (falls back to the package's
    own checkout when run from elsewhere)."""
    here = os.path.abspath(start or os.getcwd())
    probe = here
    while True:
        if os.path.isdir(os.path.join(probe, "src", "repro")):
            return probe
        parent = os.path.dirname(probe)
        if parent == probe:
            break
        probe = parent
    # package layout: <root>/src/repro/analysis/lint.py
    return os.path.abspath(os.path.join(os.path.dirname(__file__),
                                        "..", "..", ".."))


def iter_py_files(repo_root: str,
                  roots: Sequence[str] = DEFAULT_ROOTS) -> Iterable[str]:
    """Repo-relative posix paths of every .py file under ``roots``, sorted
    for deterministic reports."""
    out: List[str] = []
    for root in roots:
        base = os.path.join(repo_root, root)
        if os.path.isfile(base) and base.endswith(".py"):
            out.append(root)
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    rel = os.path.relpath(os.path.join(dirpath, fn),
                                          repo_root)
                    out.append(rel.replace(os.sep, "/"))
    return sorted(out)


def lint_file(relpath: str, source: str,
              active_rules=None) -> List[Finding]:
    """Apply every (scoped) rule to one module's source."""
    active_rules = active_rules if active_rules is not None else R.all_rules()
    try:
        tree = ast.parse(source, filename=relpath)
    except SyntaxError as e:
        return [Finding(rule="parse-error", path=relpath,
                        line=e.lineno or 0, message=str(e.msg))]
    findings: List[Finding] = []
    for rule in active_rules:
        if rule.applies_to(relpath):
            findings.extend(rule.check(tree, relpath, source))
    return findings


def run_lint(repo_root: Optional[str] = None,
             roots: Sequence[str] = DEFAULT_ROOTS,
             rule_ids: Optional[Sequence[str]] = None,
             waiver_file: Optional[str] = None) -> Report:
    """Lint every Python file under ``roots`` and return the report with
    waivers applied (``waiver_file`` defaults to ``LINT_WAIVERS`` at the
    repo root; absent == empty)."""
    repo_root = repo_root or find_repo_root()
    active = (tuple(R.get_rule(i) for i in rule_ids)
              if rule_ids is not None else R.all_rules())
    waiver_path = (waiver_file if waiver_file is not None
                   else os.path.join(repo_root, DEFAULT_WAIVER_FILE))
    waivers = load_waivers(waiver_path)

    findings: List[Finding] = []
    files = list(iter_py_files(repo_root, roots))
    for rel in files:
        with open(os.path.join(repo_root, rel)) as f:
            findings.extend(lint_file(rel, f.read(), active))
    active_findings, waived = split_waived(findings, waivers)
    return Report(roots=list(roots), rules=[r.id for r in active],
                  findings=active_findings, waived=waived,
                  waiver_file=os.path.basename(waiver_path),
                  files_scanned=len(files))
