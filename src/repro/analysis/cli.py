"""``repro-lint``: the static invariant analyzer's console entry point.

Quickstart::

  # architecture lint only (fast, no jax tracing)
  PYTHONPATH=src python -m repro.analysis --strict

  # lint + the jaxpr/HLO dispatch audit of every jitted entry point,
  # writing the machine-readable report CI commits and schema-checks
  PYTHONPATH=src python -m repro.analysis --strict --audit \
      --report LINT_REPORT.json

Exit status: 0 when clean (waived findings don't fail), 1 when any active
finding survives — with ``--strict`` this is a hard CI gate.  The waiver
file (``LINT_WAIVERS`` at the repo root) is expected to be EMPTY; a waiver
is a visible, committed debt marker, not an escape hatch.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from repro.analysis import lint as L
from repro.analysis.findings import Finding


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro-lint",
        description="Static invariant analyzer: AST architecture lint + "
                    "jaxpr/HLO dispatch audit of every jitted entry point.")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 on any active (unwaived) finding")
    p.add_argument("--audit", action="store_true",
                   help="also run the jaxpr/HLO dispatch audit (traces and "
                        "compiles every registered entry point at reduced "
                        "geometry)")
    p.add_argument("--no-compiled-hlo", action="store_true",
                   help="audit via lowering + jaxpr only (skip the "
                        "compiled-HLO walk)")
    p.add_argument("--report", metavar="PATH",
                   help="write the strict-JSON findings report here")
    p.add_argument("--waivers", metavar="PATH",
                   help="waiver file (default: LINT_WAIVERS at the repo "
                        "root; missing == empty)")
    p.add_argument("--root", metavar="DIR",
                   help="repo root (default: auto-detected)")
    p.add_argument("--rules", nargs="*", metavar="RULE",
                   help="restrict the lint pass to these rule ids")
    p.add_argument("roots", nargs="*", default=None,
                   help=f"directories to lint (default: "
                        f"{' '.join(L.DEFAULT_ROOTS)})")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    repo_root = os.path.abspath(args.root) if args.root else L.find_repo_root()
    roots = tuple(args.roots) if args.roots else L.DEFAULT_ROOTS

    report = L.run_lint(repo_root=repo_root, roots=roots,
                        rule_ids=args.rules, waiver_file=args.waivers)

    audit_findings: List[Finding] = []
    if args.audit:
        from repro.analysis import dispatch as D
        from repro.analysis import entrypoints as E
        targets, engine = E.default_targets()
        extra = D.audit_bucket_stability(engine, E.prefill_buckets(engine))
        report.audit = D.run_audit(targets,
                                   compiled=not args.no_compiled_hlo,
                                   extra_findings=extra)
        report.audit["prefill_buckets"] = E.prefill_buckets(engine)
        audit_findings = [Finding(**f) for f in report.audit["findings"]]

    for f in report.findings + audit_findings:
        print(f"LINT FAIL {f}")
    for f in report.waived:
        print(f"LINT WAIVED {f}")
    n_audited = len(report.audit.get("targets", []))
    print(f"repro-lint: {report.files_scanned} files, "
          f"{len(report.rules)} rules, {n_audited} entry points audited; "
          f"{len(report.findings) + len(audit_findings)} finding(s), "
          f"{len(report.waived)} waived")

    if args.report:
        report.write(args.report)
        print(f"report -> {args.report}")

    failed = bool(report.findings or audit_findings)
    if args.strict and report.waived:
        # strict mode enforces the empty-waiver acceptance bar: a waiver is
        # tolerated debt locally, never a green CI
        print(f"LINT FAIL --strict forbids waivers "
              f"({len(report.waived)} active in {report.waiver_file})")
        failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
