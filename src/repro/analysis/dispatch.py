"""The jaxpr/HLO dispatch auditor: statically verify the contract every
registered jitted entry point documents.

Three layers of evidence per entry point, cheapest first:

1. **Lowering metadata** — ``jit_fn.lower(*args).args_info`` carries a
   per-leaf ``donated`` flag: donation silently dropped (a wrapper re-jitted
   without ``donate_argnums``, a refactor moved an argument) is caught
   without compiling anything.  The StableHLO text is cross-checked for the
   ``tf.aliasing_output`` / ``jax.buffer_donor`` parameter attributes — the
   proof the donation survived into the program XLA sees.
2. **jaxpr walk** — every primitive in the traced graph (recursing through
   pjit/scan/cond sub-jaxprs) is scanned against the host-transfer denylist
   (callbacks, infeed/outfeed) and for forbidden dtype widenings
   (``convert_element_type`` uint8→float on the page paths, which must stay
   bit-exact).
3. **compiled HLO walk** — the post-optimization text is split with the
   :mod:`repro.roofline.hlo` walker (the same parser the roofline layer
   uses) and scanned for host-transfer opcodes and callback custom-calls
   that only appear after lowering.

Findings use the same :class:`~repro.analysis.findings.Finding` shape as
the AST linter; their ``path`` is the pseudo-path ``entry:<name>`` so the
one report covers both layers.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, FrozenSet, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.roofline import hlo as RH

# primitives whose presence inside a hot-path graph means a host round-trip
HOST_TRANSFER_PRIMITIVES = frozenset({
    "io_callback", "pure_callback", "debug_callback", "callback",
    "host_callback_call", "infeed", "outfeed",
})

# post-optimization HLO opcodes that cross the host boundary
HOST_TRANSFER_OPCODES = frozenset({
    "infeed", "outfeed", "send", "send-done", "recv", "recv-done",
})

_DONOR_ATTR = re.compile(r"tf\.aliasing_output|jax\.buffer_donor")


@dataclasses.dataclass(frozen=True)
class EntryContract:
    """What one jitted entry point promises (engine/cluster docstrings made
    machine-checkable)."""
    donate: FrozenSet[int] = frozenset()    # positional args donated
    no_host_transfer: bool = True
    uint8_preserving: bool = False          # page path: no uint8->float
    dispatches_per_call: int = 1
    max_compiles: Optional[int] = None      # bound over the declared keys


@dataclasses.dataclass(frozen=True)
class AuditTarget:
    """One registered jitted entry point with example arguments at audit
    (reduced) geometry."""
    name: str
    fn: Callable                            # the jit-wrapped callable
    args: tuple
    contract: EntryContract
    kwargs: Dict[str, object] = dataclasses.field(default_factory=dict)


def _entry_finding(rule: str, target_name: str, msg: str) -> Finding:
    return Finding(rule=rule, path=f"entry:{target_name}", line=0,
                   message=msg)


# ---------------------------------------------------------------------------
# layer 1: donation
# ---------------------------------------------------------------------------

def donated_leaf_flags(lowered, n_args: int) -> List[List[bool]]:
    """Per positional arg, the ``donated`` flag of each flattened leaf."""
    args_info, _kwargs_info = lowered.args_info
    out: List[List[bool]] = []
    for i in range(n_args):
        leaves = jax.tree_util.tree_leaves(args_info[i])
        out.append([bool(leaf.donated) for leaf in leaves])
    return out


def check_donation(target: AuditTarget, lowered,
                   hlo_text: str) -> Tuple[Dict[str, int], List[Finding]]:
    findings: List[Finding] = []
    flags = donated_leaf_flags(lowered, len(target.args))
    expected_leaves = 0
    surviving_leaves = 0        # declared AND actually donated at lowering
    for i, leaf_flags in enumerate(flags):
        if i in target.contract.donate:
            expected_leaves += len(leaf_flags)
            surviving_leaves += leaf_flags.count(True)
            if not all(leaf_flags):
                n_bad = leaf_flags.count(False)
                findings.append(_entry_finding(
                    "audit-donation", target.name,
                    f"arg {i} is documented as donated but {n_bad}/"
                    f"{len(leaf_flags)} of its buffers are not — donation "
                    f"was silently dropped (copy fallback)"))
        elif any(leaf_flags):
            findings.append(_entry_finding(
                "audit-donation", target.name,
                f"arg {i} is donated but the contract does not declare it "
                f"— callers may still be holding the buffer"))
    # cross-check only what args_info says IS donated — a dropped donation
    # already fired above and must not double-report here
    marked = len(_DONOR_ATTR.findall(hlo_text))
    if marked < surviving_leaves:
        findings.append(_entry_finding(
            "audit-donation", target.name,
            f"lowered module marks only {marked} of {surviving_leaves} "
            f"donated buffers (tf.aliasing_output/jax.buffer_donor); "
            f"donation did not survive lowering"))
    return {"donated_leaves": sum(f.count(True) for f in flags),
            "expected_donated_leaves": expected_leaves,
            "hlo_donor_marks": marked}, findings


# ---------------------------------------------------------------------------
# layer 2: jaxpr walk
# ---------------------------------------------------------------------------

def _sub_jaxprs(params: Dict):
    for v in params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for u in vals:
            if hasattr(u, "eqns"):                      # Jaxpr
                yield u
            elif hasattr(u, "jaxpr") and hasattr(u.jaxpr, "eqns"):
                yield u.jaxpr                           # ClosedJaxpr

def iter_eqns(jaxpr):
    """Every equation in ``jaxpr`` and (recursively) its sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def trace_jaxpr(fn, args, kwargs=None):
    # close over kwargs: make_jaxpr does not honor a pjit's static_argnames,
    # and every audited entry point's kwargs are static config
    if kwargs:
        return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args).jaxpr
    return jax.make_jaxpr(fn)(*args).jaxpr


def host_transfer_eqns(jaxpr) -> List[str]:
    return [e.primitive.name for e in iter_eqns(jaxpr)
            if e.primitive.name in HOST_TRANSFER_PRIMITIVES]


def uint8_upcast_eqns(jaxpr) -> List[str]:
    """convert_element_type equations that widen uint8 to floating — a
    page-path snapshot silently losing bit-exactness (and paying 4x the
    bytes)."""
    bad = []
    for e in iter_eqns(jaxpr):
        if e.primitive.name != "convert_element_type":
            continue
        src = e.invars[0].aval.dtype
        dst = e.params.get("new_dtype")
        if src == jnp.uint8 and dst is not None and \
                jnp.issubdtype(dst, jnp.floating):
            bad.append(f"uint8->{jnp.dtype(dst).name}")
    return bad


# ---------------------------------------------------------------------------
# layer 3: compiled-HLO walk (the roofline parser as backend)
# ---------------------------------------------------------------------------

def hlo_host_transfer_ops(compiled_text: str) -> List[str]:
    """Opcodes crossing the host boundary in post-optimization HLO —
    parsed with the same :func:`repro.roofline.hlo.split_computations`
    walker the roofline layer uses."""
    out: List[str] = []
    for comp in RH.split_computations(compiled_text).values():
        for op in comp.ops:
            base = op.opcode.split(".")[0]
            if base in HOST_TRANSFER_OPCODES:
                out.append(base)
            elif base == "custom-call" and "callback" in op.rest:
                out.append("custom-call:callback")
    return out


# ---------------------------------------------------------------------------
# the audit
# ---------------------------------------------------------------------------

def audit_target(target: AuditTarget,
                 compiled: bool = True) -> Tuple[Dict, List[Finding]]:
    """Audit ONE entry point against its contract; returns the record for
    the report plus any findings."""
    findings: List[Finding] = []
    lowered = target.fn.lower(*target.args, **target.kwargs)
    record: Dict[str, object] = {
        "name": target.name,
        "dispatches_per_call": target.contract.dispatches_per_call,
    }

    info, dn_findings = check_donation(target, lowered, lowered.as_text())
    record.update(info)
    findings.extend(dn_findings)

    jaxpr = trace_jaxpr(target.fn, target.args, target.kwargs)
    host = host_transfer_eqns(jaxpr)
    record["jaxpr_host_transfer_eqns"] = len(host)
    if target.contract.no_host_transfer and host:
        findings.append(_entry_finding(
            "audit-host-transfer", target.name,
            f"host-transfer primitives inside the jitted graph: "
            f"{sorted(set(host))}"))

    if target.contract.uint8_preserving:
        ups = uint8_upcast_eqns(jaxpr)
        record["uint8_upcasts"] = len(ups)
        if ups:
            findings.append(_entry_finding(
                "audit-dtype", target.name,
                f"uint8 page path widens to float: {sorted(set(ups))} — "
                f"snapshots must stay bit-exact uint8"))

    if compiled:
        hlo_text = lowered.compile().as_text()
        ops = hlo_host_transfer_ops(hlo_text)
        record["hlo_host_transfer_ops"] = len(ops)
        if target.contract.no_host_transfer and ops:
            findings.append(_entry_finding(
                "audit-host-transfer", target.name,
                f"compiled HLO contains host-boundary ops: "
                f"{sorted(set(ops))}"))
    return record, findings


def audit_bucket_stability(engine, declared: Sequence[int]) -> List[Finding]:
    """The prefill compile-key set: the image of ``_bucket_len`` over every
    admissible prompt length must be exactly the declared bucket set —
    otherwise an unexpected length recompiles in production."""
    image = sorted({engine._bucket_len(n)
                    for n in range(1, engine.max_len + 1)})
    if image != sorted(declared):
        return [_entry_finding(
            "audit-compile-keys", "prefill",
            f"bucket image {image} over lengths 1..{engine.max_len} "
            f"!= declared bucket set {sorted(declared)}")]
    return []


def run_audit(targets: Sequence[AuditTarget], *, compiled: bool = True,
              extra_findings: Sequence[Finding] = ()) -> Dict[str, object]:
    """Audit every target; returns the report's ``audit`` section (findings
    inline, serialized)."""
    records, findings = [], list(extra_findings)
    for t in targets:
        rec, fs = audit_target(t, compiled=compiled)
        records.append(rec)
        findings.extend(fs)
    return {
        "targets": records,
        "compiled_hlo_checked": bool(compiled),
        "findings": [f.as_dict() for f in findings],
    }
