"""repro-lint: static invariant analysis for the jitted hot path and the
movement architecture.

Two layers over one findings/report shape (DESIGN.md Sec. 11):

* :mod:`repro.analysis.rules` + :mod:`repro.analysis.lint` — the AST
  architecture linter (movement only via ``plan()``, no host syncs in the
  tick loop, virtual-clock determinism, strict JSON, import-time
  registries), behind the repo's fourth rule registry.
* :mod:`repro.analysis.dispatch` + :mod:`repro.analysis.entrypoints` — the
  jaxpr/HLO dispatch auditor proving every registered jitted entry point's
  documented contract (donation honored, zero in-graph host transfers,
  uint8 page paths bit-exact, bounded compile keys), with the
  :mod:`repro.roofline.hlo` walker as its compiled-HLO backend.

:mod:`repro.analysis.testlib` is the shared runtime asserter the test
suite uses for the same dispatch/compile-count invariants, so tests and CI
gate on one checker.  Console entry point: ``repro-lint`` (or
``python -m repro.analysis``).
"""
from repro.analysis.dispatch import (AuditTarget, EntryContract,
                                     audit_bucket_stability, audit_target,
                                     run_audit)
from repro.analysis.entrypoints import default_targets, prefill_buckets
from repro.analysis.findings import (Finding, Report, is_waived,
                                     load_waivers, split_waived)
from repro.analysis.lint import lint_file, run_lint
from repro.analysis.rules import (LintRule, all_rules, get_rule,
                                  register_rule, rule_ids)
from repro.analysis import testlib

__all__ = [
    "AuditTarget", "EntryContract", "Finding", "LintRule", "Report",
    "all_rules", "audit_bucket_stability", "audit_target",
    "default_targets", "get_rule", "is_waived", "lint_file",
    "load_waivers", "prefill_buckets", "register_rule", "rule_ids",
    "run_audit", "run_lint", "split_waived", "testlib",
]
