"""Shared dispatch/compile-count asserters for tests, benchmarks and CI.

PRs 2–5 pinned the one-dispatch-per-wave and compile-once invariants as
ad-hoc expressions (``eng.compile_counts()["decode"] in (1, -1)``) scattered
across test files; this module is the ONE checker both the test suite and
the ``repro-lint`` CI gate call, so the tolerance for the ``-1``
probe-unavailable sentinel (jax builds without ``_cache_size``) lives in
exactly one place.
"""
from __future__ import annotations

from typing import Dict, Iterable, Mapping, Union

UNKNOWN = -1      # compile_counts() sentinel: no jit cache-size probe


def _counts(obj) -> Mapping[str, int]:
    """Accept an Engine/Cluster (anything with ``compile_counts()``) or a
    plain counts mapping."""
    if hasattr(obj, "compile_counts"):
        return obj.compile_counts()
    return obj


def _flatten(allowed) -> set:
    flat = set()
    for a in allowed:
        if isinstance(a, int):
            flat.add(a)
        else:
            flat.update(a)
    return flat


def compile_count_ok(count: int, *allowed: Union[int, Iterable[int]]) -> bool:
    """True when ``count`` is one of ``allowed`` — or the probe-unavailable
    sentinel, which asserters must treat as 'unknown', never as a
    regression."""
    return count == UNKNOWN or count in _flatten(allowed)


def assert_compile_count(obj, key: str, *allowed) -> None:
    """The hot path ``key`` compiled an allowed number of times (decode: 1;
    a wave entry: one per wave width seen; an unused single-item path: 0)."""
    counts = _counts(obj)
    count = counts[key]
    if not compile_count_ok(count, *allowed):
        raise AssertionError(
            f"{key} compiled {count}x, expected one of "
            f"{sorted(_flatten(allowed))} (full counts: {dict(counts)})")


def assert_compile_at_most(obj, key: str, bound: int) -> None:
    counts = _counts(obj)
    count = counts[key]
    if count != UNKNOWN and count > bound:
        raise AssertionError(f"{key} compiled {count}x > bound {bound} "
                             f"(full counts: {dict(counts)})")


def assert_dispatch_delta(stats_before: Mapping[str, int],
                          stats_after: Mapping[str, int], *,
                          decode: int = None, host: int = None) -> None:
    """The paper's step invariant as a delta check: over the measured
    window, exactly ``decode`` fused dispatches and ``host`` device→host
    transfers happened (one each per step, however ragged the batch)."""
    if decode is not None:
        got = stats_after["decode_dispatches"] - stats_before[
            "decode_dispatches"]
        if got != decode:
            raise AssertionError(
                f"{got} decode dispatches over the window, expected "
                f"{decode} (one fused dispatch per step)")
    if host is not None:
        got = stats_after["host_transfers"] - stats_before["host_transfers"]
        if got != host:
            raise AssertionError(
                f"{got} host transfers over the window, expected {host} "
                f"(one device->host sync per step)")


def snapshot_stats(engine) -> Dict[str, int]:
    """Copy the dispatch counters before a measured window."""
    return dict(engine.stats)
