"""Serving metrics: per-class latency percentiles, SLO attainment, slot
utilization, and cumulative :class:`~repro.movement.plan.MovementCost`
(lisa vs memcpy) per scheduling decision.

Everything is recorded on the scheduler's *virtual clock* (modeled ns): a
decode tick costs ``decode_ns``, and every movement decision — resume wave,
preemption suspend, completion suspend — is charged its plan's Table-1
pricing, VILLA-occupancy-aware (a fast-tier hit pays the fast-subarray
fraction of the slow-tier cost).  The lisa/memcpy totals are the serving
layer's view of the paper's headline gap: the same schedule, priced under
both mechanisms.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np


def percentile_ns(xs, q) -> Optional[float]:
    """Percentile of a latency list; ``None`` for an empty one — a class
    with no completions has NO latency distribution.  (The old NaN leaked
    through ``round`` into summaries where an idle class read as a perfect
    p99, and ``json.dump(..., allow_nan=False)`` would crash on it; None
    serializes as strict-JSON ``null``.)

    ``method="linear"`` is pinned explicitly: it is numpy's current
    default, but the p50/p99 in committed BENCH artifacts must stay
    byte-stable even if a future numpy changes the default interpolation
    (single- and two-element buckets are the cases where methods disagree
    most — covered by tests)."""
    if not xs:
        return None
    return float(np.percentile(np.asarray(xs, np.float64), q,
                               method="linear"))


def _round(x: Optional[float], nd: int) -> Optional[float]:
    return None if x is None else round(x, nd)


@dataclasses.dataclass
class JobRecord:
    """One completed logical job (a fresh request or one follow-up)."""
    job_id: int
    uid: int
    kind: str               # "fresh" | "resume"
    priority: int
    arrival_ns: float
    done_ns: float
    slo_ns: float
    tokens: int
    migrations: int = 0     # cross-replica session moves while serving it

    @property
    def latency_ns(self) -> float:
        return self.done_ns - self.arrival_ns

    @property
    def slo_met(self) -> bool:
        return self.latency_ns <= self.slo_ns


@dataclasses.dataclass
class Decision:
    """One scheduling decision and its modeled movement bill (both
    mechanisms — per-decision Table-1 accounting)."""
    tick: int
    kind: str               # "submit" | "resume_wave" | "preempt_suspend"
                            # | "complete_suspend" | "migrate_wave"
    n_items: int
    ns_lisa: float = 0.0
    ns_memcpy: float = 0.0
    uj_lisa: float = 0.0
    uj_memcpy: float = 0.0
    # chaos-run kinds: "snapshot_wave" (write-behind: priced, not charged
    # to the clock), "recover_wave", "retry_wave" (both on the clock)
    backoff_ns: float = 0.0
    # retry backoff: mechanism-independent waiting charged to the clock
    # but NEVER to ns_lisa/ns_memcpy — folding it into both skewed the
    # reported advantage ratio with the fault rate (its own bucket keeps
    # the lisa-vs-memcpy A/B fault-rate-invariant)


class Metrics:
    """Accumulates job completions, decisions and per-tick occupancy;
    :meth:`summary` renders the benchmark/CI-facing dict."""

    def __init__(self):
        self.jobs: List[JobRecord] = []
        self.decisions: List[Decision] = []
        self._occupancy: List[float] = []
        self._replica_occ: List[List[float]] = []   # cluster runs only
        self._faults: Dict[str, int] = {}
        self._fault_class: Dict[int, Dict[str, int]] = {}
        # bank-model stalls (contention-on runs only): kind -> (ns, count)
        self._stalls: Dict[str, List[float]] = {}
        # the tracer's per-phase/per-leg rollup (repro.obs); set by the
        # scheduler at the end of a traced run, None on untraced runs so
        # untraced summaries are byte-identical to pre-obs output
        self.trace: Optional[Dict[str, object]] = None

    # ---- recording --------------------------------------------------------
    def record_job(self, rec: JobRecord) -> None:
        self.jobs.append(rec)

    def record_decision(self, dec: Decision) -> None:
        self.decisions.append(dec)

    def record_fault(self, kind: str, priority: Optional[int] = None,
                     n: int = 1) -> None:
        """Count one chaos event (``injected`` / ``detected`` /
        ``recovered`` / ``lost`` / ``requeued`` / ``retries`` /
        ``replica_failures`` / ``degraded``), optionally attributed to the
        affected job's class."""
        self._faults[kind] = self._faults.get(kind, 0) + n
        if priority is not None:
            per = self._fault_class.setdefault(priority, {})
            per[kind] = per.get(kind, 0) + n

    def record_stall(self, kind: str, ns: float) -> None:
        """Count one bank-model stall (``refresh`` — a decode tick pushed
        out of a tRFC window; ``contention`` — wave members queued behind
        same-bank work).  Only contention-on runs record these, so
        contention-off summaries stay byte-identical to the pre-bank
        schema."""
        acc = self._stalls.setdefault(kind, [0.0, 0])
        acc[0] += ns
        acc[1] += 1

    def record_tick(self, n_active: int, n_slots: int,
                    per_replica: Optional[Sequence[float]] = None) -> None:
        self._occupancy.append(n_active / n_slots if n_slots else 0.0)
        if per_replica is not None:
            self._replica_occ.append(list(per_replica))

    # ---- summaries --------------------------------------------------------
    def movement_totals(self) -> Dict[str, float]:
        """Cumulative movement bill under both mechanisms, plus the
        ``backoff_ns`` latency bucket (clock time that moved no bytes —
        kept OUT of the per-mechanism ns so ``advantage`` is a pure
        movement ratio, invariant to the fault rate)."""
        t = {"ns_lisa": 0.0, "ns_memcpy": 0.0, "uj_lisa": 0.0,
             "uj_memcpy": 0.0, "backoff_ns": 0.0}
        for d in self.decisions:
            t["ns_lisa"] += d.ns_lisa
            t["ns_memcpy"] += d.ns_memcpy
            t["uj_lisa"] += d.uj_lisa
            t["uj_memcpy"] += d.uj_memcpy
            t["backoff_ns"] += d.backoff_ns
        t["advantage"] = (t["ns_memcpy"] / t["ns_lisa"]
                          if t["ns_lisa"] else 1.0)
        return t

    def decision_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.decisions:
            out[d.kind] = out.get(d.kind, 0) + 1
        return out

    def wave_widths(self, kind: str) -> List[int]:
        """Item counts of every decision of ``kind`` — a fused wave of k
        suspends/resumes is ONE decision with ``n_items == k``."""
        return [d.n_items for d in self.decisions if d.kind == kind]

    def _class_summary(self, jobs: List[JobRecord]) -> Dict[str, object]:
        """Latency/SLO summary of one job bucket.  An EMPTY bucket (or one
        with no SLO-bearing jobs) reports ``None``, never a number — an
        idle class must not read as a perfect p99/attainment."""
        lats = [j.latency_ns for j in jobs]
        with_slo = [j for j in jobs if math.isfinite(j.slo_ns)]
        return {
            "n": len(jobs),
            "p50_latency_ns": _round(percentile_ns(lats, 50), 1),
            "p99_latency_ns": _round(percentile_ns(lats, 99), 1),
            "slo_attainment": (round(sum(j.slo_met for j in with_slo)
                                     / len(with_slo), 4)
                               if with_slo else None),
        }

    def migration_summary(self) -> Dict[str, object]:
        """Cross-replica view: how many sessions moved, and the latency
        split between jobs whose service involved a migration and jobs
        served entirely at home (the cluster's Table-1 question: did the
        hop chain pay for itself?)."""
        moved = [j for j in self.jobs if j.migrations > 0]
        local = [j for j in self.jobs if j.migrations == 0]
        return {
            "sessions_migrated": sum(d.n_items for d in self.decisions
                                     if d.kind == "migrate_wave"),
            "migrate_waves": sum(1 for d in self.decisions
                                 if d.kind == "migrate_wave"),
            "jobs_migrated": len(moved),
            "p99_latency_ns_migrated": _round(
                percentile_ns([j.latency_ns for j in moved], 99), 1),
            "p99_latency_ns_local": _round(
                percentile_ns([j.latency_ns for j in local], 99), 1),
        }

    def fault_summary(self) -> Dict[str, object]:
        """The chaos block: fleet-wide event counters plus the per-class
        retry/recovery/loss attribution.  Buckets that saw nothing report
        ``None`` (strict-JSON ``null``), never a fake zero distribution —
        the ``per_class`` map is ``None`` on a fault-free run."""
        per_class = ({str(c): dict(sorted(d.items()))
                      for c, d in sorted(self._fault_class.items())}
                     if self._fault_class else None)
        return {"counters": dict(sorted(self._faults.items())),
                "per_class": per_class}

    def summary(self) -> Dict[str, object]:
        per_class: Dict[str, Dict[str, object]] = {}
        for cls in sorted({j.priority for j in self.jobs}):
            per_class[str(cls)] = self._class_summary(
                [j for j in self.jobs if j.priority == cls])
        overall = self._class_summary(self.jobs)
        out = {
            "jobs_completed": len(self.jobs),
            "tokens": sum(j.tokens for j in self.jobs),
            "p50_latency_ns": overall["p50_latency_ns"],
            "p99_latency_ns": overall["p99_latency_ns"],
            "slo_attainment": overall["slo_attainment"],
            "per_class": per_class,
            "slot_utilization": (round(sum(self._occupancy)
                                       / len(self._occupancy), 4)
                                 if self._occupancy else 0.0),
            "movement": {k: round(v, 2)
                         for k, v in self.movement_totals().items()},
            "decisions": self.decision_counts(),
            "faults": self.fault_summary(),
        }
        if self._stalls:                # bank-contention run: stall view
            out["stalls"] = {k: {"ns": round(v[0], 2), "n": int(v[1])}
                             for k, v in sorted(self._stalls.items())}
        if self._replica_occ:           # cluster run: per-replica view
            n_rep = len(self._replica_occ[0])
            out["per_replica_utilization"] = [
                round(sum(t[r] for t in self._replica_occ)
                      / len(self._replica_occ), 4) for r in range(n_rep)]
            out["migration"] = self.migration_summary()
        if self.trace is not None:      # traced run: span rollup
            out["trace"] = self.trace
        return out
