"""Serving metrics: per-class latency percentiles, SLO attainment, slot
utilization, and cumulative :class:`~repro.movement.plan.MovementCost`
(lisa vs memcpy) per scheduling decision.

Everything is recorded on the scheduler's *virtual clock* (modeled ns): a
decode tick costs ``decode_ns``, and every movement decision — resume wave,
preemption suspend, completion suspend — is charged its plan's Table-1
pricing, VILLA-occupancy-aware (a fast-tier hit pays the fast-subarray
fraction of the slow-tier cost).  The lisa/memcpy totals are the serving
layer's view of the paper's headline gap: the same schedule, priced under
both mechanisms.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

import numpy as np


def percentile_ns(xs, q) -> float:
    """Percentile of a latency list; NaN for an empty one (no completions
    in that class yet) instead of numpy's empty-slice warning."""
    if not xs:
        return math.nan
    return float(np.percentile(np.asarray(xs, np.float64), q))


@dataclasses.dataclass
class JobRecord:
    """One completed logical job (a fresh request or one follow-up)."""
    job_id: int
    uid: int
    kind: str               # "fresh" | "resume"
    priority: int
    arrival_ns: float
    done_ns: float
    slo_ns: float
    tokens: int

    @property
    def latency_ns(self) -> float:
        return self.done_ns - self.arrival_ns

    @property
    def slo_met(self) -> bool:
        return self.latency_ns <= self.slo_ns


@dataclasses.dataclass
class Decision:
    """One scheduling decision and its modeled movement bill (both
    mechanisms — per-decision Table-1 accounting)."""
    tick: int
    kind: str               # "submit" | "resume_wave" | "preempt_suspend" | "complete_suspend"
    n_items: int
    ns_lisa: float = 0.0
    ns_memcpy: float = 0.0
    uj_lisa: float = 0.0
    uj_memcpy: float = 0.0


class Metrics:
    """Accumulates job completions, decisions and per-tick occupancy;
    :meth:`summary` renders the benchmark/CI-facing dict."""

    def __init__(self):
        self.jobs: List[JobRecord] = []
        self.decisions: List[Decision] = []
        self._occupancy: List[float] = []

    # ---- recording --------------------------------------------------------
    def record_job(self, rec: JobRecord) -> None:
        self.jobs.append(rec)

    def record_decision(self, dec: Decision) -> None:
        self.decisions.append(dec)

    def record_tick(self, n_active: int, n_slots: int) -> None:
        self._occupancy.append(n_active / n_slots if n_slots else 0.0)

    # ---- summaries --------------------------------------------------------
    def movement_totals(self) -> Dict[str, float]:
        t = {"ns_lisa": 0.0, "ns_memcpy": 0.0, "uj_lisa": 0.0,
             "uj_memcpy": 0.0}
        for d in self.decisions:
            t["ns_lisa"] += d.ns_lisa
            t["ns_memcpy"] += d.ns_memcpy
            t["uj_lisa"] += d.uj_lisa
            t["uj_memcpy"] += d.uj_memcpy
        t["advantage"] = (t["ns_memcpy"] / t["ns_lisa"]
                          if t["ns_lisa"] else 1.0)
        return t

    def decision_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.decisions:
            out[d.kind] = out.get(d.kind, 0) + 1
        return out

    def wave_widths(self, kind: str) -> List[int]:
        """Item counts of every decision of ``kind`` — a fused wave of k
        suspends/resumes is ONE decision with ``n_items == k``."""
        return [d.n_items for d in self.decisions if d.kind == kind]

    def _class_summary(self, jobs: List[JobRecord]) -> Dict[str, float]:
        lats = [j.latency_ns for j in jobs]
        with_slo = [j for j in jobs if math.isfinite(j.slo_ns)]
        return {
            "n": len(jobs),
            "p50_latency_ns": round(percentile_ns(lats, 50), 1),
            "p99_latency_ns": round(percentile_ns(lats, 99), 1),
            "slo_attainment": (round(sum(j.slo_met for j in with_slo)
                                     / len(with_slo), 4)
                               if with_slo else 1.0),
        }

    def summary(self) -> Dict[str, object]:
        per_class: Dict[str, Dict[str, float]] = {}
        for cls in sorted({j.priority for j in self.jobs}):
            per_class[str(cls)] = self._class_summary(
                [j for j in self.jobs if j.priority == cls])
        overall = self._class_summary(self.jobs)
        return {
            "jobs_completed": len(self.jobs),
            "tokens": sum(j.tokens for j in self.jobs),
            "p50_latency_ns": overall["p50_latency_ns"],
            "p99_latency_ns": overall["p99_latency_ns"],
            "slo_attainment": overall["slo_attainment"],
            "per_class": per_class,
            "slot_utilization": (round(sum(self._occupancy)
                                       / len(self._occupancy), 4)
                                 if self._occupancy else 0.0),
            "movement": {k: round(v, 2)
                         for k, v in self.movement_totals().items()},
            "decisions": self.decision_counts(),
        }
