"""Cost-aware continuous-batching scheduler: the controller layer that turns
the serving engine into a traffic-serving system.

Public surface::

    from repro import sched

    arrivals = sched.generate_workload(sched.WorkloadConfig(...), seed=0,
                                       vocab_size=cfg.vocab_size)
    s = sched.Scheduler(engine, policy="cost_aware", arrivals=arrivals)
    summary = s.run()          # per-class p50/p99, SLO attainment, movement

Multi-replica serving drives a :class:`~repro.serve.cluster.Cluster`
through :class:`ClusterScheduler` — same tick loop, plus placement as a
third decision axis and cost-priced live session migration::

    cluster = Cluster(cfg, params, n_replicas=4, slots=2)
    s = sched.ClusterScheduler(cluster, arrivals=arrivals)  # migrate=True

Modules:
  queue      — admission queue: priority classes, deadlines, aging
  policy     — fifo / lru / cost_aware / cost_aware_cluster policies
               (registry; admit, victim AND place orderings)
  scheduler  — the tick loops: fused waves, decode-overlapped wave prep,
               cluster placement + migration lanes
  workload   — synthetic traffic (Poisson/bursty, Zipf re-use, think time)
  metrics    — per-class latency, SLO attainment, MovementCost accounting,
               per-replica utilization + migration split

See DESIGN.md Sec. 9 (scheduler) and Sec. 10 (cluster) for the paper
mapping.
"""
from repro.sched.metrics import Decision, JobRecord, Metrics
from repro.sched.policy import (
    AdmitCand,
    CostAwareClusterPolicy,
    CostAwarePolicy,
    FifoPolicy,
    LruPolicy,
    PlaceCand,
    SchedContext,
    SchedPolicy,
    VictimCand,
    get_policy,
    policies,
    register_policy,
)
from repro.sched.queue import AdmissionQueue, QueueEntry
from repro.sched.scheduler import (Job, SchedConfig, Scheduler, Wave,
                                   ClusterScheduler, ClusterWave)
from repro.sched.workload import (
    Arrival,
    WorkloadConfig,
    generate_workload,
    n_sessions_for,
    skewed_residence_burst,
)

__all__ = [
    "AdmissionQueue", "QueueEntry",
    "SchedPolicy", "FifoPolicy", "LruPolicy", "CostAwarePolicy",
    "CostAwareClusterPolicy",
    "AdmitCand", "VictimCand", "PlaceCand", "SchedContext",
    "register_policy", "get_policy", "policies",
    "Scheduler", "SchedConfig", "Job", "Wave",
    "ClusterScheduler", "ClusterWave",
    "Arrival", "WorkloadConfig", "generate_workload", "n_sessions_for",
    "skewed_residence_burst",
    "Metrics", "JobRecord", "Decision",
]
