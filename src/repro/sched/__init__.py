"""Cost-aware continuous-batching scheduler: the controller layer that turns
the serving engine into a traffic-serving system.

Public surface::

    from repro import sched

    arrivals = sched.generate_workload(sched.WorkloadConfig(...), seed=0,
                                       vocab_size=cfg.vocab_size)
    s = sched.Scheduler(engine, policy="cost_aware", arrivals=arrivals)
    summary = s.run()          # per-class p50/p99, SLO attainment, movement

Modules:
  queue      — admission queue: priority classes, deadlines, aging
  policy     — fifo / lru / cost_aware placement+victim policies (registry)
  scheduler  — the tick loop: fused waves, decode-overlapped wave prep
  workload   — synthetic traffic (Poisson/bursty, Zipf re-use, think time)
  metrics    — per-class latency, SLO attainment, MovementCost accounting

See DESIGN.md Sec. 9 for the paper mapping.
"""
from repro.sched.metrics import Decision, JobRecord, Metrics
from repro.sched.policy import (
    AdmitCand,
    CostAwarePolicy,
    FifoPolicy,
    LruPolicy,
    SchedContext,
    SchedPolicy,
    VictimCand,
    get_policy,
    policies,
    register_policy,
)
from repro.sched.queue import AdmissionQueue, QueueEntry
from repro.sched.scheduler import Job, SchedConfig, Scheduler, Wave
from repro.sched.workload import (
    Arrival,
    WorkloadConfig,
    generate_workload,
    n_sessions_for,
)

__all__ = [
    "AdmissionQueue", "QueueEntry",
    "SchedPolicy", "FifoPolicy", "LruPolicy", "CostAwarePolicy",
    "AdmitCand", "VictimCand", "SchedContext",
    "register_policy", "get_policy", "policies",
    "Scheduler", "SchedConfig", "Job", "Wave",
    "Arrival", "WorkloadConfig", "generate_workload", "n_sessions_for",
    "Metrics", "JobRecord", "Decision",
]
