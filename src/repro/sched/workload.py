"""Synthetic serving traffic: Poisson/bursty arrivals, Zipfian session
re-use, think-time distributions.

The controller benchmarks synthesize *memory* traffic because the paper's
SPEC traces are not redistributable (:mod:`repro.core.dram.traces`); this
module is the serving-layer analogue for *request* traffic, reusing the same
generator idioms — a frozen config dataclass holding only workload knobs,
exponential inter-arrival gaps, and a Zipf draw via the inverse-CDF
(``searchsorted`` over the cumulative mass) rather than per-event
``choice``.  Generation is host-side numpy: arrivals feed the host-resident
scheduler loop, not a jitted sweep.

An :class:`Arrival` is either a *fresh* request (prompt attached) or a
*follow-up* — the chat pattern: a previously-served session returns after a
think time and must be resumed from the VILLA tiered store.  Follow-up
targets are Zipf-skewed toward the earliest sessions, which is exactly the
hot-session skew the paper's caching policy (and the ``cost_aware``
scheduling policy) exploit.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, NamedTuple, Optional, Tuple

import numpy as np


class Arrival(NamedTuple):
    t_ns: float
    uid: int
    kind: str                   # "fresh" | "resume"
    priority: int               # class id, 0 = most urgent
    slo_ns: float               # inf = batch class, no deadline
    new_tokens: int
    prompt: Optional[np.ndarray]    # fresh only


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Workload knobs only — engine/scheduler geometry lives elsewhere."""
    n_fresh: int = 8                 # distinct sessions (uids 0..n_fresh-1)
    n_followups: int = 16            # resume events over those sessions
    mean_gap_ns: float = 2_000.0     # mean inter-arrival gap
    arrival: str = "poisson"         # "poisson" | "bursty"
    burst: int = 4                   # arrivals per burst (bursty mode)
    zipf_s: float = 1.2              # follow-up target skew (0 = uniform)
    think_ns: float = 4_000.0        # mean think time before a follow-up
    prompt_lens: Tuple[int, ...] = (6, 8, 10, 12)
    new_tokens: Tuple[int, ...] = (3, 4, 5, 6)
    # class id -> (admission probability, latency SLO); classes with an
    # infinite SLO are batch traffic that only aging protects.
    class_probs: Tuple[float, ...] = (0.25, 0.5, 0.25)
    class_slo_ns: Tuple[float, ...] = (30_000.0, 120_000.0, math.inf)

    def __post_init__(self):
        if len(self.class_probs) != len(self.class_slo_ns):
            raise ValueError("class_probs and class_slo_ns must align")
        if abs(sum(self.class_probs) - 1.0) > 1e-9:
            raise ValueError("class_probs must sum to 1")
        if self.arrival not in ("poisson", "bursty"):
            raise ValueError(f"unknown arrival process {self.arrival!r}")


def _zipf_pick(rng: np.random.Generator, n: int, s: float, k: int
               ) -> np.ndarray:
    """k Zipf(s) draws over ranks 0..n-1 via the inverse CDF (the
    ``traces.generate`` idiom: cumulative mass + searchsorted)."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-s)
    p /= p.sum()
    u = rng.random(k)
    return np.minimum(np.searchsorted(np.cumsum(p), u), n - 1).astype(int)


def generate_workload(cfg: WorkloadConfig, *, seed: int,
                      vocab_size: int) -> List[Arrival]:
    """One deterministic arrival stream, sorted by time.

    Fresh sessions arrive on the base process (exponential gaps; bursty mode
    groups ``burst`` arrivals at one instant with the gap scaled up to keep
    the offered load equal).  Each follow-up targets an already-arrived
    session (Zipf rank over fresh arrival order — session 0 is hottest) and
    lands one think time after the base instant.
    """
    rng = np.random.default_rng(seed)
    n = cfg.n_fresh + cfg.n_followups
    if cfg.n_fresh < 1:
        raise ValueError("need at least one fresh session")

    # base instants: one per event; bursty mode collapses each group of
    # `burst` onto its group head so bursts hit the queue at one instant
    gaps = rng.exponential(cfg.mean_gap_ns, n)
    if cfg.arrival == "bursty":
        gaps = gaps * cfg.burst
        gaps[np.arange(n) % cfg.burst != 0] = 0.0
    base_t = np.cumsum(gaps)

    # interleave kinds: event i is fresh while fresh remain, except that the
    # first event is always fresh (a follow-up needs a prior session); the
    # order is a deterministic shuffle of the remaining kind labels
    kinds = np.array(["fresh"] * cfg.n_fresh + ["resume"] * cfg.n_followups)
    rng.shuffle(kinds)
    first_fresh = int(np.argmax(kinds == "fresh"))
    kinds[0], kinds[first_fresh] = kinds[first_fresh], kinds[0]

    cls = rng.choice(len(cfg.class_probs), size=n, p=cfg.class_probs)
    plens = rng.choice(cfg.prompt_lens, size=n)
    ntoks = rng.choice(cfg.new_tokens, size=n)
    think = rng.exponential(cfg.think_ns, n)

    arrivals: List[Arrival] = []
    fresh_uids: List[int] = []
    followup_picks = iter(_zipf_pick(rng, max(cfg.n_fresh, 1), cfg.zipf_s,
                                     cfg.n_followups))
    for i in range(n):
        pr = int(cls[i])
        if kinds[i] == "fresh":
            uid = len(fresh_uids)
            fresh_uids.append(uid)
            prompt = rng.integers(0, vocab_size, int(plens[i])).astype(
                np.int32)
            arrivals.append(Arrival(t_ns=float(base_t[i]), uid=uid,
                                    kind="fresh", priority=pr,
                                    slo_ns=cfg.class_slo_ns[pr],
                                    new_tokens=int(ntoks[i]), prompt=prompt))
        else:
            # Zipf rank over the sessions that exist *so far*: rank r picks
            # the r-th earliest session (clamped into the current set).
            rank = min(int(next(followup_picks)), len(fresh_uids) - 1)
            arrivals.append(Arrival(t_ns=float(base_t[i] + think[i]),
                                    uid=fresh_uids[rank], kind="resume",
                                    priority=pr,
                                    slo_ns=cfg.class_slo_ns[pr],
                                    new_tokens=int(ntoks[i]), prompt=None))
    arrivals.sort(key=lambda a: (a.t_ns, a.uid))
    return arrivals


def n_sessions_for(cfg: WorkloadConfig) -> int:
    """Store sizing that makes uid collisions (explicit evictions)
    impossible for this workload: one store index per distinct session."""
    return max(cfg.n_fresh, 2)


def skewed_residence_burst(vocab_size: int, *, burst_slo_ns: float = 18_000.0,
                           seed: int = 7) -> List[Arrival]:
    """The transient-imbalance scenario the cluster migration A/B gates on
    (consumed by both ``benchmarks/run.py cluster`` and
    ``tests/test_cluster.py`` — one definition, two drivers).

    Three long equal-class jobs pin replicas 0..2 of a 4x1-slot cluster, so
    four interactive sessions serialize onto replica 3 and all SUSPEND
    there; then all four return at once under a tight SLO.  Migration-
    enabled placement fans the burst across the (by then idle) other
    replicas via priced hop-chain plans; migration-off serializes the whole
    burst on the home replica and misses.  Run with a large ``age_every``
    (e.g. 64) so aging doesn't let the setup jobs preempt the pinners.
    """
    rng = np.random.default_rng(seed)
    arr = [Arrival(t_ns=0.0, uid=100 + i, kind="fresh", priority=1,
                   slo_ns=math.inf, new_tokens=30,
                   prompt=rng.integers(0, vocab_size, 8).astype(np.int32))
           for i in range(3)]
    arr += [Arrival(t_ns=1500.0 + 500.0 * i, uid=i, kind="fresh",
                    priority=1, slo_ns=60_000.0, new_tokens=3,
                    prompt=rng.integers(0, vocab_size, 6).astype(np.int32))
            for i in range(4)]
    arr += [Arrival(t_ns=45_000.0, uid=i, kind="resume", priority=0,
                    slo_ns=burst_slo_ns, new_tokens=3, prompt=None)
            for i in range(4)]
    return arr
