"""Admission queue: priority classes, per-request deadlines, aging.

Every request that cannot be placed immediately waits here — admission never
crashes the engine (`EngineFull` is a *scheduler* bug, not a traffic
condition).  An entry carries the request's priority class (0 = most
urgent), its arrival time and latency SLO (``deadline_ns = arrival + slo``),
and the tick it was enqueued at.

Starvation freedom is structural: the *effective* class of a waiting entry
drops by one every ``age_every`` ticks, **unbounded below zero**, so any
entry — however low its nominal class — eventually outranks every fresh
arrival.  Policies (:mod:`repro.sched.policy`) order candidates by effective
class first; the bound "aging promotes the oldest queued request within
``priority * age_every`` extra ticks past any class-0 arrival" is pinned by
``tests/test_sched.py``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class QueueEntry:
    """One unit of queued work: a fresh request (``prompt`` set) or the
    resumption of a suspended session (``kind == "resume"``, prompt None).
    ``new_tokens`` is the number of tokens still owed to the job."""
    seq: int                    # global admission order (FIFO tie-break)
    job_id: int                 # scheduler job this entry belongs to
    uid: int
    kind: str                   # "fresh" | "resume"
    priority: int               # nominal class, 0 = most urgent
    arrival_ns: float
    slo_ns: float               # math.inf = no deadline (batch class)
    enq_tick: int               # tick the entry entered the queue
    new_tokens: int
    prompt: Optional[np.ndarray] = None

    @property
    def deadline_ns(self) -> float:
        return self.arrival_ns + self.slo_ns


class AdmissionQueue:
    """FIFO-ordered storage with aging; selection order is policy-owned.

    The queue itself never drops or reorders — it hands policies a snapshot
    of entries plus each entry's *effective* class at the current tick, and
    removes exactly the entries the scheduler placed.
    """

    def __init__(self, age_every: int = 8):
        if age_every < 1:
            raise ValueError(f"age_every must be >= 1 (got {age_every})")
        self.age_every = age_every
        self._items: List[QueueEntry] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._items)

    def push(self, *, job_id: int, uid: int, kind: str, priority: int,
             arrival_ns: float, slo_ns: float, tick: int, new_tokens: int,
             prompt: Optional[np.ndarray] = None,
             seq: Optional[int] = None) -> QueueEntry:
        """Enqueue one unit of work.  ``seq`` may be supplied to *re*-queue
        preempted work under its original admission order (fairness: a
        preemption must not send a job to the back of the line)."""
        if kind not in ("fresh", "resume"):
            raise ValueError(f"unknown queue entry kind {kind!r}")
        if kind == "fresh" and prompt is None:
            raise ValueError("a fresh entry needs its prompt")
        if new_tokens < 1:
            raise ValueError(f"queued work owes >= 1 token (got {new_tokens})")
        if seq is None:
            seq = self._seq
            self._seq += 1
        e = QueueEntry(seq=seq, job_id=job_id, uid=uid, kind=kind,
                       priority=priority, arrival_ns=arrival_ns,
                       slo_ns=slo_ns, enq_tick=tick, new_tokens=new_tokens,
                       prompt=prompt)
        self._items.append(e)
        return e

    def effective_class(self, e: QueueEntry, tick: int) -> int:
        """Nominal class minus one per ``age_every`` waited ticks, unbounded
        below zero — the starvation-freedom mechanism."""
        return e.priority - (tick - e.enq_tick) // self.age_every

    def entries(self) -> Tuple[QueueEntry, ...]:
        return tuple(self._items)

    def remove(self, entry: QueueEntry) -> None:
        self._items.remove(entry)

    def oldest_wait(self, tick: int) -> int:
        """Ticks the longest-waiting entry has been queued (0 if empty)."""
        return max((tick - e.enq_tick for e in self._items), default=0)

    def max_priority(self) -> int:
        return max((e.priority for e in self._items), default=0)

    def bounded_wait_ticks(self, priority: int) -> int:
        """Upper bound on how long a class-``priority`` entry can wait past
        the point a class-0 entry would be served: aging closes one class
        per ``age_every`` ticks and then strictly outranks class 0."""
        return (priority + 1) * self.age_every
