"""Pluggable victim/placement policies behind a registry.

This extends the repo's registry pattern a third time: PR 1 registered
``CopyMechanism`` objects (pricing a copy), PR 3 registered movement
*backends* (performing a copy), and this module registers *policies* —
deciding **which** copies to perform at all.  That is the paper's missing
layer: LISA/RowClone make bulk movement cheap, but the win only materializes
when a controller schedules the cheap path instead of the naive one.

A policy orders two candidate lists (it never mutates engine or queue):

  * ``admit_order``  — queued entries (fresh prefills + session resumes),
    best-placed-first;
  * ``victim_order`` — active slots eligible for preemption, best-victim
    first.

``fifo`` is the pre-scheduler baseline (arrival order, lowest slot index
victim — exactly the arbitrary choice ``launch/serve.py`` used to hard-code).
``lru`` victimizes the least-recently-activated session.  ``cost_aware``
consults the modeled movement bill: admissions run earliest-deadline-first
within an effective class with cheap (VILLA fast-tier resident) resumes
breaking ties, and victims are the sessions whose suspend is cheapest under
the active :class:`~repro.core.dram.spec.DramSpec` mechanism — a session
resident in the fast tier pays the write-through to *both* pools, so the
cheap-to-suspend session is also the cold one worth displacing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Sequence, Tuple

from repro.sched.queue import QueueEntry


class AdmitCand(NamedTuple):
    """A queued entry the scheduler could place this tick."""
    entry: QueueEntry
    eff_class: int          # aged class at this tick (can be negative)
    cost_ns: float          # modeled placement cost (resume move / prefill)
    fast_resident: bool     # resume target resident in the VILLA fast tier


class VictimCand(NamedTuple):
    """An active slot the scheduler could preempt this tick."""
    slot: int
    uid: int
    priority: int           # the running job's nominal class
    last_active_tick: int   # activation tick (LRU signal)
    suspend_ns: float       # modeled suspend cost under the active mechanism
    fast_resident: bool
    # the session's snapshot row is aliased by other (forked) sessions:
    # evicting it forces a shared-row demotion and hurts every alias, so
    # shared sessions are structurally the WORST victims
    shared: bool = False


class PlaceCand(NamedTuple):
    """One replica a cluster placement could land on — the third decision
    axis (beside admission and eviction).  ``hop_ns`` is the modeled
    migration cost from the session's current residence to this replica
    (0 for fresh requests and for the home replica); ``place_ns`` the
    modeled resume/prefill cost once there."""
    replica: int
    free_slots: int         # open slots on the replica right now
    fast_occupancy: float   # fraction of the VILLA fast tier in use
    hop_ns: float
    place_ns: float
    degraded: bool = False  # VILLA fast tier degraded to slow-only (chaos)
    # the placed session's fork family already resides here: landing on
    # this replica keeps the fork an alias (zero-copy) instead of a
    # cross-replica materialization
    shared_resident: bool = False


@dataclasses.dataclass(frozen=True)
class SchedContext:
    """Read-only facts policies may consult."""
    tick: int
    now_ns: float
    mechanism: str                      # "lisa" | "memcpy"
    fast_uids: frozenset = frozenset()  # sessions resident in the fast tier


class SchedPolicy:
    """Base policy: effective-class order, FIFO within class, slot-order
    victims.  Subclasses override the sort keys only — determinism and
    starvation freedom (aging drives ``eff_class`` below any fresh class)
    come from the shared structure."""

    name = "base"

    def admit_order(self, cands: Sequence[AdmitCand],
                    ctx: SchedContext) -> List[AdmitCand]:
        return sorted(cands, key=lambda c: (c.eff_class, c.entry.seq))

    def victim_order(self, cands: Sequence[VictimCand],
                     ctx: SchedContext) -> List[VictimCand]:
        return sorted(cands, key=lambda c: c.slot)

    def place_order(self, cands: Sequence[PlaceCand],
                    ctx: SchedContext) -> List[PlaceCand]:
        """Replica preference for one placement (cluster scheduling only):
        base policies spread by free slots and ignore the movement bill."""
        return sorted(cands, key=lambda c: (-c.free_slots, c.replica))


class FifoPolicy(SchedPolicy):
    """Arrival order, arbitrary (lowest-index) victim — the baseline the
    paper's controller-scheduling argument is made against."""
    name = "fifo"


class LruPolicy(SchedPolicy):
    """FIFO admissions, least-recently-activated victim (classic working-set
    heuristic, blind to movement cost)."""
    name = "lru"

    def victim_order(self, cands, ctx):
        return sorted(cands, key=lambda c: (-c.priority, c.last_active_tick,
                                            c.slot))


class CostAwarePolicy(SchedPolicy):
    """Every ordering consults the movement bill.

    Admissions: effective class; then jobs that can still *make* their
    deadline before jobs whose deadline has already passed (plain EDF
    suffers domino misses under overload — a hopeless job must not starve a
    saveable one); then earliest deadline; then modeled placement cost — a
    fast-tier-hit resume (cheap lisa-priced move) is preferred over a
    slow-tier miss at equal urgency.  Victims: lowest-priority first, then
    cheapest modeled suspend — non-resident (cold) sessions cost one
    slow-pool write, resident (hot) ones pay the fast-pool write-through on
    top, so the policy structurally keeps hot sessions on slots.
    """
    name = "cost_aware"

    def admit_order(self, cands, ctx):
        def key(c: AdmitCand):
            hopeless = ctx.now_ns > c.entry.deadline_ns
            return (c.eff_class, hopeless, c.entry.deadline_ns, c.cost_ns,
                    c.entry.seq)
        return sorted(cands, key=key)

    def victim_order(self, cands, ctx):
        # ``shared`` before the cost keys: preempting a forked session
        # whose row other aliases still read forces a demotion clone and
        # cools the whole family — only ever the last resort
        return sorted(cands, key=lambda c: (-c.priority, c.shared,
                                            c.suspend_ns,
                                            c.last_active_tick, c.slot))


class CostAwareClusterPolicy(CostAwarePolicy):
    """``cost_aware`` plus a movement-priced placement axis.

    Placement scores every replica by (free slots, modeled movement bill,
    VILLA fast-tier occupancy): a replica with an open slot always beats
    one that needs preemption; among those, the cheapest total move wins —
    ``hop_ns`` (the ICI hop-chain price of migrating the session from its
    residence, 0 at home) plus the resume/prefill cost — and a less
    pressured fast tier breaks ties (an overfull fast tier means the
    inbound session will keep resuming at slow-subarray timings).  This is
    the paper's Sec. 3.2 "intelligent cost-aware mechanism" applied to
    replica topology: distance-1 neighbors are preferred over far hops
    exactly as LISA prefers near-subarray RBM chains.

    A chaos-degraded replica (fast tier offline) sorts behind healthy ones
    at equal slot pressure: its ``place_ns`` already reroutes to slow-tier
    pricing (the engine reports no fast residents while degraded), and the
    explicit ``degraded`` key keeps new sessions off it even when the
    priced costs tie."""
    name = "cost_aware_cluster"

    def place_order(self, cands, ctx):
        # ``not shared_resident`` ahead of the priced keys: a replica
        # already holding the session's fork family serves it by alias
        # (zero-copy) — cheaper than any hop the cost model can quote
        return sorted(cands, key=lambda c: (c.free_slots <= 0, c.degraded,
                                            not c.shared_resident,
                                            c.hop_ns + c.place_ns,
                                            c.fast_occupancy, c.replica))


_POLICIES: Dict[str, SchedPolicy] = {}


def register_policy(policy: SchedPolicy) -> SchedPolicy:
    """Register a policy instance under ``policy.name``.  Re-registering the
    same class (module reload) replaces silently; a different class under a
    taken name raises — the CopyMechanism/backend registry contract."""
    old = _POLICIES.get(policy.name)
    if old is not None and (type(old).__module__, type(old).__qualname__) != (
            type(policy).__module__, type(policy).__qualname__):
        raise ValueError(f"scheduling policy {policy.name!r} already "
                         f"registered by {type(old).__qualname__}")
    _POLICIES[policy.name] = policy
    return policy


def get_policy(name) -> SchedPolicy:
    """Look up a policy by name (a :class:`SchedPolicy` passes through)."""
    if isinstance(name, SchedPolicy):
        return name
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown scheduling policy {name!r} "
                         f"(known: {sorted(_POLICIES)})") from None


def policies() -> Tuple[str, ...]:
    return tuple(sorted(_POLICIES))


register_policy(FifoPolicy())
register_policy(LruPolicy())
register_policy(CostAwarePolicy())
register_policy(CostAwareClusterPolicy())
