"""The tick loop: a cost-aware continuous-batching scheduler that owns the
serving :class:`~repro.serve.engine.Engine`.

After PR 3 every bulk transfer in the repo is a priced
:class:`~repro.movement.plan.MovementPlan`; this module is the controller
that finally *consumes* those prices.  The paper mapping (DESIGN.md Sec. 9):

  * **tick ↔ controller cycle** — each :meth:`Scheduler.tick` is one memory-
    controller scheduling cycle: service the in-flight work, pick the next
    commands from the queue;
  * **fused waves ↔ inter-subarray hops** — admissions batch into one
    ``suspend_many`` / ``resume_many`` dispatch per wave, the way LISA moves
    a whole row per hop instead of a cache line per channel transfer; the
    scheduler never issues per-session suspend/resume dispatches;
  * **plan-prep / decode overlap ↔ LISA-LIP linked precharge** — the fused
    decode dispatch is issued first (``Engine.step_begin``), the next wave
    is planned on the host *while the device decodes*, and only then is the
    decode synced (``step_end``) — scheduling work hides behind data
    movement exactly as LIP hides the precharge behind the RBM hop;
  * **cost-aware placement ↔ Table 1** — the ``cost_aware`` policy scores
    every suspend/resume candidate by its plan's modeled ns/uJ under the
    active :class:`~repro.core.dram.spec.DramSpec` mechanism and the VILLA
    fast-tier occupancy (a resident session reads at the fast-subarray
    timings; suspending it pays the write-through to both pools).

Time is a *virtual clock* in modeled nanoseconds: a decode tick costs
``decode_ns``, prefills cost ``prefill_ns_per_token`` per prompt token, and
every movement wave is charged its occupancy-aware plan cost under the
active mechanism — so a policy that schedules cheaper movement finishes the
same offered load earlier, deterministically, CPU-only.  That is what
``benchmarks/run.py sched`` A/Bs (fifo vs cost_aware at equal load).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, List, NamedTuple, Optional, Sequence, Set, Tuple

from repro import movement as MV
from repro.core.dram.bank import RequestMultiplexer
from repro.faults.recover import (repair_row, restore_session,
                                  snapshot_sessions)
from repro.faults.spec import FaultInjector
from repro.obs import NULL_TRACER
from repro.sched.metrics import Decision, JobRecord, Metrics
from repro.sched.policy import (AdmitCand, PlaceCand, SchedContext,
                                SchedPolicy, VictimCand, get_policy)
from repro.sched.queue import AdmissionQueue, QueueEntry
from repro.sched.workload import Arrival
from repro.serve.engine import Engine, Request


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    decode_ns: float = 1_000.0        # modeled cost of one fused decode step
    prefill_ns_per_token: float = 250.0
    age_every: int = 8                # ticks per one-class aging promotion
    mechanism: str = "lisa"           # clock + scoring mechanism
    preempt: bool = True              # allow class-based slot preemption
    max_wave: int = 0                 # cap on placements per tick (0 = none)
    # bank-level contention (DESIGN.md Sec. 15): when on, every movement
    # and decode tick routes through a RequestMultiplexer — same-bank work
    # serializes, refresh windows (tREFI/tRFC) stall, disjoint banks
    # overlap.  Off (default) keeps the isolated-cost clock bit-identical.
    contention: bool = False
    n_banks: int = 8

    def __post_init__(self):
        if self.mechanism not in ("lisa", "memcpy"):
            raise ValueError(f"unknown mechanism {self.mechanism!r} "
                             "(clock pricing needs 'lisa' or 'memcpy')")
        if self.n_banks < 1:
            raise ValueError(f"n_banks must be >= 1, got {self.n_banks}")


@dataclasses.dataclass
class Job:
    """One logical unit of traffic (a fresh request or one follow-up) across
    its whole life: queued -> active (possibly preempted and re-queued) ->
    done.  ``done`` counts tokens emitted so far; the engine-level
    ``Request`` is re-created per activation, the Job is not."""
    job_id: int
    uid: int
    kind: str                  # "fresh" | "resume"
    priority: int
    arrival_ns: float
    slo_ns: float
    target_new: int
    done: int = 0
    state: str = "queued"      # queued | active | done
    slot: int = -1
    seed_tokens: int = 0       # generated[0] is a resume seed, not new work
    done_ns: float = math.nan
    migrations: int = 0        # cross-replica moves while serving this job


class Wave(NamedTuple):
    """One tick's prepared placement decisions (computed while the decode
    dispatch is in flight, executed after the sync)."""
    victims: Tuple[int, ...]            # slots to preempt (one fused suspend)
    placements: Tuple[AdmitCand, ...]   # queue entries to place


class Scheduler:
    """Owns the engine: all submits, suspends and resumes route through
    :meth:`tick`.  Callers feed traffic either up-front (``arrivals=``) or
    incrementally (:meth:`offer`) and drive :meth:`run`."""

    def __init__(self, engine: Engine, policy="cost_aware",
                 arrivals: Sequence[Arrival] = (),
                 cfg: SchedConfig = SchedConfig(), *, tracer=None):
        self.eng = engine
        self.policy: SchedPolicy = get_policy(policy)
        self.cfg = cfg
        self.queue = AdmissionQueue(age_every=cfg.age_every)
        self.metrics = Metrics()
        self.tick_count = 0
        self.now_ns = 0.0
        # span tracing (repro.obs): host bookkeeping on the virtual clock.
        # NULL_TRACER makes every trace call a no-op, so untraced runs pay
        # nothing and traced runs change no scheduling decision or charge.
        self.trace = tracer if tracer is not None else NULL_TRACER
        if self.trace.enabled:
            for lane in range(self._trace_lanes()):
                self.trace.seek(lane, 0.0)       # pre-seed lane cursors
            if hasattr(engine, "attach_tracer"):
                engine.attach_tracer(self.trace)
        self._arrivals: List[Arrival] = sorted(arrivals,
                                               key=lambda a: (a.t_ns, a.uid))
        self._arrival_keys: List[Tuple[float, int]] = [
            (a.t_ns, a.uid) for a in self._arrivals]
        self._next_arrival = 0
        self._jobs: Dict[int, Job] = {}
        self._slot_job: Dict[int, Job] = {}      # slot -> active job
        self._last_active: Dict[int, int] = {}   # uid -> activation tick
        # fast-subarray latency fraction (paper Sec. 3.2: TL-DRAM-like near
        # segment): a fast-tier hit pays this fraction of the slow-tier move
        t, v = engine.spec.timing, engine.villa_cfg
        self.fast_ratio = ((v.tRCD_fast + v.tRAS_fast + v.tRP_fast)
                           / (t.tRCD + t.tRAS + t.tRP))
        # bank-level contention (cfg.contention): the multiplexer the whole
        # tick loop shares.  Disabled it is a pure pass-through, so the
        # contention-off clock is bit-identical to the pre-bank model.
        self.mux = RequestMultiplexer(engine.spec, n_banks=cfg.n_banks,
                                      enabled=cfg.contention)

    # ---- traffic ----------------------------------------------------------
    def offer(self, arrival: Arrival) -> None:
        """Feed one arrival incrementally.  Equivalent to having passed it
        in ``arrivals=`` up front: a burst offered as singletons schedules
        identically to the same burst offered as one list (pinned by
        tests/test_sched.py::test_batched_wave_equivalence)."""
        if arrival.t_ns < self.now_ns:
            arrival = arrival._replace(t_ns=self.now_ns)
        key = (arrival.t_ns, arrival.uid)
        pos = bisect.bisect(self._arrival_keys, key, lo=self._next_arrival)
        self._arrivals.insert(pos, arrival)
        self._arrival_keys.insert(pos, key)

    def submit_request(self, req: Request) -> None:
        """Admit one hand-built engine :class:`Request`: its scheduling
        metadata (``arrival_ns``, ``priority``, ``slo_ns``) IS the admission
        record — the metadata round-trips back out on the requests the
        scheduler constructs at placement time."""
        self.offer(Arrival(t_ns=req.arrival_ns, uid=req.uid, kind="fresh",
                           priority=req.priority, slo_ns=req.slo_ns,
                           new_tokens=req.max_new, prompt=req.prompt))

    def _admit_arrivals(self) -> None:
        while (self._next_arrival < len(self._arrivals)
               and self._arrivals[self._next_arrival].t_ns <= self.now_ns):
            a = self._arrivals[self._next_arrival]
            self._next_arrival += 1
            job = Job(job_id=len(self._jobs), uid=a.uid, kind=a.kind,
                      priority=a.priority, arrival_ns=a.t_ns, slo_ns=a.slo_ns,
                      target_new=a.new_tokens)
            self._jobs[job.job_id] = job
            self.queue.push(job_id=job.job_id, uid=a.uid, kind=a.kind,
                            priority=a.priority, arrival_ns=a.t_ns,
                            slo_ns=a.slo_ns, tick=self.tick_count,
                            new_tokens=a.new_tokens, prompt=a.prompt)

    def pending(self) -> bool:
        return bool(self._next_arrival < len(self._arrivals)
                    or len(self.queue) or self.eng.active)

    def _has_admissible(self) -> bool:
        """Whether any queued entry could be placed right now: fresh always,
        a follow-up only once its session has a suspended snapshot (with an
        idle engine no session can be active, so resumable == placeable)."""
        resumable = self.eng.session_pos
        return any(e.kind == "fresh" or e.uid in resumable
                   for e in self.queue.entries())

    # ---- cost model -------------------------------------------------------
    def _move_cost(self, direction: str, resident: bool
                   ) -> Tuple[float, float, float, float]:
        """(ns_lisa, ns_memcpy, uj_lisa, uj_memcpy) of one session move,
        VILLA-occupancy-aware: a resident resume reads the fast subarray
        (``fast_ratio`` of the slow cost); a resident suspend pays the
        write-through to both pools."""
        plan = (self.eng.plan_resume if direction == "resume"
                else self.eng.plan_suspend)
        c = plan.cost
        if direction == "resume":
            f = self.fast_ratio if resident else 1.0
        else:
            f = 1.0 + (self.fast_ratio if resident else 0.0)
        return c.ns_lisa * f, c.ns_memcpy * f, c.uj_lisa * f, c.uj_memcpy * f

    def _move_ns(self, direction: str, resident: bool) -> float:
        ns_l, ns_m, _, _ = self._move_cost(direction, resident)
        return ns_l if self.cfg.mechanism == "lisa" else ns_m

    def _place_ns(self, e: QueueEntry, fast_uids: frozenset) -> float:
        if e.kind == "resume":
            return self._move_ns("resume", e.uid in fast_uids)
        return self.cfg.prefill_ns_per_token * len(e.prompt)

    def _charge_wave(self, kind: str, moves: Sequence[bool],
                     direction: str,
                     lanes: Optional[Sequence[int]] = None) -> float:
        """Record one fused wave of session moves as ONE decision (both
        mechanisms) and return the active-mechanism ns for the clock.
        ``lanes`` optionally names the trace lane of each move (cluster
        waves land on per-replica lanes); default: the scheduler lane."""
        if not moves:
            return 0.0
        tot = [0.0, 0.0, 0.0, 0.0]
        costs = []
        for resident in moves:
            mc = self._move_cost(direction, resident)
            costs.append(mc)
            for i, v in enumerate(mc):
                tot[i] += v
        self.metrics.record_decision(Decision(
            tick=self.tick_count, kind=kind, n_items=len(moves),
            ns_lisa=tot[0], ns_memcpy=tot[1], uj_lisa=tot[2],
            uj_memcpy=tot[3]))
        if self.trace.enabled:
            self._trace_moves(kind, direction, moves, costs, lanes,
                              len(self.metrics.decisions) - 1)
        return tot[0] if self.cfg.mechanism == "lisa" else tot[1]

    def _wave_advance(self, kind: str, moves: Sequence[bool],
                      direction: str, *, uids: Sequence[int], t0: float,
                      lanes: Optional[Sequence[int]] = None) -> float:
        """Charge one fused wave to the ledger (isolated Table-1 pricing,
        via :meth:`_charge_wave`) and return the CLOCK advance: the
        isolated active-mechanism total when the bank model is off —
        bit-identical to the serial pre-bank clock — else the contended
        wave span through the multiplexer: every member ready at ``t0``,
        distinct banks overlapping, same-bank members serializing, starts
        pushed out of refresh windows.  Pricing never changes; only WHEN
        the wave completes does."""
        iso = self._charge_wave(kind, moves, direction, lanes=lanes)
        if not self.mux.enabled or not moves:
            return iso
        end = t0
        for uid, resident in zip(uids, moves):
            svc = self._move_ns(direction, resident)
            start, e = self.mux.submit(self.mux.bank_of(uid), t0, svc)
            if start > t0:
                self.metrics.record_stall("contention", start - t0)
            end = max(end, e)
        return end - t0

    def _lane_add(self, lanes: List[float], r: int, uid: int,
                  service_ns: float, t0: float) -> None:
        """Accumulate one movement on replica ``r``'s lane (serial within
        the lane).  Bank model off: plain ``+=`` — the pre-bank clock.
        On: the movement queues through the session's bank at its lane's
        current ready time, so same-bank work *across* lanes serializes
        and refresh windows push starts; the lane absorbs the full sojourn
        (stall + service)."""
        if not self.mux.enabled:
            lanes[r] += service_ns
            return
        ready = t0 + lanes[r]
        start, end = self.mux.submit(self.mux.bank_of(uid), ready,
                                     service_ns)
        if start > ready:
            self.metrics.record_stall("contention", start - ready)
        lanes[r] = end - t0

    def _trace_lanes(self) -> int:
        """Lane count: scheduler lane only, or (cluster) one per replica
        plus the write-behind lane."""
        n = getattr(self.eng, "n_replicas", 0)
        return n + 2 if n else 1

    def _trace_moves(self, kind: str, direction: str,
                     moves: Sequence[bool],
                     costs: Sequence[Tuple[float, float, float, float]],
                     lanes: Optional[Sequence[int]],
                     dec_index: int) -> None:
        """One trace span per charged move, with a child span per plan leg.

        The move attrs carry the SAME occupancy-scaled cost tuple the
        Decision ledger accumulated (``costs`` — not recomputed); leg attrs
        partition it exactly (``Tracer.move_span`` residual-corrects the
        last leg), and ``dec_index`` names the owning Decision, so per-leg
        sums grouped by decision reproduce ``Metrics.movement_totals()``
        bit-for-bit (``tests/test_obs.py``)."""
        plan = (self.eng.plan_resume if direction == "resume"
                else self.eng.plan_suspend)
        legs = MV.leg_costs(plan, self.eng.spec)
        for i, (resident, mc) in enumerate(zip(moves, costs)):
            if direction == "resume":
                f = self.fast_ratio if resident else 1.0
            else:
                f = 1.0 + (self.fast_ratio if resident else 0.0)
            items = [(leg.kind,
                      (lc.ns_lisa * f, lc.ns_memcpy * f,
                       lc.uj_lisa * f, lc.uj_memcpy * f),
                      {"bytes": lc.bytes, "hops": lc.hops})
                     for leg, lc in zip(plan.legs, legs)]
            self.trace.move_span(
                kind, lanes[i] if lanes else 0, mc, items,
                attrs={"direction": direction, "decision": dec_index,
                       "fast_resident": bool(resident)})

    # ---- the tick ---------------------------------------------------------
    def tick(self) -> None:
        """One controller cycle: dispatch the fused decode, prepare the next
        wave while it is in flight, sync, then execute the wave (fused
        preemption suspends, one fused resume wave, prefill submits)."""
        self.tick_count += 1
        if (not self.eng.active and not self._has_admissible()
                and self._next_arrival < len(self._arrivals)):
            # idle (nothing decoding, nothing placeable — queued follow-ups
            # whose session hasn't been created yet don't count): fast-forward
            # the virtual clock to the next arrival
            self.now_ns = max(self.now_ns,
                              self._arrivals[self._next_arrival].t_ns)
        self._admit_arrivals()
        self.metrics.record_tick(len(self.eng.active), self.eng.slots)
        tr = self.trace
        tr.seek_all(self.now_ns)
        tick_sp = tr.begin_span("tick", lane=0, cat="tick",
                                attrs={"tick": self.tick_count,
                                       "queued": len(self.queue)})

        # 1. the tick's ONE fused decode dispatch (async — device decodes
        #    while the host plans; the LIP-linked-precharge analogue).  An
        #    all-bank refresh (tREFI/tRFC) blocks the dispatch: a tick
        #    landing inside the window waits for it to close, and idle
        #    fast-forwards cannot skip one — windows are a pure function of
        #    absolute virtual time
        handle = self.eng.step_begin()
        decoded = handle is not None
        stall = 0.0
        if decoded:
            if self.mux.enabled:
                stall = self.mux.decode_gate(self.now_ns) - self.now_ns
                if stall > 0.0:
                    self.metrics.record_stall("refresh", stall)
                    tr.emit("refresh_stall", stall, lane=0, cat="stall",
                            attrs={"refreshes": self.mux.refreshes_before(
                                self.now_ns + stall)})
            tr.emit("decode", self.cfg.decode_ns, lane=0, cat="decode",
                    attrs={"n_active": len(self.eng.active)})

        # 2. overlapped wave preparation against pre-step state
        fast_uids = self.eng.fast_resident_uids()
        wave = self._prepare_wave(fast_uids)
        tr.instant("plan", lane=0, cat="plan",
                   attrs={"victims": len(wave.victims),
                          "placements": len(wave.placements)})

        # 3. sync; the engine auto-suspends completed bursts as ONE wave
        completed = self.eng.step_end(handle)

        advance = (self.cfg.decode_ns + stall) if decoded else 0.0
        if completed:
            advance += self._wave_advance(
                "complete_suspend",
                [self._slot_job[s].uid in fast_uids for s, _ in completed],
                "suspend",
                uids=[self._slot_job[s].uid for s, _ in completed],
                t0=self.now_ns + advance)
        self.now_ns += advance
        for slot, req in completed:
            job = self._slot_job.pop(slot)
            job.done += len(req.generated) - job.seed_tokens
            self._complete_job(job, self.now_ns)

        # 4. execute the prepared wave
        self.now_ns += self._execute_wave(wave, fast_uids)
        tr.end_span(tick_sp, t1_ns=max(self.now_ns, tr.now(0)))

    def run(self, max_ticks: int = 200_000) -> Dict[str, object]:
        while self.pending():
            self._check_progress()
            self.tick()
            if self.tick_count > max_ticks:
                raise RuntimeError(
                    f"scheduler failed to drain within {max_ticks} ticks "
                    f"(queue={len(self.queue)}, active={len(self.eng.active)})")
        if self.trace.enabled:
            self.metrics.trace = self.trace.rollup()
        return self.metrics.summary()

    def _check_progress(self) -> None:
        """A queue that can never drain (every entry is a follow-up to a
        session evicted by a store-index collision) must fail loudly, not
        spin to ``max_ticks``.  Size ``n_sessions`` from the workload
        (:func:`repro.sched.workload.n_sessions_for`) to rule this out."""
        if self.eng.active or self._next_arrival < len(self._arrivals):
            return
        if not self.queue:
            return
        resumable = set(self.eng.session_pos)
        dead = [e.uid for e in self.queue.entries()
                if e.kind == "resume" and e.uid not in resumable]
        if len(dead) == len(self.queue):
            raise RuntimeError(
                f"scheduler stuck: queued follow-ups target sessions with no "
                f"suspended snapshot (evicted uids: {sorted(set(dead))}); "
                f"size the engine's n_sessions to the workload's session "
                f"count")

    # ---- wave preparation (runs while the decode is in flight) ------------
    def _victim_cands(self, fast_uids: frozenset) -> List[VictimCand]:
        out = []
        shared = self.eng.shared_uids()     # host dicts only — no sync
        for slot, job in self._slot_job.items():
            resident = job.uid in fast_uids
            out.append(VictimCand(
                slot=slot, uid=job.uid, priority=job.priority,
                last_active_tick=self._last_active.get(job.uid, 0),
                suspend_ns=self._move_ns("suspend", resident),
                fast_resident=resident,
                shared=job.uid in shared))
        return out

    def _prepare_wave(self, fast_uids: frozenset) -> Wave:
        tick = self.tick_count
        ctx = SchedContext(tick=tick, now_ns=self.now_ns,
                           mechanism=self.cfg.mechanism, fast_uids=fast_uids)
        active_uids = {j.uid for j in self._slot_job.values()}
        resumable = set(self.eng.session_pos)
        cands = []
        for e in self.queue.entries():
            if e.kind == "resume" and (e.uid in active_uids
                                       or e.uid not in resumable):
                continue        # target still running / not yet suspended
            cands.append(AdmitCand(
                entry=e, eff_class=self.queue.effective_class(e, tick),
                cost_ns=self._place_ns(e, fast_uids),
                fast_resident=e.uid in fast_uids))

        free = len(self.eng.free_slots())
        budget = self.cfg.max_wave or len(cands)
        victims: List[VictimCand] = []
        placements: List[AdmitCand] = []
        picked_uids: set = set()
        victim_order: Optional[List[VictimCand]] = None
        for c in self.policy.admit_order(cands, ctx):
            if len(placements) >= budget:
                break
            if c.entry.uid in picked_uids:
                continue        # one placement per session per wave
            if free > 0:
                free -= 1
            elif self.cfg.preempt:
                # preempt only a strictly-worse class than the candidate's
                # aged class; victims ranked by the policy (cost_aware:
                # cheapest modeled suspend among the worst class)
                if victim_order is None:
                    victim_order = self.policy.victim_order(
                        self._victim_cands(fast_uids), ctx)
                v = next((v for v in victim_order
                          if v not in victims and v.priority > c.eff_class),
                         None)
                if v is None:
                    break       # admit_order is best-first: nothing later wins
                victims.append(v)
            else:
                break
            placements.append(c)
            picked_uids.add(c.entry.uid)
        return Wave(victims=tuple(v.slot for v in victims),
                    placements=tuple(placements))

    # ---- wave execution ---------------------------------------------------
    def _execute_wave(self, wave: Wave, fast_uids: frozenset) -> float:
        advance = 0.0
        # a completion during the overlapped decode may have evicted a
        # colliding store index — drop resumes whose snapshot vanished
        # (the progress check surfaces them if they can never be served)
        resumes = [c for c in wave.placements
                   if c.entry.kind == "resume"
                   and c.entry.uid in self.eng.session_pos]
        submits = [c for c in wave.placements if c.entry.kind == "fresh"]

        # preemption suspends: ONE fused dispatch for the whole wave.  A
        # planned victim may have completed during the overlapped decode —
        # its slot is already free, so it drops out; and every slot a
        # completion freed is credited against the wave first, so no job is
        # displaced for a placement that already has room (victims are in
        # policy order — the kept prefix is the best-victim prefix).
        victims = [s for s in wave.victims if s in self.eng.active]
        short = (len(resumes) + len(submits)) - len(self.eng.free_slots())
        victims = victims[:max(0, short)]
        if victims:
            requeue = []
            for slot in victims:
                job = self._slot_job.pop(slot)
                req = self.eng.active[slot]
                job.done += len(req.generated) - job.seed_tokens
                job.state, job.slot = "queued", -1
                self._last_active[job.uid] = self.tick_count
                requeue.append(job)
            if len(victims) == 1:
                self.eng.suspend(victims[0])
            else:
                self.eng.suspend_many(victims)
            advance += self._wave_advance(
                "preempt_suspend",
                [j.uid in fast_uids for j in requeue], "suspend",
                uids=[j.uid for j in requeue],
                t0=self.now_ns + advance)
            for job in requeue:
                # re-queue under the ORIGINAL admission order (seq == job_id
                # order is preserved by pushing with the job's first seq)
                self.queue.push(job_id=job.job_id, uid=job.uid, kind="resume",
                                priority=job.priority,
                                arrival_ns=job.arrival_ns, slo_ns=job.slo_ns,
                                tick=self.tick_count,
                                new_tokens=job.target_new - job.done,
                                seq=job.job_id)

        # session resumes: ONE fused resume_many wave, per-uid extra_new
        # (re-check snapshots: a preemption suspend just above can itself
        # evict a colliding store index)
        resumes = [c for c in resumes
                   if c.entry.uid in self.eng.session_pos]
        ready, extras = [], []
        for c in resumes:
            # the context envelope: decoding k tokens from position pos
            # writes cache positions pos..pos+k-1, so only `room` more
            # tokens fit; a follow-up past max_len completes with what the
            # session already produced ("context exhausted"), and a partial
            # fit serves the truncated budget
            room = self.eng.max_len - self.eng.session_pos[c.entry.uid]
            n = min(c.entry.new_tokens, room)
            job = self._jobs[c.entry.job_id]
            if n < 1:
                self.queue.remove(c.entry)
                job.target_new = job.done        # nothing more can be served
                self._complete_job(job, self.now_ns + advance)
                continue
            job.target_new -= c.entry.new_tokens - n
            ready.append(c)
            extras.append(n + 1)                 # +1: the restored seed token
        if ready:
            slots = self.eng.resume_many([c.entry.uid for c in ready], extras)
            for c, slot in zip(ready, slots):
                self._activate(c.entry, slot, seed_tokens=1)
            advance += self._wave_advance(
                "resume_wave", [c.fast_resident for c in ready], "resume",
                uids=[c.entry.uid for c in ready],
                t0=self.now_ns + advance)

        # fresh admissions: prefill inserts (inherently per-request — the
        # prefill is compute, not a session move)
        for c in submits:
            e = c.entry
            # fresh jobs fit the envelope too: prompt length n + k decoded
            # tokens occupy positions 0..n+k-2, so at most max_len-n+1 fit
            job = self._jobs[e.job_id]
            budget = min(e.new_tokens, self.eng.max_len - len(e.prompt) + 1)
            job.target_new -= e.new_tokens - budget
            req = Request(uid=e.uid, prompt=e.prompt, max_new=budget,
                          arrival_ns=e.arrival_ns, priority=e.priority,
                          slo_ns=e.slo_ns)
            slot = self.eng.submit(req)
            advance += self.cfg.prefill_ns_per_token * len(e.prompt)
            self.trace.emit(
                "prefill", self.cfg.prefill_ns_per_token * len(e.prompt),
                lane=0, cat="prefill",
                attrs={"uid": e.uid, "prompt_tokens": len(e.prompt)})
            self.metrics.record_decision(Decision(
                tick=self.tick_count, kind="submit", n_items=1))
            if slot in self.eng.active:
                self._activate(e, slot, seed_tokens=0)
            else:
                # a 1-token job: the prefill token met the budget and the
                # engine already suspended the session — complete it here
                self.queue.remove(e)
                job.done += len(req.generated)
                advance += self._wave_advance(
                    "complete_suspend", [job.uid in fast_uids], "suspend",
                    uids=[job.uid], t0=self.now_ns + advance)
                self._complete_job(job, self.now_ns + advance)
        return advance

    def _complete_job(self, job: Job, done_ns: float) -> None:
        """The single completion transition: every path that finishes a job
        (decode completion, one-token prefill, exhausted context) lands
        here."""
        job.state, job.slot, job.done_ns = "done", -1, done_ns
        self._last_active[job.uid] = self.tick_count
        self.metrics.record_job(JobRecord(
            job_id=job.job_id, uid=job.uid, kind=job.kind,
            priority=job.priority, arrival_ns=job.arrival_ns,
            done_ns=job.done_ns, slo_ns=job.slo_ns, tokens=job.done,
            migrations=job.migrations))

    def _activate(self, entry: QueueEntry, slot: int, *,
                  seed_tokens: int) -> None:
        self.queue.remove(entry)
        job = self._jobs[entry.job_id]
        job.state, job.slot, job.seed_tokens = "active", slot, seed_tokens
        self._slot_job[slot] = job
        self._last_active[job.uid] = self.tick_count

    # ---- introspection ----------------------------------------------------
    def jobs(self) -> Tuple[Job, ...]:
        return tuple(self._jobs.values())

    def active_jobs(self) -> Dict[int, Job]:
        return dict(self._slot_job)


class ClusterWave(NamedTuple):
    """One tick's prepared cluster decisions: preemption victims (global
    slots) and placements, each annotated with its target replica."""
    victims: Tuple[int, ...]
    placements: Tuple[AdmitCand, ...]
    targets: Tuple[int, ...]


class ClusterScheduler(Scheduler):
    """The cluster tick loop: admission, eviction AND placement.

    Drives a :class:`~repro.serve.cluster.Cluster` through the same
    engine-shaped surface the base scheduler uses, plus the third decision
    axis: every placement picks a *replica*, scored by the policy's
    ``place_order`` over (free slots, VILLA fast-tier occupancy, modeled
    hop cost from the session's current residence).  A resume placed off
    its home replica triggers a live migration — suspended pages cross the
    mesh as one fused hop-chain plan per route — before the per-replica
    fused resume waves fire.  ``migrate=False`` pins every resume to its
    residence replica (the A/B arm ``benchmarks/run.py cluster`` gates on).

    The virtual clock models the replicas as parallel lanes: each tick
    advances by one ``decode_ns`` (all replicas decode concurrently) plus
    the MAX over replicas of that replica's movement/prefill work, with a
    migration occupying both endpoints of its route.  The base scheduler's
    serial-advance semantics are unchanged — single-engine benchmarks
    (BENCH_sched) are bit-identical to PR 4.
    """

    def __init__(self, cluster, policy="cost_aware_cluster",
                 arrivals: Sequence[Arrival] = (),
                 cfg: SchedConfig = SchedConfig(), *, migrate: bool = True,
                 faults: Optional[FaultInjector] = None,
                 snapshot_every: int = 0, tracer=None):
        super().__init__(cluster, policy=policy, arrivals=arrivals, cfg=cfg,
                         tracer=tracer)
        self.cluster = cluster
        # trace lanes: 0 = scheduler, 1+r = replica r, last = write-behind
        self._wb_lane = cluster.n_replicas + 1
        self.migrate = migrate
        if snapshot_every < 0:
            raise ValueError(f"snapshot_every must be >= 0, "
                             f"got {snapshot_every}")
        # chaos wiring: the injector drives at-rest corruption and scheduled
        # replica/degrade events here; the cluster consumes the SAME
        # injector for movement-wave faults — one seeded draw stream
        self.faults = faults if faults is not None else cluster.faults
        self.snapshot_every = snapshot_every
        self._snaps: Dict[int, object] = {}     # uid -> SessionSnapshot
        self._lost_uids: Set[int] = set()       # sessions gone for good
        # per-tick lane accounting, for introspection and the lane-advance
        # regression test: each entry records the decode part, the lanes
        # seeded by complete-suspends, the final per-replica lanes after
        # wave execution, and the tick's total clock advance — the model's
        # contract is advance == decode_ns + max(lanes), never a sum of
        # per-phase maxima
        self.lane_log: List[Dict[str, object]] = []

    # ---- the tick (parallel replica lanes) --------------------------------
    def tick(self) -> None:
        self.tick_count += 1
        if (not self.eng.active and not self._has_admissible()
                and self._next_arrival < len(self._arrivals)):
            self.now_ns = max(self.now_ns,
                              self._arrivals[self._next_arrival].t_ns)
        self._admit_arrivals()
        self._fault_tick()
        self.metrics.record_tick(
            len(self.eng.active), self.eng.slots,
            per_replica=[len(e.active) / e.slots
                         for e in self.cluster.replicas])
        tr = self.trace
        tr.seek_all(self.now_ns)
        tick_sp = tr.begin_span("tick", lane=0, cat="tick",
                                attrs={"tick": self.tick_count,
                                       "queued": len(self.queue)})

        # 1. ONE fused decode dispatch per replica, all in flight at once.
        #    An all-bank refresh blocks the whole fleet's dispatch: windows
        #    are a pure function of absolute virtual time, so the idle
        #    fast-forward above cannot skip a pending one
        handle = self.eng.step_begin()
        decoded = handle is not None
        stall = 0.0
        if decoded:
            if self.mux.enabled:
                stall = self.mux.decode_gate(self.now_ns) - self.now_ns
                if stall > 0.0:
                    self.metrics.record_stall("refresh", stall)
                    tr.emit("refresh_stall", stall, lane=0, cat="stall",
                            attrs={"refreshes": self.mux.refreshes_before(
                                self.now_ns + stall)})
            tr.emit("decode", self.cfg.decode_ns, lane=0, cat="decode",
                    attrs={"n_active": len(self.eng.active)})
            if tr.enabled:
                # replica movement lanes start after the concurrent decode
                for r in range(self.cluster.n_replicas):
                    tr.seek(1 + r, tr.now(0))

        # 2. overlapped wave preparation against pre-step state
        fast_uids = self.eng.fast_resident_uids()
        wave = self._prepare_wave(fast_uids)
        tr.instant("plan", lane=0, cat="plan",
                   attrs={"victims": len(wave.victims),
                          "placements": len(wave.placements)})

        # 3. sync; completed bursts auto-suspend per replica (fused waves).
        #    ONE per-replica lanes vector carries ALL of the tick's
        #    post-decode movement — the complete-suspends seeded here AND
        #    the prepared wave executed below — so the tick advances by
        #    decode + max over replicas of each replica's TOTAL.  (The old
        #    accounting summed max(complete lanes) + max(wave lanes): two
        #    phase maxima added serially even though the model says a
        #    replica's wave work overlaps another replica's suspends.)
        completed = self.eng.step_end(handle)
        tick_t0 = self.now_ns
        advance = (self.cfg.decode_ns + stall) if decoded else 0.0
        lanes = [0.0] * self.cluster.n_replicas
        t0 = self.now_ns + advance
        if completed:
            flags = [self._slot_job[s].uid in fast_uids
                     for s, _ in completed]
            self._charge_wave("complete_suspend", flags, "suspend",
                              lanes=[self.cluster.replica_of(s) + 1
                                     for s, _ in completed])
            for (s, _), f in zip(completed, flags):
                self._lane_add(lanes, self.cluster.replica_of(s),
                               self._slot_job[s].uid,
                               self._move_ns("suspend", f), t0)
        seed = tuple(lanes)
        self.now_ns = t0
        for slot, req in completed:
            r = self.cluster.replica_of(slot)
            job = self._slot_job.pop(slot)
            job.done += len(req.generated) - job.seed_tokens
            self._complete_job(job, self.now_ns + lanes[r])

        # 4. execute the prepared wave on the SAME lanes
        self.now_ns += self._execute_wave(wave, fast_uids, lanes)
        self.lane_log.append({
            "tick": self.tick_count, "decode_ns": advance,
            "complete_lanes": seed, "lanes": tuple(lanes),
            "advance": self.now_ns - tick_t0})
        tr.end_span(tick_sp, t1_ns=max(self.now_ns, tr.now(0)))

    # ---- chaos: injection, snapshots, replica recovery --------------------
    def _mech_ns(self, c: MV.MovementCost) -> float:
        return c.ns_lisa if self.cfg.mechanism == "lisa" else c.ns_memcpy

    def _class_of(self, uid: int) -> Optional[int]:
        """Job class attribution for a chaos event on ``uid`` (latest job
        wins; chaos events are rare, the scan is fine)."""
        for j in reversed(list(self._jobs.values())):
            if j.uid == uid:
                return j.priority
        return None

    def _admit_arrivals(self) -> None:
        super()._admit_arrivals()
        if self._lost_uids:
            self._drain_lost()

    def _drain_lost(self) -> None:
        """Complete (as lost) queued follow-ups whose session died with a
        replica and has no snapshot: they can never be served, and leaving
        them queued would spin the run to ``max_ticks``.  A uid whose
        session has been re-created (fresh re-prefill in flight or done)
        is servable again and is skipped."""
        active_uids = {j.uid for j in self._slot_job.values()}
        fresh_uids = {e.uid for e in self.queue.entries()
                      if e.kind == "fresh"}
        resumable = set(self.eng.session_pos)
        for e in list(self.queue.entries()):
            if (e.kind != "resume" or e.uid not in self._lost_uids
                    or e.uid in resumable or e.uid in active_uids
                    or e.uid in fresh_uids):
                continue
            self.queue.remove(e)
            job = self._jobs[e.job_id]
            job.target_new = job.done
            self._complete_job(job, self.now_ns)
            self.metrics.record_fault("lost", job.priority)

    def _fault_tick(self) -> None:
        """The chaos gate at the top of every tick: refresh snapshots,
        fire scheduled replica failures / fast-tier degradations, and take
        this tick's seeded at-rest corruption draw."""
        inj, cl = self.faults, self.cluster
        if self.snapshot_every and self.tick_count % self.snapshot_every == 0:
            snaps, cost = snapshot_sessions(cl)
            if inj is not None:
                # never refresh a ledger-known corrupt session's snapshot:
                # the LAST CLEAN copy is the one recovery must restore
                snaps = {u: s for u, s in snaps.items()
                         if not inj.is_corrupt(u)}
            if snaps:
                self._snaps.update(snaps)
                # write-behind: snapshot bytes are priced and recorded but
                # NOT charged to the clock — the copy overlaps decode the
                # way LISA-VILLA's dirty-line writeback overlaps service
                self.metrics.record_decision(Decision(
                    tick=self.tick_count, kind="snapshot_wave",
                    n_items=len(snaps), ns_lisa=cost.ns_lisa,
                    ns_memcpy=cost.ns_memcpy, uj_lisa=cost.uj_lisa,
                    uj_memcpy=cost.uj_memcpy))
                if self.trace.enabled:
                    cs = (cost.ns_lisa, cost.ns_memcpy,
                          cost.uj_lisa, cost.uj_memcpy)
                    self.trace.move_span(
                        "snapshot_wave", self._wb_lane, cs,
                        [("snapshot", cs, {"bytes": cost.bytes})],
                        attrs={"n": len(snaps), "clock_charged": False,
                               "decision":
                                   len(self.metrics.decisions) - 1})
        if inj is None:
            return
        for r in inj.replica_failures_at(self.tick_count):
            self.trace.instant("replica_failure", lane=r + 1, cat="fault",
                               attrs={"replica": r})
            self._handle_replica_failure(r)
        for r in inj.degrade_at(self.tick_count):
            cl.degrade_fast(r)
            self.metrics.record_fault("degraded")
            self.trace.instant("fast_degraded", lane=r + 1, cat="fault",
                               attrs={"replica": r})
        # at-rest corruption: one seeded draw per tick over the suspended,
        # not-yet-corrupt sessions (deterministic candidate order).  An
        # ACTIVE session's store row is a stale copy the next suspend
        # overwrites wholesale — corrupting it would silently heal, so only
        # truly at-rest snapshots are candidates.
        active_uids = {req.uid for req in self.eng.active.values()}
        # fork-aware exclusion: the corruption target is the PHYSICAL row,
        # so a row with a marked alias is already corrupt for its whole
        # family — drawing a sibling would be a second incident on the
        # same bytes that one repair closes, splitting the ledger
        cands = [u for u in sorted(self.eng.session_pos)
                 if u not in active_uids
                 and not any(inj.is_corrupt(f) for f in self._family(u))]
        if cands:
            spec = cl.page_spec
            draw = inj.draw_storage(len(cands), spec.n_pages,
                                    spec.page_bytes)
            if draw is not None:
                ci, page, byte, xor = draw
                uid = cands[ci]
                eng = cl.replicas[cl.residence[uid]]
                # the PHYSICAL row (fork-aware): corrupting a shared row
                # rots every alias's bytes at once — and the scrub detects
                # it ONCE, per row, not per alias
                eng.corrupt_stored(eng.forks.resolve(uid), page, byte, xor)
                inj.note_corrupt(uid)
                self.metrics.record_fault("injected", self._class_of(uid))
                self.trace.instant(
                    "fault_injected", lane=cl.residence[uid] + 1,
                    cat="fault", attrs={"uid": uid, "page": int(page),
                                        "byte": int(byte)})

    def _family(self, uid: int) -> Tuple[int, ...]:
        """Every uid aliasing ``uid``'s physical store row on its home
        replica (``(uid,)`` for an exclusive row) — the unit chaos
        accounting works in, since corruption and repair both act on the
        row, not the alias."""
        eng = self.cluster.replicas[self.cluster.residence[uid]]
        if uid not in eng.forks:
            return (uid,)
        return eng.forks.aliases(eng.forks.resolve(uid))

    def _recovery_target(self, dead: int) -> Optional[int]:
        """Where refugees from a dead replica land: the surviving replica
        with the most free slots (lowest index on ties)."""
        if self.cluster.n_replicas < 2:
            return None
        free = self.cluster.free_by_replica()
        best = max((f, -r) for r, f in enumerate(free) if r != dead)
        return -best[1]

    def _handle_replica_failure(self, r: int) -> None:
        """Replica ``r`` dies mid-service.  Suspended sessions with a live
        snapshot are restored onto a surviving replica over the priced
        channel (charged to the clock as a ``recover_wave``); in-flight
        jobs are re-queued under their ORIGINAL admission seq — from their
        snapshot where one exists, from a fresh re-prefill of the prompt
        otherwise; sessions with neither are completed as lost so the
        queue stays drainable (starvation-free: requeues keep their aged
        class)."""
        cl, inj = self.cluster, self.faults
        # capture the jobs running on the dying replica BEFORE the wipe
        doomed = {g: self._slot_job.pop(g) for g in list(self._slot_job)
                  if cl.replica_of(g) == r}
        inflight, suspended = cl.fail_replica(r)
        self.metrics.record_fault("replica_failures")
        target = self._recovery_target(r)
        tot = [0.0, 0.0, 0.0, 0.0]
        recover_ns, n_restored = 0.0, 0

        def restore(uid: int) -> bool:
            nonlocal recover_ns, n_restored
            snap = self._snaps.get(uid)
            if snap is None or target is None:
                return False
            c = restore_session(cl, snap, target)
            if c is None:       # alias snap whose owner was not restored
                return False
            n_restored += 1
            recover_ns += self._mech_ns(c)
            for i, v in enumerate((c.ns_lisa, c.ns_memcpy,
                                   c.uj_lisa, c.uj_memcpy)):
                tot[i] += v
            if self.trace.enabled:
                cs = (c.ns_lisa, c.ns_memcpy, c.uj_lisa, c.uj_memcpy)
                # the recover_wave Decision is recorded AFTER the restores
                # (its index is the CURRENT ledger length); nothing in
                # between records a decision
                self.trace.move_span(
                    "recover_wave", target + 1, cs,
                    [("restore", cs, {"bytes": c.bytes, "uid": uid})],
                    attrs={"direction": "restore",
                           "decision": len(self.metrics.decisions)})
            return True

        # owners before aliases: an aliased snapshot restores by
        # re-attaching to its owner's already-restored physical row (one
        # repair heals the whole fork family), so the owner must land first
        def _owner_first(uid: int) -> tuple:
            snap = self._snaps.get(uid)
            alias = snap is not None and getattr(snap, "alias_of",
                                                 None) is not None
            return (alias, uid)

        for uid in sorted(suspended, key=_owner_first):
            if restore(uid):
                self.metrics.record_fault("recovered", self._class_of(uid))
            else:
                self._lost_uids.add(uid)
        for g, req in inflight:
            job = doomed.pop(g, None)
            if job is None:
                continue
            job.state, job.slot = "queued", -1
            self._last_active[job.uid] = self.tick_count
            if job.uid not in cl.session_pos:
                restore(job.uid)
            if job.uid in cl.session_pos:
                # tokens decoded since the snapshot died with the replica;
                # the job resumes from the snapshot state it restored to
                self.queue.push(job_id=job.job_id, uid=job.uid,
                                kind="resume", priority=job.priority,
                                arrival_ns=job.arrival_ns,
                                slo_ns=job.slo_ns, tick=self.tick_count,
                                new_tokens=job.target_new - job.done,
                                seq=job.job_id)
                self.metrics.record_fault("recovered", job.priority)
            elif job.kind == "fresh" and len(req.prompt):
                # no snapshot, but the prompt survives in the request:
                # re-prefill from scratch under the original admission seq
                job.done = 0
                self.queue.push(job_id=job.job_id, uid=job.uid,
                                kind="fresh", priority=job.priority,
                                arrival_ns=job.arrival_ns,
                                slo_ns=job.slo_ns, tick=self.tick_count,
                                new_tokens=job.target_new,
                                prompt=req.prompt, seq=job.job_id)
                self._lost_uids.discard(job.uid)
                self.metrics.record_fault("requeued", job.priority)
            else:
                self._lost_uids.add(job.uid)
                job.target_new = job.done
                self._complete_job(job, self.now_ns)
                self.metrics.record_fault("lost", job.priority)
        if inj is not None:
            for uid in list(self._lost_uids):
                if inj.is_corrupt(uid):
                    inj.discard_corrupt(uid)
        if n_restored:
            self.metrics.record_decision(Decision(
                tick=self.tick_count, kind="recover_wave",
                n_items=n_restored, ns_lisa=tot[0], ns_memcpy=tot[1],
                uj_lisa=tot[2], uj_memcpy=tot[3]))
            self.now_ns += recover_ns
        self._drain_lost()

    # ---- placement scoring ------------------------------------------------
    def _place_cands(self, e: QueueEntry, fast_uids: frozenset,
                     free: List[int], occ: List[float]) -> List[PlaceCand]:
        """Every replica this entry may land on, with its modeled bill.
        With migration off, a resume can ONLY land where its snapshot
        resides."""
        home = (self.cluster.residence.get(e.uid)
                if e.kind == "resume" else None)
        if e.kind == "resume" and not self.migrate:
            reps: Sequence[int] = (home,)
        else:
            reps = range(self.cluster.n_replicas)
        mech = self.cfg.mechanism
        # replicas already holding this session's fork family (the shared
        # physical row): placing there keeps the session an alias — a
        # zero-copy resume — instead of a cross-replica materialization
        family = {rr for rr, eng in enumerate(self.cluster.replicas)
                  if e.uid in eng.shared_uids()}
        out = []
        for r in reps:
            if e.kind == "resume":
                place = self._move_ns("resume",
                                      e.uid in fast_uids and r == home)
                hop = self.cluster.hop_ns(home, r, mech)
            else:
                place = self.cfg.prefill_ns_per_token * len(e.prompt)
                hop = 0.0
            out.append(PlaceCand(replica=r, free_slots=free[r],
                                 fast_occupancy=occ[r], hop_ns=hop,
                                 place_ns=place,
                                 degraded=self.cluster.replicas[
                                     r].fast_degraded,
                                 shared_resident=r in family))
        return out

    # ---- wave preparation (runs while the decodes are in flight) ----------
    def _prepare_wave(self, fast_uids: frozenset) -> ClusterWave:
        tick = self.tick_count
        ctx = SchedContext(tick=tick, now_ns=self.now_ns,
                           mechanism=self.cfg.mechanism, fast_uids=fast_uids)
        active_uids = {j.uid for j in self._slot_job.values()}
        resumable = set(self.eng.session_pos)
        free = self.cluster.free_by_replica()
        occ = self.cluster.fast_occupancy()
        cands = []
        # hop/place pricing per entry is computed ONCE; only the free-slot
        # counts change as the wave reserves slots below
        place_cache: Dict[int, List[PlaceCand]] = {}
        for e in self.queue.entries():
            if e.kind == "resume" and (e.uid in active_uids
                                       or e.uid not in resumable):
                continue
            pcs = self._place_cands(e, fast_uids, free, occ)
            place_cache[id(e)] = pcs
            cands.append(AdmitCand(
                entry=e, eff_class=self.queue.effective_class(e, tick),
                cost_ns=min(pc.hop_ns + pc.place_ns for pc in pcs),
                fast_resident=e.uid in fast_uids))

        budget = self.cfg.max_wave or len(cands)
        victims: List[VictimCand] = []
        placements: List[AdmitCand] = []
        targets: List[int] = []
        picked_uids: set = set()
        victim_order: Optional[List[VictimCand]] = None
        for c in self.policy.admit_order(cands, ctx):
            if len(placements) >= budget:
                break
            if c.entry.uid in picked_uids:
                continue
            chosen: Optional[int] = None
            victim: Optional[VictimCand] = None
            cands_now = [pc._replace(free_slots=free[pc.replica])
                         for pc in place_cache[id(c.entry)]]
            for pc in self.policy.place_order(cands_now, ctx):
                if free[pc.replica] > 0:
                    chosen = pc.replica
                    break
                if self.cfg.preempt:
                    if victim_order is None:
                        victim_order = self.policy.victim_order(
                            self._victim_cands(fast_uids), ctx)
                    victim = next(
                        (v for v in victim_order if v not in victims
                         and v.priority > c.eff_class
                         and self.cluster.replica_of(v.slot) == pc.replica),
                        None)
                    if victim is not None:
                        chosen = pc.replica
                        break
            if chosen is None:
                # unlike the single-engine case, unplaceable is per-
                # candidate (a migration-off resume may be pinned to a full
                # replica while others are open) — skip, don't give up
                continue
            if victim is not None:
                victims.append(victim)
            else:
                free[chosen] -= 1
            placements.append(c)
            targets.append(chosen)
            picked_uids.add(c.entry.uid)
        return ClusterWave(victims=tuple(v.slot for v in victims),
                           placements=tuple(placements),
                           targets=tuple(targets))

    # ---- wave execution ---------------------------------------------------
    def _execute_wave(self, wave: ClusterWave, fast_uids: frozenset,
                      lanes: Optional[List[float]] = None) -> float:
        cl = self.cluster
        if lanes is None:       # direct callers (tests): fresh lanes
            lanes = [0.0] * cl.n_replicas
        t0 = self.now_ns        # lane origin: all lane values are offsets
        spos = self.eng.session_pos          # one merged snapshot per phase
        active = self.eng.active
        pairs = [(c, t) for c, t in zip(wave.placements, wave.targets)
                 if c.entry.kind == "fresh" or c.entry.uid in spos]

        # keep only the victims still needed: completions during the
        # overlapped decode may have freed slots on a placement's replica,
        # and a context-exhausted resume (no room left) completes without
        # ever taking a slot — neither justifies a preemption
        free = cl.free_by_replica()
        need: Dict[int, int] = {}
        for c, t in pairs:
            if (c.entry.kind == "resume"
                    and self.eng.max_len - spos[c.entry.uid] < 1):
                continue
            need[t] = need.get(t, 0) + 1
        victims = []
        for g in wave.victims:
            if g not in active:
                continue
            r = cl.replica_of(g)
            if need.get(r, 0) > free[r]:
                victims.append(g)
                free[r] += 1
        if victims:
            requeue = []
            for g in victims:
                job = self._slot_job.pop(g)
                req = active[g]
                job.done += len(req.generated) - job.seed_tokens
                job.state, job.slot = "queued", -1
                self._last_active[job.uid] = self.tick_count
                requeue.append(job)
            cl.suspend_many(victims)        # one fused dispatch per replica
            self._charge_wave("preempt_suspend",
                              [j.uid in fast_uids for j in requeue],
                              "suspend",
                              lanes=[cl.replica_of(g) + 1 for g in victims])
            for g, job in zip(victims, requeue):
                self._lane_add(lanes, cl.replica_of(g), job.uid,
                               self._move_ns("suspend",
                                             job.uid in fast_uids), t0)
            for job in requeue:
                self.queue.push(job_id=job.job_id, uid=job.uid,
                                kind="resume", priority=job.priority,
                                arrival_ns=job.arrival_ns, slo_ns=job.slo_ns,
                                tick=self.tick_count,
                                new_tokens=job.target_new - job.done,
                                seq=job.job_id)

        # resumes: migrate off-home sessions (one fused plan per route),
        # then ONE fused resume_many wave per replica.  Fresh snapshot:
        # the preemption suspends above can evict colliding store indices
        spos = self.eng.session_pos
        resumes = [(c, t) for c, t in pairs if c.entry.kind == "resume"
                   and c.entry.uid in spos]
        ready, extras, rtargets = [], [], []
        for c, t in resumes:
            room = self.eng.max_len - spos[c.entry.uid]
            n = min(c.entry.new_tokens, room)
            job = self._jobs[c.entry.job_id]
            if n < 1:
                self.queue.remove(c.entry)
                job.target_new = job.done       # context exhausted
                self._complete_job(job, self.now_ns + lanes[t])
                continue
            job.target_new -= c.entry.new_tokens - n
            ready.append(c)
            extras.append(n + 1)                # +1: the restored seed token
            rtargets.append(t)
        inj = self.faults
        if inj is not None and ready:
            # pre-resume repair: a session the ledger knows is corrupt at
            # rest is restored from its snapshot BEFORE it resumes (clean
            # bytes migrate/resume below); without recovery — or without a
            # snapshot — it resumes as-is and the device-side verify counts
            # the detection (served corrupt, never silent)
            for c in ready:
                uid = c.entry.uid
                # fork-aware: corruption lives on the PHYSICAL row, so the
                # incident may be ledgered under a sibling alias of the
                # row this resume is about to read
                fam = self._family(uid)
                marked = [f for f in fam if inj.is_corrupt(f)]
                if not marked:
                    continue
                home = cl.residence[uid]
                rc = None
                if inj.spec.recover:
                    if len(fam) > 1:
                        # a SHARED row heals in place from any family
                        # member's pages-bearing snapshot (aliases are
                        # meta-only): restore_session would re-admit the
                        # carrier and demote the corrupt row to the
                        # siblings, repairing one alias instead of all
                        snap = next(
                            (self._snaps[f] for f in fam
                             if f in self._snaps
                             and self._snaps[f].pages is not None), None)
                        if snap is not None:
                            rc = repair_row(cl, snap, home)
                    else:
                        snap = self._snaps.get(uid)
                        if snap is not None:
                            rc = restore_session(cl, snap, home)
                if rc is not None:
                    self._lane_add(lanes, home, uid, self._mech_ns(rc), t0)
                    self.metrics.record_decision(Decision(
                        tick=self.tick_count, kind="recover_wave",
                        n_items=len(marked), ns_lisa=rc.ns_lisa,
                        ns_memcpy=rc.ns_memcpy, uj_lisa=rc.uj_lisa,
                        uj_memcpy=rc.uj_memcpy))
                    if self.trace.enabled:
                        cs = (rc.ns_lisa, rc.ns_memcpy,
                              rc.uj_lisa, rc.uj_memcpy)
                        self.trace.move_span(
                            "recover_wave", home + 1, cs,
                            [("restore", cs, {"uid": uid})],
                            attrs={"direction": "repair", "decision":
                                   len(self.metrics.decisions) - 1})
                    for f in marked:
                        inj.consume_corrupt(f, "recovered")
                        self.metrics.record_fault("recovered",
                                                  self._class_of(f))
                else:
                    # served corrupt; the device verify counts the read.
                    # NB an unrepaired shared row can be read by several
                    # aliases (one incident, many corrupt serves), so in
                    # the no-recovery/no-snapshot corner the device
                    # counter can exceed ledger ``detected``
                    for f in marked:
                        inj.consume_corrupt(f, "detected")
                        self.metrics.record_fault("detected",
                                                  self._class_of(f))
        if ready:
            homes = {c.entry.uid: cl.residence[c.entry.uid] for c in ready}
            migs = [(c, t) for c, t in zip(ready, rtargets)
                    if homes[c.entry.uid] != t]
            if migs:
                tot = [0.0, 0.0, 0.0, 0.0]
                for c, t in migs:
                    src = homes[c.entry.uid]
                    mplan = cl.migration_plan(src, t)
                    mc = mplan.cost
                    ns = (mc.ns_lisa if self.cfg.mechanism == "lisa"
                          else mc.ns_memcpy)
                    # the inbound replica waits for the hop chain; the
                    # source end only runs the (free) page gather — its
                    # decode lane is not stalled by an outbound migration
                    self._lane_add(lanes, t, c.entry.uid, ns, t0)
                    for i, v in enumerate((mc.ns_lisa, mc.ns_memcpy,
                                           mc.uj_lisa, mc.uj_memcpy)):
                        tot[i] += v
                    self._jobs[c.entry.job_id].migrations += 1
                    if self.trace.enabled:
                        items = [(leg.kind,
                                  (lc.ns_lisa, lc.ns_memcpy,
                                   lc.uj_lisa, lc.uj_memcpy),
                                  {"bytes": lc.bytes, "hops": lc.hops})
                                 for leg, lc in zip(
                                     mplan.legs,
                                     MV.leg_costs(mplan, cl.spec))]
                        # the migrate_wave Decision lands after the loop,
                        # at the CURRENT ledger length
                        self.trace.move_span(
                            "migrate_wave", t + 1,
                            (mc.ns_lisa, mc.ns_memcpy,
                             mc.uj_lisa, mc.uj_memcpy), items,
                            attrs={"uid": c.entry.uid,
                                   "src": src, "dst": t, "decision":
                                   len(self.metrics.decisions)})
                self.metrics.record_decision(Decision(
                    tick=self.tick_count, kind="migrate_wave",
                    n_items=len(migs), ns_lisa=tot[0], ns_memcpy=tot[1],
                    uj_lisa=tot[2], uj_memcpy=tot[3]))
            slots = cl.resume_many([c.entry.uid for c in ready], extras,
                                   rtargets)
            for c, slot in zip(ready, slots):
                self._activate(c.entry, slot, seed_tokens=1)
            flags = [c.fast_resident and homes[c.entry.uid] == t
                     for c, t in zip(ready, rtargets)]
            self._charge_wave("resume_wave", flags, "resume",
                              lanes=[t + 1 for t in rtargets])
            for c, t, f in zip(ready, rtargets, flags):
                self._lane_add(lanes, t, c.entry.uid,
                               self._move_ns("resume", f), t0)
            if inj is not None:
                # migration-wave faults: each retried route's re-copies
                # (k× the route plan) and the bounded-exponential backoff
                # are real latency on the inbound lane — but only the
                # re-copies are MOVEMENT; backoff is its own bucket
                for ev in cl.drain_fault_events():
                    retries = int(ev["retries"])
                    if retries:
                        base = cl.migration_plan(ev["src"], ev["dst"],
                                                 ev["k"]).cost
                        rc = MV.retry_cost(base, retries)
                        backoff = float(ev["backoff_ns"])
                        dst = ev["dst"]
                        svc = self._mech_ns(rc)
                        if self.mux.enabled:
                            # retries re-queue through the multiplexer on
                            # the banks of the route's sessions: each
                            # session's re-copied share occupies its own
                            # bank, so re-copies overlap across banks but
                            # contend with everything else on them
                            ruids = tuple(ev.get("uids") or ()) or (dst,)
                            share = svc / len(ruids)
                            ready_t = t0 + lanes[dst]
                            end = ready_t
                            for u in ruids:
                                start, e = self.mux.submit(
                                    self.mux.bank_of(u), ready_t, share)
                                if start > ready_t:
                                    self.metrics.record_stall(
                                        "contention", start - ready_t)
                                end = max(end, e)
                            lanes[dst] = (end - t0) + backoff
                        else:
                            # the re-copies AND the bounded-exponential
                            # backoff are real latency on the inbound lane
                            lanes[dst] += svc + backoff
                        # ledger: pure movement under both mechanisms; the
                        # mechanism-independent backoff rides in its own
                        # bucket so the lisa/memcpy advantage ratio stays
                        # fault-rate-invariant
                        self.metrics.record_decision(Decision(
                            tick=self.tick_count, kind="retry_wave",
                            n_items=retries, ns_lisa=rc.ns_lisa,
                            ns_memcpy=rc.ns_memcpy, uj_lisa=rc.uj_lisa,
                            uj_memcpy=rc.uj_memcpy, backoff_ns=backoff))
                        self.metrics.record_fault("retries", n=retries)
                        if self.trace.enabled and backoff > 0.0:
                            self.trace.emit(
                                "backoff", backoff, lane=dst + 1,
                                cat="stall", attrs={"retries": retries})
                        if self.trace.enabled:
                            bplan = cl.migration_plan(ev["src"], ev["dst"],
                                                      ev["k"])
                            items = [(leg.kind,
                                      (lc.ns_lisa * retries,
                                       lc.ns_memcpy * retries,
                                       lc.uj_lisa * retries,
                                       lc.uj_memcpy * retries),
                                      {"bytes": lc.bytes * retries,
                                       "hops": lc.hops})
                                     for leg, lc in zip(
                                         bplan.legs,
                                         MV.leg_costs(bplan, cl.spec))]
                            # trailing backoff leg: mechanism-independent
                            # wait; move_span's residual prices it exactly
                            items.append(("backoff", (0.0, 0.0, 0.0, 0.0),
                                          {"bytes": 0, "hops": 0}))
                            self.trace.move_span(
                                "retry_wave", ev["dst"] + 1,
                                (rc.ns_lisa, rc.ns_memcpy,
                                 rc.uj_lisa, rc.uj_memcpy), items,
                                attrs={"retries": retries,
                                       "src": ev["src"], "dst": ev["dst"],
                                       "backoff_ns":
                                           float(ev["backoff_ns"]),
                                       "decision":
                                           len(self.metrics.decisions)
                                           - 1})
                    uid = ev["corrupt_uid"]
                    if uid is not None:
                        # landed corrupt (retries exhausted or recovery
                        # off) and resumed in this very wave — the device
                        # verify caught it; close the incident as detected
                        inj.consume_corrupt(uid, "detected")
                        self.metrics.record_fault("detected",
                                                  self._class_of(uid))

        # fresh admissions: prefills run concurrently across replicas
        for c, t in pairs:
            if c.entry.kind != "fresh":
                continue
            e = c.entry
            job = self._jobs[e.job_id]
            budget = min(e.new_tokens, self.eng.max_len - len(e.prompt) + 1)
            job.target_new -= e.new_tokens - budget
            req = Request(uid=e.uid, prompt=e.prompt, max_new=budget,
                          arrival_ns=e.arrival_ns, priority=e.priority,
                          slo_ns=e.slo_ns)
            gslot = cl.submit(req, replica=t)
            lanes[t] += self.cfg.prefill_ns_per_token * len(e.prompt)
            self.trace.emit(
                "prefill", self.cfg.prefill_ns_per_token * len(e.prompt),
                lane=t + 1, cat="prefill",
                attrs={"uid": e.uid, "prompt_tokens": len(e.prompt)})
            self.metrics.record_decision(Decision(
                tick=self.tick_count, kind="submit", n_items=1))
            if gslot in self.eng.active:
                self._activate(e, gslot, seed_tokens=0)
            else:                   # 1-token job: completed at prefill
                self.queue.remove(e)
                job.done += len(req.generated)
                self._charge_wave("complete_suspend",
                                  [job.uid in fast_uids], "suspend",
                                  lanes=[t + 1])
                self._lane_add(lanes, t, job.uid,
                               self._move_ns("suspend",
                                             job.uid in fast_uids), t0)
                self._complete_job(job, self.now_ns + lanes[t])
        return max(lanes) if lanes else 0.0
