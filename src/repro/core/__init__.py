"""Core: the paper's contribution.

``repro.core.dram`` — faithful reproduction of the LISA DRAM substrate
                      (timing/energy exact to Table 1; system sim for Figs 3/4).
``repro.core.lisa`` — the same substrate adapted to the TPU mesh
                      (hop-chain collectives, tiered VILLA cache, cost model).
"""
