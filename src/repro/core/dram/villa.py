"""LISA-VILLA: in-DRAM caching policy (paper Sec. 3.2.1), pure JAX.

The policy is reproduced exactly as described:
  * a set of 1024 saturating counters per bank tracks row accesses;
  * counter values are halved every epoch (staleness control);
  * at the end of an epoch the 16 most-frequently-accessed rows are marked
    *hot* and are cached into the fast subarray on their next access;
  * replacement is *benefit-based* (Lee et al. [57]): every cached row has a
    benefit counter incremented on hit; the minimum-benefit row is evicted.

The same policy object is reused by the TPU-side tiered cache
(``repro.core.lisa.villa_cache``) — that is the point of LISA-as-substrate.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

COUNTER_SATURATION = 32767          # 15-bit saturating counters (6KB/bank, Sec 3.2.1 fn2)


@dataclasses.dataclass(frozen=True)
class VillaConfig:
    n_counters: int = 1024
    n_hot: int = 16                  # rows marked hot per epoch
    n_slots: int = 16                # rows the fast subarray can hold
    epoch_len: int = 256             # accesses per epoch (controller ticks it)
    # fast-subarray timings (short bitlines; TL-DRAM-like near segment), ns
    tRCD_fast: float = 7.5
    tRAS_fast: float = 18.0
    tRP_fast: float = 8.75
    tCL_fast: float = 13.75          # column path unchanged


class VillaState(NamedTuple):
    counters: jax.Array      # (n_counters,) int32, saturating
    hot: jax.Array           # (n_counters,) bool — marked hot last epoch
    tags: jax.Array          # (n_slots,) int32 cached row id, -1 empty
    benefit: jax.Array       # (n_slots,) int32
    tick: jax.Array          # () int32 — accesses since epoch start


def villa_init(cfg: VillaConfig) -> VillaState:
    return VillaState(
        counters=jnp.zeros((cfg.n_counters,), jnp.int32),
        hot=jnp.zeros((cfg.n_counters,), bool),
        tags=jnp.full((cfg.n_slots,), -1, jnp.int32),
        benefit=jnp.zeros((cfg.n_slots,), jnp.int32),
        tick=jnp.zeros((), jnp.int32),
    )


def villa_epoch(state: VillaState, cfg: VillaConfig) -> VillaState:
    """End-of-epoch maintenance: halve counters, re-mark the top-16 as hot."""
    topk_vals, _ = jax.lax.top_k(state.counters, cfg.n_hot)
    threshold = jnp.maximum(topk_vals[-1], 1)
    hot = state.counters >= threshold
    return state._replace(counters=state.counters // 2, hot=hot,
                          tick=jnp.zeros((), jnp.int32))


def villa_access(state: VillaState, row_id: jax.Array, cfg: VillaConfig
                 ) -> Tuple[VillaState, jax.Array, jax.Array, jax.Array]:
    """One access to ``row_id``.  Returns (state, hit, insert, victim_slot).

    ``hit``    — row is resident in the fast subarray (serve at fast latency,
                 bump its benefit counter).
    ``insert`` — row was marked hot and is not resident: cache it *now*
                 ("cache them when they are accessed the next time"), evicting
                 the minimum-benefit slot.  The caller charges the configured
                 copy mechanism's latency/energy for the insertion.
    Epoch bookkeeping (halving + hot re-marking) fires every ``epoch_len``
    accesses, matching the paper's per-epoch description.
    """
    row_id = jnp.asarray(row_id, jnp.int32)
    cidx = row_id % cfg.n_counters
    counters = state.counters.at[cidx].set(
        jnp.minimum(state.counters[cidx] + 1, COUNTER_SATURATION))

    hit_mask = state.tags == row_id
    hit = hit_mask.any()
    benefit = jnp.where(hit_mask, state.benefit + 1, state.benefit)

    is_hot = state.hot[cidx]
    insert = is_hot & ~hit
    victim = jnp.argmin(benefit)
    tags = jnp.where(insert, state.tags.at[victim].set(row_id), state.tags)
    benefit = jnp.where(insert, benefit.at[victim].set(1), benefit)

    new = VillaState(counters=counters, hot=state.hot, tags=tags,
                     benefit=benefit, tick=state.tick + 1)
    new = jax.lax.cond(new.tick >= cfg.epoch_len,
                       lambda s: villa_epoch(s, cfg), lambda s: s, new)
    return new, hit, insert, victim


# ---------------------------------------------------------------------------
# Split form of the policy, for the controller's jitted scan.
#
# The counter / hot-marking half of VILLA is *data-independent* of hits and
# insertions: counters bump on every access, epochs fire every ``epoch_len``
# accesses, and the hot set is a pure function of the access sequence.  The
# controller therefore precomputes per-request hotness *vectorized* outside
# its scan (``hot_for_sequence``) and keeps only the tiny tags/benefit half
# (``tags_access``) inside — exactly equivalent to running ``villa_access``
# per request, but without (n_counters,)-sized work per scan step.
# ---------------------------------------------------------------------------

def tags_access(tags: jax.Array, benefit: jax.Array, row_id: jax.Array,
                is_hot: jax.Array) -> Tuple[jax.Array, jax.Array,
                                            jax.Array, jax.Array]:
    """The tags/benefit half of ``villa_access`` for one access.

    ``is_hot`` is the precomputed hotness of the row's counter slot at this
    access (see ``hot_for_sequence``).  Returns (tags, benefit, hit, insert).
    """
    row_id = jnp.asarray(row_id, jnp.int32)
    hit_mask = tags == row_id
    hit = hit_mask.any()
    benefit = jnp.where(hit_mask, benefit + 1, benefit)
    insert = is_hot & ~hit
    victim = jnp.argmin(benefit)
    tags = jnp.where(insert, tags.at[victim].set(row_id), tags)
    benefit = jnp.where(insert, benefit.at[victim].set(1), benefit)
    return tags, benefit, hit, insert


def hot_for_sequence(bank: jax.Array, row: jax.Array, n_banks: int,
                     cfg: VillaConfig) -> jax.Array:
    """Per-request hotness for a whole access sequence, fully vectorized.

    For request ``i`` touching ``bank[i]``/``row[i]``, replays the
    counter/epoch half of the per-bank VILLA policy in dense ops:
    the request's per-bank rank decides its epoch; per-(bank, epoch) counter
    increments come from one scatter-add; the epoch loop (a short static
    Python loop) applies saturation, top-k hot marking, and halving.
    Returns ``is_hot`` of shape ``(n,)`` — ``hot[bank_i's epoch][row_i %
    n_counters]`` exactly as ``villa_access`` would have read it.
    """
    n = bank.shape[0]
    bank = jnp.asarray(bank, jnp.int32)
    cidx = jnp.asarray(row, jnp.int32) % cfg.n_counters
    onehot = (bank[:, None] == jnp.arange(n_banks)[None, :]).astype(jnp.int32)
    rank = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - onehot,
                               bank[:, None], axis=1)[:, 0]     # prior count
    epoch = rank // cfg.epoch_len
    max_epochs = n // cfg.epoch_len
    seg = bank * (max_epochs + 1) + jnp.minimum(epoch, max_epochs)
    inc = jnp.zeros((n_banks * (max_epochs + 1), cfg.n_counters), jnp.int32)
    inc = inc.at[seg, cidx].add(1).reshape(n_banks, max_epochs + 1,
                                           cfg.n_counters)
    hot_tab = [jnp.zeros((n_banks, cfg.n_counters), bool)]  # before 1st epoch
    counters = jnp.zeros((n_banks, cfg.n_counters), jnp.int32)
    for e in range(max_epochs):
        counters = jnp.minimum(counters + inc[:, e], COUNTER_SATURATION)
        topk_vals = jax.lax.top_k(counters, cfg.n_hot)[0]
        threshold = jnp.maximum(topk_vals[:, -1], 1)
        hot_tab.append(counters >= threshold[:, None])
        counters = counters // 2
    hot_tab = jnp.stack(hot_tab, axis=1)    # (banks, max_epochs+1, counters)
    return hot_tab[bank, jnp.minimum(epoch, max_epochs), cidx]
