"""LISA-VILLA: in-DRAM caching policy (paper Sec. 3.2.1), pure JAX.

The policy is reproduced exactly as described:
  * a set of 1024 saturating counters per bank tracks row accesses;
  * counter values are halved every epoch (staleness control);
  * at the end of an epoch the 16 most-frequently-accessed rows are marked
    *hot* and are cached into the fast subarray on their next access;
  * replacement is *benefit-based* (Lee et al. [57]): every cached row has a
    benefit counter incremented on hit; the minimum-benefit row is evicted.

The same policy object is reused by the TPU-side tiered cache
(``repro.core.lisa.villa_cache``) — that is the point of LISA-as-substrate.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

COUNTER_SATURATION = 32767          # 15-bit saturating counters (6KB/bank, Sec 3.2.1 fn2)


@dataclasses.dataclass(frozen=True)
class VillaConfig:
    n_counters: int = 1024
    n_hot: int = 16                  # rows marked hot per epoch
    n_slots: int = 16                # rows the fast subarray can hold
    epoch_len: int = 256             # accesses per epoch (controller ticks it)
    # fast-subarray timings (short bitlines; TL-DRAM-like near segment), ns
    tRCD_fast: float = 7.5
    tRAS_fast: float = 18.0
    tRP_fast: float = 8.75
    tCL_fast: float = 13.75          # column path unchanged


class VillaState(NamedTuple):
    counters: jax.Array      # (n_counters,) int32, saturating
    hot: jax.Array           # (n_counters,) bool — marked hot last epoch
    tags: jax.Array          # (n_slots,) int32 cached row id, -1 empty
    benefit: jax.Array       # (n_slots,) int32
    tick: jax.Array          # () int32 — accesses since epoch start


def villa_init(cfg: VillaConfig) -> VillaState:
    return VillaState(
        counters=jnp.zeros((cfg.n_counters,), jnp.int32),
        hot=jnp.zeros((cfg.n_counters,), bool),
        tags=jnp.full((cfg.n_slots,), -1, jnp.int32),
        benefit=jnp.zeros((cfg.n_slots,), jnp.int32),
        tick=jnp.zeros((), jnp.int32),
    )


def villa_epoch(state: VillaState, cfg: VillaConfig) -> VillaState:
    """End-of-epoch maintenance: halve counters, re-mark the top-16 as hot."""
    topk_vals, _ = jax.lax.top_k(state.counters, cfg.n_hot)
    threshold = jnp.maximum(topk_vals[-1], 1)
    hot = state.counters >= threshold
    return state._replace(counters=state.counters // 2, hot=hot,
                          tick=jnp.zeros((), jnp.int32))


def villa_access(state: VillaState, row_id: jax.Array, cfg: VillaConfig
                 ) -> Tuple[VillaState, jax.Array, jax.Array, jax.Array]:
    """One access to ``row_id``.  Returns (state, hit, insert, victim_slot).

    ``hit``    — row is resident in the fast subarray (serve at fast latency,
                 bump its benefit counter).
    ``insert`` — row was marked hot and is not resident: cache it *now*
                 ("cache them when they are accessed the next time"), evicting
                 the minimum-benefit slot.  The caller charges the configured
                 copy mechanism's latency/energy for the insertion.
    Epoch bookkeeping (halving + hot re-marking) fires every ``epoch_len``
    accesses, matching the paper's per-epoch description.
    """
    row_id = jnp.asarray(row_id, jnp.int32)
    cidx = row_id % cfg.n_counters
    counters = state.counters.at[cidx].set(
        jnp.minimum(state.counters[cidx] + 1, COUNTER_SATURATION))

    hit_mask = state.tags == row_id
    hit = hit_mask.any()
    benefit = jnp.where(hit_mask, state.benefit + 1, state.benefit)

    is_hot = state.hot[cidx]
    insert = is_hot & ~hit
    victim = jnp.argmin(benefit)
    tags = jnp.where(insert, state.tags.at[victim].set(row_id), state.tags)
    benefit = jnp.where(insert, benefit.at[victim].set(1), benefit)

    new = VillaState(counters=counters, hot=state.hot, tags=tags,
                     benefit=benefit, tick=state.tick + 1)
    new = jax.lax.cond(new.tick >= cfg.epoch_len,
                       lambda s: villa_epoch(s, cfg), lambda s: s, new)
    return new, hit, insert, victim
