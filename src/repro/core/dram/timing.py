"""DRAM timing & energy model for the LISA substrate (HPCA'16 / 2018 summary).

Command-level model calibrated against JEDEC DDR3-1600 timings.  Every number in
Table 1 of the paper is reproduced by the formulas below — the latency
decompositions are documented inline; the energy components are a calibrated
component model solved on the paper's anchor points (the paper reports SPICE
results, not component breakdowns, so the per-component constants here are
back-solved and documented as such).

Units: nanoseconds (ns) and microjoules (uJ) throughout.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

CACHE_LINE_BYTES = 64
ROW_BYTES = 8192                      # 8 KB DRAM row (rank-level)
LINES_PER_ROW = ROW_BYTES // CACHE_LINE_BYTES   # 128


@dataclasses.dataclass(frozen=True)
class DDR3Timing:
    """JEDEC DDR3-1600 (11-11-11) timing parameters, in ns."""

    tCK: float = 1.25
    tRCD: float = 13.75     # ACT -> column command
    tRP: float = 13.75      # PRE -> ACT (baseline precharge latency)
    tRAS: float = 35.0      # ACT -> PRE (restoration complete)
    tCL: float = 13.75      # column read latency
    tCWL: float = 12.5      # column write latency (CWL=10)
    tCCD: float = 5.0       # column-to-column, 4 cycles
    tBURST: float = 5.0     # 8-beat burst, 4 cycles
    tWR: float = 15.0       # write recovery
    tRTP: float = 7.5       # read -> precharge

    @property
    def tRC(self) -> float:
        return self.tRAS + self.tRP


@dataclasses.dataclass(frozen=True)
class LISATiming:
    """LISA-specific timings from the paper's SPICE evaluation.

    * ``t_rbm_hop`` — per-hop increment of a LISA-RISC copy.  Table 1:
      (260.5 - 148.5) / 14 hops = 8 ns/hop exactly.
    * ``t_rbm_row`` — time for one RBM row-buffer movement used for the
      bandwidth claim: 8 KB / 500 GB/s = 16.384 ns (includes the paper's
      conservative 60% margin).
    * ``risc_base`` — hop-independent part of LISA-RISC: ACT(src, full tRAS)
      + ACT(dst, amplify+restore tRAS) + PRE(tRP) + SPICE sensing margin.
      Back-solved: 148.5 - 8 = 140.5;  margin = 140.5 - (35+35+13.75) = 56.75.
    * ``t_pre_linked`` — LISA-LIP precharge: 13 ns -> 5 ns (2.6x, Sec. 3.3).
    """

    t_rbm_hop: float = 8.0
    t_rbm_row: float = 16.384
    sense_margin: float = 56.75
    t_pre_baseline: float = 13.0
    t_pre_linked: float = 5.0

    def risc_base(self, t: DDR3Timing) -> float:
        return t.tRAS + t.tRAS + t.tRP + self.sense_margin


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Component energy model (uJ), back-solved from Table 1 anchors.

    * ``e_act_pre`` — one ACT(+share of PRE) row operation.  RC-IntraSA does
      ACT->ACT->PRE and costs 0.06 uJ  =>  0.03 per row op (2 row ops).
    * ``e_col_internal`` — one 64 B column transfer over the internal bus.
      RC-Bank = 4 row ops + 256 col ops = 2.08  =>  (2.08-0.12)/256.
    * ``e_intersa_extra`` — extra global-bus/driver energy of RowClone
      inter-subarray serial mode (calibrated so RC-InterSA = 4.33 exactly).
    * ``e_col_channel`` — extra channel+I/O energy per 64 B transfer for
      memcpy: 128 lines out + 128 lines back = 256 channel transfers;
      (6.2 - 4.33) / 256 ~= 14.3 pJ/bit, in line with DDR3 I/O energy.
    * ``e_risc_base`` / ``e_rbm_hop`` — LISA-RISC energy: 0.09 at 1 hop,
      +0.08/14 per extra hop (Table 1: 0.09 / 0.12 / 0.17 at 1/7/15 hops).
    """

    e_act_pre: float = 0.03
    e_col_internal: float = (2.08 - 0.12) / 256.0
    e_intersa_extra: float = 4.33 - (0.12 + 512 * (2.08 - 0.12) / 256.0)
    e_col_channel: float = (6.2 - 4.33) / 256.0
    e_risc_base: float = 0.09
    e_rbm_hop: float = 0.08 / 14.0


DDR3 = DDR3Timing()
LISA = LISATiming()
ENERGY = EnergyModel()

# DDR4-2400 x64 channel, for the bandwidth-ratio claim (Sec. 2).
CHANNEL_BW_GBPS = 19.2
RBM_BW_GBPS = ROW_BYTES / LISA.t_rbm_row    # bytes/ns == GB/s -> 500.0


# ---------------------------------------------------------------------------
# Copy-mechanism latency / energy (8 KB row copy), Table 1.
# ---------------------------------------------------------------------------

def latency_rc_intra_sa(t: DDR3Timing = DDR3) -> float:
    """RowClone FPM: ACT(src) tRAS -> ACT(dst) tRAS -> PRE.  = 83.75 ns."""
    return t.tRAS + t.tRAS + t.tRP


def latency_rc_bank(t: DDR3Timing = DDR3) -> float:
    """RowClone PSM across banks: ACT, first-read tCL, 128 pipelined col ops,
    trailing burst, write recovery, PRE.  = 701.25 ns."""
    return t.tRCD + t.tCL + LINES_PER_ROW * t.tCCD + t.tBURST + t.tWR + t.tRP


def latency_rc_inter_sa(t: DDR3Timing = DDR3) -> float:
    """RowClone PSM within a bank: ACT(src) tRAS, 128 RD + 128 WR serialized
    over the internal bus (no read/write overlap within one bank),
    ACT/restore(dst) tRAS, PRE.  = 1363.75 ns."""
    return 2 * LINES_PER_ROW * t.tCCD + t.tRAS + t.tRAS + t.tRP


def latency_memcpy(t: DDR3Timing = DDR3) -> float:
    """memcpy over the channel: read phase + write phase.  The paper's Fig. 2
    shows memcpy ~= RC-InterSA; our command model gives 1393.75 ns (within
    2.2% of RC-InterSA), Table 1 leaves the cell blank."""
    read_phase = t.tRCD + t.tCL + LINES_PER_ROW * t.tCCD + t.tBURST + t.tRTP + t.tRP
    write_phase = t.tRCD + t.tCWL + LINES_PER_ROW * t.tCCD + t.tBURST + t.tWR + t.tRP
    return read_phase + write_phase


def latency_lisa_risc(hops: int, t: DDR3Timing = DDR3, l: LISATiming = LISA) -> float:
    """LISA-RISC: ACT(src) -> RBM x hops -> ACT(dst) -> PRE.
    = 140.5 + 8*hops ns  (148.5 / 196.5 / 260.5 at 1 / 7 / 15 hops)."""
    if hops < 1:
        raise ValueError("LISA-RISC requires at least one hop (adjacent subarrays)")
    return l.risc_base(t) + l.t_rbm_hop * hops


def energy_rc_intra_sa(e: EnergyModel = ENERGY) -> float:
    return 2 * e.e_act_pre                                    # 0.06


def energy_rc_bank(e: EnergyModel = ENERGY) -> float:
    return 4 * e.e_act_pre + 2 * LINES_PER_ROW * e.e_col_internal   # 2.08


def energy_rc_inter_sa(e: EnergyModel = ENERGY) -> float:
    return (4 * e.e_act_pre + 4 * LINES_PER_ROW * e.e_col_internal
            + e.e_intersa_extra)                              # 4.33


def energy_memcpy(e: EnergyModel = ENERGY) -> float:
    # 128 lines read over the channel + 128 written back = 256 transfers.
    return energy_rc_inter_sa(e) + 2 * LINES_PER_ROW * e.e_col_channel   # 6.2


def energy_lisa_risc(hops: int, e: EnergyModel = ENERGY) -> float:
    """0.09 at one hop, + 0.08/14 uJ per extra hop (0.09/0.12/0.17)."""
    if hops < 1:
        raise ValueError("LISA-RISC requires at least one hop")
    return e.e_risc_base + (hops - 1) * e.e_rbm_hop


def table1() -> Dict[str, Tuple[float, float]]:
    """Reproduce Table 1: mechanism -> (latency ns, DRAM energy uJ)."""
    return {
        "memcpy": (latency_memcpy(), energy_memcpy()),
        "RC-InterSA": (latency_rc_inter_sa(), energy_rc_inter_sa()),
        "RC-Bank": (latency_rc_bank(), energy_rc_bank()),
        "RC-IntraSA": (latency_rc_intra_sa(), energy_rc_intra_sa()),
        "LISA-RISC-1": (latency_lisa_risc(1), energy_lisa_risc(1)),
        "LISA-RISC-7": (latency_lisa_risc(7), energy_lisa_risc(7)),
        "LISA-RISC-15": (latency_lisa_risc(15), energy_lisa_risc(15)),
    }


def precharge_latency(linked: bool, l: LISATiming = LISA) -> float:
    """LISA-LIP: linked precharge 13 ns -> 5 ns (2.6x)."""
    return l.t_pre_linked if linked else l.t_pre_baseline
