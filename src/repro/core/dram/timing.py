"""DEPRECATED back-compat shim over :mod:`repro.core.dram.spec`.

Importing this module emits a :class:`DeprecationWarning`: the device model
lives in ``spec.DramSpec`` (preset registry — ``DDR3_1600`` calibrated to
Table 1 — plus a ``CopyMechanism`` registry); every repo module takes a
``DramSpec``.  This shim only keeps the historical names importable for
external/REPL users and will be removed once nothing imports it.

Units: nanoseconds (ns) and microjoules (uJ) throughout.
"""
from __future__ import annotations

import warnings
from typing import Dict, Tuple

from repro.core.dram.spec import (  # noqa: F401  (re-exports)
    DDR3_1600,
    DramSpec,
    DramTiming,
    EnergyModel,
    LisaTiming,
    get_mechanism,
    get_preset,
)

warnings.warn(
    "repro.core.dram.timing is deprecated: import DramSpec presets and the "
    "CopyMechanism registry from repro.core.dram.spec instead",
    DeprecationWarning, stacklevel=2)

# Legacy class names / constants / singletons, all from the default preset.
DDR3Timing, LISATiming = DramTiming, LisaTiming
CACHE_LINE_BYTES = DDR3_1600.cache_line_bytes
ROW_BYTES = DDR3_1600.row_bytes
LINES_PER_ROW = DDR3_1600.lines_per_row
CHANNEL_BW_GBPS = DDR3_1600.channel_bw_gbps
RBM_BW_GBPS = DDR3_1600.rbm_bw_gbps
DDR3, LISA, ENERGY = DDR3_1600.timing, DDR3_1600.lisa, DDR3_1600.energy


def _alias(mechanism: str, kind: str):
    def fn(spec: DramSpec = DDR3_1600) -> float:
        return getattr(spec, f"copy_{kind}")(mechanism)
    fn.__name__ = f"{kind}_{mechanism}"
    fn.__doc__ = f"Deprecated alias for ``spec.copy_{kind}({mechanism!r})``."
    return fn


latency_rc_intra_sa = _alias("rc_intrasa", "latency")
latency_rc_bank = _alias("rc_bank", "latency")
latency_rc_inter_sa = _alias("rc_intersa", "latency")
latency_memcpy = _alias("memcpy", "latency")
energy_rc_intra_sa = _alias("rc_intrasa", "energy")
energy_rc_bank = _alias("rc_bank", "energy")
energy_rc_inter_sa = _alias("rc_intersa", "energy")
energy_memcpy = _alias("memcpy", "energy")


def latency_lisa_risc(hops: int, spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_latency("lisa", hops)


def energy_lisa_risc(hops: int, spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_energy("lisa", hops)


def table1() -> Dict[str, Tuple[float, float]]:
    """Reproduce Table 1 under the default (calibrated) preset."""
    return DDR3_1600.table1()


def precharge_latency(linked: bool, spec: DramSpec = DDR3_1600) -> float:
    """LISA-LIP: linked precharge 13 ns -> 5 ns (2.6x)."""
    return spec.precharge_latency(linked)
