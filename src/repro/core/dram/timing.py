"""Back-compat shim over :mod:`repro.core.dram.spec` (the `DramSpec` API).

Historically this module *was* the device model: it exported `DDR3` / `LISA` /
`ENERGY` singletons plus free functions that every other layer read directly.
That hardwired one device and forced string dispatch; the model now lives in
``spec.DramSpec`` with a preset registry (``DDR3_1600`` calibrated to Table 1,
plus DDR4/LPDDR presets) and a ``CopyMechanism`` registry.

This shim keeps the old names importable.  The singletons below are retained
for interactive use only — **no repo module may read them**; every consumer
takes a ``DramSpec``.  ``table1()`` stays as the canonical thin wrapper over
the default preset and still reproduces the paper's exact numbers.

Units: nanoseconds (ns) and microjoules (uJ) throughout.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.dram.spec import (  # noqa: F401  (re-exports)
    DDR3_1600,
    DramSpec,
    DramTiming,
    EnergyModel,
    LisaTiming,
    get_mechanism,
    get_preset,
)

# Legacy class names.
DDR3Timing = DramTiming
LISATiming = LisaTiming

# Legacy constants, all derived from the default preset.
CACHE_LINE_BYTES = DDR3_1600.cache_line_bytes
ROW_BYTES = DDR3_1600.row_bytes
LINES_PER_ROW = DDR3_1600.lines_per_row
CHANNEL_BW_GBPS = DDR3_1600.channel_bw_gbps
RBM_BW_GBPS = DDR3_1600.rbm_bw_gbps

# Legacy singletons — kept importable for back-compat/REPL use only; no
# module in this repo reads them (consumers take a DramSpec).
DDR3 = DDR3_1600.timing
LISA = DDR3_1600.lisa
ENERGY = DDR3_1600.energy


def latency_rc_intra_sa(spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_latency("rc_intrasa")


def latency_rc_bank(spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_latency("rc_bank")


def latency_rc_inter_sa(spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_latency("rc_intersa")


def latency_memcpy(spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_latency("memcpy")


def latency_lisa_risc(hops: int, spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_latency("lisa", hops)


def energy_rc_intra_sa(spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_energy("rc_intrasa")


def energy_rc_bank(spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_energy("rc_bank")


def energy_rc_inter_sa(spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_energy("rc_intersa")


def energy_memcpy(spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_energy("memcpy")


def energy_lisa_risc(hops: int, spec: DramSpec = DDR3_1600) -> float:
    return spec.copy_energy("lisa", hops)


def table1() -> Dict[str, Tuple[float, float]]:
    """Reproduce Table 1 under the default (calibrated) preset."""
    return DDR3_1600.table1()


def precharge_latency(linked: bool, spec: DramSpec = DDR3_1600) -> float:
    """LISA-LIP: linked precharge 13 ns -> 5 ns (2.6x)."""
    return spec.precharge_latency(linked)
