"""Bank-level contention under the virtual clock: refresher, per-bank
state machines, and a request multiplexer.

This is the LASMIcon decomposition (misoc, SNIPPETS.md) ported onto
:class:`~repro.core.dram.spec.DramSpec`'s Table-1 timings — the missing
piece ROADMAP calls "bank-level realism":

  * :class:`Refresher` — issues an all-bank refresh every ``tREFI`` and
    blocks the whole rank for ``tRFC``.  Refresh windows are a pure
    function of absolute (virtual) time, window ``k`` occupying
    ``[k*tREFI, k*tREFI + tRFC)`` for ``k >= 1`` — so idle fast-forwards
    cannot "skip" a pending refresh: any command issued inside a window is
    pushed to its end, no matter how the clock got there.
  * :class:`BankMachine` — one bank's row-state machine: row-open/closed
    tracking with ``tRCD`` activation, ``tRP`` precharge and the ``tRAS``
    restoration window an open row must honor before it may close.
  * :class:`RequestMultiplexer` — maps each priced request (a
    ``MovementPlan`` leg's service time, a decode tick) onto a bank at a
    ready time and grants it a ``(start, end)`` occupancy: requests on
    *distinct* banks overlap (subarray/bank-level parallelism), requests
    on the *same* bank serialize exactly, and every start is pushed out of
    refresh windows.

Everything here runs on the scheduler's deterministic virtual clock
(modeled ns): no wall-clock reads, no RNG — repro-lint's
``wallclock-in-virtual-clock`` rule covers this module for exactly that
reason.  Contention never changes *pricing*: a ``MovementCost`` stays the
isolated Table-1 bill; the multiplexer only decides *when* that bill's
service window lands (``movement.contend`` pairs the two).

See DESIGN.md Sec. 15 for the paper mapping and a worked two-route
migration-wave example.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.dram.spec import DramSpec, DramTiming


class Refresher:
    """All-bank refresh on a fixed cadence: window ``k`` (``k >= 1``)
    occupies ``[k*tREFI, k*tREFI + tRFC)`` on the virtual clock.

    Windows are derived from absolute time, never from mutable state — a
    scheduler that fast-forwards its clock across three windows still sees
    the fourth one block, and two schedulers that reach the same virtual
    time agree on every past and future window (determinism the BENCH
    gates rely on).
    """

    def __init__(self, tREFI: float, tRFC: float):
        if not 0.0 < tRFC < tREFI:
            raise ValueError(f"need 0 < tRFC ({tRFC}) < tREFI ({tREFI})")
        self.tREFI = float(tREFI)
        self.tRFC = float(tRFC)

    def window(self, k: int) -> Tuple[float, float]:
        """The ``k``-th refresh window ``[start, end)`` (``k >= 1``)."""
        if k < 1:
            raise ValueError(f"refresh windows are 1-indexed, got {k}")
        return k * self.tREFI, k * self.tREFI + self.tRFC

    def window_at(self, t_ns: float) -> Optional[int]:
        """Index of the refresh window covering ``t_ns``, else None."""
        k = int(math.floor(t_ns / self.tREFI))
        if k >= 1 and t_ns < k * self.tREFI + self.tRFC:
            return k
        return None

    def next_free(self, t_ns: float) -> float:
        """Earliest time ``>= t_ns`` outside every refresh window — where
        a command landing at ``t_ns`` may actually issue."""
        k = self.window_at(t_ns)
        return t_ns if k is None else k * self.tREFI + self.tRFC

    def refreshes_before(self, t_ns: float) -> int:
        """How many refresh windows have *started* by ``t_ns`` — the
        count a fast-forwarded clock must still account for."""
        return max(0, int(math.floor(t_ns / self.tREFI)))

    def stall_ns(self, t_ns: float) -> float:
        return self.next_free(t_ns) - t_ns


@dataclasses.dataclass
class BankMachine:
    """One bank's state machine: the open row, when it was activated, and
    when the bank's current occupancy window ends.

    ``accept`` grants a request its service window: wait for the bank to
    free (``busy_until``), pay the row transition (``tRP`` precharge after
    the ``tRAS`` restoration window, ``tRCD`` activate) when the request
    names a row the bank does not have open, and never start inside a
    refresh window.  Deliberately *open-page*: the row stays open after
    service, so back-to-back requests to the same row are row hits.
    """

    timing: DramTiming
    refresher: Refresher
    busy_until: float = 0.0
    open_row: Optional[int] = None
    act_at: float = -math.inf       # when the open row was activated
    n_requests: int = 0
    n_row_hits: int = 0
    n_row_misses: int = 0
    queue_stall_ns: float = 0.0     # waited behind same-bank work
    refresh_stall_ns: float = 0.0   # pushed out of a refresh window

    def accept(self, t_ready: float, service_ns: float,
               row: Optional[int] = None) -> Tuple[float, float]:
        """Grant one request: returns its ``(start, end)`` occupancy."""
        if service_ns < 0:
            raise ValueError(f"negative service time {service_ns}")
        t = max(t_ready, self.busy_until)
        overhead = 0.0
        if row is not None:
            if self.open_row == row:
                self.n_row_hits += 1
            else:
                self.n_row_misses += 1
                if self.open_row is not None:
                    # the open row must sit tRAS past its ACT before the
                    # precharge that closes it may issue
                    t = max(t, self.act_at + self.timing.tRAS)
                    overhead += self.timing.tRP
                overhead += self.timing.tRCD
        # an all-bank refresh blocks the start; a request already in
        # service runs to completion (the JEDEC pull-in/postpone slack)
        start = self.refresher.next_free(t)
        self.queue_stall_ns += t - t_ready
        self.refresh_stall_ns += start - t
        if row is not None and self.open_row != row:
            self.act_at = start + overhead - self.timing.tRCD
            self.open_row = row
        end = start + overhead + service_ns
        self.busy_until = end
        self.n_requests += 1
        return start, end


class RequestMultiplexer:
    """The arbiter between priced requests and bank/refresh resources.

    One multiplexer serves one scheduler: every movement-wave member and
    every decode tick submits ``(bank, ready, service_ns)`` and receives
    the ``(start, end)`` window the model grants.  With ``enabled=False``
    the multiplexer is a pure pass-through — ``(ready, ready+service)``,
    today's isolated pricing, bit-identical — so contention is an A/B arm,
    not a fork of the scheduler.

    Stall accounting (all in modeled ns, summed across requests):
      * ``queue_stall_ns``   — time spent behind an earlier request or a
        row transition on the same bank;
      * ``refresh_stall_ns`` — time pushed out of refresh windows.
    """

    def __init__(self, spec: Union[DramSpec, DramTiming], *,
                 n_banks: int = 8, enabled: bool = True):
        timing = spec.timing if isinstance(spec, DramSpec) else spec
        if n_banks < 1:
            raise ValueError(f"n_banks must be >= 1, got {n_banks}")
        self.timing = timing
        self.n_banks = int(n_banks)
        self.enabled = bool(enabled)
        self.refresher = Refresher(timing.tREFI, timing.tRFC)
        self.banks: List[BankMachine] = [
            BankMachine(timing, self.refresher) for _ in range(self.n_banks)]
        self.stats: Dict[str, float] = {
            "n_requests": 0, "queue_stall_ns": 0.0,
            "refresh_stall_ns": 0.0, "decode_refresh_stall_ns": 0.0,
            "n_decode_stalls": 0}

    # ---- routing -----------------------------------------------------------
    def bank_of(self, uid: int) -> int:
        """Deterministic session-to-bank map: a session's pages live in one
        bank for its whole life (uid mod n_banks)."""
        return int(uid) % self.n_banks

    # ---- the multiplexer ---------------------------------------------------
    def submit(self, bank: int, t_ready: float, service_ns: float,
               row: Optional[int] = None) -> Tuple[float, float]:
        """Grant one request its ``(start, end)`` service window.

        Disabled: ``(t_ready, t_ready + service_ns)`` — the isolated cost,
        untouched.  Enabled: the bank machine serializes same-bank
        requests, charges row transitions, and the refresher pushes starts
        out of refresh windows; disjoint banks overlap freely.
        """
        if not self.enabled:
            return t_ready, t_ready + service_ns
        if not 0 <= bank < self.n_banks:
            raise ValueError(f"bank {bank} out of range [0, {self.n_banks})")
        b = self.banks[bank]
        q0, r0 = b.queue_stall_ns, b.refresh_stall_ns
        start, end = b.accept(t_ready, service_ns, row)
        self.stats["n_requests"] += 1
        self.stats["queue_stall_ns"] += b.queue_stall_ns - q0
        self.stats["refresh_stall_ns"] += b.refresh_stall_ns - r0
        return start, end

    def wave(self, items: Sequence[Tuple[int, float]],
             t_ready: float) -> float:
        """Submit one fused wave — ``(bank, service_ns)`` per member, all
        ready at ``t_ready`` — and return its completion time.  Members on
        distinct banks overlap; same-bank members serialize in submission
        order (deterministic: callers submit in wave order)."""
        end = t_ready
        for bank, service_ns in items:
            _, e = self.submit(bank, t_ready, service_ns)
            end = max(end, e)
        return end

    def decode_gate(self, t_ns: float) -> float:
        """Earliest time ``>= t_ns`` a decode tick may issue: an all-bank
        refresh blocks every bank, so a tick landing inside ``tRFC`` waits
        for the window to close.  Returns the (possibly pushed) start."""
        if not self.enabled:
            return t_ns
        start = self.refresher.next_free(t_ns)
        if start > t_ns:
            self.stats["decode_refresh_stall_ns"] += start - t_ns
            self.stats["n_decode_stalls"] += 1
        return start

    # ---- introspection -----------------------------------------------------
    def refreshes_before(self, t_ns: float) -> int:
        return self.refresher.refreshes_before(t_ns)

    def snapshot(self) -> Dict[str, float]:
        """Stall counters plus per-bank activity, JSON-ready (the bench
        artifact's contention block)."""
        out = {k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in self.stats.items()}
        out["n_banks"] = self.n_banks
        out["enabled"] = self.enabled
        out["per_bank_requests"] = [b.n_requests for b in self.banks]
        out["row_hits"] = sum(b.n_row_hits for b in self.banks)
        out["row_misses"] = sum(b.n_row_misses for b in self.banks)
        return out
