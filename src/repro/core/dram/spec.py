"""`DramSpec` — the single device-model API for the LISA reproduction.

Everything the substrate, controller, traces, benchmarks, and the TPU-side
analogy need to know about a DRAM device lives in one immutable value:

  * geometry      — ``n_subarrays`` / ``rows_per_subarray`` / ``row_bytes``
                    (+ ``cache_line_bytes``), used by ``substrate.make_bank``
                    and ``traces.generate``;
  * timing        — JEDEC-style command timings (``DramTiming``) plus the
                    LISA SPICE-derived constants (``LisaTiming``);
  * energy        — the calibrated per-component model (``EnergyModel``);
  * channel       — off-chip channel bandwidth, for the Sec. 2 ratio claim.

Copy mechanisms (memcpy / RowClone variants / LISA-RISC) are *objects* in a
registry, not string ``if/elif`` chains.  Each ``CopyMechanism`` exposes its
cost as a hop-linear model ``cost(h) = base + per_hop * max(h, 1)`` —
coefficients that lower to **traced data**: ``controller.mechanism_params``
feeds them to the single jitted ``simulate`` (no recompiling per mechanism
via ``static_argnums``), and ``mechanism_table`` offers the same lowering as
one dense ``(n_mechanisms, 5)`` array for sweeps indexed by ``mech_id``.

``DDR3_1600`` is the calibrated default: its ``table1()`` reproduces the
paper's Table 1 exactly (148.5 / 196.5 / 260.5 ns and 0.09 / 0.12 / 0.17 uJ
for LISA-RISC-1/7/15; 1363.75 ns / 4.33 uJ for RC-InterSA).  Other presets
(``DDR4_2400``, ``LPDDR4_3200``) carry the same LISA/energy calibration over
plausible interface timings for geometry/timing sensitivity sweeps; the
DRAM<->TPU analogy is made literal by ``core.lisa.topology.ici_dram_spec``,
which expresses the ICI mesh as just another ``DramSpec`` instance.

Units: nanoseconds (ns) and microjoules (uJ) throughout.  See DESIGN.md
Sec. 5 for the modeling assumptions and Sec. 6 for this API.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Component models.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DramTiming:
    """JEDEC-style command timings, in ns (defaults: DDR3-1600 11-11-11)."""

    tCK: float = 1.25
    tRCD: float = 13.75     # ACT -> column command
    tRP: float = 13.75      # PRE -> ACT (baseline precharge latency)
    tRAS: float = 35.0      # ACT -> PRE (restoration complete)
    tCL: float = 13.75      # column read latency
    tCWL: float = 12.5      # column write latency (CWL=10)
    tCCD: float = 5.0       # column-to-column, 4 cycles
    tBURST: float = 5.0     # 8-beat burst, 4 cycles
    tWR: float = 15.0       # write recovery
    tRTP: float = 7.5       # read -> precharge
    tREFI: float = 7800.0   # average refresh interval (64 ms / 8192 rows)
    tRFC: float = 260.0     # all-bank refresh cycle time (4 Gb density)

    @property
    def tRC(self) -> float:
        return self.tRAS + self.tRP

    def __post_init__(self):
        if not 0.0 < self.tRFC < self.tREFI:
            raise ValueError(
                f"tRFC ({self.tRFC}) must be positive and shorter than "
                f"tREFI ({self.tREFI}) — the device must spend most of its "
                f"time NOT refreshing")


@dataclasses.dataclass(frozen=True)
class LisaTiming:
    """LISA-specific timings from the paper's SPICE evaluation.

    * ``t_rbm_hop`` — per-hop increment of a LISA-RISC copy.  Table 1:
      (260.5 - 148.5) / 14 hops = 8 ns/hop exactly.
    * ``t_rbm_row`` — time for one RBM row-buffer movement used for the
      bandwidth claim: 8 KB / 500 GB/s = 16.384 ns (includes the paper's
      conservative 60% margin).
    * ``sense_margin`` — hop-independent part of LISA-RISC beyond
      ACT/ACT/PRE.  Back-solved: 148.5 - 8 = 140.5;
      margin = 140.5 - (35+35+13.75) = 56.75.
    * ``t_pre_linked`` — LISA-LIP precharge: 13 ns -> 5 ns (2.6x, Sec. 3.3).
    """

    t_rbm_hop: float = 8.0
    t_rbm_row: float = 16.384
    sense_margin: float = 56.75
    t_pre_baseline: float = 13.0
    t_pre_linked: float = 5.0

    def risc_base(self, t: DramTiming) -> float:
        """Hop-independent LISA-RISC latency: ACT(src) + ACT(dst) + PRE."""
        return t.tRAS + t.tRAS + t.tRP + self.sense_margin


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Component energy model (uJ), back-solved from Table 1 anchors.

    * ``e_act_pre`` — one ACT(+share of PRE) row operation.  RC-IntraSA does
      ACT->ACT->PRE and costs 0.06 uJ  =>  0.03 per row op (2 row ops).
    * ``e_col_internal`` — one 64 B column transfer over the internal bus.
      RC-Bank = 4 row ops + 256 col ops = 2.08  =>  (2.08-0.12)/256.
    * ``e_intersa_extra`` — extra global-bus/driver energy of RowClone
      inter-subarray serial mode (calibrated so RC-InterSA = 4.33 exactly).
    * ``e_col_channel`` — extra channel+I/O energy per 64 B transfer for
      memcpy: 128 lines out + 128 lines back = 256 channel transfers;
      (6.2 - 4.33) / 256 ~= 14.3 pJ/bit, in line with DDR3 I/O energy.
    * ``e_risc_base`` / ``e_rbm_hop`` — LISA-RISC energy: 0.09 at 1 hop,
      +0.08/14 per extra hop (Table 1: 0.09 / 0.12 / 0.17 at 1/7/15 hops).
    """

    e_act_pre: float = 0.03
    e_col_internal: float = (2.08 - 0.12) / 256.0
    e_intersa_extra: float = 4.33 - (0.12 + 512 * (2.08 - 0.12) / 256.0)
    e_col_channel: float = (6.2 - 4.33) / 256.0
    e_risc_base: float = 0.09
    e_rbm_hop: float = 0.08 / 14.0


# ---------------------------------------------------------------------------
# The device model.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DramSpec:
    """One DRAM device: geometry + timing/energy preset + channel.

    Immutable and hashable, so a spec can be a jit static argument; all
    *swept* quantities are lowered to traced data via ``mechanism_table`` /
    ``controller.mechanism_params`` instead.
    """

    name: str = "DDR3_1600"
    n_subarrays: int = 16
    rows_per_subarray: int = 64
    row_bytes: int = 8192                 # 8 KB DRAM row (rank-level)
    cache_line_bytes: int = 64
    timing: DramTiming = dataclasses.field(default_factory=DramTiming)
    lisa: LisaTiming = dataclasses.field(default_factory=LisaTiming)
    energy: EnergyModel = dataclasses.field(default_factory=EnergyModel)
    channel_bw_gbps: float = 19.2         # DDR4-2400 x64 channel (Sec. 2)

    # ---- geometry ----------------------------------------------------------
    @property
    def lines_per_row(self) -> int:
        return self.row_bytes // self.cache_line_bytes

    @property
    def n_rows(self) -> int:
        """Rows per bank (across all subarrays)."""
        return self.n_subarrays * self.rows_per_subarray

    @property
    def rbm_bw_gbps(self) -> float:
        """RBM bandwidth: bytes/ns == GB/s (500.0 for the default preset)."""
        return self.row_bytes / self.lisa.t_rbm_row

    def with_geometry(self, n_subarrays: int | None = None,
                      rows_per_subarray: int | None = None,
                      row_bytes: int | None = None) -> "DramSpec":
        """A copy of this spec with some geometry fields replaced."""
        return dataclasses.replace(
            self,
            n_subarrays=n_subarrays or self.n_subarrays,
            rows_per_subarray=rows_per_subarray or self.rows_per_subarray,
            row_bytes=row_bytes or self.row_bytes,
        )

    # ---- copy-mechanism costs ---------------------------------------------
    def copy_latency(self, mechanism: str, hops: int = 1) -> float:
        return get_mechanism(mechanism).latency(self, hops)

    def copy_energy(self, mechanism: str, hops: int = 1) -> float:
        return get_mechanism(mechanism).energy(self, hops)

    def copy_cost(self, mechanism: str, hops: int = 1
                  ) -> Tuple[float, float, bool]:
        """(latency ns, energy uJ, occupies_channel) for one row copy."""
        m = get_mechanism(mechanism)
        return m.latency(self, hops), m.energy(self, hops), m.occupies_channel

    def mechanism_table(self) -> np.ndarray:
        """Dense ``(n_mechanisms, 5)`` float32 coefficient table, row ``i`` =
        ``(lat_base, lat_per_hop, e_base, e_per_hop, occupies_channel)`` for
        the mechanism with ``mech_id == i``; ``cost(h) = base + per_hop *
        max(h, 1)``.  The same lowering ``controller.mechanism_params``
        applies per config, as one dense array for mechanism-indexed
        sweeps."""
        rows = [m.coefficients(self) for m in mechanisms()]
        return np.asarray(rows, np.float32)

    def precharge_latency(self, linked: bool) -> float:
        """LISA-LIP: linked precharge 13 ns -> 5 ns (2.6x, Sec. 3.3)."""
        return self.lisa.t_pre_linked if linked else self.lisa.t_pre_baseline

    def table1(self) -> Dict[str, Tuple[float, float]]:
        """Table 1 rows: display name -> (latency ns, DRAM energy uJ)."""
        return {
            "memcpy": (self.copy_latency("memcpy"),
                       self.copy_energy("memcpy")),
            "RC-InterSA": (self.copy_latency("rc_intersa"),
                           self.copy_energy("rc_intersa")),
            "RC-Bank": (self.copy_latency("rc_bank"),
                        self.copy_energy("rc_bank")),
            "RC-IntraSA": (self.copy_latency("rc_intrasa"),
                           self.copy_energy("rc_intrasa")),
            "LISA-RISC-1": (self.copy_latency("lisa", 1),
                            self.copy_energy("lisa", 1)),
            "LISA-RISC-7": (self.copy_latency("lisa", 7),
                            self.copy_energy("lisa", 7)),
            "LISA-RISC-15": (self.copy_latency("lisa", 15),
                             self.copy_energy("lisa", 15)),
        }


# ---------------------------------------------------------------------------
# Copy-mechanism registry (replaces the string if/elif chains).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CopyMechanism:
    """One bulk row-copy mechanism, cost = ``base + per_hop * max(h, 1)``.

    ``hop_dependent`` mechanisms (LISA-RISC) require ``hops >= 1`` and scale
    with subarray distance; the others are flat and ignore ``hops`` beyond
    the clamp.  ``occupies_channel`` is the bank-level-parallelism property
    of Sec. 3.1: memcpy owns the off-chip channel for its whole duration,
    in-DRAM mechanisms leave it free (RC-Bank moves over the shared internal
    bus, also off-channel).
    """

    name: str
    mech_id: int
    occupies_channel: bool
    hop_dependent: bool
    lat_base: Callable[[DramSpec], float]
    lat_per_hop: Callable[[DramSpec], float]
    e_base: Callable[[DramSpec], float]
    e_per_hop: Callable[[DramSpec], float]
    description: str = ""

    def _check(self, hops: int) -> int:
        if self.hop_dependent and hops < 1:
            raise ValueError(
                f"{self.name} requires at least one hop (adjacent subarrays)")
        return max(int(hops), 1)

    def latency(self, spec: DramSpec, hops: int = 1) -> float:
        return self.lat_base(spec) + self.lat_per_hop(spec) * self._check(hops)

    def energy(self, spec: DramSpec, hops: int = 1) -> float:
        return self.e_base(spec) + self.e_per_hop(spec) * self._check(hops)

    def coefficients(self, spec: DramSpec) -> Tuple[float, float, float, float, float]:
        return (self.lat_base(spec), self.lat_per_hop(spec),
                self.e_base(spec), self.e_per_hop(spec),
                float(self.occupies_channel))


_MECHANISMS: Dict[str, CopyMechanism] = {}


def register_mechanism(mech: CopyMechanism) -> CopyMechanism:
    if mech.name in _MECHANISMS:
        raise ValueError(f"copy mechanism {mech.name!r} already registered")
    ids = {m.mech_id for m in _MECHANISMS.values()}
    if mech.mech_id in ids:
        raise ValueError(f"mech_id {mech.mech_id} already taken")
    _MECHANISMS[mech.name] = mech
    return mech


def get_mechanism(name: str) -> CopyMechanism:
    try:
        return _MECHANISMS[name]
    except KeyError:
        raise ValueError(f"unknown copy mechanism: {name!r} "
                         f"(known: {sorted(_MECHANISMS)})") from None


def mechanism_id(name: str) -> int:
    return get_mechanism(name).mech_id


def mechanisms() -> Tuple[CopyMechanism, ...]:
    """All registered mechanisms, ordered by ``mech_id`` (table row order)."""
    return tuple(sorted(_MECHANISMS.values(), key=lambda m: m.mech_id))


def mechanism_names() -> Tuple[str, ...]:
    return tuple(m.name for m in mechanisms())


# ---- closed-form cost components (Table 1 decompositions) ------------------

def _lat_memcpy(s: DramSpec) -> float:
    """memcpy over the channel: read phase + write phase.  The paper's Fig. 2
    shows memcpy ~= RC-InterSA; the command model gives 1393.75 ns (within
    2.2% of RC-InterSA); Table 1 leaves the cell blank."""
    t = s.timing
    read_phase = (t.tRCD + t.tCL + s.lines_per_row * t.tCCD + t.tBURST
                  + t.tRTP + t.tRP)
    write_phase = (t.tRCD + t.tCWL + s.lines_per_row * t.tCCD + t.tBURST
                   + t.tWR + t.tRP)
    return read_phase + write_phase


def _lat_rc_intersa(s: DramSpec) -> float:
    """RowClone PSM within a bank: 128 RD + 128 WR serialized over the
    internal bus, plus ACT(src)/ACT(dst)/PRE.  = 1363.75 ns."""
    t = s.timing
    return 2 * s.lines_per_row * t.tCCD + t.tRAS + t.tRAS + t.tRP


def _lat_rc_bank(s: DramSpec) -> float:
    """RowClone PSM across banks: ACT, first-read tCL, pipelined col ops,
    trailing burst, write recovery, PRE.  = 701.25 ns."""
    t = s.timing
    return (t.tRCD + t.tCL + s.lines_per_row * t.tCCD + t.tBURST + t.tWR
            + t.tRP)


def _lat_rc_intrasa(s: DramSpec) -> float:
    """RowClone FPM: ACT(src) tRAS -> ACT(dst) tRAS -> PRE.  = 83.75 ns."""
    t = s.timing
    return t.tRAS + t.tRAS + t.tRP


def _e_memcpy(s: DramSpec) -> float:
    # 128 lines read over the channel + 128 written back = 256 transfers.
    return _e_rc_intersa(s) + 2 * s.lines_per_row * s.energy.e_col_channel


def _e_rc_intersa(s: DramSpec) -> float:
    return (4 * s.energy.e_act_pre
            + 4 * s.lines_per_row * s.energy.e_col_internal
            + s.energy.e_intersa_extra)                       # 4.33


def _e_rc_bank(s: DramSpec) -> float:
    return (4 * s.energy.e_act_pre
            + 2 * s.lines_per_row * s.energy.e_col_internal)  # 2.08


def _zero(s: DramSpec) -> float:
    return 0.0


register_mechanism(CopyMechanism(
    name="memcpy", mech_id=0, occupies_channel=True, hop_dependent=False,
    lat_base=_lat_memcpy, lat_per_hop=_zero,
    e_base=_e_memcpy, e_per_hop=_zero,
    description="CPU copy over the off-chip channel (read + write phases)"))

register_mechanism(CopyMechanism(
    name="rc_intersa", mech_id=1, occupies_channel=False, hop_dependent=False,
    lat_base=_lat_rc_intersa, lat_per_hop=_zero,
    e_base=_e_rc_intersa, e_per_hop=_zero,
    description="RowClone PSM between subarrays over the internal bus"))

register_mechanism(CopyMechanism(
    name="rc_bank", mech_id=2, occupies_channel=False, hop_dependent=False,
    lat_base=_lat_rc_bank, lat_per_hop=_zero,
    e_base=_e_rc_bank, e_per_hop=_zero,
    description="RowClone PSM between banks (pipelined internal-bus copy)"))

register_mechanism(CopyMechanism(
    name="rc_intrasa", mech_id=3, occupies_channel=False, hop_dependent=False,
    lat_base=_lat_rc_intrasa, lat_per_hop=_zero,
    e_base=lambda s: 2 * s.energy.e_act_pre, e_per_hop=_zero,
    description="RowClone FPM within one subarray (back-to-back ACTs)"))

# LISA-RISC energy 0.09 + (h-1)*e_hop rewritten hop-linear:
# e_base' = e_risc_base - e_rbm_hop, so cost(h) = e_base' + e_hop * h.
register_mechanism(CopyMechanism(
    name="lisa", mech_id=4, occupies_channel=False, hop_dependent=True,
    lat_base=lambda s: s.lisa.risc_base(s.timing),
    lat_per_hop=lambda s: s.lisa.t_rbm_hop,
    e_base=lambda s: s.energy.e_risc_base - s.energy.e_rbm_hop,
    e_per_hop=lambda s: s.energy.e_rbm_hop,
    description="LISA-RISC: RBM hop chain between subarrays (Sec. 3.1)"))

# The fork subsystem's pricing anchor (repro/fork, PAPERS.md arXiv
# 1805.03502): an in-subarray page alias costs one RowClone FPM
# (ACT->ACT->PRE, 83.75 ns / 0.06 uJ at hops=1 — identical to rc_intrasa),
# and a cross-subarray materialization grows per hop like a LISA chain
# (same hop-linear rewrite as lisa: base' = base - per_hop, cost(h) =
# base' + per_hop * h).  NOT a Table-1 row: table1() is the paper's fixed
# set; this mechanism exists so plan() can price `fork` transfers.
register_mechanism(CopyMechanism(
    name="rowclone", mech_id=5, occupies_channel=False, hop_dependent=True,
    lat_base=lambda s: _lat_rc_intrasa(s) - s.lisa.t_rbm_hop,
    lat_per_hop=lambda s: s.lisa.t_rbm_hop,
    e_base=lambda s: 2 * s.energy.e_act_pre - s.energy.e_rbm_hop,
    e_per_hop=lambda s: s.energy.e_rbm_hop,
    description="RowClone page alias: FPM in-subarray, LISA-hop "
                "materialization across (fork/CoW pricing)"))


# ---------------------------------------------------------------------------
# Preset registry.
# ---------------------------------------------------------------------------

_PRESETS: Dict[str, DramSpec] = {}


def register_preset(spec: DramSpec, *, overwrite: bool = False) -> DramSpec:
    if not overwrite and spec.name in _PRESETS:
        raise ValueError(f"preset {spec.name!r} already registered")
    _PRESETS[spec.name] = spec
    return spec


def get_preset(name: str) -> DramSpec:
    try:
        return _PRESETS[name]
    except KeyError:
        raise ValueError(f"unknown DRAM preset: {name!r} "
                         f"(known: {sorted(_PRESETS)})") from None


def preset_names() -> Tuple[str, ...]:
    return tuple(sorted(_PRESETS))


#: Calibrated default — reproduces the paper's Table 1 exactly.
DDR3_1600 = register_preset(DramSpec(name="DDR3_1600"))

#: DDR4-2400 (17-17-17): faster column cadence, same LISA/energy calibration
#: (the RBM path is a cell-array property, not an interface property).
DDR4_2400 = register_preset(DramSpec(
    name="DDR4_2400",
    timing=DramTiming(tCK=0.833, tRCD=14.16, tRP=14.16, tRAS=32.0,
                      tCL=14.16, tCWL=10.0, tCCD=3.33, tBURST=3.33,
                      tWR=15.0, tRTP=7.5, tREFI=7800.0, tRFC=350.0),
    channel_bw_gbps=19.2))

#: LPDDR4-3200 x32: slower core timings, narrower channel, deeper banks.
LPDDR4_3200 = register_preset(DramSpec(
    name="LPDDR4_3200",
    n_subarrays=32,
    timing=DramTiming(tCK=0.625, tRCD=18.0, tRP=21.0, tRAS=42.0,
                      tCL=18.0, tCWL=10.0, tCCD=5.0, tBURST=5.0,
                      tWR=18.0, tRTP=7.5, tREFI=3904.0, tRFC=180.0),
    channel_bw_gbps=12.8))
