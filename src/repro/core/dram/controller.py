"""Command-level multi-core memory-controller simulator (pure JAX, lax.scan).

Models the system-level effects the paper evaluates on Ramulator:

  * one channel, N banks; requests gated on bank availability and channel
    occupancy (64 B bursts for reads, full-duration occupancy for memcpy
    copies — LISA/RowClone copies leave the channel free, which is exactly
    the bank-level-parallelism benefit of Sec. 3.1);
  * open-row policy per bank: row hit / row conflict (precharge first, LIP
    shortens it) / closed row;
  * bulk-copy requests dispatched to the configured mechanism
    (memcpy / RC-InterSA / LISA-RISC with real hop distances);
  * optional VILLA fast-subarray cache per bank with the paper's exact policy
    (counters/epochs/benefit replacement), insertions charged to the
    configured copy mechanism (LISA vs RC-InterSA — Fig. 3's comparison).

"Weighted speedup" is reported as in the paper's WS metric [14,93], with each
core's IPC proxied by the reciprocal of its total memory stall time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dram import timing as T
from repro.core.dram import villa as V
from repro.core.dram.traces import Trace, TraceConfig


@dataclasses.dataclass(frozen=True)
class MechanismConfig:
    copy_mech: str = "memcpy"         # memcpy | rc_intersa | lisa
    use_villa: bool = False
    use_lip: bool = False
    villa_copy_mech: str = "lisa"     # lisa | rc_intersa  (Fig. 3 comparison)
    villa: V.VillaConfig = dataclasses.field(default_factory=V.VillaConfig)


class SimState(NamedTuple):
    bank_free: jax.Array     # (banks,) f32
    chan_free: jax.Array     # () f32
    open_row: jax.Array      # (banks,) i32, -1 closed
    fast_open: jax.Array     # (banks,) i32 — open row in the fast subarray
    villa: V.VillaState      # stacked over banks
    core_stall: jax.Array    # (cores,) f32
    energy: jax.Array        # () f32 uJ
    villa_hits: jax.Array    # () i32
    villa_accesses: jax.Array  # () i32


def _copy_cost(mech: str, hops: jax.Array):
    """(latency ns, energy uJ, occupies_channel) for an 8 KB copy."""
    hops = jnp.maximum(hops, 1).astype(jnp.float32)
    if mech == "memcpy":
        return (jnp.float32(T.latency_memcpy()), jnp.float32(T.energy_memcpy()), True)
    if mech == "rc_intersa":
        return (jnp.float32(T.latency_rc_inter_sa()),
                jnp.float32(T.energy_rc_inter_sa()), False)
    if mech == "lisa":
        base = T.LISA.risc_base(T.DDR3)
        lat = base + T.LISA.t_rbm_hop * hops
        ene = T.ENERGY.e_risc_base + (hops - 1.0) * T.ENERGY.e_rbm_hop
        return (lat, ene, False)
    raise ValueError(f"unknown copy mechanism: {mech}")


def simulate(trace: Trace, tcfg: TraceConfig, mcfg: MechanismConfig) -> Dict[str, jax.Array]:
    t = T.DDR3
    tPRE = jnp.float32(T.precharge_latency(mcfg.use_lip))
    lat_hit = jnp.float32(t.tCL)
    lat_closed = jnp.float32(t.tRCD + t.tCL)
    lat_fast_hit = jnp.float32(mcfg.villa.tCL_fast)
    lat_fast_open = jnp.float32(mcfg.villa.tRP_fast + mcfg.villa.tRCD_fast
                                + mcfg.villa.tCL_fast)
    lat_fast_closed = jnp.float32(mcfg.villa.tRCD_fast + mcfg.villa.tCL_fast)

    e_access_miss = jnp.float32(T.ENERGY.e_act_pre + T.ENERGY.e_col_internal
                                + T.ENERGY.e_col_channel)
    e_access_hit = jnp.float32(T.ENERGY.e_col_internal + T.ENERGY.e_col_channel)

    def step(state: SimState, req):
        arrival, core, bank, row, is_copy, dst_row = req
        sa = row // tcfg.rows_per_subarray
        dst_sa = dst_row // tcfg.rows_per_subarray

        t0 = jnp.maximum(arrival, state.bank_free[bank])

        # ---- normal access latency (open-row policy) --------------------
        is_hit = state.open_row[bank] == row
        is_open = state.open_row[bank] >= 0
        lat_conflict = tPRE + lat_closed
        lat_normal = jnp.where(is_hit, lat_hit,
                               jnp.where(is_open, lat_conflict, lat_closed))
        e_normal = jnp.where(is_hit, e_access_hit, e_access_miss)

        # ---- VILLA ------------------------------------------------------
        if mcfg.use_villa:
            vbank = jax.tree.map(lambda x: x[bank], state.villa)
            vbank2, vhit, vinsert, _ = V.villa_access(vbank, row, mcfg.villa)
            new_villa = jax.tree.map(
                lambda full, leaf: full.at[bank].set(leaf), state.villa, vbank2)
            ins_lat, ins_ene, _ = _copy_cost(mcfg.villa_copy_mech,
                                             jnp.maximum(sa, 1))
            # The fast subarray has its own row buffer (it *is* a subarray).
            f_hit = state.fast_open[bank] == row
            f_open = state.fast_open[bank] >= 0
            lat_fast = jnp.where(f_hit, lat_fast_hit,
                                 jnp.where(f_open, lat_fast_open,
                                           lat_fast_closed))
            # An insertion reuses the row buffer the access just activated:
            # the requestor is served at slow latency; the RBM + restore then
            # occupies the *bank* in the background (charged below), not the
            # request's critical path.
            lat_normal = jnp.where(vhit, lat_fast, lat_normal)
            bank_extra = jnp.where(vinsert, ins_lat, 0.0)
            e_normal = jnp.where(vhit, e_access_hit,
                                 e_normal + jnp.where(vinsert, ins_ene, 0.0))
            new_fast_open = jnp.where(vhit | vinsert, row,
                                      state.fast_open[bank]).astype(jnp.int32)
            villa_hits = state.villa_hits + vhit.astype(jnp.int32)
            villa_acc = state.villa_accesses + 1
        else:
            vhit = jnp.zeros((), bool)
            bank_extra = jnp.zeros((), jnp.float32)
            new_villa = state.villa
            new_fast_open = state.fast_open[bank]
            villa_hits, villa_acc = state.villa_hits, state.villa_accesses

        # ---- bulk copy --------------------------------------------------
        hops = jnp.abs(dst_sa - sa)
        copy_lat, copy_ene, copy_on_chan = _copy_cost(mcfg.copy_mech, hops)

        lat = jnp.where(is_copy, copy_lat, lat_normal)
        ene = jnp.where(is_copy, copy_ene, e_normal)

        # ---- channel occupancy ------------------------------------------
        # Normal reads burst 64 B at the end of the access; memcpy copies own
        # the channel for their whole duration; in-DRAM copies never touch it.
        if copy_on_chan:
            chan_start_copy = jnp.maximum(t0, state.chan_free)
            t_end_copy = chan_start_copy + lat
            chan_after_copy = t_end_copy
        else:
            t_end_copy = t0 + lat
            chan_after_copy = state.chan_free

        burst = jnp.maximum(t0 + lat - t.tBURST, state.chan_free)
        t_end_normal = burst + t.tBURST
        chan_after_normal = t_end_normal

        t_end = jnp.where(is_copy, t_end_copy, t_end_normal)
        chan_free = jnp.where(is_copy, chan_after_copy, chan_after_normal)

        # A VILLA fast hit is served by the fast subarray and leaves the slow
        # subarrays' row buffer untouched.
        new_open = jnp.where(is_copy, -1,
                             jnp.where(vhit, state.open_row[bank], row)
                             ).astype(jnp.int32)
        state = SimState(
            bank_free=state.bank_free.at[bank].set(t_end + bank_extra),
            chan_free=chan_free,
            open_row=state.open_row.at[bank].set(new_open),
            fast_open=state.fast_open.at[bank].set(new_fast_open),
            villa=new_villa,
            core_stall=state.core_stall.at[core].add(t_end - arrival),
            energy=state.energy + ene,
            villa_hits=villa_hits,
            villa_accesses=villa_acc,
        )
        return state, t_end - arrival

    villa0 = jax.vmap(lambda _: V.villa_init(mcfg.villa))(jnp.arange(tcfg.n_banks))
    init = SimState(
        bank_free=jnp.zeros((tcfg.n_banks,), jnp.float32),
        chan_free=jnp.zeros((), jnp.float32),
        open_row=jnp.full((tcfg.n_banks,), -1, jnp.int32),
        fast_open=jnp.full((tcfg.n_banks,), -1, jnp.int32),
        villa=villa0,
        core_stall=jnp.zeros((tcfg.n_cores,), jnp.float32),
        energy=jnp.zeros((), jnp.float32),
        villa_hits=jnp.zeros((), jnp.int32),
        villa_accesses=jnp.zeros((), jnp.int32),
    )
    xs = (trace.t, trace.core, trace.bank, trace.row, trace.is_copy, trace.dst_row)
    final, lat_trace = jax.lax.scan(step, init, xs)
    return {
        "core_stall": final.core_stall,
        "energy_uJ": final.energy,
        "avg_latency_ns": lat_trace.mean(),
        "villa_hit_rate": jnp.where(
            final.villa_accesses > 0,
            final.villa_hits / jnp.maximum(final.villa_accesses, 1), 0.0),
    }


def weighted_speedup(base_stall: jax.Array, mech_stall: jax.Array) -> jax.Array:
    """WS proxy: sum over cores of IPC_mech/IPC_base with IPC ~ 1/stall."""
    return (base_stall / jnp.maximum(mech_stall, 1e-3)).mean()


simulate_jit = jax.jit(simulate, static_argnums=(1, 2))
