"""Command-level multi-core memory-controller simulator (pure JAX, lax.scan).

Models the system-level effects the paper evaluates on Ramulator:

  * one channel, N banks; requests gated on bank availability and channel
    occupancy (64 B bursts for reads, full-duration occupancy for memcpy
    copies — LISA/RowClone copies leave the channel free, which is exactly
    the bank-level-parallelism benefit of Sec. 3.1);
  * open-row policy per bank: row hit / row conflict (precharge first, LIP
    shortens it) / closed row;
  * bulk-copy requests dispatched to the configured mechanism
    (memcpy / RC-InterSA / LISA-RISC with real hop distances);
  * optional VILLA fast-subarray cache per bank with the paper's exact policy
    (counters/epochs/benefit replacement), insertions charged to the
    configured copy mechanism (LISA vs RC-InterSA — Fig. 3's comparison).

Mechanism parameters are **traced data** (:class:`MechanismParams` — the
hop-linear cost coefficients from the :class:`~repro.core.dram.spec`
``CopyMechanism`` registry, LIP precharge latency, VILLA on/off and fast-tier
timings), so ONE jitted :func:`simulate_params` serves every copy mechanism
and every ``DramSpec`` preset, and ``jax.vmap`` batches whole workload sweeps
(:func:`simulate_sweep`) instead of re-jitting per configuration.  The only
static arguments are shapes: bank/core counts and the VILLA table geometry.

"Weighted speedup" is reported as in the paper's WS metric [14,93], with each
core's IPC proxied by the reciprocal of its total memory stall time.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.dram import villa as V
from repro.core.dram.spec import DDR3_1600, DramSpec, get_mechanism
from repro.core.dram.traces import Trace, TraceConfig


@dataclasses.dataclass(frozen=True)
class MechanismConfig:
    copy_mech: str = "memcpy"         # any registered CopyMechanism name
    use_villa: bool = False
    use_lip: bool = False
    villa_copy_mech: str = "lisa"     # lisa | rc_intersa  (Fig. 3 comparison)
    villa: V.VillaConfig = dataclasses.field(default_factory=V.VillaConfig)


class MechanismParams(NamedTuple):
    """Everything the jitted simulator needs, as traced f32/i32 scalars.

    ``copy_*`` / ``ins_*`` are hop-linear cost coefficients
    (``cost(h) = base + per_hop * max(h, 1)``) for the bulk-copy mechanism
    and the VILLA-insertion mechanism; the rest are the spec's access-path
    timings.  Build with :func:`mechanism_params`; stack instances (e.g. via
    ``jax.tree.map(jnp.stack, ...)``) to vmap over configurations.
    """

    copy_lat_base: jax.Array
    copy_lat_hop: jax.Array
    copy_e_base: jax.Array
    copy_e_hop: jax.Array
    copy_on_chan: jax.Array     # bool: copy occupies the off-chip channel
    ins_lat_base: jax.Array
    ins_lat_hop: jax.Array
    ins_e_base: jax.Array
    ins_e_hop: jax.Array
    use_villa: jax.Array        # bool
    t_pre: jax.Array            # precharge latency (LIP-shortened or not)
    lat_hit: jax.Array
    lat_closed: jax.Array
    lat_fast_hit: jax.Array
    lat_fast_open: jax.Array
    lat_fast_closed: jax.Array
    e_hit: jax.Array
    e_miss: jax.Array
    t_burst: jax.Array
    rows_per_subarray: jax.Array  # i32


def mechanism_params(mcfg: MechanismConfig,
                     spec: DramSpec = DDR3_1600) -> MechanismParams:
    """Lower a (spec, config) pair to the traced-data form of the simulator."""
    copy_m = get_mechanism(mcfg.copy_mech)
    ins_m = get_mechanism(mcfg.villa_copy_mech)
    c_lat0, c_lath, c_e0, c_eh, c_chan = copy_m.coefficients(spec)
    i_lat0, i_lath, i_e0, i_eh, _ = ins_m.coefficients(spec)
    t, e, v = spec.timing, spec.energy, mcfg.villa
    f32 = jnp.float32
    return MechanismParams(
        copy_lat_base=f32(c_lat0), copy_lat_hop=f32(c_lath),
        copy_e_base=f32(c_e0), copy_e_hop=f32(c_eh),
        copy_on_chan=jnp.asarray(bool(c_chan)),
        ins_lat_base=f32(i_lat0), ins_lat_hop=f32(i_lath),
        ins_e_base=f32(i_e0), ins_e_hop=f32(i_eh),
        use_villa=jnp.asarray(mcfg.use_villa),
        t_pre=f32(spec.precharge_latency(mcfg.use_lip)),
        lat_hit=f32(t.tCL),
        lat_closed=f32(t.tRCD + t.tCL),
        lat_fast_hit=f32(v.tCL_fast),
        lat_fast_open=f32(v.tRP_fast + v.tRCD_fast + v.tCL_fast),
        lat_fast_closed=f32(v.tRCD_fast + v.tCL_fast),
        e_hit=f32(e.e_col_internal + e.e_col_channel),
        e_miss=f32(e.e_act_pre + e.e_col_internal + e.e_col_channel),
        t_burst=f32(t.tBURST),
        rows_per_subarray=jnp.int32(spec.rows_per_subarray),
    )


class SimState(NamedTuple):
    bank_free: jax.Array     # (banks,) f32
    chan_free: jax.Array     # () f32
    open_row: jax.Array      # (banks,) i32, -1 closed
    fast_open: jax.Array     # (banks,) i32 — open row in the fast subarray
    tags: jax.Array          # (banks, n_slots) i32 — VILLA resident rows
    benefit: jax.Array       # (banks, n_slots) i32 — VILLA benefit counters
    core_stall: jax.Array    # (cores,) f32
    energy: jax.Array        # () f32 uJ
    villa_hits: jax.Array    # () i32
    villa_accesses: jax.Array  # () i32


@partial(jax.jit, static_argnames=("n_banks", "n_cores", "villa_cfg", "unroll"))
def simulate_params(trace: Trace, p: MechanismParams, *, n_banks: int,
                    n_cores: int, villa_cfg: V.VillaConfig,
                    unroll: int = 4) -> Dict[str, jax.Array]:
    """THE jitted simulator: one compilation serves all copy mechanisms,
    LIP/VILLA settings, and DRAM presets (all traced via ``p``); recompiles
    only when a shape changes.

    Per-request quantities with no serial dependence — subarray/hop
    distances, copy costs, and VILLA hotness (``villa.hot_for_sequence``) —
    are precomputed vectorized; the scan carries only the serialized state
    (bank/channel occupancy, open rows, VILLA tags/benefit), with the VILLA
    branch behind ``lax.cond`` so disabled runs skip it at runtime within
    the same compilation.
    """
    # ---- vectorized precomputation (no serial dependence) ---------------
    sa_v = trace.row // p.rows_per_subarray
    dst_sa_v = trace.dst_row // p.rows_per_subarray
    hops_v = jnp.maximum(jnp.abs(dst_sa_v - sa_v), 1).astype(jnp.float32)
    copy_lat_v = p.copy_lat_base + p.copy_lat_hop * hops_v
    copy_ene_v = p.copy_e_base + p.copy_e_hop * hops_v
    sa_f = jnp.maximum(sa_v, 1).astype(jnp.float32)
    ins_lat_v = p.ins_lat_base + p.ins_lat_hop * sa_f
    ins_ene_v = p.ins_e_base + p.ins_e_hop * sa_f
    is_hot_v = V.hot_for_sequence(trace.bank, trace.row, n_banks, villa_cfg)

    def villa_on(args):
        (tags, benefit, bank, row, is_hot, fast_open_b, ins_lat, ins_ene,
         lat_normal, e_normal) = args
        tags_b, ben_b, vhit, vinsert = V.tags_access(
            tags[bank], benefit[bank], row, is_hot)
        # The fast subarray has its own row buffer (it *is* a subarray).
        f_hit = fast_open_b == row
        f_open = fast_open_b >= 0
        lat_fast = jnp.where(f_hit, p.lat_fast_hit,
                             jnp.where(f_open, p.lat_fast_open,
                                       p.lat_fast_closed))
        # An insertion reuses the row buffer the access just activated:
        # the requestor is served at slow latency; the RBM + restore then
        # occupies the *bank* in the background (charged by the caller),
        # not the request's critical path.
        lat_normal = jnp.where(vhit, lat_fast, lat_normal)
        bank_extra = jnp.where(vinsert, ins_lat, 0.0)
        e_normal = jnp.where(vhit, p.e_hit,
                             e_normal + jnp.where(vinsert, ins_ene, 0.0))
        new_fast = jnp.where(vhit | vinsert, row, fast_open_b).astype(
            jnp.int32)
        return (tags.at[bank].set(tags_b), benefit.at[bank].set(ben_b),
                vhit, lat_normal, e_normal, bank_extra, new_fast,
                jnp.ones((), jnp.int32))

    def villa_off(args):
        (tags, benefit, bank, row, is_hot, fast_open_b, ins_lat, ins_ene,
         lat_normal, e_normal) = args
        return (tags, benefit, jnp.zeros((), bool), lat_normal, e_normal,
                jnp.zeros((), jnp.float32), fast_open_b,
                jnp.zeros((), jnp.int32))

    def step(state: SimState, req):
        (arrival, core, bank, row, is_copy, is_hot, copy_lat, copy_ene,
         ins_lat, ins_ene) = req

        t0 = jnp.maximum(arrival, state.bank_free[bank])

        # ---- normal access latency (open-row policy) --------------------
        is_hit = state.open_row[bank] == row
        is_open = state.open_row[bank] >= 0
        lat_conflict = p.t_pre + p.lat_closed
        lat_normal = jnp.where(is_hit, p.lat_hit,
                               jnp.where(is_open, lat_conflict, p.lat_closed))
        e_normal = jnp.where(is_hit, p.e_hit, p.e_miss)

        # ---- VILLA (same compilation; skipped at runtime when off) -------
        (new_tags, new_benefit, vhit, lat_normal, e_normal, bank_extra,
         new_fast_open, acc) = jax.lax.cond(
            p.use_villa, villa_on, villa_off,
            (state.tags, state.benefit, bank, row, is_hot,
             state.fast_open[bank], ins_lat, ins_ene, lat_normal, e_normal))
        villa_hits = state.villa_hits + vhit.astype(jnp.int32)
        villa_acc = state.villa_accesses + acc

        # ---- bulk copy --------------------------------------------------
        lat = jnp.where(is_copy, copy_lat, lat_normal)
        ene = jnp.where(is_copy, copy_ene, e_normal)

        # ---- channel occupancy ------------------------------------------
        # Normal reads burst 64 B at the end of the access; memcpy copies own
        # the channel for their whole duration; in-DRAM copies never touch it.
        chan_start_copy = jnp.maximum(t0, state.chan_free)
        t_end_copy = jnp.where(p.copy_on_chan, chan_start_copy + lat, t0 + lat)
        chan_after_copy = jnp.where(p.copy_on_chan, t_end_copy,
                                    state.chan_free)

        burst = jnp.maximum(t0 + lat - p.t_burst, state.chan_free)
        t_end_normal = burst + p.t_burst
        chan_after_normal = t_end_normal

        t_end = jnp.where(is_copy, t_end_copy, t_end_normal)
        chan_free = jnp.where(is_copy, chan_after_copy, chan_after_normal)

        # A VILLA fast hit is served by the fast subarray and leaves the slow
        # subarrays' row buffer untouched.
        new_open = jnp.where(is_copy, -1,
                             jnp.where(vhit, state.open_row[bank], row)
                             ).astype(jnp.int32)
        state = SimState(
            bank_free=state.bank_free.at[bank].set(t_end + bank_extra),
            chan_free=chan_free,
            open_row=state.open_row.at[bank].set(new_open),
            fast_open=state.fast_open.at[bank].set(new_fast_open),
            tags=new_tags,
            benefit=new_benefit,
            core_stall=state.core_stall.at[core].add(t_end - arrival),
            energy=state.energy + ene,
            villa_hits=villa_hits,
            villa_accesses=villa_acc,
        )
        return state, t_end - arrival

    init = SimState(
        bank_free=jnp.zeros((n_banks,), jnp.float32),
        chan_free=jnp.zeros((), jnp.float32),
        open_row=jnp.full((n_banks,), -1, jnp.int32),
        fast_open=jnp.full((n_banks,), -1, jnp.int32),
        tags=jnp.full((n_banks, villa_cfg.n_slots), -1, jnp.int32),
        benefit=jnp.zeros((n_banks, villa_cfg.n_slots), jnp.int32),
        core_stall=jnp.zeros((n_cores,), jnp.float32),
        energy=jnp.zeros((), jnp.float32),
        villa_hits=jnp.zeros((), jnp.int32),
        villa_accesses=jnp.zeros((), jnp.int32),
    )
    xs = (trace.t, trace.core, trace.bank, trace.row, trace.is_copy,
          is_hot_v, copy_lat_v, copy_ene_v, ins_lat_v, ins_ene_v)
    final, lat_trace = jax.lax.scan(step, init, xs, unroll=unroll)
    return {
        "core_stall": final.core_stall,
        "energy_uJ": final.energy,
        "avg_latency_ns": lat_trace.mean(),
        "villa_hit_rate": jnp.where(
            final.villa_accesses > 0,
            final.villa_hits / jnp.maximum(final.villa_accesses, 1), 0.0),
    }


def simulate(trace: Trace, tcfg: TraceConfig, mcfg: MechanismConfig,
             spec: DramSpec = DDR3_1600) -> Dict[str, jax.Array]:
    """Convenience wrapper: lower ``(spec, mcfg)`` to traced params and run
    the single jitted core.  Repeated calls with different mechanisms (or
    presets) reuse one compilation."""
    return simulate_params(trace, mechanism_params(mcfg, spec),
                           n_banks=tcfg.n_banks, n_cores=tcfg.n_cores,
                           villa_cfg=mcfg.villa)


# The historical name: the wrapper already runs jitted, so keep the alias for
# call sites written against the old `jax.jit(simulate, static_argnums=...)`.
simulate_jit = simulate


def stack_traces(traces: Sequence[Trace]) -> Trace:
    """Stack same-shape traces along a new leading axis for vmapped sweeps."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *traces)


def stack_params(params: Sequence[MechanismParams]) -> MechanismParams:
    """Stack MechanismParams along a new leading axis (vmap over configs)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params)


@partial(jax.jit, static_argnames=("n_banks", "n_cores", "villa_cfg"))
def _simulate_vmapped(traces: Trace, p: MechanismParams, *, n_banks: int,
                      n_cores: int, villa_cfg: V.VillaConfig):
    return jax.vmap(
        lambda tr: simulate_params(tr, p, n_banks=n_banks, n_cores=n_cores,
                                   villa_cfg=villa_cfg, unroll=1))(traces)


def simulate_sweep(traces: Trace, tcfg: TraceConfig, mcfg: MechanismConfig,
                   spec: DramSpec = DDR3_1600) -> Dict[str, jax.Array]:
    """Batch a whole workload sweep: ``traces`` is a stacked Trace (leading
    axis = workloads, see :func:`stack_traces`); one vmapped execution of the
    single jitted simulator replaces per-workload re-jitting.  Results gain a
    leading workload axis."""
    return _simulate_vmapped(traces, mechanism_params(mcfg, spec),
                             n_banks=tcfg.n_banks, n_cores=tcfg.n_cores,
                             villa_cfg=mcfg.villa)


@partial(jax.jit, static_argnames=("n_banks", "n_cores", "villa_cfg"))
def _simulate_grid(traces: Trace, p: MechanismParams, *, n_banks: int,
                   n_cores: int, villa_cfg: V.VillaConfig):
    return jax.vmap(lambda one_p: jax.vmap(
        lambda tr: simulate_params(tr, one_p, n_banks=n_banks,
                                   n_cores=n_cores, villa_cfg=villa_cfg,
                                   unroll=1))(traces))(p)


def simulate_grid(traces: Trace, tcfg: TraceConfig,
                  mcfgs: Sequence[MechanismConfig],
                  spec: DramSpec = DDR3_1600) -> Dict[str, jax.Array]:
    """The full cross product in one execution: stacked ``traces``
    (workload axis) x a list of mechanism configs (stacked into a params
    axis).  Results carry leading axes ``(len(mcfgs), n_workloads)`` —
    this is the fig3/fig4 "50 workloads x all mechanisms" sweep as a single
    dispatch of the single compiled simulator."""
    villa_cfg = mcfgs[0].villa
    if any(m.villa != villa_cfg for m in mcfgs):
        raise ValueError("simulate_grid requires a shared VillaConfig "
                         "(its table geometry is a static shape)")
    params = stack_params([mechanism_params(m, spec) for m in mcfgs])
    return _simulate_grid(traces, params, n_banks=tcfg.n_banks,
                          n_cores=tcfg.n_cores, villa_cfg=villa_cfg)


def weighted_speedup(base_stall: jax.Array, mech_stall: jax.Array) -> jax.Array:
    """WS proxy: mean over cores of IPC_mech/IPC_base with IPC ~ 1/stall.
    Works element-wise over leading batch axes (reduces the last axis)."""
    return (base_stall / jnp.maximum(mech_stall, 1e-3)).mean(axis=-1)
