"""Synthetic multi-core memory traces for the controller simulator.

The paper evaluates 50 four-core workloads built from SPEC/TPC traces (via
Pin + Ramulator).  Those traces are not redistributable, so the system-level
benchmarks here use parameterised synthetic traces with the two properties
the paper's results hinge on:

  * a Zipf-like hot-row access distribution (drives VILLA hit rate), and
  * a configurable fraction of bulk-copy operations (drives RISC gains).

Benchmarks sweep these knobs across "50 workloads" and assert the paper's
*orderings* (see DESIGN.md Sec. 5, assumption 5).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_requests: int = 8192
    n_cores: int = 4
    n_banks: int = 8
    n_subarrays: int = 16
    rows_per_subarray: int = 64
    copy_prob: float = 0.005         # fraction of requests that are bulk copies
    zipf_s: float = 1.4              # hot-row skew
    hot_rows: int = 64               # size of the hot set per bank
    mean_gap_ns: float = 100.0       # mean inter-arrival time


class Trace(NamedTuple):
    t: jax.Array         # (N,) float32 arrival times, sorted
    core: jax.Array      # (N,) int32
    bank: jax.Array      # (N,) int32
    row: jax.Array       # (N,) int32 global row id within bank (sa*rows + r)
    is_copy: jax.Array   # (N,) bool
    dst_row: jax.Array   # (N,) int32 copy destination row id


def generate(key: jax.Array, cfg: TraceConfig) -> Trace:
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    n = cfg.n_requests
    n_rows = cfg.n_subarrays * cfg.rows_per_subarray

    gaps = jax.random.exponential(k1, (n,)) * cfg.mean_gap_ns
    t = jnp.cumsum(gaps).astype(jnp.float32)

    core = jax.random.randint(k2, (n,), 0, cfg.n_cores, jnp.int32)
    bank = jax.random.randint(k3, (n,), 0, cfg.n_banks, jnp.int32)

    # Zipf over a hot set + uniform tail.  Hot set lives in the *slow*
    # subarrays (sa >= 1); subarray 0 is the fast (VILLA) subarray.
    ranks = jnp.arange(1, cfg.hot_rows + 1, dtype=jnp.float32)
    p = ranks ** (-cfg.zipf_s)
    p = p / p.sum()
    hot_pick = jax.random.choice(k4, cfg.hot_rows, (n,), p=p)
    hot_rows = cfg.rows_per_subarray + hot_pick          # rows in subarray 1+
    uniform_rows = jax.random.randint(k5, (n,), cfg.rows_per_subarray,
                                      n_rows, jnp.int32)
    take_hot = jax.random.bernoulli(k6, 0.8, (n,))
    row = jnp.where(take_hot, hot_rows, uniform_rows).astype(jnp.int32)

    kc, kd = jax.random.split(k7)
    is_copy = jax.random.bernoulli(kc, cfg.copy_prob, (n,))
    dst_row = jax.random.randint(kd, (n,), cfg.rows_per_subarray, n_rows,
                                 jnp.int32)
    # ensure copy src/dst land in different subarrays
    same_sa = (dst_row // cfg.rows_per_subarray) == (row // cfg.rows_per_subarray)
    dst_row = jnp.where(same_sa, (dst_row + cfg.rows_per_subarray) % n_rows,
                        dst_row)
    dst_row = jnp.maximum(dst_row, cfg.rows_per_subarray)
    return Trace(t=t, core=core, bank=bank, row=row, is_copy=is_copy,
                 dst_row=dst_row)
