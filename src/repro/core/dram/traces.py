"""Synthetic multi-core memory traces for the controller simulator.

The paper evaluates 50 four-core workloads built from SPEC/TPC traces (via
Pin + Ramulator).  Those traces are not redistributable, so the system-level
benchmarks here use parameterised synthetic traces with the two properties
the paper's results hinge on:

  * a Zipf-like hot-row access distribution (drives VILLA hit rate), and
  * a configurable fraction of bulk-copy operations (drives RISC gains).

Bank geometry (subarray count, rows per subarray) comes from the
:class:`~repro.core.dram.spec.DramSpec` passed to :func:`generate`;
:class:`TraceConfig` holds only the *workload* knobs.  Benchmarks sweep
these knobs across "50 workloads" and assert the paper's *orderings*
(see DESIGN.md Sec. 5, assumption 5).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dram.spec import DDR3_1600, DramSpec


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_requests: int = 8192
    n_cores: int = 4
    n_banks: int = 8
    copy_prob: float = 0.005         # fraction of requests that are bulk copies
    zipf_s: float = 1.4              # hot-row skew
    hot_rows: int = 64               # size of the hot set per bank
    mean_gap_ns: float = 100.0       # mean inter-arrival time


class Trace(NamedTuple):
    t: jax.Array         # (N,) float32 arrival times, sorted
    core: jax.Array      # (N,) int32
    bank: jax.Array      # (N,) int32
    row: jax.Array       # (N,) int32 global row id within bank (sa*rows + r)
    is_copy: jax.Array   # (N,) bool
    dst_row: jax.Array   # (N,) int32 copy destination row id


def generate(key: jax.Array, cfg: TraceConfig,
             spec: DramSpec = DDR3_1600) -> Trace:
    return _generate_traced(key, jnp.float32(cfg.copy_prob),
                            jnp.float32(cfg.zipf_s), cfg, spec)


def generate_batch(keys: jax.Array, copy_probs: jax.Array,
                   zipf_ss: jax.Array, cfg: TraceConfig,
                   spec: DramSpec = DDR3_1600) -> Trace:
    """Generate a whole workload sweep in one vmapped call: ``keys`` /
    ``copy_probs`` / ``zipf_ss`` share a leading workload axis (the two
    workload knobs are traced data, so one compilation covers the sweep).
    The result is a stacked :class:`Trace` ready for
    ``controller.simulate_sweep``."""
    return jax.vmap(
        lambda k, p, z: _generate_traced(k, p, z, cfg, spec)
    )(keys, jnp.asarray(copy_probs, jnp.float32),
      jnp.asarray(zipf_ss, jnp.float32))


@partial(jax.jit, static_argnames=("cfg", "spec"))
def _generate_traced(key: jax.Array, copy_prob: jax.Array,
                     zipf_s: jax.Array, cfg: TraceConfig,
                     spec: DramSpec) -> Trace:
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    n = cfg.n_requests
    rows_per_sa = spec.rows_per_subarray
    n_rows = spec.n_rows

    gaps = jax.random.exponential(k1, (n,)) * cfg.mean_gap_ns
    t = jnp.cumsum(gaps).astype(jnp.float32)

    core = jax.random.randint(k2, (n,), 0, cfg.n_cores, jnp.int32)
    bank = jax.random.randint(k3, (n,), 0, cfg.n_banks, jnp.int32)

    # Zipf over a hot set + uniform tail.  Hot set lives in the *slow*
    # subarrays (sa >= 1); subarray 0 is the fast (VILLA) subarray.
    ranks = jnp.arange(1, cfg.hot_rows + 1, dtype=jnp.float32)
    p = ranks ** (-zipf_s)
    p = p / p.sum()
    # inverse-CDF categorical draw (compiles fast under vmap, unlike
    # jax.random.choice with per-lane probabilities)
    u = jax.random.uniform(k4, (n,))
    hot_pick = jnp.searchsorted(jnp.cumsum(p), u).astype(jnp.int32)
    hot_pick = jnp.minimum(hot_pick, cfg.hot_rows - 1)
    hot_rows = rows_per_sa + hot_pick                    # rows in subarray 1+
    uniform_rows = jax.random.randint(k5, (n,), rows_per_sa, n_rows, jnp.int32)
    take_hot = jax.random.bernoulli(k6, 0.8, (n,))
    row = jnp.where(take_hot, hot_rows, uniform_rows).astype(jnp.int32)

    kc, kd = jax.random.split(k7)
    is_copy = jax.random.bernoulli(kc, copy_prob, (n,))
    dst_row = jax.random.randint(kd, (n,), rows_per_sa, n_rows, jnp.int32)
    # ensure copy src/dst land in different subarrays
    same_sa = (dst_row // rows_per_sa) == (row // rows_per_sa)
    dst_row = jnp.where(same_sa, (dst_row + rows_per_sa) % n_rows, dst_row)
    dst_row = jnp.maximum(dst_row, rows_per_sa)
    return Trace(t=t, core=core, bank=bank, row=row, is_copy=is_copy,
                 dst_row=dst_row)
