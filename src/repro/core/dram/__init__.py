"""Faithful reproduction of the LISA DRAM substrate (HPCA'16 / 2018 summary).

Modules:
  spec        — the `DramSpec` device-model API: geometry + timing/energy
                presets (DDR3_1600 calibrated to Table 1, DDR4/LPDDR) and the
                `CopyMechanism` registry (DESIGN.md Sec. 6)
  bank        — bank-level contention under the virtual clock: refresher
                (tREFI/tRFC), per-bank row-state machines, and the request
                multiplexer (DESIGN.md Sec. 15)
  substrate   — data-correct functional DRAM bank with RBM / RISC / multicast
  villa       — the VILLA hot-row caching policy (Sec. 3.2.1, exact)
  controller  — command-level multi-core system simulator (Figs. 3/4
                orderings); mechanism config is traced data, one jitted
                simulate covers all mechanisms and vmaps over workloads
  traces      — synthetic workload generation (SPEC traces are not shippable)
"""
from repro.core.dram import (  # noqa: F401
    bank,
    controller,
    spec,
    substrate,
    traces,
    villa,
)
from repro.core.dram.bank import (  # noqa: F401
    BankMachine,
    Refresher,
    RequestMultiplexer,
)
from repro.core.dram.spec import DDR3_1600, DDR4_2400, DramSpec  # noqa: F401
