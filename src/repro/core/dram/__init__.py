"""Faithful reproduction of the LISA DRAM substrate (HPCA'16 / 2018 summary).

Modules:
  timing      — DDR3-1600 + LISA timing/energy models (Table 1, exact)
  substrate   — data-correct functional DRAM bank with RBM / RISC / multicast
  villa       — the VILLA hot-row caching policy (Sec. 3.2.1, exact)
  controller  — command-level multi-core system simulator (Figs. 3/4 orderings)
  traces      — synthetic workload generation (SPEC traces are not shippable)
"""
from repro.core.dram import timing, substrate, villa, controller, traces  # noqa: F401
