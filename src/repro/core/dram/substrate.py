"""Functional (data-correct) model of a LISA-enabled DRAM bank.

This is the *semantic* half of the reproduction: a pure-JAX state machine whose
operations mirror the DRAM commands the paper reasons about —
ACTIVATE / PRECHARGE / RBM (row buffer movement) / column READ / WRITE — plus
the composed LISA-RISC copy and the 1-to-N multicast enabled by intermediate
row-buffer latching (paper Sec. 5.2).  Geometry and all command costs come
from a :class:`repro.core.dram.spec.DramSpec`; this module guarantees the
*data movement itself* is correct, including the adjacency and precharge-state
preconditions of RBM.  Composed copies return a typed :class:`CopyResult`.

State layout (one bank):
  cells        (n_subarrays, rows_per_subarray, row_bytes)  uint8
  row_buffer   (n_subarrays, row_bytes)                     uint8
  rb_valid     (n_subarrays,)  bool   — row buffer holds latched data
  open_row     (n_subarrays,)  int32  — activated row id, -1 if precharged
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.dram.spec import DDR3_1600, DramSpec, get_mechanism


class CopyResult(NamedTuple):
    """Typed result of a composed copy: new state + modeled cost.

    Unpacks like the historical ``(state, latency_ns, energy_uj)`` tuple.
    """

    state: "BankState"
    latency_ns: float
    energy_uj: float


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BankState:
    cells: jax.Array
    row_buffer: jax.Array
    rb_valid: jax.Array
    open_row: jax.Array

    def tree_flatten(self):
        return (self.cells, self.row_buffer, self.rb_valid, self.open_row), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_subarrays(self) -> int:
        return self.cells.shape[0]

    @property
    def rows_per_subarray(self) -> int:
        return self.cells.shape[1]

    @property
    def row_bytes(self) -> int:
        return self.cells.shape[2]


def make_bank(spec: DramSpec = DDR3_1600, *,
              key: jax.Array | None = None) -> BankState:
    """Construct one bank with the spec's geometry (zeroed or random cells)."""
    shape = (spec.n_subarrays, spec.rows_per_subarray, spec.row_bytes)
    if key is None:
        cells = jnp.zeros(shape, jnp.uint8)
    else:
        cells = jax.random.randint(key, shape, 0, 256, jnp.uint8)
    return BankState(
        cells=cells,
        row_buffer=jnp.zeros((spec.n_subarrays, spec.row_bytes), jnp.uint8),
        rb_valid=jnp.zeros((spec.n_subarrays,), bool),
        open_row=jnp.full((spec.n_subarrays,), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Primitive DRAM commands (pure functions: state -> state).
# ---------------------------------------------------------------------------

def activate(state: BankState, sa: jax.Array, row: jax.Array) -> BankState:
    """ACTIVATE row ``row`` of subarray ``sa``: latch it into the row buffer.

    If the row buffer already holds *valid* latched data (e.g. after an RBM)
    and the subarray is precharged, activation instead *restores* the buffer
    contents into the target row — this is exactly how LISA-RISC writes the
    moved data into the destination row (paper Sec. 3.1 step 3).
    """
    sa = jnp.asarray(sa, jnp.int32)
    row = jnp.asarray(row, jnp.int32)
    restore_mode = state.rb_valid[sa] & (state.open_row[sa] < 0)

    stored = state.cells[sa, row]
    buf = state.row_buffer[sa]
    new_buf = jnp.where(restore_mode, buf, stored)
    new_cells = state.cells.at[sa, row].set(new_buf)

    return BankState(
        cells=new_cells,
        row_buffer=state.row_buffer.at[sa].set(new_buf),
        rb_valid=state.rb_valid.at[sa].set(True),
        open_row=state.open_row.at[sa].set(row),
    )


def precharge(state: BankState, sa: jax.Array) -> BankState:
    """PRECHARGE subarray ``sa``: close the open row, invalidate the buffer."""
    sa = jnp.asarray(sa, jnp.int32)
    return BankState(
        cells=state.cells,
        row_buffer=state.row_buffer,
        rb_valid=state.rb_valid.at[sa].set(False),
        open_row=state.open_row.at[sa].set(-1),
    )


def rbm(state: BankState, src_sa: jax.Array, dst_sa: jax.Array) -> BankState:
    """Row Buffer Movement between *adjacent* subarrays (the LISA primitive).

    Preconditions (checked with ``checkify``-style masking): |src-dst| == 1,
    src buffer valid, dst subarray precharged.  On success the activated
    source row buffer drives the precharged destination bitlines; the
    destination senses and latches (paper Sec. 2).  On a violated
    precondition the destination's data is untouched but its buffer is
    conservatively *invalidated* (``rb_valid[dst] = False``): a misfired RBM
    disturbs the destination sense amplifiers, and marking the buffer invalid
    makes misuse detectable by property tests instead of silently keeping
    stale contents trustworthy.
    """
    src_sa = jnp.asarray(src_sa, jnp.int32)
    dst_sa = jnp.asarray(dst_sa, jnp.int32)
    ok = (jnp.abs(src_sa - dst_sa) == 1) & state.rb_valid[src_sa] & (state.open_row[dst_sa] < 0)
    moved = jnp.where(ok, state.row_buffer[src_sa], state.row_buffer[dst_sa])
    return BankState(
        cells=state.cells,
        row_buffer=state.row_buffer.at[dst_sa].set(moved),
        rb_valid=state.rb_valid.at[dst_sa].set(ok),
        open_row=state.open_row,
    )


def read_line(state: BankState, sa: jax.Array, line: jax.Array,
              spec: DramSpec = DDR3_1600) -> jax.Array:
    """Column read of one cache line from the open row buffer."""
    start = jnp.asarray(line, jnp.int32) * spec.cache_line_bytes
    return jax.lax.dynamic_slice(state.row_buffer[sa], (start,),
                                 (spec.cache_line_bytes,))


def write_line(state: BankState, sa: jax.Array, line: jax.Array,
               data: jax.Array, spec: DramSpec = DDR3_1600) -> BankState:
    """Column write of one cache line into the open row (and buffer)."""
    sa = jnp.asarray(sa, jnp.int32)
    start = jnp.asarray(line, jnp.int32) * spec.cache_line_bytes
    buf = jax.lax.dynamic_update_slice(state.row_buffer[sa], data.astype(jnp.uint8), (start,))
    row = state.open_row[sa]
    return BankState(
        cells=state.cells.at[sa, row].set(buf),
        row_buffer=state.row_buffer.at[sa].set(buf),
        rb_valid=state.rb_valid,
        open_row=state.open_row,
    )


# ---------------------------------------------------------------------------
# Composed operations: LISA-RISC copy, 1-to-N multicast, baselines.
# ---------------------------------------------------------------------------

def _hop_chain(state: BankState, src_sa: int, dst_sa: int) -> BankState:
    """RBM hop-by-hop from src to dst; every intermediate buffer latches."""
    step = 1 if dst_sa >= src_sa else -1
    sas = list(range(src_sa, dst_sa, step))
    for cur in sas:
        state = rbm(state, cur, cur + step)
    return state


def lisa_risc_copy(state: BankState, src_sa: int, src_row: int,
                   dst_sa: int, dst_row: int,
                   spec: DramSpec = DDR3_1600) -> CopyResult:
    """Full LISA-RISC row copy.

    ACTIVATE(src) -> RBM x hops -> ACTIVATE(dst, restore mode) -> PRE.
    Subarray indices are Python ints (command schedules are static), data is
    traced, so this composes with jit.
    """
    hops = abs(dst_sa - src_sa)
    if hops < 1:
        raise ValueError("source and destination subarrays must differ")
    state = activate(state, src_sa, src_row)
    state = _hop_chain(state, src_sa, dst_sa)
    state = precharge(state, src_sa)          # close source; dst buffer holds data
    state = activate(state, dst_sa, dst_row)  # restore-mode: buffer -> cells
    state = precharge(state, dst_sa)
    return CopyResult(state, spec.copy_latency("lisa", hops),
                      spec.copy_energy("lisa", hops))


def lisa_broadcast(state: BankState, src_sa: int, src_row: int,
                   dst_sas: Tuple[int, ...], dst_row: int,
                   spec: DramSpec = DDR3_1600) -> CopyResult:
    """1-to-N multicast (paper Sec. 5.2): one hop chain to the farthest
    destination latches the data in *every* intermediate row buffer; a single
    ACTIVATE per destination then restores it into ``dst_row``.

    Latency: one RISC traversal to the farthest destination + one
    (tRAS + tRP) restore per *additional* destination (they are in distinct
    subarrays and proceed back-to-back per the command-level model).
    """
    if src_sa in dst_sas:
        raise ValueError("destination equals source subarray")
    fwd = [d for d in dst_sas if d > src_sa]
    bwd = [d for d in dst_sas if d < src_sa]
    state = activate(state, src_sa, src_row)
    hops = 0
    if fwd:                                   # chain toward max destination
        state = _hop_chain(state, src_sa, max(fwd))
        hops += max(fwd) - src_sa
    if bwd:                                   # chain toward min destination
        state = _hop_chain(state, src_sa, min(bwd))
        hops += src_sa - min(bwd)
    state = precharge(state, src_sa)
    lat = spec.copy_latency("lisa", hops)     # chains serialized (conservative)
    ene = spec.copy_energy("lisa", hops)
    t = spec.timing
    for i, d in enumerate(sorted(dst_sas, key=lambda d: abs(d - src_sa))):
        state = activate(state, d, dst_row)   # restore latched buffer
        state = precharge(state, d)
        if i > 0:
            lat += t.tRAS + t.tRP
            ene += 2 * spec.energy.e_act_pre
    return CopyResult(state, lat, ene)


def _serial_copy(state: BankState, src_sa: int, src_row: int,
                 dst_sa: int, dst_row: int) -> BankState:
    """Data path shared by the serial baselines (RC-InterSA / RC-Bank /
    memcpy): read the source row out through its buffer, write it into the
    destination row.  Only the *cost* differs between those mechanisms."""
    state = activate(state, src_sa, src_row)
    data = state.row_buffer[src_sa]
    state = precharge(state, src_sa)
    state = activate(state, dst_sa, dst_row)
    return BankState(
        cells=state.cells.at[dst_sa, dst_row].set(data),
        row_buffer=state.row_buffer.at[dst_sa].set(data),
        rb_valid=state.rb_valid,
        open_row=state.open_row,
    )


def rowclone_intersa_copy(state: BankState, src_sa: int, src_row: int,
                          dst_sa: int, dst_row: int,
                          spec: DramSpec = DDR3_1600) -> CopyResult:
    """Baseline RowClone inter-subarray copy (via the narrow internal bus):
    semantically a row copy; cost from the calibrated Table-1 model."""
    state = precharge(_serial_copy(state, src_sa, src_row, dst_sa, dst_row),
                      dst_sa)
    return CopyResult(state, spec.copy_latency("rc_intersa"),
                      spec.copy_energy("rc_intersa"))


def memcpy_copy(state: BankState, src_sa: int, src_row: int,
                dst_sa: int, dst_row: int,
                spec: DramSpec = DDR3_1600) -> CopyResult:
    """Baseline CPU memcpy: the row crosses the off-chip channel twice (read
    phase + write phase).  Data path as the serial baselines; cost and
    channel occupancy from the ``memcpy`` mechanism."""
    state = precharge(_serial_copy(state, src_sa, src_row, dst_sa, dst_row),
                      dst_sa)
    return CopyResult(state, spec.copy_latency("memcpy"),
                      spec.copy_energy("memcpy"))


def rowclone_bank_copy(state: BankState, src_sa: int, src_row: int,
                       dst_sa: int, dst_row: int,
                       spec: DramSpec = DDR3_1600) -> CopyResult:
    """Baseline RowClone PSM between banks, modeled within one bank state
    (the pipelined internal-bus transfer has the same data semantics; only
    the cost differs)."""
    state = precharge(_serial_copy(state, src_sa, src_row, dst_sa, dst_row),
                      dst_sa)
    return CopyResult(state, spec.copy_latency("rc_bank"),
                      spec.copy_energy("rc_bank"))


def rowclone_intrasa_copy(state: BankState, sa: int, src_row: int,
                          dst_row: int,
                          spec: DramSpec = DDR3_1600) -> CopyResult:
    """Baseline RowClone FPM: back-to-back ACTIVATEs within one subarray
    copy ``src_row`` onto ``dst_row`` through the shared row buffer."""
    state = activate(state, sa, src_row)
    buf = state.row_buffer[sa]
    state = BankState(
        cells=state.cells.at[sa, dst_row].set(buf),
        row_buffer=state.row_buffer,
        rb_valid=state.rb_valid,
        open_row=state.open_row.at[sa].set(dst_row),
    )
    state = precharge(state, sa)
    return CopyResult(state, spec.copy_latency("rc_intrasa"),
                      spec.copy_energy("rc_intrasa"))


# Functional substrate op per registered mechanism name.  New mechanisms
# (spec.register_mechanism) advertise a data path here via
# register_copy_op; cost-model-only mechanisms simply have no entry.
_COPY_OPS = {}


def register_copy_op(mechanism: str, op) -> None:
    """Attach a functional substrate op ``op(state, src_sa, src_row, dst_sa,
    dst_row, spec) -> CopyResult`` to a registered mechanism name."""
    get_mechanism(mechanism)            # validates the name
    _COPY_OPS[mechanism] = op


def execute_copy(state: BankState, mechanism: str, src_sa: int, src_row: int,
                 dst_sa: int, dst_row: int,
                 spec: DramSpec = DDR3_1600) -> CopyResult:
    """Run one row copy under the named :class:`CopyMechanism` from the
    registry — the functional dispatch point used by benchmarks and demos
    (no string if/elif chains at call sites)."""
    mech = get_mechanism(mechanism)     # validates the name
    op = _COPY_OPS.get(mech.name)
    if op is None:
        raise ValueError(
            f"mechanism {mech.name!r} has no functional substrate op "
            f"(have: {sorted(_COPY_OPS)}); register one with "
            "substrate.register_copy_op")
    if mech.name == "rc_intrasa":
        if src_sa != dst_sa:
            raise ValueError("rc_intrasa copies within one subarray "
                             f"(got {src_sa} -> {dst_sa})")
    elif src_sa == dst_sa:
        raise ValueError(f"{mech.name} requires distinct subarrays")
    return op(state, src_sa, src_row, dst_sa, dst_row, spec)


register_copy_op("lisa", lisa_risc_copy)
register_copy_op("rc_intersa", rowclone_intersa_copy)
register_copy_op("rc_bank", rowclone_bank_copy)
register_copy_op("memcpy", memcpy_copy)
register_copy_op("rc_intrasa",
                 lambda state, src_sa, src_row, dst_sa, dst_row, spec:
                 rowclone_intrasa_copy(state, src_sa, src_row, dst_row, spec))
