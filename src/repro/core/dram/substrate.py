"""Functional (data-correct) model of a LISA-enabled DRAM bank.

This is the *semantic* half of the reproduction: a pure-JAX state machine whose
operations mirror the DRAM commands the paper reasons about —
ACTIVATE / PRECHARGE / RBM (row buffer movement) / column READ / WRITE — plus
the composed LISA-RISC copy and the 1-to-N multicast enabled by intermediate
row-buffer latching (paper Sec. 5.2).  Timing/energy accounting comes from
``timing.py``; this module guarantees the *data movement itself* is correct,
including the adjacency and precharge-state preconditions of RBM.

State layout (one bank):
  cells        (n_subarrays, rows_per_subarray, row_bytes)  uint8
  row_buffer   (n_subarrays, row_bytes)                     uint8
  rb_valid     (n_subarrays,)  bool   — row buffer holds latched data
  open_row     (n_subarrays,)  int32  — activated row id, -1 if precharged
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.dram import timing as T


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BankState:
    cells: jax.Array
    row_buffer: jax.Array
    rb_valid: jax.Array
    open_row: jax.Array

    def tree_flatten(self):
        return (self.cells, self.row_buffer, self.rb_valid, self.open_row), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_subarrays(self) -> int:
        return self.cells.shape[0]

    @property
    def rows_per_subarray(self) -> int:
        return self.cells.shape[1]

    @property
    def row_bytes(self) -> int:
        return self.cells.shape[2]


def make_bank(n_subarrays: int = 16, rows_per_subarray: int = 64,
              row_bytes: int = T.ROW_BYTES, key: jax.Array | None = None) -> BankState:
    if key is None:
        cells = jnp.zeros((n_subarrays, rows_per_subarray, row_bytes), jnp.uint8)
    else:
        cells = jax.random.randint(
            key, (n_subarrays, rows_per_subarray, row_bytes), 0, 256, jnp.uint8)
    return BankState(
        cells=cells,
        row_buffer=jnp.zeros((n_subarrays, row_bytes), jnp.uint8),
        rb_valid=jnp.zeros((n_subarrays,), bool),
        open_row=jnp.full((n_subarrays,), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Primitive DRAM commands (pure functions: state -> state).
# ---------------------------------------------------------------------------

def activate(state: BankState, sa: jax.Array, row: jax.Array) -> BankState:
    """ACTIVATE row ``row`` of subarray ``sa``: latch it into the row buffer.

    If the row buffer already holds *valid* latched data (e.g. after an RBM)
    and the subarray is precharged, activation instead *restores* the buffer
    contents into the target row — this is exactly how LISA-RISC writes the
    moved data into the destination row (paper Sec. 3.1 step 3).
    """
    sa = jnp.asarray(sa, jnp.int32)
    row = jnp.asarray(row, jnp.int32)
    restore_mode = state.rb_valid[sa] & (state.open_row[sa] < 0)

    stored = state.cells[sa, row]
    buf = state.row_buffer[sa]
    new_buf = jnp.where(restore_mode, buf, stored)
    new_cells = state.cells.at[sa, row].set(new_buf)

    return BankState(
        cells=new_cells,
        row_buffer=state.row_buffer.at[sa].set(new_buf),
        rb_valid=state.rb_valid.at[sa].set(True),
        open_row=state.open_row.at[sa].set(row),
    )


def precharge(state: BankState, sa: jax.Array) -> BankState:
    """PRECHARGE subarray ``sa``: close the open row, invalidate the buffer."""
    sa = jnp.asarray(sa, jnp.int32)
    return BankState(
        cells=state.cells,
        row_buffer=state.row_buffer,
        rb_valid=state.rb_valid.at[sa].set(False),
        open_row=state.open_row.at[sa].set(-1),
    )


def rbm(state: BankState, src_sa: jax.Array, dst_sa: jax.Array) -> BankState:
    """Row Buffer Movement between *adjacent* subarrays (the LISA primitive).

    Preconditions (checked with ``checkify``-style masking — the op is a no-op
    with ``rb_valid[dst]=False`` if violated, so property tests can detect
    misuse): |src-dst| == 1, src buffer valid, dst subarray precharged.
    The activated source row buffer drives the precharged destination
    bitlines; the destination senses and latches (paper Sec. 2).
    """
    src_sa = jnp.asarray(src_sa, jnp.int32)
    dst_sa = jnp.asarray(dst_sa, jnp.int32)
    ok = (jnp.abs(src_sa - dst_sa) == 1) & state.rb_valid[src_sa] & (state.open_row[dst_sa] < 0)
    moved = jnp.where(ok, state.row_buffer[src_sa], state.row_buffer[dst_sa])
    return BankState(
        cells=state.cells,
        row_buffer=state.row_buffer.at[dst_sa].set(moved),
        rb_valid=state.rb_valid.at[dst_sa].set(ok | state.rb_valid[dst_sa]),
        open_row=state.open_row,
    )


def read_line(state: BankState, sa: jax.Array, line: jax.Array) -> jax.Array:
    """Column read of one 64 B cache line from the open row buffer."""
    start = jnp.asarray(line, jnp.int32) * T.CACHE_LINE_BYTES
    return jax.lax.dynamic_slice(state.row_buffer[sa], (start,), (T.CACHE_LINE_BYTES,))


def write_line(state: BankState, sa: jax.Array, line: jax.Array,
               data: jax.Array) -> BankState:
    """Column write of one 64 B cache line into the open row (and buffer)."""
    sa = jnp.asarray(sa, jnp.int32)
    start = jnp.asarray(line, jnp.int32) * T.CACHE_LINE_BYTES
    buf = jax.lax.dynamic_update_slice(state.row_buffer[sa], data.astype(jnp.uint8), (start,))
    row = state.open_row[sa]
    return BankState(
        cells=state.cells.at[sa, row].set(buf),
        row_buffer=state.row_buffer.at[sa].set(buf),
        rb_valid=state.rb_valid,
        open_row=state.open_row,
    )


# ---------------------------------------------------------------------------
# Composed operations: LISA-RISC copy and 1-to-N multicast.
# ---------------------------------------------------------------------------

def _hop_chain(state: BankState, src_sa: int, dst_sa: int) -> BankState:
    """RBM hop-by-hop from src to dst; every intermediate buffer latches."""
    step = 1 if dst_sa >= src_sa else -1
    sas = list(range(src_sa, dst_sa, step))
    for cur in sas:
        state = rbm(state, cur, cur + step)
    return state


def lisa_risc_copy(state: BankState, src_sa: int, src_row: int,
                   dst_sa: int, dst_row: int) -> Tuple[BankState, float, float]:
    """Full LISA-RISC row copy.  Returns (state, latency_ns, energy_uJ).

    ACTIVATE(src) -> RBM x hops -> ACTIVATE(dst, restore mode) -> PRE.
    Subarray indices are Python ints (command schedules are static), data is
    traced, so this composes with jit.
    """
    hops = abs(dst_sa - src_sa)
    if hops < 1:
        raise ValueError("source and destination subarrays must differ")
    state = activate(state, src_sa, src_row)
    state = _hop_chain(state, src_sa, dst_sa)
    state = precharge(state, src_sa)          # close source; dst buffer holds data
    state = activate(state, dst_sa, dst_row)  # restore-mode: buffer -> cells
    state = precharge(state, dst_sa)
    return state, T.latency_lisa_risc(hops), T.energy_lisa_risc(hops)


def lisa_broadcast(state: BankState, src_sa: int, src_row: int,
                   dst_sas: Tuple[int, ...], dst_row: int
                   ) -> Tuple[BankState, float, float]:
    """1-to-N multicast (paper Sec. 5.2): one hop chain to the farthest
    destination latches the data in *every* intermediate row buffer; a single
    ACTIVATE per destination then restores it into ``dst_row``.

    Latency: one RISC traversal to the farthest destination + one
    (tRAS + tRP) restore per *additional* destination (they are in distinct
    subarrays and proceed back-to-back per the command-level model).
    """
    if src_sa in dst_sas:
        raise ValueError("destination equals source subarray")
    fwd = [d for d in dst_sas if d > src_sa]
    bwd = [d for d in dst_sas if d < src_sa]
    state = activate(state, src_sa, src_row)
    hops = 0
    if fwd:                                   # chain toward max destination
        state = _hop_chain(state, src_sa, max(fwd))
        hops += max(fwd) - src_sa
    if bwd:                                   # chain toward min destination
        state = _hop_chain(state, src_sa, min(bwd))
        hops += src_sa - min(bwd)
    state = precharge(state, src_sa)
    lat = T.latency_lisa_risc(hops)           # chains serialized (conservative)
    ene = T.energy_lisa_risc(hops)
    for i, d in enumerate(sorted(dst_sas, key=lambda d: abs(d - src_sa))):
        state = activate(state, d, dst_row)   # restore latched buffer
        state = precharge(state, d)
        if i > 0:
            lat += T.DDR3.tRAS + T.DDR3.tRP
            ene += 2 * T.ENERGY.e_act_pre
    return state, lat, ene


def rowclone_intersa_copy(state: BankState, src_sa: int, src_row: int,
                          dst_sa: int, dst_row: int) -> Tuple[BankState, float, float]:
    """Baseline RowClone inter-subarray copy (via the narrow internal bus):
    semantically a row copy; cost from the calibrated Table-1 model."""
    state = activate(state, src_sa, src_row)
    data = state.row_buffer[src_sa]
    state = precharge(state, src_sa)
    state = activate(state, dst_sa, dst_row)
    buf = data
    state = BankState(
        cells=state.cells.at[dst_sa, dst_row].set(buf),
        row_buffer=state.row_buffer.at[dst_sa].set(buf),
        rb_valid=state.rb_valid,
        open_row=state.open_row,
    )
    state = precharge(state, dst_sa)
    return state, T.latency_rc_inter_sa(), T.energy_rc_inter_sa()
