"""LISA substrate adapted to the TPU mesh (see DESIGN.md Sec. 2).

  rbm          — hop primitives: lisa_copy, lisa_broadcast, ring collectives
                 with per-hop compute overlap
  villa_cache  — tiered hot/cold store driven by the paper's exact policy
  topology     — linear-in-hops cost model (Table 1 re-parameterised for ICI)
  compression  — int8 error-feedback gradient compression for the DP axis
"""
from repro.core.lisa import rbm, villa_cache, topology, compression  # noqa: F401
