"""Ring attention: context parallelism as a LISA hop chain.

The sequence is sharded over a mesh axis; each device keeps its Q shard and
the KV shards rotate around the ring via ``rbm.ring_scan`` — one ppermute
hop per step, overlapped with that step's blockwise attention (online
softmax merge).  This is the paper's substrate verbatim: the KV block is the
"row buffer", the hop is the inter-subarray link, and the per-hop compute is
the bank that keeps serving during the move (DESIGN.md §2).

Runs inside shard_map; validated against the dense oracle on 8 host devices
(tests/test_ring_attention.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lisa import rbm

NEG_INF = -1e30


def ring_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   axis_name: str, *, causal: bool = True) -> jax.Array:
    """q/k/v: local shards (B, S_loc, H|K, D), sequence sharded over
    ``axis_name`` in axis order.  Returns the local output shard."""
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    B, S, H, Dk = q.shape
    K = k.shape[2]
    G = H // K
    Dv = v.shape[-1]
    scale = Dk ** -0.5

    q_pos = idx * S + jnp.arange(S, dtype=jnp.int32)            # (S,)
    qr = (q.reshape(B, S, K, G, Dk) * scale).astype(jnp.float32)

    def merge(carry, kv_shard, src):
        m, l, acc = carry
        kj = kv_shard[0].astype(jnp.float32)                    # (B,S,K,Dk)
        vj = kv_shard[1].astype(jnp.float32)
        kv_pos = src * S + jnp.arange(S, dtype=jnp.int32)
        s = jnp.einsum("bskgd,btkd->bkgst", qr, kj)
        if causal:
            valid = kv_pos[None, :] <= q_pos[:, None]           # (S, T)
            s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        if causal:
            p = jnp.where(valid[None, None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vj)
        return m_new, l_new, acc_new

    kv = jnp.stack([k.astype(jnp.float32), v.astype(jnp.float32)])
    init = (jnp.full((B, K, G, S), NEG_INF, jnp.float32),
            jnp.zeros((B, K, G, S), jnp.float32),
            jnp.zeros((B, K, G, S, Dv), jnp.float32))
    m, l, acc = rbm.ring_scan(
        kv, axis_name,
        lambda c, shard, src: merge(c, shard, src), init)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, K * G, S, Dv).swapaxes(1, 2).reshape(
        B, S, H, Dv).astype(q.dtype)
