"""RBM hop primitives on a TPU mesh axis — the LISA substrate, adapted.

Every function here is meant to run *inside* ``jax.shard_map`` (or a manual
SPMD region) over a named mesh axis.  The mapping (DESIGN.md Sec. 2):

  DRAM subarray            ->  device position on the axis
  RBM (adjacent buffers)   ->  ``jax.lax.ppermute`` one-step shift
  RBM hop chain            ->  sequential single-pair ppermutes (linear cost)
  1-to-N via latching      ->  every intermediate device keeps a copy
  bank-level parallelism   ->  per-hop compute-overlap hook (``ring_scan``)

The ring collectives built from hop chains are what the training runtime uses
for FSDP weight gathering / gradient reduce-scatter and for ring attention
(sequence parallelism); XLA emits its own collectives for the pjit paths, and
these explicit schedules are the LISA-faithful alternative we hillclimb with.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp


def _shift_perm(n: int, step: int = 1) -> list[tuple[int, int]]:
    return [(i, (i + step) % n) for i in range(n)]


def rbm_hop(x: jax.Array, axis_name: str, step: int = 1) -> jax.Array:
    """One RBM hop: every device's shard moves to its neighbor (+step)."""
    n = jax.lax.axis_size(axis_name)
    return jax.lax.ppermute(x, axis_name, _shift_perm(n, step))


def lisa_copy(x: jax.Array, src: int, dst: int, axis_name: str,
              wraparound: bool = True) -> jax.Array:
    """Point-to-point shard movement via a neighbor-hop chain (LISA-RISC).

    After the call, device ``dst`` holds device ``src``'s shard; all other
    devices keep their own.  The schedule is ``hops`` sequential single-pair
    ppermutes — each hop crosses exactly one ICI link, so cost is linear in
    hop count, exactly Table 1's structure.
    """
    n = jax.lax.axis_size(axis_name)
    if src == dst:
        return x
    fwd = (dst - src) % n
    if wraparound:
        # Ring: take the shorter direction.
        step, hops = ((-1, n - fwd) if (n - fwd) < fwd else (1, fwd))
    else:
        # Linear chain (no wrap links): the direct route is the only route.
        step, hops = ((1, dst - src) if dst >= src else (-1, src - dst))
    v = x
    cur = src
    for _ in range(hops):
        nxt = (cur + step) % n
        v = jax.lax.ppermute(v, axis_name, [(cur, nxt)])
        cur = nxt
    idx = jax.lax.axis_index(axis_name)
    return jnp.where(idx == dst, v, x)


def lisa_broadcast(x: jax.Array, src: int, axis_name: str,
                   dsts: Optional[Sequence[int]] = None) -> jax.Array:
    """1-to-N multicast with intermediate latching (paper Sec. 5.2).

    One hop chain from ``src`` to the farthest destination; *every* device the
    data passes through latches a copy — that is the free multicast the paper
    points out ("moving data ... latches the source row's data in all the
    intermediate subarrays' row buffers").  ``dsts=None`` broadcasts to all.

    Returns: on devices in ``dsts`` (and src) the source shard, elsewhere the
    device's own shard.
    """
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    if dsts is None:
        dsts = [d for d in range(n) if d != src]
    want = jnp.zeros((n,), bool).at[jnp.array(list(dsts) + [src])].set(True)[idx]

    # Walk both directions to the farthest requested destination.
    fwd_hops = max(((d - src) % n) for d in dsts)
    bwd_hops = max(((src - d) % n) for d in dsts)
    if fwd_hops + bwd_hops >= n:          # full ring: one direction suffices
        fwd_hops, bwd_hops = n - 1, 0

    latched = x
    got = idx == src
    for direction, hops in ((1, fwd_hops), (-1, bwd_hops)):
        v = x
        cur = src
        for _ in range(hops):
            nxt = (cur + direction) % n
            v = jax.lax.ppermute(v, axis_name, [(cur, nxt)])
            cur = nxt
            here = idx == cur
            latched = jnp.where(here, v, latched)
            got = got | here
    return jnp.where(want & got, latched, x)


def ring_scan(x: jax.Array, axis_name: str,
              fn: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
              init: jax.Array, reverse: bool = False) -> jax.Array:
    """The compute-overlap hook (bank-level-parallelism analogue).

    Runs ``n`` steps; at step ``k`` the device holds the shard originally on
    device ``(idx -+ k) mod n`` and calls ``acc = fn(acc, shard, src_index)``.
    The ppermute for step k+1 overlaps with fn's compute at step k (XLA
    schedules the collective-permute-start before the dot).
    """
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    step = -1 if reverse else 1
    perm = _shift_perm(n, step)
    init = jax.lax.pvary(init, (axis_name,))   # mark device-varying for scan

    def body(k, carry):
        acc, buf = carry
        src = (idx - step * k) % n
        nxt = jax.lax.ppermute(buf, axis_name, perm)   # overlaps with fn
        acc = fn(acc, buf, src)
        return acc, nxt

    acc, _ = jax.lax.fori_loop(0, n, body, (init, x))
    return acc


def ring_allgather(x: jax.Array, axis_name: str, axis: int = 0) -> jax.Array:
    """All-gather via an RBM hop ring: n-1 hops, each carrying one shard."""
    n = jax.lax.axis_size(axis_name)
    shape = (n,) + x.shape

    def take(acc, shard, src):
        return jax.lax.dynamic_update_index_in_dim(acc, shard, src, 0)

    out = ring_scan(x, axis_name, take, jnp.zeros(shape, x.dtype))
    if axis != 0:
        out = jnp.moveaxis(out, 0, axis)
        return out.reshape(x.shape[:axis] + (n * x.shape[axis],) + x.shape[axis + 1:])
    return out.reshape((n * x.shape[0],) + x.shape[1:])


def ring_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """Reduce-scatter via a hop ring.  ``x``: (n, chunk...) per device;
    returns chunk ``idx`` summed across devices (n-1 hops)."""
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = _shift_perm(n, 1)

    def body(t, acc):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        return acc + x[(idx - t - 1) % n]

    acc = x[(idx - 1) % n]
    return jax.lax.fori_loop(1, n, body, acc)


def ring_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce = reduce-scatter + all-gather over the hop ring.

    2(n-1) hops each carrying 1/n of the payload — the bandwidth-optimal
    schedule, and structurally the paper's hop chain run twice.
    """
    n = jax.lax.axis_size(axis_name)
    if n == 1:
        return x
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    mine = ring_reduce_scatter(chunks, axis_name)
    full = ring_allgather(mine, axis_name)
    return full.reshape(-1)[:x.size].reshape(x.shape)


def ring_allgather_matmul(x: jax.Array, w: jax.Array, axis_name: str
                          ) -> jax.Array:
    """FSDP forward pattern with per-hop overlap: ``w`` is sharded on its
    *input* dim over the axis; computes ``x @ w_full`` without ever
    materialising ``w_full`` — each hop's shard is consumed by a partial
    matmul while the next hop is in flight (LISA's "other banks keep
    serving" property).

    x: (..., d) with d = n * d_shard;  w: (d_shard, f)  ->  (..., f)
    """
    n = jax.lax.axis_size(axis_name)
    d_shard = w.shape[0]

    def partial(acc, w_shard, src):
        x_slice = jax.lax.dynamic_slice_in_dim(x, src * d_shard, d_shard, -1)
        return acc + x_slice @ w_shard

    out_shape = x.shape[:-1] + (w.shape[1],)
    init = jnp.zeros(out_shape, jnp.result_type(x.dtype, w.dtype))
    return ring_scan(w, axis_name, partial, init)
