"""Hop-distance cost model for the TPU mesh (the Table-1 linear model,
re-parameterised with ICI constants).

The paper's central quantitative structure is *linear-in-hops* transfer cost
with a large constant advantage over the global bus (Table 1:
T_RISC(h) = 140.5 + 8h ns vs. 1363.75 ns flat).  On a TPU v5e mesh the same
structure holds for neighbor-hop (collective-permute) schedules vs.
host-mediated / DCN movement:

    T_hop_chain(h, bytes) = h * (alpha_ici + bytes / bw_ici)
    T_host_path(bytes)    = 2 * (alpha_pcie + bytes / bw_pcie)

The analogy is *literal in the API*: :func:`ici_dram_spec` expresses the mesh
as just another :class:`~repro.core.dram.spec.DramSpec` instance — a "row" is
one transfer of ``nbytes``, the RBM hop is one ICI neighbor hop, and the
off-chip channel is the PCIe host path — and the public cost functions below
are computed through that spec's ``CopyMechanism`` registry ("lisa" for the
hop chain, "memcpy" for the host path).

The runtime uses this model for cost-aware migration decisions (the paper's
"intelligent cost-aware mechanism", Sec. 3.2) — e.g. whether moving a KV page
between replicas is worth it, or which of several fast-tier slots to fill.
See DESIGN.md Sec. 2 for the full DRAM <-> TPU mapping.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.core.dram.spec import DramSpec, DramTiming, LisaTiming

# TPU v5e-ish constants (per task spec + public system papers).
ICI_LINK_GBPS = 50.0        # GB/s per ICI link direction
HBM_GBPS = 819.0            # GB/s HBM bandwidth per chip
PEAK_BF16_TFLOPS = 197.0    # per chip
ICI_ALPHA_US = 1.0          # per-hop launch latency (us), conservative
PCIE_GBPS = 16.0            # host <-> device path (the "narrow bus")
PCIE_ALPHA_US = 5.0


@functools.lru_cache(maxsize=256)
def ici_dram_spec(nbytes: int) -> DramSpec:
    """The ICI mesh as a ``DramSpec``: the DRAM <-> TPU analogy made literal.

    One "row" is a transfer of ``nbytes``; moving it one subarray over
    (``spec.copy_latency("lisa", h)``) is ``h`` ICI neighbor hops, and moving
    it over the "off-chip channel" (``spec.copy_latency("memcpy")``) is the
    two-leg PCIe host path.  Mapping (GB/s == bytes/ns; us == 1000 ns):

      * ``lisa.t_rbm_hop``  = alpha_ici + nbytes / bw_ici, with a zero
        ``risc_base`` (tRAS = tRP = sense_margin = 0 — there is no sensing
        phase on the mesh), so T_lisa(h) = h * per-hop cost exactly;
      * ``timing.tRCD``     = alpha_pcie and ``timing.tCCD`` = the PCIe
        transfer time, with one "cache line" per row and every other phase
        zeroed, so T_memcpy = 2 * (alpha_pcie + transfer) exactly;
      * ``t_rbm_row`` makes ``spec.rbm_bw_gbps`` == the ICI link bandwidth,
        and ``channel_bw_gbps`` is PCIe — the Sec. 2 bandwidth-ratio claim
        becomes the ICI : PCIe ratio (~3.1x).
    """
    alpha_ici_ns = ICI_ALPHA_US * 1e3
    alpha_pcie_ns = PCIE_ALPHA_US * 1e3
    return DramSpec(
        name=f"TPU_V5E_ICI_{nbytes}B",
        row_bytes=nbytes,
        cache_line_bytes=nbytes,       # one transfer per "row"
        timing=DramTiming(tCK=0.0, tRCD=alpha_pcie_ns, tRP=0.0, tRAS=0.0,
                          tCL=0.0, tCWL=0.0, tCCD=nbytes / PCIE_GBPS,
                          tBURST=0.0, tWR=0.0, tRTP=0.0),
        lisa=LisaTiming(t_rbm_hop=alpha_ici_ns + nbytes / ICI_LINK_GBPS,
                        t_rbm_row=nbytes / ICI_LINK_GBPS,
                        sense_margin=0.0,
                        t_pre_baseline=0.0, t_pre_linked=0.0),
        channel_bw_gbps=PCIE_GBPS,
    )


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """A 1-D ring view of one mesh axis (what hop schedules run over)."""
    size: int
    wraparound: bool = True     # TPU ICI tori have wraparound links

    def hops(self, src: int, dst: int) -> int:
        d = abs(dst - src)
        return min(d, self.size - d) if self.wraparound else d

    def path(self, src: int, dst: int) -> list[int]:
        d = (dst - src) % self.size
        if self.wraparound and d > self.size - d:
            step, n = -1, self.size - d
        else:
            step, n = 1, d
        return [(src + step * (i + 1)) % self.size for i in range(n)]


def hop_chain_us(hops: int, nbytes: int) -> float:
    """Neighbor-hop chain cost (the RBM-chain analogue).  Zero hops — the
    data is already local — is a free move."""
    if hops <= 0:
        return 0.0
    return ici_dram_spec(nbytes).copy_latency("lisa", hops) / 1e3


def host_path_us(nbytes: int) -> float:
    """Through-the-host cost (the memcpy-over-channel analogue)."""
    return ici_dram_spec(nbytes).copy_latency("memcpy") / 1e3


def ring_collective_us(axis_size: int, shard_bytes: int,
                       kind: str = "all_gather") -> float:
    """Cost of a ring collective over one mesh axis.

    all_gather / reduce_scatter: (n-1) hops, each carrying one shard.
    all_reduce: reduce_scatter + all_gather = 2(n-1) hops.
    """
    steps = {"all_gather": axis_size - 1,
             "reduce_scatter": axis_size - 1,
             "all_reduce": 2 * (axis_size - 1)}[kind]
    if steps <= 0:
        return 0.0
    return ici_dram_spec(shard_bytes).copy_latency("lisa", steps) / 1e3


def migration_worthwhile(nbytes: int, hops: int, expected_hits: float,
                         fast_gain_us: float) -> bool:
    """Paper Sec. 3.2: 'an intelligent cost-aware mechanism is required to
    make astute decisions on which data to cache and when.'  Move data only
    if the expected latency saved exceeds the movement cost."""
    return expected_hits * fast_gain_us > hop_chain_us(hops, nbytes)
