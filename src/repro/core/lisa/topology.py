"""Hop-distance cost model for the TPU mesh (the Table-1 linear model,
re-parameterised with ICI constants).

The paper's central quantitative structure is *linear-in-hops* transfer cost
with a large constant advantage over the global bus (Table 1:
T_RISC(h) = 140.5 + 8h ns vs. 1363.75 ns flat).  On a TPU v5e mesh the same
structure holds for neighbor-hop (collective-permute) schedules vs.
host-mediated / DCN movement:

    T_hop_chain(h, bytes) = h * (alpha_ici + bytes / bw_ici)
    T_host_path(bytes)    = 2 * (alpha_pcie + bytes / bw_pcie)

The runtime uses this model for cost-aware migration decisions (the paper's
"intelligent cost-aware mechanism", Sec. 3.2) — e.g. whether moving a KV page
between replicas is worth it, or which of several fast-tier slots to fill.
"""
from __future__ import annotations

import dataclasses

# TPU v5e-ish constants (per task spec + public system papers).
ICI_LINK_GBPS = 50.0        # GB/s per ICI link direction
HBM_GBPS = 819.0            # GB/s HBM bandwidth per chip
PEAK_BF16_TFLOPS = 197.0    # per chip
ICI_ALPHA_US = 1.0          # per-hop launch latency (us), conservative
PCIE_GBPS = 16.0            # host <-> device path (the "narrow bus")
PCIE_ALPHA_US = 5.0


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """A 1-D ring view of one mesh axis (what hop schedules run over)."""
    size: int
    wraparound: bool = True     # TPU ICI tori have wraparound links

    def hops(self, src: int, dst: int) -> int:
        d = abs(dst - src)
        return min(d, self.size - d) if self.wraparound else d

    def path(self, src: int, dst: int) -> list[int]:
        d = (dst - src) % self.size
        if self.wraparound and d > self.size - d:
            step, n = -1, self.size - d
        else:
            step, n = 1, d
        return [(src + step * (i + 1)) % self.size for i in range(n)]


def hop_chain_us(hops: int, nbytes: int) -> float:
    """Neighbor-hop chain cost (the RBM-chain analogue)."""
    return hops * (ICI_ALPHA_US + nbytes / (ICI_LINK_GBPS * 1e3))


def host_path_us(nbytes: int) -> float:
    """Through-the-host cost (the memcpy-over-channel analogue)."""
    return 2 * (PCIE_ALPHA_US + nbytes / (PCIE_GBPS * 1e3))


def ring_collective_us(axis_size: int, shard_bytes: int,
                       kind: str = "all_gather") -> float:
    """Cost of a ring collective over one mesh axis.

    all_gather / reduce_scatter: (n-1) hops, each carrying one shard.
    all_reduce: reduce_scatter + all_gather = 2(n-1) hops.
    """
    steps = {"all_gather": axis_size - 1,
             "reduce_scatter": axis_size - 1,
             "all_reduce": 2 * (axis_size - 1)}[kind]
    return steps * (ICI_ALPHA_US + shard_bytes / (ICI_LINK_GBPS * 1e3))


def migration_worthwhile(nbytes: int, hops: int, expected_hits: float,
                         fast_gain_us: float) -> bool:
    """Paper Sec. 3.2: 'an intelligent cost-aware mechanism is required to
    make astute decisions on which data to cache and when.'  Move data only
    if the expected latency saved exceeds the movement cost."""
    return expected_hits * fast_gain_us > hop_chain_us(hops, nbytes)
