"""LISA-VILLA on TPU: a tiered store with the paper's exact caching policy.

The DRAM version caches hot rows in fast (short-bitline) subarrays; the TPU
version caches hot *items* (KV-cache pages, expert weights, request states) in
a small fast tier against a large slow tier.  On real hardware the fast tier
is HBM-resident working set and the slow tier is host memory / a compressed
pool; movement between them is the expensive bulk transfer LISA accelerates —
cost-awareness comes from ``topology.migration_worthwhile``.

The *policy* (counters / epochs / top-16 hot marking / benefit-based
replacement) is literally ``repro.core.dram.villa`` — the same code drives the
DRAM reproduction and the TPU runtime.  That reuse is the "LISA as substrate"
claim made concrete.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.dram.villa import VillaConfig, VillaState, villa_access, villa_init


class TieredStore(NamedTuple):
    policy: VillaState
    fast: jax.Array      # (n_slots, *item_shape) — hot tier
    slow: jax.Array      # (n_items, *item_shape) — bulk tier
    hits: jax.Array      # () int32
    accesses: jax.Array  # () int32


def make_store(slow: jax.Array, cfg: VillaConfig) -> TieredStore:
    item_shape = slow.shape[1:]
    return TieredStore(
        policy=villa_init(cfg),
        fast=jnp.zeros((cfg.n_slots,) + item_shape, slow.dtype),
        slow=slow,
        hits=jnp.zeros((), jnp.int32),
        accesses=jnp.zeros((), jnp.int32),
    )


def access(store: TieredStore, item_id: jax.Array, cfg: VillaConfig
           ) -> Tuple[TieredStore, jax.Array, jax.Array]:
    """Read item ``item_id`` through the tiered store.

    Returns (store', data, hit).  Hot items are promoted on access (the
    paper's "cache them when they are accessed the next time"), evicting the
    minimum-benefit slot.  Promotion copies slow->fast — the bulk movement
    that LISA-RISC (hop chains / rbm_copy kernel) performs on hardware.
    """
    item_id = jnp.asarray(item_id, jnp.int32)
    policy, hit, insert, victim = villa_access(store.policy, item_id, cfg)
    slow_data = store.slow[item_id]
    fast = jnp.where(insert, store.fast.at[victim].set(slow_data), store.fast)
    slot = jnp.argmax(policy.tags == item_id)          # valid for hit & insert
    data = jnp.where(hit, fast[slot], slow_data)
    return (TieredStore(policy=policy, fast=fast, slow=store.slow,
                        hits=store.hits + hit.astype(jnp.int32),
                        accesses=store.accesses + 1),
            data, hit)


def write(store: TieredStore, item_id: jax.Array, data: jax.Array
          ) -> TieredStore:
    """Write-through: update the slow tier, and the fast slot if resident."""
    item_id = jnp.asarray(item_id, jnp.int32)
    slow = store.slow.at[item_id].set(data)
    resident = store.policy.tags == item_id
    slot = jnp.argmax(resident)
    fast = jnp.where(resident.any(), store.fast.at[slot].set(data), store.fast)
    return store._replace(slow=slow, fast=fast)


def hit_rate(store: TieredStore) -> jax.Array:
    return jnp.where(store.accesses > 0,
                     store.hits / jnp.maximum(store.accesses, 1), 0.0)
