"""LISA-VILLA on TPU: a tiered store with the paper's exact caching policy.

The DRAM version caches hot rows in fast (short-bitline) subarrays; the TPU
version caches hot *items* (KV-cache pages, expert weights, request states) in
a small fast tier against a large slow tier.  On real hardware the fast tier
is HBM-resident working set and the slow tier is host memory / a compressed
pool; movement between them is the expensive bulk transfer LISA accelerates —
cost-awareness comes from ``topology.migration_worthwhile``.

The *policy* (counters / epochs / top-16 hot marking / benefit-based
replacement) is literally ``repro.core.dram.villa`` — the same code drives the
DRAM reproduction and the TPU runtime.  That reuse is the "LISA as substrate"
claim made concrete.

Items may be flat vectors or *paged*: a store whose items have shape
(pages, P, d) — e.g. the serving engine's KV-snapshot pages
(``repro.serve.paged_store``) — moves data through the Pallas RBM kernels
(``villa_gather`` / ``villa_scatter``, scalar-prefetched page tables, LIP
double buffering) instead of dense indexing, so tier movement is the wide
in-DRAM transfer of the paper rather than a narrow-channel memcpy.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.dram.villa import VillaConfig, VillaState, villa_access, villa_init
from repro.kernels.rbm_copy import villa_gather, villa_scatter


class TieredStore(NamedTuple):
    policy: VillaState
    fast: jax.Array      # (n_slots, *item_shape) — hot tier
    slow: jax.Array      # (n_items, *item_shape) — bulk tier
    hits: jax.Array      # () int32
    accesses: jax.Array  # () int32


def _paged(arr: jax.Array) -> bool:
    """Items of shape (pages, P, d) route through the RBM page kernels."""
    return arr.ndim == 4


def _read_item(arr: jax.Array, item_id: jax.Array) -> jax.Array:
    if _paged(arr):
        n, spp, P, d = arr.shape
        table = item_id * spp + jnp.arange(spp, dtype=jnp.int32)
        return villa_gather(arr.reshape(n * spp, P, d), table)
    return arr[item_id]


def _write_item(arr: jax.Array, item_id: jax.Array, data: jax.Array
                ) -> jax.Array:
    if _paged(arr):
        n, spp, P, d = arr.shape
        table = item_id * spp + jnp.arange(spp, dtype=jnp.int32)
        return villa_scatter(arr.reshape(n * spp, P, d), table,
                             data).reshape(arr.shape)
    return arr.at[item_id].set(data)


def make_store(slow: jax.Array, cfg: VillaConfig) -> TieredStore:
    item_shape = slow.shape[1:]
    return TieredStore(
        policy=villa_init(cfg),
        fast=jnp.zeros((cfg.n_slots,) + item_shape, slow.dtype),
        slow=slow,
        hits=jnp.zeros((), jnp.int32),
        accesses=jnp.zeros((), jnp.int32),
    )


def access(store: TieredStore, item_id: jax.Array, cfg: VillaConfig
           ) -> Tuple[TieredStore, jax.Array, jax.Array]:
    """Read item ``item_id`` through the tiered store.

    Returns (store', data, hit).  Hot items are promoted on access (the
    paper's "cache them when they are accessed the next time"), evicting the
    minimum-benefit slot.  Promotion copies slow->fast — the bulk movement
    that LISA-RISC (hop chains / rbm_copy kernel) performs on hardware.
    """
    item_id = jnp.asarray(item_id, jnp.int32)
    policy, hit, insert, victim = villa_access(store.policy, item_id, cfg)
    slow_data = _read_item(store.slow, item_id)
    fast = jnp.where(insert, _write_item(store.fast, victim, slow_data),
                     store.fast)
    slot = jnp.argmax(policy.tags == item_id)          # valid for hit & insert
    data = jnp.where(hit, _read_item(fast, slot), slow_data)
    return (TieredStore(policy=policy, fast=fast, slow=store.slow,
                        hits=store.hits + hit.astype(jnp.int32),
                        accesses=store.accesses + 1),
            data, hit)


def write(store: TieredStore, item_id: jax.Array, data: jax.Array
          ) -> TieredStore:
    """Write-through: update the slow tier, and the fast slot if resident."""
    item_id = jnp.asarray(item_id, jnp.int32)
    slow = _write_item(store.slow, item_id, data)
    resident = store.policy.tags == item_id
    slot = jnp.argmax(resident)
    fast = jnp.where(resident.any(), _write_item(store.fast, slot, data),
                     store.fast)
    return store._replace(slow=slow, fast=fast)


def access_many(store: TieredStore, item_ids: jax.Array, cfg: VillaConfig
                ) -> Tuple[TieredStore, jax.Array, jax.Array]:
    """Batched :func:`access`: one jitted dispatch serves a whole wave of
    reads (e.g. a burst of session resumes).  Policy updates apply
    sequentially in ``item_ids`` order — exactly equivalent to a Python loop
    of ``access`` calls, without the per-item dispatch/sync.

    Returns (store', data (k, *item_shape), hits (k,)).
    """
    item_ids = jnp.asarray(item_ids, jnp.int32)

    def body(st, i):
        st, data, hit = access(st, i, cfg)
        return st, (data, hit)

    store, (data, hits) = jax.lax.scan(body, store, item_ids)
    return store, data, hits


def write_many(store: TieredStore, item_ids: jax.Array, data: jax.Array
               ) -> TieredStore:
    """Batched :func:`write`: one dispatch for a wave of write-throughs.
    ``data``: (k, *item_shape), written in order (later duplicates win)."""
    item_ids = jnp.asarray(item_ids, jnp.int32)

    def body(st, xs):
        i, d = xs
        return write(st, i, d), None

    store, _ = jax.lax.scan(body, store, (item_ids, data))
    return store


def hit_rate(store: TieredStore) -> jax.Array:
    return jnp.where(store.accesses > 0,
                     store.hits / jnp.maximum(store.accesses, 1), 0.0)
