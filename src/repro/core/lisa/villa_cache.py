"""LISA-VILLA on TPU: a tiered store with the paper's exact caching policy.

The DRAM version caches hot rows in fast (short-bitline) subarrays; the TPU
version caches hot *items* (KV-cache pages, expert weights, request states) in
a small fast tier against a large slow tier.  On real hardware the fast tier
is HBM-resident working set and the slow tier is host memory / a compressed
pool; movement between them is the expensive bulk transfer LISA accelerates —
cost-awareness comes from ``topology.migration_worthwhile``.

The *policy* (counters / epochs / top-16 hot marking / benefit-based
replacement) is literally ``repro.core.dram.villa`` — the same code drives the
DRAM reproduction and the TPU runtime.  That reuse is the "LISA as substrate"
claim made concrete.

This module owns WHAT moves (the caching policy); HOW it moves is the
movement substrate: every paged read/write lowers through
``repro.movement.plan`` to page gather/scatter legs executed by the Pallas
RBM kernels (scalar-prefetched page tables, LIP double buffering,
input/output aliasing), so tier movement is the wide in-DRAM transfer of
the paper rather than a narrow-channel memcpy.  In return this module
registers the policy-mediated ``tier_read`` / ``tier_write`` legs with the
movement registry, so higher layers (the serving engine) can plan whole
suspend/resume transfers that route through the policy.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro import movement as MV
from repro.core.dram.villa import VillaConfig, VillaState, villa_access, villa_init


class TieredStore(NamedTuple):
    policy: VillaState
    fast: jax.Array      # (n_slots, *item_shape) — hot tier
    slow: jax.Array      # (n_items, *item_shape) — bulk tier
    hits: jax.Array      # () int32
    accesses: jax.Array  # () int32


def _paged(arr: jax.Array) -> bool:
    """Items of shape (pages, P, d) route through the RBM page kernels."""
    return arr.ndim == 4


@functools.lru_cache(maxsize=None)
def _pool_plan(direction: str, tier: str, spp: int, P: int, d: int,
               dtype_name: str) -> MV.MovementPlan:
    """One item's worth of raw page movement, planned once per pool shape.

    ``direction``: "read" lowers to a page-gather leg, "write" to a
    page-scatter leg; ``tier`` names the pool being addressed ("slow" or
    "fast") so the plan's transfer — and its ``describe()`` — reports the
    tier the movement actually touches.  Both are priced at one item's
    true bytes.  (An explicit whole-item promotion is the composite
    slow->fast plan: gather from ``src_pool``, scatter into ``dst_pool``,
    priced as one copy.)"""
    layout = MV.Layout.raw_pages(spp, P, d, dtype_name)
    src, dst = ((tier, "compute") if direction == "read"
                else ("compute", tier))
    return MV.plan(MV.Transfer(MV.Tier(src), MV.Tier(dst), layout))


def _read_item(arr: jax.Array, item_id: jax.Array,
               tier: str = "slow") -> jax.Array:
    if _paged(arr):
        n, spp, P, d = arr.shape
        table = item_id * spp + jnp.arange(spp, dtype=jnp.int32)
        p = _pool_plan("read", tier, spp, P, d, str(arr.dtype))
        return MV.execute(p, pool=arr.reshape(n * spp, P, d),
                          table=table)["data"]
    return arr[item_id]


def _write_item(arr: jax.Array, item_id: jax.Array, data: jax.Array,
                tier: str = "slow") -> jax.Array:
    if _paged(arr):
        n, spp, P, d = arr.shape
        table = item_id * spp + jnp.arange(spp, dtype=jnp.int32)
        p = _pool_plan("write", tier, spp, P, d, str(arr.dtype))
        return MV.execute(p, pool=arr.reshape(n * spp, P, d), table=table,
                          data=data)["pool"].reshape(arr.shape)
    return arr.at[item_id].set(data)


def make_store(slow: jax.Array, cfg: VillaConfig) -> TieredStore:
    item_shape = slow.shape[1:]
    return TieredStore(
        policy=villa_init(cfg),
        fast=jnp.zeros((cfg.n_slots,) + item_shape, slow.dtype),
        slow=slow,
        hits=jnp.zeros((), jnp.int32),
        accesses=jnp.zeros((), jnp.int32),
    )


def access(store: TieredStore, item_id: jax.Array, cfg: VillaConfig
           ) -> Tuple[TieredStore, jax.Array, jax.Array]:
    """Read item ``item_id`` through the tiered store.

    Returns (store', data, hit).  Hot items are promoted on access (the
    paper's "cache them when they are accessed the next time"), evicting the
    minimum-benefit slot.  Promotion copies slow->fast — a gather+scatter
    movement plan, the bulk transfer LISA-RISC performs on hardware.
    """
    item_id = jnp.asarray(item_id, jnp.int32)
    policy, hit, insert, victim = villa_access(store.policy, item_id, cfg)
    slow_data = _read_item(store.slow, item_id, tier="slow")
    fast = jnp.where(insert,
                     _write_item(store.fast, victim, slow_data, tier="fast"),
                     store.fast)
    slot = jnp.argmax(policy.tags == item_id)          # valid for hit & insert
    data = jnp.where(hit, _read_item(fast, slot, tier="fast"), slow_data)
    return (TieredStore(policy=policy, fast=fast, slow=store.slow,
                        hits=store.hits + hit.astype(jnp.int32),
                        accesses=store.accesses + 1),
            data, hit)


def write(store: TieredStore, item_id: jax.Array, data: jax.Array
          ) -> TieredStore:
    """Write-through: update the slow tier, and the fast slot if resident."""
    item_id = jnp.asarray(item_id, jnp.int32)
    slow = _write_item(store.slow, item_id, data, tier="slow")
    resident = store.policy.tags == item_id
    slot = jnp.argmax(resident)
    fast = jnp.where(resident.any(),
                     _write_item(store.fast, slot, data, tier="fast"),
                     store.fast)
    return store._replace(slow=slow, fast=fast)


def access_many(store: TieredStore, item_ids: jax.Array, cfg: VillaConfig
                ) -> Tuple[TieredStore, jax.Array, jax.Array]:
    """Batched :func:`access`: one jitted dispatch serves a whole wave of
    reads (e.g. a burst of session resumes).  Policy updates apply
    sequentially in ``item_ids`` order — exactly equivalent to a Python loop
    of ``access`` calls, without the per-item dispatch/sync.

    Returns (store', data (k, *item_shape), hits (k,)).
    """
    item_ids = jnp.asarray(item_ids, jnp.int32)

    def body(st, i):
        st, data, hit = access(st, i, cfg)
        return st, (data, hit)

    store, (data, hits) = jax.lax.scan(body, store, item_ids)
    return store, data, hits


def write_many(store: TieredStore, item_ids: jax.Array, data: jax.Array
               ) -> TieredStore:
    """Batched :func:`write`: one dispatch for a wave of write-throughs.
    ``data``: (k, *item_shape), written in order (later duplicates win)."""
    item_ids = jnp.asarray(item_ids, jnp.int32)

    def body(st, xs):
        i, d = xs
        return write(st, i, d), None

    store, _ = jax.lax.scan(body, store, (item_ids, data))
    return store


def clone_item(store: TieredStore, src_id: jax.Array,
               dst_id: jax.Array) -> TieredStore:
    """Device-side slow-row clone ``src_id -> dst_id`` (a shared-row
    demotion: the fork table is about to repoint aliases onto ``dst_id``
    and hand ``src_id`` to a new exclusive owner).

    Copies through the same priced page gather/scatter plans as any other
    pool movement, and invalidates any fast-tier residency of the
    DESTINATION row on-device (``jnp.where`` over the tags — no host
    sync): the fast slot, if any, still tags the SOURCE id, which keeps
    serving the aliases until their next access re-resolves.
    """
    src_id = jnp.asarray(src_id, jnp.int32)
    dst_id = jnp.asarray(dst_id, jnp.int32)
    data = _read_item(store.slow, src_id, tier="slow")
    slow = _write_item(store.slow, dst_id, data, tier="slow")
    tags = jnp.where(store.policy.tags == dst_id,
                     jnp.full_like(store.policy.tags, -1),
                     store.policy.tags)
    return store._replace(slow=slow,
                          policy=store.policy._replace(tags=tags))


def hit_rate(store: TieredStore) -> jax.Array:
    return jnp.where(store.accesses > 0,
                     store.hits / jnp.maximum(store.accesses, 1), 0.0)


# ---------------------------------------------------------------------------
# Movement-registry integration: the policy-mediated tier legs.  A plan
# whose transfer sets ``policy=`` lowers to these; the serving engine's
# suspend/resume flows are exactly such plans.
# ---------------------------------------------------------------------------

@MV.register_backend("tier_read")
def _tier_read_backend(leg: MV.TierReadLeg, env: MV.Env) -> MV.Env:
    # Plural env keys declare a wave, so a batch-1 fused plan (one-element
    # resume wave) still routes through the batched scan path.
    env = dict(env)
    if leg.batch > 1 or "items" in env:
        env["store"], env["data"], env["hits"] = access_many(
            env["store"], env["items"], leg.policy)
    else:
        env["store"], env["data"], env["hit"] = access(
            env["store"], env["item"], leg.policy)
    return env


@MV.register_backend("tier_write")
def _tier_write_backend(leg: MV.TierWriteLeg, env: MV.Env) -> MV.Env:
    env = dict(env)
    if leg.batch > 1 or "items" in env:
        env["store"] = write_many(env["store"], env["items"], env["data"])
    else:
        env["store"] = write(env["store"], env["item"], env["data"])
    return env
