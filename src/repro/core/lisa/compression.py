"""Error-feedback int8 gradient compression for the data-parallel axis.

A 1000+-node requirement: gradient all-reduce bandwidth.  Per-tensor absmax
int8 quantization with local error feedback (residual carried to the next
step) keeps convergence while cutting DP collective bytes 2x vs bf16 / 4x vs
fp32.  Composes with the LISA ring all-reduce (the quantized payload rides
the hop chain).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize ``g + err`` to int8.  Returns (q, scale, new_err)."""
    target = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(target)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    new_err = target - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def allreduce_mean_compressed(g: jax.Array, err: jax.Array, axis_name: str
                              ) -> Tuple[jax.Array, jax.Array]:
    """DP gradient mean with int8 payload + error feedback.

    The int32 sum is exact for <= 2^23 devices; the shared scale is the max
    across the axis so all devices dequantize identically.
    """
    target = g.astype(jnp.float32) + err
    scale = jnp.maximum(jax.lax.pmax(
        jnp.max(jnp.abs(target)), axis_name), 1e-12) / 127.0
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    new_err = target - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.axis_size(axis_name)
    return (total.astype(jnp.float32) * scale / n).astype(g.dtype), new_err


def init_error(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
