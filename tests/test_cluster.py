"""Multi-replica cluster serving: live migration (bit-exact, loss-free),
placement scheduling, fused per-replica waves, and the mesh-executed
migration plan.

The decode parity test extends PR 2's suspend→resume equivalence across a
replica boundary: suspend on replica A, hop-chain migrate, resume on
replica B must be token-identical to the uninterrupted single-replica run.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _multidev import run_with_devices

from repro import sched
from repro.analysis import testlib as TL
from repro.configs import get_reduced
from repro.models import lm
from repro.serve.cluster import Cluster
from repro.serve.engine import Engine, Request, UnknownSession


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("tinyllama-1.1b")
    params = lm.init_lm(cfg, jax.random.key(0))
    return cfg, params


def _greedy_reference(cfg, params, prompt, n_new, max_len=96):
    cache = lm.init_cache(cfg, 1, max_len=max_len)
    logits, cache = lm.prefill(cfg, params, jnp.asarray(prompt)[None], cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(toks) < n_new:
        lg, cache = lm.decode_step(cfg, params, cache,
                                   jnp.asarray([[toks[-1]]]), jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return toks


def _drain_to_store(cl, uid, prompt, max_new, replica):
    """Submit on ``replica``, run to completion (auto-suspend), return the
    request."""
    req = Request(uid=uid, prompt=prompt, max_new=max_new)
    cl.submit(req, replica=replica)
    while cl.active:
        cl.step()
    return req


# ---------------------------------------------------------------------------
# live migration: bit-exactness and loss-freedom
# ---------------------------------------------------------------------------

def test_migrated_decode_matches_uninterrupted(setup):
    """suspend on replica A -> hop-chain migrate -> resume on replica B is
    token-identical to the uninterrupted single-replica decode (the PR 2
    parity test, extended across a replica boundary)."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    straight = _greedy_reference(cfg, params, prompt, 8)

    cl = Cluster(cfg, params, n_replicas=4, slots=2, max_len=96,
                 n_sessions=8)
    req = _drain_to_store(cl, 7, prompt, 4, replica=0)
    assert cl.residence[7] == 0
    cl.migrate(7, 2)
    assert cl.residence[7] == 2
    assert 7 not in cl.replicas[0].session_pos      # loss-free handoff:
    assert 7 in cl.replicas[2].session_pos          # exactly one snapshot
    slot = cl.resume(7, extra_new=5)                # seed + 4 new tokens
    assert cl.replica_of(slot) == 2
    r2 = cl.active[slot]
    while cl.active:
        cl.step()
    assert req.generated + r2.generated[1:] == straight
    assert cl.cluster_stats["migrations"] == 1


def test_migration_moves_the_exact_snapshot_bytes(setup):
    """The migrated page block lands in the destination pool bit-for-bit
    (uint8 pages, no re-encode), at the destination's store index."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=96,
                 n_sessions=8)
    _drain_to_store(cl, 3, prompt, 3, replica=0)
    src_block = np.asarray(cl.replicas[0].sessions.slow[3]).copy()
    cl.migrate(3, 1)
    dst_block = np.asarray(cl.replicas[1].sessions.slow[3])
    assert src_block.dtype == np.uint8
    assert np.array_equal(src_block, dst_block)


def test_migrate_many_fuses_one_dispatch_per_route(setup):
    """A rebalance burst of k sessions sharing a route is ONE gather+
    scatter dispatch (one fused page table), not k dispatches."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    cl = Cluster(cfg, params, n_replicas=4, slots=2, max_len=96,
                 n_sessions=16)
    for uid in range(4):
        _drain_to_store(cl, uid,
                        rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                        3, replica=0)
    metas = {u: cl.replicas[0].session_meta(u) for u in range(4)}
    cl.migrate_many([(0, 1), (1, 1), (2, 1), (3, 3)])
    assert cl.cluster_stats["migrations"] == 4
    assert cl.cluster_stats["migration_waves"] == 2     # routes 0->1, 0->3
    TL.assert_compile_count(cl, "migrate", 2)           # one per wave width
    for u, dst in [(0, 1), (1, 1), (2, 1), (3, 3)]:
        assert cl.residence[u] == dst
        assert cl.replicas[dst].session_meta(u) == metas[u]   # loss-free
    # the 3-session wave is priced as 3x the single-session route plan
    assert cl.migration_plan(0, 1, 3).cost.ns_lisa == pytest.approx(
        3 * cl.migration_plan(0, 1).cost.ns_lisa)


def test_migration_errors_are_loud(setup):
    cfg, params = setup
    rng = np.random.default_rng(3)
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=96,
                 n_sessions=8)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    with pytest.raises(UnknownSession):
        cl.migrate(9, 1)                       # never suspended anywhere
    req = Request(uid=0, prompt=prompt, max_new=10)
    cl.submit(req, replica=0)
    with pytest.raises(ValueError, match="active"):
        cl.migrate_many([(0, 1)])              # running sessions don't move
    while cl.active:
        cl.step()
    with pytest.raises(ValueError, match="real route"):
        cl.migrate(0, 0)                       # already home
    with pytest.raises(ValueError, match="duplicate"):
        cl.migrate_many([(0, 1), (0, 1)])
    with pytest.raises(ValueError, match="unknown destination"):
        cl.migrate(0, 5)
    assert cl.cluster_stats["migrations"] == 0  # failed waves mutate nothing


def test_migration_pricing_is_the_ici_hop_model(setup):
    """A route plan prices gather/scatter free and the hop chain at the
    ICI Table-1 analogue: ONE copy, linear in hop distance, with the PCIe
    host path as the memcpy alternative."""
    cfg, params = setup
    from repro.core.lisa.topology import ici_dram_spec
    cl = Cluster(cfg, params, n_replicas=4, slots=1, max_len=96,
                 n_sessions=4)
    nbytes = cl.snapshot_bytes
    for dst, hops in [(1, 1), (2, 2), (3, 1)]:          # ring of 4
        p = cl.migration_plan(0, dst)
        assert [l.kind for l in p.legs] == ["page_gather", "hop_chain",
                                            "page_scatter"]
        assert p.legs[1].hops == hops
        assert p.cost.bytes == nbytes
        assert p.cost.ns_lisa == pytest.approx(
            ici_dram_spec(nbytes).copy_latency("lisa", hops))
        assert p.cost.ns_memcpy == pytest.approx(
            ici_dram_spec(nbytes).copy_latency("memcpy"))
        assert p.cost.advantage > 1.0
    assert cl.hop_ns(0, 0) == 0.0                       # home is free


def test_migration_invalidates_stale_fast_residency(setup):
    """An inbound migration that evicts a colliding store index must also
    drop that index's fast-tier residency — otherwise the next resume
    would hit the OLD session's bytes in the fast pool."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=96,
                 n_sessions=4)
    p1 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    _drain_to_store(cl, 1, p1, 3, replica=1)
    # hammer uid 1 on replica 1 until the VILLA policy promotes it
    for _ in range(12):
        cl.resume(1, extra_new=2, replica=1)
        while cl.active:
            cl.step()
        if 1 in cl.replicas[1].fast_resident_uids():
            break
    assert 1 in cl.replicas[1].fast_resident_uids()

    # uid 5 aliases store index 1 (5 % 4); migrating it to replica 1
    # evicts uid 1 there AND must clear the stale fast-tier tag
    p5 = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    straight = _greedy_reference(cfg, params, p5, 6)
    req5 = _drain_to_store(cl, 5, p5, 3, replica=0)
    cl.migrate(5, 1)
    assert 1 not in cl.replicas[1].fast_resident_uids()
    slot = cl.resume(5, extra_new=4)
    r5 = cl.active[slot]
    while cl.active:
        cl.step()
    assert req5.generated + r5.generated[1:] == straight   # not uid 1's bytes


# ---------------------------------------------------------------------------
# fleet mechanics
# ---------------------------------------------------------------------------

def test_fleet_shares_one_compilation(setup):
    """N replicas adopt replica 0's jitted entry points: the whole fleet
    compiles decode/prefill/suspend once, and per-replica decode is still
    one dispatch per replica per step."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    cl = Cluster(cfg, params, n_replicas=3, slots=1, max_len=96,
                 n_sessions=8)
    for r in range(3):
        cl.submit(Request(uid=r, prompt=rng.integers(
            0, cfg.vocab_size, 5 + r).astype(np.int32), max_new=4),
            replica=r)
    before = TL.snapshot_stats(cl)
    cl.step()
    TL.assert_dispatch_delta(before, cl.stats, decode=3)   # one per replica
    while cl.active:
        cl.step()
    TL.assert_compile_count(cl, "decode", 1)            # fleet-shared jit
    TL.assert_compile_count(cl, "prefill", (1, 2))      # per bucket length

    eng_other = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    with pytest.raises(ValueError, match="identically-configured"):
        eng_other.adopt_jits(cl.replicas[0])            # slots differ


def test_cluster_engine_shaped_views(setup):
    cfg, params = setup
    rng = np.random.default_rng(6)
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=96,
                 n_sessions=8)
    assert cl.slots == 4 and len(cl.free_slots()) == 4
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    g = cl.submit(Request(uid=0, prompt=prompt, max_new=5), replica=1)
    assert cl.replica_of(g) == 1 and g in cl.active
    assert cl.free_by_replica() == [2, 1]
    cl.suspend(g)
    assert cl.residence[0] == 1 and cl.session_pos[0] == len(prompt)
    # default resume returns home; explicit replica placement migrates
    slot = cl.resume(0, extra_new=2)
    assert cl.replica_of(slot) == 1
    while cl.active:
        cl.step()


# ---------------------------------------------------------------------------
# cluster scheduling: placement + migration as policy decisions
# ---------------------------------------------------------------------------

def _run_crafted(cfg, params, migrate):
    """Drive the shared transient-imbalance scenario (the same arrival
    stream ``benchmarks/run.py cluster`` gates on)."""
    cl = Cluster(cfg, params, n_replicas=4, slots=1, max_len=96,
                 n_sessions=128)
    s = sched.ClusterScheduler(
        cl, arrivals=sched.skewed_residence_burst(cfg.vocab_size),
        cfg=sched.SchedConfig(age_every=64), migrate=migrate)
    summary = s.run()
    return s, cl, summary


def test_migration_on_beats_migration_off_on_slo(setup):
    """The A/B the cluster bench gates on, at test scale: under a skewed-
    residence burst, migration-enabled placement fans out (all SLOs met)
    while migration-off serializes on the home replica (misses)."""
    cfg, params = setup
    s_on, cl_on, sm_on = _run_crafted(cfg, params, migrate=True)
    s_off, cl_off, sm_off = _run_crafted(cfg, params, migrate=False)
    assert sm_on["jobs_completed"] == sm_off["jobs_completed"] == 11
    assert sm_on["slo_attainment"] > sm_off["slo_attainment"]
    assert sm_on["migration"]["sessions_migrated"] >= 2
    # migration-off means exactly that: no session ever crosses replicas
    assert sm_off["migration"]["sessions_migrated"] == 0
    assert cl_off.cluster_stats["migrations"] == 0
    assert all(j.migrations == 0 for j in s_off.metrics.jobs)
    # loss-free both ways: every job serves its exact budget
    for s in (s_on, s_off):
        assert all(j.state == "done" and j.done == j.target_new
                   for j in s.jobs())
    # the cross-replica latency split is reported
    assert sm_on["migration"]["p99_latency_ns_migrated"] is not None
    assert len(sm_on["per_replica_utilization"]) == 4


def test_cluster_scheduler_slot_conservation(setup):
    """The base scheduler's core invariant holds cluster-wide: the job map
    equals the engines' active maps, one slot per session, per-replica
    occupancy never exceeds slots_per_replica."""
    cfg, params = setup
    wl = sched.WorkloadConfig(n_fresh=6, n_followups=10, mean_gap_ns=900.0,
                              arrival="bursty", burst=3, zipf_s=1.4,
                              class_slo_ns=(25_000.0, 80_000.0, math.inf))
    arrivals = sched.generate_workload(wl, seed=2, vocab_size=cfg.vocab_size)
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=96,
                 n_sessions=sched.n_sessions_for(wl))
    s = sched.ClusterScheduler(cl, arrivals=arrivals)
    last_ns = 0.0
    while s.pending():
        s.tick()
        assert s.now_ns >= last_ns                     # clock monotone
        last_ns = s.now_ns
        active = s.active_jobs()
        assert set(active) == set(cl.active)
        uids = [j.uid for j in active.values()]
        assert len(uids) == len(set(uids))
        for eng in cl.replicas:
            assert len(eng.active) <= eng.slots
        assert s.tick_count < 3000
    assert all(j.state == "done" and j.done == j.target_new
               for j in s.jobs())
    # every suspended session's residence agrees with the engine that
    # actually holds its snapshot
    for uid, r in cl.residence.items():
        assert uid in cl.replicas[r].session_pos


def test_cluster_placement_spreads_fresh_load(setup):
    """A simultaneous burst of fresh requests lands one per replica (the
    free-slot axis of place_order), not all on replica 0."""
    cfg, params = setup
    rng = np.random.default_rng(8)
    arrivals = [sched.Arrival(t_ns=0.0, uid=i, kind="fresh", priority=1,
                              slo_ns=math.inf, new_tokens=3,
                              prompt=rng.integers(0, cfg.vocab_size, 6)
                              .astype(np.int32)) for i in range(4)]
    cl = Cluster(cfg, params, n_replicas=4, slots=1, max_len=96,
                 n_sessions=8)
    s = sched.ClusterScheduler(cl, arrivals=arrivals)
    s.run()
    assert sorted(cl.residence.values()) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# the migration plan on a REAL mesh (forced host devices)
# ---------------------------------------------------------------------------

MESH_MIGRATION_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import movement as MV
from repro.core.lisa.topology import MeshTopology

mesh = jax.make_mesh((4,), ("replica",))
SRC, DST = 0, 2
pool = jax.random.randint(jax.random.key(0), (4, 8, 8, 128), 0, 256,
                          jnp.int32).astype(jnp.uint8)
src_table = jnp.asarray([1, 4, 6], jnp.int32)
dst_table = jnp.asarray([0, 2, 5], jnp.int32)
plan = MV.plan(MV.Transfer(MV.Tier("slow", index=SRC, axis="replica"),
                           MV.Tier("slow", index=DST, axis="replica"),
                           MV.Layout.raw_pages(3, 8, 128, jnp.uint8)),
               topo=MeshTopology(4))
assert [l.kind for l in plan.legs] == ["page_gather", "hop_chain",
                                       "page_scatter"]
assert plan.legs[1].hops == 2

def body(shard):
    local = shard.reshape(8, 8, 128)
    env = MV.execute(plan, src_pool=local, src_table=src_table,
                     dst_pool=local, dst_table=dst_table)
    # every replica ran the scatter on its own shard, but only the
    # destination's result is the migration; others keep their pool
    out = jnp.where(jax.lax.axis_index("replica") == DST,
                    env["dst_pool"], local)
    return out.reshape(shard.shape)

out = np.asarray(jax.jit(jax.shard_map(
    body, mesh=mesh, in_specs=P("replica"), out_specs=P("replica"),
    check_rep=False))(pool))   # pallas_call has no replication rule yet
want = np.asarray(pool).copy()
want[DST][np.asarray(dst_table)] = want[SRC][np.asarray(src_table)]
assert (out == want).all(), "migrated pages did not land bit-exactly"
print("MESH_MIGRATION_OK")
"""


def test_migration_plan_executes_on_real_mesh():
    """The same slow->slow plan the cluster prices executes its hop-chain
    leg as a real ppermute chain on a 4-device mesh: the source replica's
    pages land bit-exactly in the destination replica's pool shard."""
    out = run_with_devices(MESH_MIGRATION_CODE, 4)
    assert "MESH_MIGRATION_OK" in out
