"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops

KEY = jax.random.key(0)


def _qkv(B, H, K, S, T, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, K, T, D), dtype)
    v = jax.random.normal(ks[2], (B, K, T, D), dtype)
    return q, k, v


SWEEP = [
    # B, H, K, S,   T,   D,  causal, window, dtype
    (1, 4, 2, 128, 128, 64, True, 0, jnp.float32),
    (2, 8, 8, 64, 256, 32, True, 0, jnp.bfloat16),
    (1, 4, 4, 100, 100, 64, True, 24, jnp.float32),   # ragged + window
    (2, 2, 1, 1, 300, 128, True, 0, jnp.float32),     # decode shape
    (1, 16, 4, 256, 256, 128, True, 0, jnp.bfloat16),  # MXU-aligned
    (1, 2, 2, 64, 64, 64, False, 0, jnp.float32),     # bidirectional
    (1, 4, 2, 72, 136, 64, True, 48, jnp.bfloat16),   # odd shapes + window
]


@pytest.mark.parametrize("B,H,K,S,T,D,causal,window,dtype", SWEEP)
def test_flash_attention_sweep(B, H, K, S, T, D, causal, window, dtype):
    q, k, v = _qkv(B, H, K, S, T, D, dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    ref = ops.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    err = float(jnp.abs(out.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    assert err < tol, err


@pytest.mark.parametrize("shape,dtype", [
    ((1000, 333), jnp.float32),
    ((7, 129, 65), jnp.bfloat16),
    ((4096,), jnp.int32),
    ((256, 128), jnp.int8),
])
def test_rbm_copy_sweep(shape, dtype):
    if dtype in (jnp.int32, jnp.int8):
        x = jax.random.randint(KEY, shape, -100, 100).astype(dtype)
    else:
        x = jax.random.normal(KEY, shape, dtype)
    out = ops.rbm_copy(x, tile_rows=64)
    assert out.dtype == x.dtype and out.shape == x.shape
    assert (out == ops.rbm_copy_ref(x)).all()


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=1, max_size=12))
def test_villa_gather_property(table):
    pages = jax.random.normal(KEY, (16, 8, 128))
    t = jnp.asarray(table, jnp.int32)
    got = ops.villa_gather(pages, t)
    assert np.allclose(got, ops.villa_gather_ref(pages, t))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8,
                                   jnp.int32, jnp.uint8])
def test_villa_scatter_roundtrip_dtypes(dtype):
    """scatter∘gather round-trips bit-exactly, preserving the dtype."""
    if dtype in (jnp.int8, jnp.int32, jnp.uint8):
        pages = jax.random.randint(KEY, (16, 8, 128), 0, 100).astype(dtype)
        upd = jax.random.randint(jax.random.key(1), (5, 8, 128),
                                 -100, 0).astype(dtype)
    else:
        pages = jax.random.normal(KEY, (16, 8, 128), dtype)
        upd = jax.random.normal(jax.random.key(1), (5, 8, 128), dtype)
    table = jnp.asarray([3, 0, 11, 7, 15], jnp.int32)
    out = ops.villa_scatter(pages + 0, table, upd)
    assert out.dtype == dtype
    assert (out == ops.villa_scatter_ref(pages, table, upd)).all()
    back = ops.villa_gather(out, table)
    assert (back == upd).all()                 # gather reads the writes back


def test_villa_scatter_untouched_pages_and_dup_order():
    pages = jax.random.normal(KEY, (8, 8, 128))
    upd = jnp.stack([jnp.full((8, 128), 1.0), jnp.full((8, 128), 2.0)])
    out = ops.villa_scatter(pages + 0, jnp.asarray([2, 2], jnp.int32), upd)
    assert (out[2] == 2.0).all()               # duplicate: last write wins
    keep = [i for i in range(8) if i != 2]
    assert (out[jnp.asarray(keep)] == pages[jnp.asarray(keep)]).all()


def test_flash_attention_grad_close_to_ref():
    q, k, v = _qkv(1, 4, 2, 64, 64, 32, jnp.float32)

    def loss_kernel(q, k, v):
        return ops.flash_attention(q, k, v, block_q=32, block_k=32).sum()

    def loss_ref(q, k, v):
        return ops.flash_attention_ref(q, k, v).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        assert float(jnp.abs(a - b).max()) < 5e-4


GRAD_SWEEP = [
    # S, T, window, block_q, block_k, dtype — ragged shapes on purpose
    (100, 100, 24, 64, 64, jnp.float32),
    (72, 136, 48, 64, 32, jnp.float32),
    (96, 96, 40, 32, 64, jnp.float32),
    (50, 70, 20, 32, 32, jnp.float32),
    (64, 64, 16, 32, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("S,T,window,bq,bk,dtype", GRAD_SWEEP)
def test_flash_attention_windowed_causal_grad_equivalence(S, T, window, bq,
                                                          bk, dtype):
    """Gradient drift guard for windowed causal attention: the kernel's
    custom VJP recomputes the backward through the jnp oracle with the SAME
    ``causal``/``window`` masking, so for a NONLINEAR loss (where the
    forward value feeds the cotangent) kernel gradients must match oracle
    gradients — any forward/backward mask inconsistency (including one
    introduced by ``block_q``/``block_k`` tiling) would surface here."""
    q, k, v = _qkv(1, 4, 2, S, T, 32, dtype)

    def loss_kernel(q, k, v):
        o = ops.flash_attention(q, k, v, causal=True, window=window,
                                block_q=bq, block_k=bk)
        return (o.astype(jnp.float32) ** 2).sum()

    def loss_ref(q, k, v):
        o = ops.flash_attention_ref(q, k, v, causal=True, window=window)
        return (o.astype(jnp.float32) ** 2).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 5e-4
    for a, b in zip(gk, gr):
        assert float(jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max()) < tol


def test_flash_attention_windowed_grad_block_size_invariant():
    """block_q/block_k are a tiling choice, not semantics: windowed-causal
    gradients must be identical (to float noise) across block sizes."""
    q, k, v = _qkv(1, 2, 2, 96, 96, 32, jnp.float32)

    def grads(bq, bk):
        def loss(q, k, v):
            o = ops.flash_attention(q, k, v, causal=True, window=24,
                                    block_q=bq, block_k=bk)
            return (o ** 2).sum()
        return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    base = grads(96, 96)
    for bq, bk in [(16, 16), (32, 64), (64, 32)]:
        for a, b in zip(grads(bq, bk), base):
            assert float(jnp.abs(a - b).max()) < 5e-5, (bq, bk)
