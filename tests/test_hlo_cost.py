"""Unit tests for the loop-aware HLO cost model (roofline/hlo.py)."""
import pytest

from repro.roofline import hlo as H

SYNTH = """HloModule test, num_partitions=16

%cond.1 (arg: (s32[], f32[8,8])) -> pred[] {
  %arg = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body.1 (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,128]{1,0} all-gather(%d), replica_groups=[1,16]<=[16], dimensions={1}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %d)
}

ENTRY %main (p0: f32[8,8]) -> (s32[], f32[8,8]) {
  %p0 = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %p0)
  ROOT %w = (s32[], f32[8,8]) while(%t0), condition=%cond.1, body=%body.1
}
"""


def test_while_trip_count_from_condition():
    cost = H.HloCost(SYNTH).cost()
    # dot: 2*8*8*8 = 1024 flops, x5 trips
    assert cost.flops == pytest.approx(5 * 1024)


def test_collectives_weighted_by_trips():
    cost = H.HloCost(SYNTH).cost()
    ag = cost.collectives["all-gather"]
    assert ag["count"] == 5
    assert ag["operand_bytes"] == 5 * 8 * 8 * 4
    # ring all-gather: operand * (n-1) per link, n=16
    assert ag["link_bytes"] == pytest.approx(5 * 8 * 8 * 4 * 15)


def test_backend_config_trip_count_overrides():
    txt = SYNTH.replace(
        "condition=%cond.1, body=%body.1",
        'condition=%cond.1, body=%body.1, '
        'backend_config={"known_trip_count":{"n":"7"}}')
    cost = H.HloCost(txt).cost()
    assert cost.flops == pytest.approx(7 * 1024)


def test_dus_bytes_only_charge_slice():
    txt = """HloModule t2

ENTRY %main (p: f32[100,8], u: f32[1,8]) -> f32[100,8] {
  %p = f32[100,8]{1,0} parameter(0)
  %u = f32[1,8]{1,0} parameter(1)
  %z = s32[] constant(0)
  ROOT %d = f32[100,8]{1,0} dynamic-update-slice(%p, %u, %z, %z)
}
"""
    cost = H.HloCost(txt).cost()
    assert cost.bytes == 2 * 1 * 8 * 4      # slice in + out, not the buffer


def test_link_bytes_model():
    assert H.link_bytes("all-gather", 100, 4) == 300
    assert H.link_bytes("reduce-scatter", 100, 4) == pytest.approx(75)
    assert H.link_bytes("all-reduce", 100, 4) == pytest.approx(150)
    assert H.link_bytes("collective-permute", 100, 0) == 100


def test_group_size_formats():
    assert H._group_size("replica_groups={{0,1,2,3}}, x") == 4
    assert H._group_size("replica_groups=[16,16]<=[16,16]T(1,0)") == 16


def test_tuple_types_with_index_comments_parse():
    line = ("  %w = (s32[], f32[16,1,1,64]{3,2,1,0}, /*index=5*/f32[2,3]{1,0})"
            " while(%t), condition=%c, body=%b")
    m = H._OP_LINE.match(line)
    assert m and m.group("op") == "while"
    assert H._bytes_of_type(m.group("type")) == 4 + 16 * 64 * 4 + 6 * 4
