"""TPU-side tiered store: data correctness under the VILLA policy."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.dram.villa import VillaConfig
from repro.core.lisa import villa_cache as VC
from repro.core.lisa.topology import (MeshTopology, hop_chain_us,
                                      host_path_us, migration_worthwhile,
                                      ring_collective_us)

CFG = VillaConfig(n_counters=32, n_hot=4, n_slots=4, epoch_len=8)


def _store(seed=0, n=32, d=5):
    slow = jax.random.normal(jax.random.key(seed), (n, d))
    return VC.make_store(slow, CFG), slow


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 31), min_size=1, max_size=60),
       st.integers(0, 5))
def test_access_always_returns_truth(ids, seed):
    store, slow = _store(seed)
    for i in ids:
        store, data, hit = VC.access(store, jnp.int32(i), CFG)
        assert np.allclose(data, slow[i]), f"wrong data for {i} (hit={bool(hit)})"


def test_hot_items_hit_fast_tier():
    store, slow = _store()
    ids = [3, 9] * 20
    hits = 0
    for i in ids:
        store, data, hit = VC.access(store, jnp.int32(i), CFG)
        hits += int(hit)
    assert hits > 5
    assert float(VC.hit_rate(store)) > 0.1


def test_write_through_updates_both_tiers():
    store, slow = _store()
    for i in [7] * 12:                     # make 7 hot + resident
        store, _, _ = VC.access(store, jnp.int32(i), CFG)
    new = jnp.full((5,), 42.0)
    store = VC.write(store, jnp.int32(7), new)
    store, data, hit = VC.access(store, jnp.int32(7), CFG)
    assert np.allclose(data, new)
    assert np.allclose(store.slow[7], new)


def test_topology_costs():
    t = MeshTopology(16)
    assert t.hops(0, 15) == 1              # wraparound
    assert t.hops(0, 8) == 8
    assert t.path(14, 1) == [15, 0, 1]
    # linear-in-hops (Table 1 structure)
    c1 = hop_chain_us(1, 1 << 20)
    c4 = hop_chain_us(4, 1 << 20)
    assert abs(c4 - 4 * c1) < 1e-9
    # neighbor chain beats the host path for few hops (the paper's point)
    assert hop_chain_us(1, 8 << 20) < host_path_us(8 << 20)
    # ring allreduce = 2x ring allgather steps
    ag = ring_collective_us(16, 1 << 20, "all_gather")
    ar = ring_collective_us(16, 1 << 20, "all_reduce")
    assert abs(ar - 2 * ag) < 1e-9


def test_migration_decision():
    nbytes = 64 << 20
    assert migration_worthwhile(nbytes, hops=1, expected_hits=100,
                                fast_gain_us=1000)
    assert not migration_worthwhile(nbytes, hops=8, expected_hits=1,
                                    fast_gain_us=1.0)
    # zero hops: data already local, the move is free
    assert hop_chain_us(0, nbytes) == 0.0
    assert migration_worthwhile(nbytes, hops=0, expected_hits=1,
                                fast_gain_us=1e-6)
