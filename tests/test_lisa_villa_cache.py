"""TPU-side tiered store: data correctness under the VILLA policy."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.dram.villa import VillaConfig
from repro.core.lisa import villa_cache as VC
from repro.core.lisa.topology import (MeshTopology, hop_chain_us,
                                      host_path_us, migration_worthwhile,
                                      ring_collective_us)

CFG = VillaConfig(n_counters=32, n_hot=4, n_slots=4, epoch_len=8)


def _store(seed=0, n=32, d=5):
    slow = jax.random.normal(jax.random.key(seed), (n, d))
    return VC.make_store(slow, CFG), slow


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 31), min_size=1, max_size=60),
       st.integers(0, 5))
def test_access_always_returns_truth(ids, seed):
    store, slow = _store(seed)
    for i in ids:
        store, data, hit = VC.access(store, jnp.int32(i), CFG)
        assert np.allclose(data, slow[i]), f"wrong data for {i} (hit={bool(hit)})"


def test_hot_items_hit_fast_tier():
    store, slow = _store()
    ids = [3, 9] * 20
    hits = 0
    for i in ids:
        store, data, hit = VC.access(store, jnp.int32(i), CFG)
        hits += int(hit)
    assert hits > 5
    assert float(VC.hit_rate(store)) > 0.1


def test_write_through_updates_both_tiers():
    store, slow = _store()
    for i in [7] * 12:                     # make 7 hot + resident
        store, _, _ = VC.access(store, jnp.int32(i), CFG)
    new = jnp.full((5,), 42.0)
    store = VC.write(store, jnp.int32(7), new)
    store, data, hit = VC.access(store, jnp.int32(7), CFG)
    assert np.allclose(data, new)
    assert np.allclose(store.slow[7], new)


def test_access_many_matches_sequential_access():
    """One-dispatch batched access == the per-item Python loop, exactly."""
    ids = [3, 9, 3, 9, 1, 3, 9, 30, 3, 9]
    store_a, slow = _store()
    seq_data, seq_hits = [], []
    for i in ids:
        store_a, data, hit = VC.access(store_a, jnp.int32(i), CFG)
        seq_data.append(np.asarray(data))
        seq_hits.append(bool(hit))
    store_b, _ = _store()
    store_b, data_b, hits_b = jax.jit(
        lambda s, i: VC.access_many(s, i, CFG))(store_b,
                                                jnp.asarray(ids, jnp.int32))
    assert np.allclose(np.stack(seq_data), np.asarray(data_b))
    assert seq_hits == [bool(h) for h in np.asarray(hits_b)]
    assert int(store_a.hits) == int(store_b.hits)
    assert np.array_equal(np.asarray(store_a.policy.tags),
                          np.asarray(store_b.policy.tags))


def test_write_many_matches_sequential_write():
    store_a, _ = _store()
    store_b, _ = _store()
    ids = jnp.asarray([4, 17, 4], jnp.int32)          # duplicate: last wins
    data = jnp.stack([jnp.full((5,), float(i)) for i in range(3)])
    for i in range(3):
        store_a = VC.write(store_a, ids[i], data[i])
    store_b = jax.jit(VC.write_many)(store_b, ids, data)
    assert np.allclose(store_a.slow, store_b.slow)
    assert np.allclose(store_b.slow[4], 2.0)


def test_paged_store_moves_through_kernels():
    """A store with (pages, P, d) items uses the RBM gather/scatter path and
    stays bit-exact under the same policy."""
    slow = jax.random.randint(jax.random.key(0), (8, 3, 8, 128),
                              0, 255).astype(jnp.uint8)
    store = VC.make_store(slow, CFG)
    for i in [5, 2] * 10 + [7]:
        store, data, _ = VC.access(store, jnp.int32(i), CFG)
        assert data.dtype == jnp.uint8
        assert (data == slow[i]).all()
    new = jnp.full((3, 8, 128), 9, jnp.uint8)
    store = VC.write(store, jnp.int32(5), new)        # 5 is hot + resident
    store, data, hit = VC.access(store, jnp.int32(5), CFG)
    assert bool(hit) and (data == new).all()
    assert (store.slow[5] == new).all()


def test_topology_costs():
    t = MeshTopology(16)
    assert t.hops(0, 15) == 1              # wraparound
    assert t.hops(0, 8) == 8
    assert t.path(14, 1) == [15, 0, 1]
    # linear-in-hops (Table 1 structure)
    c1 = hop_chain_us(1, 1 << 20)
    c4 = hop_chain_us(4, 1 << 20)
    assert abs(c4 - 4 * c1) < 1e-9
    # neighbor chain beats the host path for few hops (the paper's point)
    assert hop_chain_us(1, 8 << 20) < host_path_us(8 << 20)
    # ring allreduce = 2x ring allgather steps
    ag = ring_collective_us(16, 1 << 20, "all_gather")
    ar = ring_collective_us(16, 1 << 20, "all_reduce")
    assert abs(ar - 2 * ag) < 1e-9


def test_migration_decision():
    nbytes = 64 << 20
    assert migration_worthwhile(nbytes, hops=1, expected_hits=100,
                                fast_gain_us=1000)
    assert not migration_worthwhile(nbytes, hops=8, expected_hits=1,
                                    fast_gain_us=1.0)
    # zero hops: data already local, the move is free
    assert hop_chain_us(0, nbytes) == 0.0
    assert migration_worthwhile(nbytes, hops=0, expected_hits=1,
                                fast_gain_us=1e-6)
