"""Property-based hardening layer (hypothesis via tests/_hypothesis_compat).

Two families, each with a deterministic fixed-case fallback so the checkers
run even where hypothesis is absent (the @given tests then skip):

  * random offer/complete/preempt streams against the scheduler, asserting
    slot conservation, the aging bound (starvation freedom), and virtual-
    clock monotonicity at EVERY tick, plus exact token budgets at drain;
  * random ``Transfer`` payloads against the movement substrate, asserting
    ``plan()`` cost additivity (fused waves and batched layouts price
    linearly) and pack/unpack round-trip identity on int8 / bf16 / f32.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro import movement as MV
from repro import sched
from repro.configs import get_reduced
from repro.core.dram.villa import VillaConfig
from repro.core.lisa.topology import MeshTopology, ici_dram_spec
from repro.models import lm
from repro.movement.paging import PageSpec, pack_slot, unpack_into_slot
from repro.serve.engine import Engine

DTYPES = (jnp.int8, jnp.bfloat16, jnp.float32)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("tinyllama-1.1b")
    params = lm.init_lm(cfg, jax.random.key(0))
    return cfg, params


# ---------------------------------------------------------------------------
# scheduler streams: slot conservation, aging bound, clock monotonicity
# ---------------------------------------------------------------------------

def _check_stream(cfg, params, *, n_fresh, n_followups, seed, slots,
                  age_every, mean_gap_ns, preempt=True):
    """Drive one generated offer/complete/preempt stream to drain, checking
    the core invariants after every tick."""
    wl = sched.WorkloadConfig(
        n_fresh=n_fresh, n_followups=n_followups, mean_gap_ns=mean_gap_ns,
        arrival="bursty" if seed % 2 else "poisson", burst=3, zipf_s=1.3,
        new_tokens=(1, 2, 3), think_ns=1500.0,
        class_slo_ns=(15_000.0, 50_000.0, math.inf))
    arrivals = sched.generate_workload(wl, seed=seed,
                                       vocab_size=cfg.vocab_size)
    eng = Engine(cfg, params, slots=slots, max_len=96,
                 n_sessions=sched.n_sessions_for(wl))
    s = sched.Scheduler(eng, policy="cost_aware", arrivals=arrivals,
                        cfg=sched.SchedConfig(age_every=age_every,
                                              preempt=preempt))
    last_ns = 0.0
    while s.pending():
        s.tick()
        # virtual-clock monotonicity
        assert s.now_ns >= last_ns, (s.now_ns, last_ns)
        last_ns = s.now_ns
        # slot conservation: scheduler job map == engine active map,
        # one slot per session, never more jobs than slots
        active = s.active_jobs()
        assert set(active) == set(eng.active)
        assert len(active) <= eng.slots
        uids = [j.uid for j in active.values()]
        assert len(uids) == len(set(uids))
        for slot, job in active.items():
            assert job.slot == slot and job.state == "active"
            assert eng.active[slot].uid == job.uid
        # aging bound: every queued entry's effective class is exactly its
        # nominal class minus one per age_every waited ticks (unbounded
        # below zero — the starvation-freedom mechanism), so the longest
        # waiter's effective class is bounded by the structural formula
        for e in s.queue.entries():
            waited = s.tick_count - e.enq_tick
            assert s.queue.effective_class(e, s.tick_count) == (
                e.priority - waited // age_every)
        assert s.tick_count < 5000, "stream failed to drain"
    # loss-free drain: every job completed its exact (possibly truncated)
    # token budget, and the metrics saw every job exactly once
    jobs = s.jobs()
    assert all(j.state == "done" and j.done == j.target_new for j in jobs)
    assert s.metrics.summary()["jobs_completed"] == len(jobs)
    return s


STREAM_CASES = [
    dict(n_fresh=2, n_followups=3, seed=11, slots=1, age_every=3,
         mean_gap_ns=700.0),
    dict(n_fresh=3, n_followups=5, seed=5, slots=2, age_every=4,
         mean_gap_ns=1200.0),
    dict(n_fresh=4, n_followups=4, seed=8, slots=2, age_every=8,
         mean_gap_ns=500.0, preempt=False),
]


@pytest.mark.parametrize("case", STREAM_CASES)
def test_stream_invariants_fixed_cases(setup, case):
    """Deterministic fallback: the same checker hypothesis drives, on three
    pinned streams (runs even without hypothesis installed)."""
    cfg, params = setup
    _check_stream(cfg, params, **case)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4), st.integers(0, 6), st.integers(0, 60),
       st.integers(1, 3), st.integers(2, 8), st.integers(4, 24))
def test_stream_invariants_random(setup, n_fresh, n_followups, seed, slots,
                                  age_every, gap_100ns):
    cfg, params = setup
    _check_stream(cfg, params, n_fresh=n_fresh, n_followups=n_followups,
                  seed=seed, slots=slots, age_every=age_every,
                  mean_gap_ns=100.0 * gap_100ns)


# ---------------------------------------------------------------------------
# movement algebra: cost additivity + pack/unpack round trips
# ---------------------------------------------------------------------------

def _rand_cache(key, leaf_dims, dtypes, slots=3):
    """A pytree of (reps, slots, *dims) leaves — the batched-cache layout
    PageSpec stages."""
    leaves = {}
    for i, (dims, dt) in enumerate(zip(leaf_dims, dtypes)):
        key, k = jax.random.split(key)
        shape = (2, slots) + dims
        if np.dtype(dt).kind in "iu":
            leaves[f"l{i}"] = jax.random.randint(k, shape, -100, 100
                                                 ).astype(dt)
        else:
            leaves[f"l{i}"] = jax.random.normal(k, shape, dt)
    return leaves


def _check_roundtrip(leaf_dims, dtypes, slot, seed):
    cache = _rand_cache(jax.random.key(seed), leaf_dims, dtypes)
    spec = PageSpec.for_cache(cache)
    pages = pack_slot(spec, cache, jnp.int32(slot))
    assert pages.dtype == jnp.uint8
    assert pages.shape == (spec.n_pages, spec.page_rows, spec.page_lanes)
    blank = jax.tree.map(jnp.zeros_like, cache)
    out = unpack_into_slot(spec, blank, jnp.int32(slot), pages)
    for name in cache:
        got, want = out[name], cache[name]
        assert got.dtype == want.dtype
        # the target slot restores bit-exactly; every other slot untouched
        assert (np.asarray(got[:, slot]) == np.asarray(want[:, slot])).all()
        other = [s for s in range(want.shape[1]) if s != slot]
        assert (np.asarray(got[:, other]) == 0).all()


def _check_cost_additivity(leaf_dims, dtypes, k, hops_n, src, dst):
    cache = _rand_cache(jax.random.key(0), leaf_dims, dtypes)
    spec = PageSpec.for_cache(cache)
    vcfg = VillaConfig(n_counters=4, n_hot=2, n_slots=2, epoch_len=4)
    # policy-staged suspend: fuse(k) == Layout(batch=k) == k * single
    single = MV.plan(MV.Transfer(MV.Tier("compute"), MV.Tier("slow"),
                                 MV.Layout.pages(spec), policy=vcfg))
    fused = MV.fuse([single] * k)
    batched = MV.plan(MV.Transfer(MV.Tier("compute"), MV.Tier("slow"),
                                  MV.Layout.pages(spec, batch=k),
                                  policy=vcfg))
    for got in (fused.cost, batched.cost):
        assert got.bytes == k * single.cost.bytes
        assert got.ns_lisa == pytest.approx(k * single.cost.ns_lisa)
        assert got.ns_memcpy == pytest.approx(k * single.cost.ns_memcpy)
        assert got.uj_lisa == pytest.approx(k * single.cost.uj_lisa)
    # cross-replica migration: batch-k wave == k identical sessions, and
    # the hop leg prices EXACTLY the ICI model at the topology distance
    topo = MeshTopology(hops_n)
    mig1 = MV.plan(MV.Transfer(MV.Tier("slow", index=src, axis="r"),
                               MV.Tier("slow", index=dst, axis="r"),
                               MV.Layout.pages(spec)), topo=topo)
    migk = MV.plan(MV.Transfer(MV.Tier("slow", index=src, axis="r"),
                               MV.Tier("slow", index=dst, axis="r"),
                               MV.Layout.pages(spec, batch=k)), topo=topo)
    h = topo.hops(src, dst)
    want1 = (ici_dram_spec(spec.total_bytes).copy_latency("lisa", h)
             if h else 0.0)
    assert mig1.cost.ns_lisa == pytest.approx(want1)
    assert migk.cost.ns_lisa == pytest.approx(k * mig1.cost.ns_lisa)
    assert migk.cost.bytes == k * mig1.cost.bytes


TREE_CASES = [
    (((3, 9), (5, 4, 2)), (jnp.int8, jnp.float32), 0, 3),
    (((7,), (2, 3, 5), (11, 2)), (jnp.bfloat16, jnp.int8, jnp.float32), 2, 9),
    (((4, 128),), (jnp.bfloat16,), 1, 1),
]


@pytest.mark.parametrize("leaf_dims,dtypes,slot,seed", TREE_CASES)
def test_pack_unpack_roundtrip_fixed_cases(leaf_dims, dtypes, slot, seed):
    _check_roundtrip(leaf_dims, dtypes, slot, seed)


@pytest.mark.parametrize("leaf_dims,dtypes", [c[:2] for c in TREE_CASES])
def test_cost_additivity_fixed_cases(leaf_dims, dtypes):
    _check_cost_additivity(leaf_dims, dtypes, k=3, hops_n=4, src=0, dst=3)


if HAVE_HYPOTHESIS:
    _dims = st.lists(st.tuples(st.integers(1, 6), st.integers(1, 9)),
                     min_size=1, max_size=3)
    _dts = st.lists(st.sampled_from(DTYPES), min_size=3, max_size=3)
else:                                   # stubs; the tests below skip
    _dims = _dts = st.none()


@settings(max_examples=15, deadline=None)
@given(_dims, _dts, st.integers(0, 2), st.integers(0, 100))
def test_pack_unpack_roundtrip_random(dims, dts, slot, seed):
    """Random Transfer payloads: dtype-preserving uint8 paging restores the
    exact bits into the exact slot, for any leaf mix of int8/bf16/f32."""
    _check_roundtrip(tuple(tuple(d) for d in dims), tuple(dts[:len(dims)]),
                     slot, seed)


@settings(max_examples=15, deadline=None)
@given(_dims, _dts, st.integers(1, 5), st.integers(2, 8),
       st.integers(0, 31), st.integers(0, 31))
def test_cost_additivity_random(dims, dts, k, n, a, b):
    """plan() cost is additive: fused/batched waves price linearly, and
    migration routes price the ICI hop model at the topology distance."""
    _check_cost_additivity(tuple(tuple(d) for d in dims),
                           tuple(dts[:len(dims)]), k, n, a % n, b % n)
