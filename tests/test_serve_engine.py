"""Serving engine: decode fidelity, suspension/resume, VILLA tiering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import testlib as TL
from repro.configs import get_reduced
from repro.models import lm
from repro.serve.engine import Engine, EngineFull, Request, UnknownSession


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("tinyllama-1.1b")
    params = lm.init_lm(cfg, jax.random.key(0))
    return cfg, params


def _greedy_reference(cfg, params, prompt, n_new):
    cache = lm.init_cache(cfg, 1, max_len=96)
    logits, cache = lm.prefill(cfg, params, jnp.asarray(prompt)[None], cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(toks) < n_new:
        lg, cache = lm.decode_step(cfg, params, cache,
                                   jnp.asarray([[toks[-1]]]), jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return toks


def test_engine_matches_reference_decode(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    eng = Engine(cfg, params, slots=2, max_len=96)
    req = Request(uid=0, prompt=prompt, max_new=6)
    eng.submit(req)
    while eng.active:
        eng.step()
    assert eng.stats["suspends"] == 1
    assert req.generated == _greedy_reference(cfg, params, prompt, 6)


def test_engine_continuous_batching_isolation(setup):
    """Two concurrent requests must produce the same tokens as served
    alone — slots don't leak state across the batch."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)

    eng = Engine(cfg, params, slots=2, max_len=96)
    r1, r2 = Request(0, p1, 5), Request(1, p2, 5)
    eng.submit(r1)
    eng.submit(r2)
    while eng.active:
        eng.step()
    alone1 = _greedy_reference(cfg, params, p1, 5)
    alone2 = _greedy_reference(cfg, params, p2, 5)
    assert r1.generated == alone1
    assert r2.generated == alone2


def test_suspend_resume_roundtrip(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    req = Request(uid=3, prompt=prompt, max_new=4)
    eng.submit(req)
    while eng.active:
        eng.step()
    pos_after = eng.session_pos[3]
    assert pos_after == len(prompt) + 3      # prompt + (max_new-1) decodes
    slot = eng.resume(3, extra_new=2)
    assert eng.pos[slot] == pos_after
    while eng.active:
        eng.step()
    assert eng.stats["resumes"] == 1


def test_one_dispatch_one_transfer_per_step(setup):
    """The tentpole invariant: however ragged the slot positions are, a step
    is exactly ONE jitted dispatch and ONE device→host transfer, and the
    decode function compiles exactly once."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    eng = Engine(cfg, params, slots=3, max_len=96)
    # three different prompt lengths -> three different positions per step
    for uid, ln in enumerate((5, 9, 13)):
        eng.submit(Request(uid=uid, max_new=50,
                           prompt=rng.integers(0, cfg.vocab_size, ln)
                           .astype(np.int32)))
    assert len(set(eng.pos[list(eng.active)])) == 3
    before = TL.snapshot_stats(eng)
    for _ in range(6):
        eng.step()
    TL.assert_dispatch_delta(before, eng.stats, decode=6, host=6)
    TL.assert_compile_count(eng, "decode", 1)


def test_engine_full_raises_clearly(setup):
    cfg, params = setup
    rng = np.random.default_rng(5)
    eng = Engine(cfg, params, slots=1, max_len=96, n_sessions=8)
    prompt = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    eng.submit(Request(uid=0, prompt=prompt, max_new=30))
    with pytest.raises(EngineFull):
        eng.submit(Request(uid=1, prompt=prompt, max_new=2))
    while eng.active:
        eng.step()
    eng.submit(Request(uid=1, prompt=prompt, max_new=30))  # slot freed
    with pytest.raises(EngineFull):
        eng.resume(0, extra_new=2)
    while eng.active:
        eng.step()
    assert eng.resume(0, extra_new=2) == 0


def test_resume_unknown_uid_is_rejected_without_mutation(setup):
    cfg, params = setup
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    before = jax.tree.map(np.asarray, eng.sessions)
    with pytest.raises(UnknownSession):
        eng.resume(99, extra_new=2)
    after = jax.tree.map(np.asarray, eng.sessions)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        assert np.array_equal(a, b)            # store untouched by the error
    assert not eng.active and eng.stats["resumes"] == 0


def test_resume_of_active_uid_and_duplicate_wave_rejected(setup):
    """A uid can only be resumed while suspended: resuming it twice (or
    duplicating it in a wave) would fork a stale snapshot."""
    cfg, params = setup
    rng = np.random.default_rng(12)
    eng = Engine(cfg, params, slots=3, max_len=96, n_sessions=8)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    eng.submit(Request(uid=0, prompt=prompt, max_new=3))
    eng.submit(Request(uid=1, prompt=prompt, max_new=3))
    while eng.active:
        eng.step()
    eng.resume(0, extra_new=30)
    with pytest.raises(ValueError, match="already active"):
        eng.resume(0, extra_new=2)
    with pytest.raises(ValueError, match="already active"):
        eng.resume_many([1, 0], extra_new=2)
    with pytest.raises(ValueError, match="duplicate"):
        eng.resume_many([1, 1], extra_new=2)
    assert [r.uid for r in eng.active.values()] == [0]  # failed waves: no-op


def test_store_index_collision_evicts_explicitly(setup):
    """uid and uid+n_sessions alias the same store index: the older session
    must be evicted (stats + UnknownSession), never silently corrupted."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=4)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    for uid in (1, 5):                          # 5 % 4 == 1 % 4
        eng.submit(Request(uid=uid, prompt=prompt, max_new=3))
        while eng.active:
            eng.step()
    assert eng.stats["evictions"] == 1
    with pytest.raises(UnknownSession):
        eng.resume(1, extra_new=2)              # evicted by uid 5
    eng.resume(5, extra_new=2)                  # survivor resumes fine
    while eng.active:
        eng.step()


def test_suspend_resume_decode_matches_uninterrupted(setup):
    """End-to-end equivalence: suspend→resume→decode produces exactly the
    tokens an uninterrupted decode would have produced."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    straight = _greedy_reference(cfg, params, prompt, 10)

    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    req = Request(uid=11, prompt=prompt, max_new=4)
    eng.submit(req)
    while eng.active:
        eng.step()                              # emit 4, then auto-suspend
    slot = eng.resume(11, extra_new=4)          # continue: 3 more tokens
    r1 = eng.active[slot]
    while eng.active:
        eng.step()
    slot = eng.resume(11, extra_new=4)          # and 3 more again
    r2 = eng.active[slot]
    while eng.active:
        eng.step()
    # generated[0] of a resumed request is the pre-suspension token (the
    # decode seed), so the genuinely new tokens are generated[1:]
    got = req.generated + r1.generated[1:] + r2.generated[1:]
    assert got == straight
    assert eng.stats["suspends"] == 3 and eng.stats["resumes"] == 2


def test_suspend_resume_preserves_dtypes(setup):
    """The session store holds raw bytes (uint8 pages) sized by the true leaf
    dtypes — no float32 upcast — and restore is bit-exact."""
    cfg, params = setup
    rng = np.random.default_rng(8)
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    assert eng.sessions.slow.dtype == jnp.uint8
    exact = sum(np.prod(l.shape[:1] + l.shape[2:]) * l.dtype.itemsize
                for l in jax.tree.leaves(eng.cache))
    assert eng.snapshot_bytes == exact          # not 4x'd by an upcast
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    eng.submit(Request(uid=0, prompt=prompt, max_new=3))
    while eng.active:
        eng.step()
    snap = jax.tree.map(lambda x: np.asarray(x[:, 0]), eng.cache)
    slot = eng.resume(0, extra_new=2)
    restored = jax.tree.map(lambda x: np.asarray(x[:, slot]), eng.cache)
    for a, b in zip(jax.tree.leaves(snap), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        assert np.array_equal(a, b)             # bit-exact round trip


def test_resume_many_single_wave_matches_sequential(setup):
    cfg, params = setup
    rng = np.random.default_rng(9)
    prompts = {uid: rng.integers(0, cfg.vocab_size, 6 + uid).astype(np.int32)
               for uid in range(3)}

    def serve(resume_batched):
        eng = Engine(cfg, params, slots=3, max_len=96, n_sessions=8)
        for uid, p in prompts.items():
            eng.submit(Request(uid=uid, prompt=p, max_new=3))
        while eng.active:
            eng.step()
        if resume_batched:
            slots = eng.resume_many([0, 1, 2], extra_new=3)
        else:
            slots = [eng.resume(uid, extra_new=3) for uid in range(3)]
        resumed = {eng.active[s].uid: eng.active[s] for s in slots}
        while eng.active:
            eng.step()
        # post-resume tokens per uid — the state the wave restored
        return {uid: r.generated for uid, r in resumed.items()}

    seq = serve(False)
    bat = serve(True)
    assert set(bat) == {0, 1, 2}
    assert all(len(t) == 3 for t in bat.values())
    assert seq == bat


def test_step_unbatched_reference_path_and_ragged_fix(setup):
    """Uniform positions: the kept pre-PR path (position groups + per-slot
    sync) emits the same tokens as the one-dispatch path.  Ragged positions:
    the grouped path pays one dispatch per group AND corrupts neighbouring
    slots (every group's cache write lands in all rows — the latent bug the
    active-mask fixes), so there only the one-dispatch path tracks the
    per-request greedy reference."""
    cfg, params = setup
    rng = np.random.default_rng(10)

    def serve(step_name, lens):
        prompts = [rng.integers(0, cfg.vocab_size, ln).astype(np.int32)
                   for ln in lens]
        eng = Engine(cfg, params, slots=3, max_len=96)
        reqs = [Request(uid=i, prompt=p, max_new=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        while eng.active:
            getattr(eng, step_name)()
        refs = [_greedy_reference(cfg, params, p, 5) for p in prompts]
        return [r.generated for r in reqs], refs, eng.stats["decode_dispatches"]

    rng = np.random.default_rng(10)
    toks_new, refs_new, d_new = serve("step", (7, 7, 7))
    rng = np.random.default_rng(10)
    toks_old, refs_old, d_old = serve("step_unbatched", (7, 7, 7))
    assert toks_new == toks_old == refs_new     # uniform: paths agree
    assert d_new == d_old == 4                  # one group per step

    toks_new, refs, d_new = serve("step", (5, 8, 11))
    assert toks_new == refs                     # ragged: one-sync path exact
    toks_old, refs, d_old = serve("step_unbatched", (5, 8, 11))
    assert d_old > d_new                        # one dispatch per group
    assert toks_old != refs                     # the corruption being fixed


def test_suspend_many_wave_matches_sequential(setup):
    """A burst of completions suspends in ONE fused wave (step() routes
    through suspend_many): session state, later resumed tokens, and the
    modeled movement charge all match per-slot sequential suspends."""
    cfg, params = setup
    rng = np.random.default_rng(13)
    prompts = {uid: rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for uid in range(3)}

    def finish(eng):
        toks = {}
        for uid in prompts:                  # resume + decode to completion
            slot = eng.resume(uid, extra_new=3)
            toks[uid] = eng.active[slot]
        while eng.active:
            eng.step()
        return {uid: r.generated for uid, r in toks.items()}

    # wave path: same-length prompts all complete on the same step, so
    # step() suspends the whole burst through suspend_many
    eng_w = Engine(cfg, params, slots=3, max_len=96, n_sessions=8)
    for uid, p in prompts.items():
        eng_w.submit(Request(uid=uid, prompt=p, max_new=3))
    while eng_w.active:
        eng_w.step()
    assert eng_w.stats["suspends"] == 3
    TL.assert_compile_count(eng_w, "suspend_many", 1)
    TL.assert_compile_count(eng_w, "suspend", 0)          # wave, not 3 calls

    # sequential reference: stop at the same position, suspend one by one
    eng_s = Engine(cfg, params, slots=3, max_len=96, n_sessions=8)
    for uid, p in prompts.items():
        eng_s.submit(Request(uid=uid, prompt=p, max_new=10**9))
    eng_s.step()
    eng_s.step()                             # 3 generated tokens, like above
    for s in sorted(eng_s.active):
        eng_s.suspend(s)
    assert eng_w.session_pos == eng_s.session_pos
    assert eng_w.session_tok == eng_s.session_tok
    # fusion is cost-transparent: wave charge == sum of single charges
    assert eng_w.stats["modeled_move_ns_lisa"] == pytest.approx(
        eng_s.stats["modeled_move_ns_lisa"])
    assert finish(eng_w) == finish(eng_s)    # resumed decode identical


def test_resume_many_single_element_wave(setup):
    """A wave of exactly one resume is valid and equals a plain resume
    (regression: the k=1 fused plan must still take the batched env path)."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)

    def serve(batched):
        eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
        eng.submit(Request(uid=0, prompt=prompt, max_new=3))
        while eng.active:
            eng.step()
        slots = (eng.resume_many([0], extra_new=3) if batched
                 else [eng.resume(0, extra_new=3)])
        req = eng.active[slots[0]]
        while eng.active:
            eng.step()
        return req.generated, eng.stats["modeled_move_ns_lisa"]

    toks_wave, ns_wave = serve(True)
    toks_one, ns_one = serve(False)
    assert toks_wave == toks_one
    assert ns_wave == ns_one                   # wave of 1 charges like 1


def test_villa_hit_rate_with_hot_sessions(setup):
    cfg, params = setup
    rng = np.random.default_rng(3)
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    for uid in range(6):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size, 6).astype(
                               np.int32), max_new=3))
        while eng.active:
            eng.step()
    for _ in range(24):                       # hot sessions 0 and 1
        uid = int(rng.integers(0, 2)) if rng.random() < 0.85 else \
            int(rng.integers(0, 6))
        eng.resume(uid, extra_new=2)
        while eng.active:
            eng.step()
    assert eng.hit_rate() > 0.15
