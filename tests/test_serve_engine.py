"""Serving engine: decode fidelity, suspension/resume, VILLA tiering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import lm
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("tinyllama-1.1b")
    params = lm.init_lm(cfg, jax.random.key(0))
    return cfg, params


def _greedy_reference(cfg, params, prompt, n_new):
    cache = lm.init_cache(cfg, 1, max_len=96)
    logits, cache = lm.prefill(cfg, params, jnp.asarray(prompt)[None], cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(toks) < n_new:
        lg, cache = lm.decode_step(cfg, params, cache,
                                   jnp.asarray([[toks[-1]]]), jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return toks


def test_engine_matches_reference_decode(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    eng = Engine(cfg, params, slots=2, max_len=96)
    req = Request(uid=0, prompt=prompt, max_new=6)
    eng.submit(req)
    while eng.active:
        eng.step()
    assert eng.stats["suspends"] == 1
    assert req.generated == _greedy_reference(cfg, params, prompt, 6)


def test_engine_continuous_batching_isolation(setup):
    """Two concurrent requests must produce the same tokens as served
    alone — slots don't leak state across the batch."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)

    eng = Engine(cfg, params, slots=2, max_len=96)
    r1, r2 = Request(0, p1, 5), Request(1, p2, 5)
    eng.submit(r1)
    eng.submit(r2)
    while eng.active:
        eng.step()
    alone1 = _greedy_reference(cfg, params, p1, 5)
    alone2 = _greedy_reference(cfg, params, p2, 5)
    assert r1.generated == alone1
    assert r2.generated == alone2


def test_suspend_resume_roundtrip(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    req = Request(uid=3, prompt=prompt, max_new=4)
    eng.submit(req)
    while eng.active:
        eng.step()
    pos_after = eng.session_pos[3]
    assert pos_after == len(prompt) + 3      # prompt + (max_new-1) decodes
    slot = eng.resume(3, extra_new=2)
    assert eng.pos[slot] == pos_after
    while eng.active:
        eng.step()
    assert eng.stats["resumes"] == 1


def test_villa_hit_rate_with_hot_sessions(setup):
    cfg, params = setup
    rng = np.random.default_rng(3)
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    for uid in range(6):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size, 6).astype(
                               np.int32), max_new=3))
        while eng.active:
            eng.step()
    for _ in range(24):                       # hot sessions 0 and 1
        uid = int(rng.integers(0, 2)) if rng.random() < 0.85 else \
            int(rng.integers(0, 6))
        eng.resume(uid, extra_new=2)
        while eng.active:
            eng.step()
    assert eng.hit_rate() > 0.15
