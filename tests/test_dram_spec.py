"""The `DramSpec` device-model API: preset registry round-trips, Table-1
golden values, traced-mechanism dispatch, and vmap-over-workloads
equivalence of the single jitted controller."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.dram import spec as SP
from repro.core.dram.controller import (MechanismConfig, mechanism_params,
                                        simulate, simulate_params,
                                        simulate_sweep, stack_params,
                                        stack_traces, weighted_speedup)
from repro.core.dram.spec import DDR3_1600, DDR4_2400
from repro.core.dram.traces import TraceConfig, generate

# Paper Table 1 golden values under the calibrated default preset.
GOLDEN = {
    "LISA-RISC-1": (148.5, 0.09),
    "LISA-RISC-7": (196.5, 0.12),
    "LISA-RISC-15": (260.5, 0.17),
    "RC-InterSA": (1363.75, 4.33),
}


# ---------------------------------------------------------------------------
# Preset registry.
# ---------------------------------------------------------------------------

def test_preset_registry_round_trip():
    for name in SP.preset_names():
        spec = SP.get_preset(name)
        assert spec.name == name
        assert SP.get_preset(spec.name) is spec
    assert SP.get_preset("DDR3_1600") is DDR3_1600
    assert SP.get_preset("DDR4_2400") is DDR4_2400
    assert {"DDR3_1600", "DDR4_2400"} <= set(SP.preset_names())


def test_unknown_preset_and_duplicate_registration():
    with pytest.raises(ValueError, match="unknown DRAM preset"):
        SP.get_preset("DDR9_9999")
    with pytest.raises(ValueError, match="already registered"):
        SP.register_preset(dataclasses.replace(DDR3_1600))
    # explicit overwrite is allowed and round-trips
    custom = dataclasses.replace(DDR3_1600, name="TEST_CUSTOM",
                                 n_subarrays=64)
    try:
        assert SP.register_preset(custom) is custom
        assert SP.get_preset("TEST_CUSTOM").n_subarrays == 64
    finally:
        SP._PRESETS.pop("TEST_CUSTOM", None)


def test_with_geometry_keeps_timing_calibration():
    small = DDR3_1600.with_geometry(8, 8, 64)
    assert (small.n_subarrays, small.rows_per_subarray, small.row_bytes) == \
        (8, 8, 64)
    # timing/energy calibration untouched
    assert small.copy_latency("lisa", 7) == \
        DDR3_1600.copy_latency("lisa", 7)


def test_table1_golden_values_default_preset():
    got = DDR3_1600.table1()
    for mech, (lat, ene) in GOLDEN.items():
        assert got[mech][0] == pytest.approx(lat, abs=1e-9), mech
        assert round(got[mech][1], 2) == pytest.approx(ene, abs=1e-9), mech


def test_presets_differ_but_orderings_hold():
    for spec in (DDR3_1600, DDR4_2400):
        assert spec.copy_latency("lisa", 1) < spec.copy_latency("rc_intersa")
        assert spec.copy_energy("lisa", 1) < spec.copy_energy("rc_intersa")
    assert DDR4_2400.copy_latency("rc_intersa") != \
        DDR3_1600.copy_latency("rc_intersa")


# ---------------------------------------------------------------------------
# CopyMechanism registry.
# ---------------------------------------------------------------------------

def test_mechanism_registry_ids_and_table():
    names = SP.mechanism_names()
    assert names == tuple(SP.get_mechanism(n).name for n in names)
    ids = [SP.mechanism_id(n) for n in names]
    assert ids == list(range(len(names)))           # dense table row order
    table = DDR3_1600.mechanism_table()
    assert table.shape == (len(names), 5)
    for n in names:
        m = SP.get_mechanism(n)
        lat0, lath, e0, eh, chan = table[m.mech_id]
        for hops in (1, 7, 15):
            assert lat0 + lath * hops == pytest.approx(
                m.latency(DDR3_1600, hops), rel=1e-6), (n, hops)
            assert e0 + eh * hops == pytest.approx(
                m.energy(DDR3_1600, hops), rel=1e-5), (n, hops)
        assert bool(chan) == m.occupies_channel
    assert SP.get_mechanism("memcpy").occupies_channel
    assert not SP.get_mechanism("lisa").occupies_channel


def test_unknown_mechanism_raises_with_choices():
    with pytest.raises(ValueError, match="unknown copy mechanism"):
        DDR3_1600.copy_latency("warp_drive")


# ---------------------------------------------------------------------------
# One jitted simulate: traced mechanism config + vmap over workloads.
# ---------------------------------------------------------------------------

TCFG = TraceConfig(n_requests=1024)
CFGS = [MechanismConfig("memcpy"), MechanismConfig("rc_intersa"),
        MechanismConfig("lisa"),
        MechanismConfig("lisa", use_villa=True, use_lip=True)]


def test_single_compilation_serves_all_mechanisms():
    tr = generate(jax.random.key(0), TCFG)
    before = simulate_params._cache_size()
    outs = [simulate(tr, TCFG, c) for c in CFGS]
    jax.block_until_ready(outs)
    added = simulate_params._cache_size() - before
    assert added <= 1, \
        f"mechanism configs caused {added} compilations (want one)"
    # and a different *preset* reuses it too (all-traced timing)
    simulate(tr, TCFG, MechanismConfig("lisa"), DDR4_2400)
    assert simulate_params._cache_size() - before <= 1


def test_vmap_over_workloads_matches_per_config():
    tcfgs = [TraceConfig(n_requests=1024, copy_prob=cp, zipf_s=z)
             for cp, z in [(0.002, 1.0), (0.01, 1.4), (0.04, 1.8)]]
    trs = [generate(jax.random.key(i), c) for i, c in enumerate(tcfgs)]
    mcfg = MechanismConfig("lisa", use_villa=True)
    swept = simulate_sweep(stack_traces(trs), TCFG, mcfg)
    for i, tr in enumerate(trs):
        one = simulate(tr, TCFG, mcfg)
        for k in ("core_stall", "energy_uJ", "villa_hit_rate"):
            np.testing.assert_allclose(np.asarray(swept[k][i]),
                                       np.asarray(one[k]), rtol=1e-5,
                                       err_msg=f"workload {i}, {k}")


def test_vmap_over_mechanism_params():
    """The other batching axis: stack MechanismParams and vmap configs."""
    tr = generate(jax.random.key(3), TCFG)
    params = stack_params([mechanism_params(c) for c in CFGS])
    vsim = jax.vmap(lambda p: simulate_params(
        tr, p, n_banks=TCFG.n_banks, n_cores=TCFG.n_cores,
        villa_cfg=CFGS[0].villa))
    batched = vsim(params)
    for i, c in enumerate(CFGS):
        one = simulate(tr, TCFG, c)
        np.testing.assert_allclose(np.asarray(batched["core_stall"][i]),
                                   np.asarray(one["core_stall"]), rtol=1e-5)


def test_spec_threading_changes_system_results():
    """A different preset must actually reach the simulator's cost model."""
    tr = generate(jax.random.key(5), TraceConfig(n_requests=2048,
                                                 copy_prob=0.02))
    r3 = simulate(tr, TCFG, MechanismConfig("rc_intersa"), DDR3_1600)
    r4 = simulate(tr, TCFG, MechanismConfig("rc_intersa"), DDR4_2400)
    assert float(r3["avg_latency_ns"]) != float(r4["avg_latency_ns"])
    base3 = simulate(tr, TCFG, MechanismConfig("memcpy"), DDR3_1600)
    ws = float(weighted_speedup(base3["core_stall"], r3["core_stall"]).mean())
    assert ws > 1.0
