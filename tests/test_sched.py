"""Scheduler invariants: slot conservation, starvation freedom, fused
batched waves, queue-not-crash admission, and policy/queue units."""
import math

import jax
import numpy as np
import pytest

from repro import sched
from repro.analysis import testlib as TL
from repro.configs import get_reduced
from repro.models import lm
from repro.sched.policy import (AdmitCand, SchedContext, VictimCand,
                                get_policy)
from repro.sched.queue import AdmissionQueue, QueueEntry
from repro.serve.engine import Engine


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("tinyllama-1.1b")
    params = lm.init_lm(cfg, jax.random.key(0))
    return cfg, params


def _engine(cfg, params, *, slots=2, n_sessions=8):
    return Engine(cfg, params, slots=slots, max_len=96,
                  n_sessions=n_sessions)


def _fresh(t, uid, *, priority=1, slo=math.inf, tokens=3, plen=5, seed=0):
    rng = np.random.default_rng(seed + uid)
    return sched.Arrival(t_ns=t, uid=uid, kind="fresh", priority=priority,
                        slo_ns=slo, new_tokens=tokens,
                        prompt=rng.integers(0, 1000, plen).astype(np.int32))


def _followup(t, uid, *, priority=1, slo=math.inf, tokens=2):
    return sched.Arrival(t_ns=t, uid=uid, kind="resume", priority=priority,
                        slo_ns=slo, new_tokens=tokens, prompt=None)


# ---------------------------------------------------------------------------
# queue + policy units
# ---------------------------------------------------------------------------

def test_queue_aging_is_unbounded_below_zero():
    """Effective class drops one step per age_every ticks without a floor —
    the structural starvation-freedom mechanism: any entry eventually
    outranks every fresh class-0 arrival."""
    q = AdmissionQueue(age_every=4)
    e = q.push(job_id=0, uid=0, kind="resume", priority=2, arrival_ns=0.0,
               slo_ns=math.inf, tick=0, new_tokens=1)
    assert q.effective_class(e, 0) == 2
    assert q.effective_class(e, 4) == 1
    assert q.effective_class(e, 8) == 0
    assert q.effective_class(e, 12) == -1          # now beats fresh class 0
    assert q.bounded_wait_ticks(2) == 12


def test_queue_rejects_malformed_entries():
    q = AdmissionQueue(age_every=4)
    with pytest.raises(ValueError, match="prompt"):
        q.push(job_id=0, uid=0, kind="fresh", priority=0, arrival_ns=0.0,
               slo_ns=math.inf, tick=0, new_tokens=1)
    with pytest.raises(ValueError, match="kind"):
        q.push(job_id=0, uid=0, kind="bulk", priority=0, arrival_ns=0.0,
               slo_ns=math.inf, tick=0, new_tokens=1)


def test_policy_registry_contract():
    assert set(sched.policies()) >= {"fifo", "lru", "cost_aware"}
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        get_policy("round_robin")
    # an instance passes through; a name resolves to the registered object
    p = get_policy("cost_aware")
    assert get_policy(p) is p


def test_cost_aware_prefers_cheap_suspend_victim():
    """Same class, same recency: the cost_aware victim is the session whose
    modeled suspend is cheapest (the non-fast-resident, cold one)."""
    ctx = SchedContext(tick=0, now_ns=0.0, mechanism="lisa")
    cands = [
        VictimCand(slot=0, uid=7, priority=1, last_active_tick=0,
                   suspend_ns=3000.0, fast_resident=True),
        VictimCand(slot=1, uid=8, priority=1, last_active_tick=0,
                   suspend_ns=1900.0, fast_resident=False),
    ]
    order = get_policy("cost_aware").victim_order(cands, ctx)
    assert [v.uid for v in order] == [8, 7]
    # ... but a lower-priority (larger class) job is always victimized first
    cands.append(VictimCand(slot=2, uid=9, priority=2, last_active_tick=0,
                            suspend_ns=9000.0, fast_resident=True))
    order = get_policy("cost_aware").victim_order(cands, ctx)
    assert order[0].uid == 9


def test_cost_aware_admission_deprioritizes_hopeless_jobs():
    """Within a class: still-saveable deadlines first (EDF), jobs whose
    deadline already passed last — a hopeless job must not starve a
    saveable one (the overload domino-miss fix)."""
    def entry(seq, arrival, slo):
        return QueueEntry(seq=seq, job_id=seq, uid=seq, kind="resume",
                          priority=1, arrival_ns=arrival, slo_ns=slo,
                          enq_tick=0, new_tokens=1)
    ctx = SchedContext(tick=0, now_ns=50_000.0, mechanism="lisa")
    cands = [
        AdmitCand(entry(0, 0.0, 10_000.0), 1, 100.0, False),    # hopeless
        AdmitCand(entry(1, 0.0, 90_000.0), 1, 100.0, False),    # saveable
        AdmitCand(entry(2, 0.0, 60_000.0), 1, 100.0, False),    # saveable, EDF
    ]
    order = get_policy("cost_aware").admit_order(cands, ctx)
    assert [c.entry.seq for c in order] == [2, 1, 0]


def test_empty_metric_buckets_report_none_not_zero():
    """Regression: an idle class must not read as a perfect p99/attainment.
    Empty latency buckets are ``None`` (strict-JSON ``null``), and a class
    whose only jobs have infinite SLOs has no attainment to report."""
    import json

    from repro.sched.metrics import JobRecord, Metrics, percentile_ns

    assert percentile_ns([], 99) is None
    assert percentile_ns([5.0], 99) == 5.0

    m = Metrics()
    s = m.summary()                              # no jobs at all
    assert s["p50_latency_ns"] is None and s["p99_latency_ns"] is None
    assert s["slo_attainment"] is None
    json.dumps(s, allow_nan=False)               # strict JSON round-trips

    # one batch-class job (inf SLO): latency exists, attainment does not
    m.record_job(JobRecord(job_id=0, uid=0, kind="fresh", priority=2,
                           arrival_ns=0.0, done_ns=100.0, slo_ns=math.inf,
                           tokens=3))
    s = m.summary()
    assert s["per_class"]["2"]["p99_latency_ns"] == 100.0
    assert s["per_class"]["2"]["slo_attainment"] is None
    json.dumps(s, allow_nan=False)


def test_workload_generator_is_deterministic_and_well_formed():
    wl = sched.WorkloadConfig(n_fresh=5, n_followups=9, arrival="bursty",
                              burst=3)
    a1 = sched.generate_workload(wl, seed=3, vocab_size=128)
    a2 = sched.generate_workload(wl, seed=3, vocab_size=128)
    assert len(a1) == 14
    for x, y in zip(a1, a2):
        assert x.t_ns == y.t_ns and x.uid == y.uid and x.kind == y.kind
        if x.kind == "fresh":
            assert np.array_equal(x.prompt, y.prompt)
    # every follow-up targets a session that arrived fresh earlier
    seen = set()
    for a in a1:
        if a.kind == "fresh":
            seen.add(a.uid)
        else:
            assert a.uid in seen
    assert all(a.t_ns <= b.t_ns for a, b in zip(a1, a1[1:]))


# ---------------------------------------------------------------------------
# scheduler invariants (engine-backed)
# ---------------------------------------------------------------------------

def test_slot_conservation_across_ticks(setup):
    """No slot is ever double-booked and no session runs in two slots:
    after every tick the scheduler's job map is exactly the engine's active
    map, one job per slot, one slot per session."""
    cfg, params = setup
    wl = sched.WorkloadConfig(n_fresh=5, n_followups=8, mean_gap_ns=900.0,
                              arrival="bursty", burst=3, zipf_s=1.5,
                              class_slo_ns=(20_000.0, 60_000.0, math.inf))
    arrivals = sched.generate_workload(wl, seed=1, vocab_size=cfg.vocab_size)
    eng = _engine(cfg, params, slots=2, n_sessions=sched.n_sessions_for(wl))
    s = sched.Scheduler(eng, policy="cost_aware", arrivals=arrivals)
    while s.pending():
        s.tick()
        active = s.active_jobs()
        assert set(active) == set(eng.active)          # same slots
        assert len(active) <= eng.slots
        uids = [j.uid for j in active.values()]
        assert len(uids) == len(set(uids))             # one slot per session
        for slot, job in active.items():
            assert job.slot == slot and job.state == "active"
            assert eng.active[slot].uid == job.uid
        assert s.tick_count < 3000
    # every job ran to its exact token budget
    assert all(j.state == "done" and j.done == j.target_new
               for j in s.jobs())


def test_no_starvation_under_sustained_high_priority_load(setup):
    """A class-2 request queued behind a sustained class-0 stream is
    promoted by aging and completes within a bounded number of ticks —
    with aging effectively disabled it is served dead last."""
    cfg, params = setup
    # the class-2 job arrives just after a sustained class-0 stream starts
    # (the slot is already taken and the queue always holds class-0 work)
    arrivals = [_fresh(5.0, 0, priority=2, tokens=2)] + [
        _fresh(3_000.0 * i, 1 + i, priority=0, slo=30_000.0, tokens=2)
        for i in range(14)]
    eng = _engine(cfg, params, slots=1, n_sessions=16)
    s = sched.Scheduler(eng, policy="cost_aware", arrivals=arrivals,
                        cfg=sched.SchedConfig(age_every=4))
    bound = s.queue.bounded_wait_ticks(2) + 12      # aging + service slack
    done_tick = None
    while s.pending():
        s.tick()
        job0 = next((j for j in s.jobs() if j.uid == 0), None)
        if job0 is not None and job0.state == "done" and done_tick is None:
            done_tick = s.tick_count
    assert done_tick is not None and done_tick <= bound, (done_tick, bound)
    order = [r.uid for r in s.metrics.jobs]
    assert order.index(0) < len(order) - 4          # well before the tail

    # aging effectively off: the class-2 job drops to the very end
    eng = _engine(cfg, params, slots=1, n_sessions=16)
    s2 = sched.Scheduler(eng, policy="cost_aware", arrivals=arrivals,
                         cfg=sched.SchedConfig(age_every=10_000))
    s2.run()
    assert [r.uid for r in s2.metrics.jobs].index(0) == len(order) - 1


def test_batched_wave_equivalence(setup):
    """A burst offered as one arrival list and the same burst offered as
    singleton offer() calls schedule identically — and the burst's resumes
    drain as ONE fused wave, not per-session dispatches."""
    cfg, params = setup
    arrivals = [_fresh(float(i), i, tokens=2) for i in range(3)]
    arrivals += [_followup(9_000.0, i, tokens=2) for i in range(3)]  # burst

    def run(as_singletons):
        eng = _engine(cfg, params, slots=3, n_sessions=8)
        if as_singletons:
            s = sched.Scheduler(eng, policy="cost_aware")
            for a in arrivals:
                s.offer(a)
        else:
            s = sched.Scheduler(eng, policy="cost_aware", arrivals=arrivals)
        s.run()
        return s, eng

    s_list, eng_list = run(False)
    s_one, eng_one = run(True)
    assert s_list.metrics.decisions == s_one.metrics.decisions
    assert ([(r.job_id, r.uid, r.done_ns) for r in s_list.metrics.jobs]
            == [(r.job_id, r.uid, r.done_ns) for r in s_one.metrics.jobs])
    # the follow-up burst resumed as one fused three-session wave
    assert 3 in s_list.metrics.wave_widths("resume_wave")
    assert eng_list.stats["resumes"] == 3
    TL.assert_compile_count(eng_list, "resume_many", 1)


def test_admission_overflow_queues_instead_of_crashing(setup):
    """Regression for the launcher's old ``n_sessions=max(requests, 8)``
    hand-rolled loop: offering far more simultaneous requests than slots
    must queue the overflow — the engine never sees EngineFull — and every
    job must still complete."""
    cfg, params = setup
    arrivals = [_fresh(0.0, i, tokens=2) for i in range(7)]   # 7 jobs, 2 slots
    eng = _engine(cfg, params, slots=2, n_sessions=8)
    s = sched.Scheduler(eng, policy="fifo", arrivals=arrivals)
    s.tick()
    assert len(eng.active) == 2 and len(s.queue) == 5         # queued, alive
    summary = s.run()
    assert summary["jobs_completed"] == 7
    assert all(j.state == "done" for j in s.jobs())


def test_launch_serve_routes_through_scheduler(setup):
    """The launcher admits from the scheduler queue: requests beyond the
    slot count queue (no EngineFull crash), and the output carries the
    scheduler's metrics."""
    from repro.launch import serve as launch_serve
    out = launch_serve.main([
        "--arch", "tinyllama-1.1b", "--reduced", "--slots", "2",
        "--requests", "6", "--followups", "4", "--max-new", "2",
        "--mean-gap-ns", "500"])
    assert out["jobs_completed"] == 10
    assert out["decode_compile_count"] in (1, -1)
    assert "p99_latency_ns" in out and "slot_utilization" in out
    assert out["decisions"].get("resume_wave", 0) >= 1


def test_followup_ahead_of_fresh_does_not_livelock(setup):
    """Regression: a queued follow-up whose session does not exist yet must
    not block the idle-clock fast-forward — the fresh arrival behind it
    still gets admitted and both jobs complete (the old gate on an *empty*
    queue span to the max-tick guard here)."""
    cfg, params = setup
    arrivals = [_followup(0.0, 0, tokens=2), _fresh(1_000.0, 0, tokens=2)]
    eng = _engine(cfg, params, slots=2, n_sessions=4)
    s = sched.Scheduler(eng, policy="cost_aware", arrivals=arrivals)
    summary = s.run(max_ticks=500)
    assert summary["jobs_completed"] == 2
    assert all(j.state == "done" for j in s.jobs())


def test_preempted_job_resumes_and_finishes_exactly(setup):
    """Preemption is loss-free: a class-1 job displaced by class-0 traffic
    is re-queued, resumed, and still emits exactly its token budget."""
    cfg, params = setup
    arrivals = [_fresh(0.0, 0, priority=1, tokens=6)]
    arrivals += [_fresh(2_000.0 + 100.0 * i, 1 + i, priority=0,
                        slo=30_000.0, tokens=2) for i in range(3)]
    eng = _engine(cfg, params, slots=1, n_sessions=8)
    s = sched.Scheduler(eng, policy="cost_aware", arrivals=arrivals)
    s.run()
    job0 = next(j for j in s.jobs() if j.uid == 0)
    assert job0.state == "done" and job0.done == 6
    assert s.metrics.decision_counts().get("preempt_suspend", 0) >= 1
    assert all(j.done == j.target_new for j in s.jobs())


def test_scheduler_charges_movement_under_both_mechanisms(setup):
    """Every suspend/resume decision carries its Table-1 bill under lisa AND
    memcpy: the totals reproduce the engine-plan advantage at serving
    scale, and fast-tier hits are charged at the fast-subarray fraction."""
    cfg, params = setup
    arrivals = [_fresh(0.0, 0, tokens=2), _followup(4_000.0, 0, tokens=2),
                _followup(8_000.0, 0, tokens=2)]
    eng = _engine(cfg, params, slots=2, n_sessions=4)
    s = sched.Scheduler(eng, policy="cost_aware", arrivals=arrivals)
    s.run()
    mv = s.metrics.movement_totals()
    assert mv["ns_lisa"] > 0 and mv["uj_memcpy"] > 0
    assert mv["advantage"] == pytest.approx(
        eng.plan_resume.cost.ns_memcpy / eng.plan_resume.cost.ns_lisa,
        rel=1e-6)
    moves = [d for d in s.metrics.decisions
             if d.kind in ("resume_wave", "complete_suspend")]
    assert moves and all(d.ns_memcpy > d.ns_lisa for d in moves)


def test_single_token_job_completes_on_exact_budget(setup):
    """A fresh job owing exactly one token is completed by its prefill
    token: the engine suspends it at submit (no overshoot decode), the
    scheduler records done == 1, and the session is resumable."""
    cfg, params = setup
    rng = np.random.default_rng(21)
    from repro.serve.engine import Request
    eng = _engine(cfg, params, slots=2, n_sessions=4)
    slot = eng.submit(Request(uid=0, max_new=1,
                              prompt=rng.integers(0, cfg.vocab_size, 5)
                              .astype(np.int32)))
    assert slot not in eng.active                # completed at prefill
    assert eng.stats["suspends"] == 1 and 0 in eng.session_pos

    arrivals = [_fresh(0.0, 0, tokens=1), _followup(2_000.0, 0, tokens=2)]
    eng = _engine(cfg, params, slots=2, n_sessions=4)
    s = sched.Scheduler(eng, policy="cost_aware", arrivals=arrivals)
    summary = s.run(max_ticks=500)
    assert summary["jobs_completed"] == 2
    assert [j.done for j in s.jobs()] == [1, 2]  # exact budgets, no extras


def test_followups_truncate_to_the_context_envelope(setup):
    """A session cannot decode past max_len: the engine refuses an
    out-of-envelope resume (silent OOB cache writes were the old failure
    mode), and the scheduler truncates follow-ups to the remaining room —
    a context-exhausted follow-up completes instead of corrupting."""
    cfg, params = setup
    rng = np.random.default_rng(23)
    from repro.serve.engine import Engine, Request
    eng = Engine(cfg, params, slots=2, max_len=16, n_sessions=4)
    eng.submit(Request(uid=0, max_new=4,
                       prompt=rng.integers(0, cfg.vocab_size, 8)
                       .astype(np.int32)))
    while eng.active:
        eng.step()
    assert eng.session_pos[0] == 11
    with pytest.raises(ValueError, match="max_len"):
        eng.resume(0, extra_new=8)           # 11 + 7 decodes > 16
    eng.resume(0, extra_new=6)               # exactly fills the envelope
    while eng.active:
        eng.step()
    assert eng.session_pos[0] == 16

    # scheduler: follow-ups beyond the room truncate, at the wall complete
    arrivals = [_fresh(0.0, 0, tokens=4, plen=8),
                _followup(3_000.0, 0, tokens=9),    # room for only 5
                _followup(6_000.0, 0, tokens=3)]    # context exhausted: 0
    eng = Engine(cfg, params, slots=2, max_len=16, n_sessions=4)
    s = sched.Scheduler(eng, policy="cost_aware", arrivals=arrivals)
    summary = s.run(max_ticks=500)
    assert summary["jobs_completed"] == 3
    assert [j.done for j in s.jobs()] == [4, 5, 0]
    assert all(j.done == j.target_new for j in s.jobs())
    assert eng.session_pos[0] == 16          # pinned at the envelope


def test_submit_request_reads_request_metadata(setup):
    """`Scheduler.submit_request` admits a hand-built engine Request by its
    own scheduling metadata (arrival/priority/SLO), equivalently to the
    same Arrival."""
    cfg, params = setup
    rng = np.random.default_rng(22)
    from repro.serve.engine import Request
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    eng = _engine(cfg, params, slots=2, n_sessions=4)
    s = sched.Scheduler(eng, policy="cost_aware")
    s.submit_request(Request(uid=0, prompt=prompt, max_new=3,
                             arrival_ns=500.0, priority=2, slo_ns=40_000.0))
    s.run(max_ticks=200)
    rec = s.metrics.jobs[0]
    assert (rec.uid, rec.priority, rec.slo_ns) == (0, 2, 40_000.0)
    assert rec.arrival_ns == 500.0 and rec.tokens == 3


def test_engine_resume_many_per_uid_extra_new(setup):
    """One fused wave can hand each session a different remaining-token
    budget (host bookkeeping only — still ONE dispatch)."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    eng = _engine(cfg, params, slots=3, n_sessions=8)
    from repro.serve.engine import Request
    for uid in range(3):
        eng.submit(Request(uid=uid, max_new=2,
                           prompt=rng.integers(0, cfg.vocab_size, 5)
                           .astype(np.int32)))
    while eng.active:
        eng.step()
    slots = eng.resume_many([0, 1, 2], extra_new=[2, 3, 4])
    budgets = {eng.active[s].uid: eng.active[s].max_new for s in slots}
    assert budgets == {0: 2, 1: 3, 2: 4}
    with pytest.raises(ValueError, match="extra_new"):
        eng.resume_many([0], extra_new=[1, 2])
    while eng.active:
        eng.step()
    TL.assert_compile_count(eng, "resume_many", 1)
