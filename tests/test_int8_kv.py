"""int8-quantised KV caches (the §Perf C1 serving optimization)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.models import lm


def _decode_err(cfg, dtype, S=8):
    params = lm.init_lm(cfg, jax.random.key(3))
    B = 2
    toks = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab_size)
    full, _, _ = lm.forward(cfg, params, toks)
    cache = lm.init_cache(cfg, B, max_len=16, dtype=dtype)
    outs = []
    for t in range(S):
        lg, cache = lm.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    return float(jnp.abs(dec - full).max() / (jnp.abs(full).max() + 1e-9))


def test_gqa_int8_cache_close():
    assert _decode_err(get_reduced("tinyllama-1.1b"), jnp.int8) < 0.05


def test_mla_int8_cache_close():
    ds = get_reduced("deepseek-v2-236b")
    mla_only = dataclasses.replace(ds, n_experts=0, top_k=0,
                                   n_shared_experts=0)
    assert _decode_err(mla_only, jnp.int8) < 0.05


def test_moe_int8_routing_flips_tolerated():
    """Quantisation noise may flip top-k expert routing (discontinuous
    outputs) — quality metric is greedy-token agreement, not logits."""
    cfg = get_reduced("olmoe-1b-7b")
    params = lm.init_lm(cfg, jax.random.key(3))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab_size)
    outs = {}
    for dtype in (jnp.float32, jnp.int8):
        cache = lm.init_cache(cfg, B, max_len=16, dtype=dtype)
        tok_out = []
        for t in range(S):
            lg, cache = lm.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                       jnp.int32(t))
            tok_out.append(jnp.argmax(lg[:, 0], -1))
        outs[dtype.__name__] = jnp.stack(tok_out, 1)
    agree = float((outs["float32"] == outs["int8"]).mean())
    assert agree >= 0.75, agree
