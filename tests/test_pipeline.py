"""GPipe over LISA hops: pipelined == sequential execution (4 stages)."""
from _multidev import run_with_devices

CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.train.pipeline import pipeline_transformer

mesh = jax.make_mesh((4,), ("pp",))
D, L_PER, N_MICRO, MB = 16, 2, 6, 3

def layer_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

key = jax.random.key(0)
ks = jax.random.split(key, 8)
params = {
    "w": jax.random.normal(key, (4, L_PER, D, D)) * 0.3,
    "b": jax.random.normal(ks[1], (4, L_PER, D)) * 0.1,
}
micro = jax.random.normal(ks[2], (N_MICRO, MB, D))

pipelined = pipeline_transformer(mesh, "pp", layer_fn, L_PER)
got = jax.jit(pipelined)(params, micro)

# sequential reference: all 8 layers in order
ref = micro
for s in range(4):
    for l in range(L_PER):
        ref = layer_fn({"w": params["w"][s, l], "b": params["b"][s, l]}, ref)
assert jnp.allclose(got, ref, atol=1e-5), float(jnp.abs(got - ref).max())

# the schedule emits collective-permutes (the RBM hops)
txt = jax.jit(pipelined).lower(params, micro).compile().as_text()
assert "collective-permute" in txt
print("PIPE_OK")
"""


def test_gpipe_matches_sequential():
    out = run_with_devices(CODE, 4)
    assert "PIPE_OK" in out
