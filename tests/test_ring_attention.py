"""Ring attention (context parallel over LISA hops) vs the dense oracle."""
from _multidev import run_with_devices

CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.lisa.ring_attention import ring_attention
from repro.kernels.ref import flash_attention_ref

mesh = jax.make_mesh((8,), ("sp",))
B, S, H, K, D = 2, 128, 8, 4, 32
ks = jax.random.split(jax.random.key(0), 3)
q = jax.random.normal(ks[0], (B, S, H, D))
k = jax.random.normal(ks[1], (B, S, K, D))
v = jax.random.normal(ks[2], (B, S, K, D))

for causal in (True, False):
    ring = jax.jit(jax.shard_map(
        lambda q_, k_, v_: ring_attention(q_, k_, v_, "sp", causal=causal),
        mesh=mesh, in_specs=P(None, "sp"), out_specs=P(None, "sp")))
    got = ring(q, k, v)
    ref = flash_attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                              v.swapaxes(1, 2), causal=causal).swapaxes(1, 2)
    err = float(jnp.abs(got - ref).max())
    assert err < 3e-5, (causal, err)

# hop structure: the lowered ring must use collective-permutes, not all-gather
txt = jax.jit(jax.shard_map(
    lambda q_, k_, v_: ring_attention(q_, k_, v_, "sp"),
    mesh=mesh, in_specs=P(None, "sp"), out_specs=P(None, "sp"))
).lower(q, k, v).compile().as_text()
assert "collective-permute" in txt
print("RING_OK")
"""


def test_ring_attention_matches_oracle():
    out = run_with_devices(CODE, 8)
    assert "RING_OK" in out
