"""Degrade hypothesis property tests to skips when hypothesis is absent.

Import ``given`` / ``settings`` / ``st`` from here instead of ``hypothesis``:
with hypothesis installed this is a pass-through; without it, ``@given(...)``
replaces the test with a zero-argument skip stub (so collection never errors
and plain pytest tests in the same module still run), per the
``pytest.importorskip``-style degradation the suite promises.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def _skip_decorator(*_args, **_kwargs):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    given = settings = _skip_decorator

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies``: any strategy call
        returns None (never consumed — the test body is skipped)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
