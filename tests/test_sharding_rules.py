"""Sharding rules: TP/FSDP specs, divisibility fallback, on a 16-dev mesh."""
from _multidev import run_with_devices

CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_reduced, get_config
from repro.launch.mesh import make_local_mesh
from repro.models import lm
from repro.train import shardings as SH
from repro.train.step import ParallelConfig, init_train_state, make_train_step
from repro.data.pipeline import DataConfig, batch_at

mesh = make_local_mesh(4, 4)
cfg = get_reduced("tinyllama-1.1b")

params = jax.eval_shape(lambda: lm.init_lm(cfg, jax.random.key(0)))
sh = SH.tree_shardings(params, mesh, SH.param_spec, fsdp=True)

# embed (V=512, M=64): vocab over model, fsdp over d
assert sh["embed"].spec == P("model", "data"), sh["embed"].spec
# attention out proj stacked (L, H*D, M): row-parallel
assert sh["stage0"]["b0"]["mixer"]["wo"].spec[-2] == "model"
# norms replicated
assert all(s is None for s in sh["final_norm"].spec)

# kv-head divisibility: n_kv=2 < model axis 4 -> wk output dim (2*16=32)
# divides 4 -> sharded; force a case that doesn't divide:
import dataclasses
cfg3 = dataclasses.replace(cfg, n_kv_heads=1, head_dim=17)   # wk out = 17
p3 = jax.eval_shape(lambda: lm.init_lm(cfg3, jax.random.key(0)))
s3 = SH.tree_shardings(p3, mesh, SH.param_spec, fsdp=True)
assert s3["stage0"]["b0"]["mixer"]["wk"].spec[-1] is None   # replicate

# end-to-end: sharded train step runs on the 4x4 mesh and stays finite
pcfg = ParallelConfig(fsdp=True)
state = init_train_state(cfg, jax.random.key(0), pcfg)
_, compile_step, _ = make_train_step(cfg, mesh, pcfg)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
batch = batch_at(dcfg, 0)
shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                      (state, batch))
step = compile_step(*shapes)
state2, metrics = step(state, batch)
assert bool(jnp.isfinite(metrics["loss"])), metrics
# loss agrees with the single-device run (SPMD correctness)
mesh1 = make_local_mesh(1, 1)
_, compile1, _ = make_train_step(cfg, mesh1, ParallelConfig(fsdp=False))
state1 = init_train_state(cfg, jax.random.key(0), ParallelConfig(fsdp=False))
step1 = compile1(*jax.tree.map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), (state1, batch)))
_, m1 = step1(state1, batch)
import numpy as np
assert abs(float(metrics["loss"]) - float(m1["loss"])) < 5e-3, (
    float(metrics["loss"]), float(m1["loss"]))
print("SHARD_OK")
"""


def test_sharding_rules_and_spmd_equivalence():
    out = run_with_devices(CODE, 16, timeout=560)
    assert "SHARD_OK" in out
