"""Run a snippet in a subprocess with N forced host devices.

Multi-device tests must not set XLA_FLAGS in this process (smoke tests and
benches see 1 device, per the dry-run contract), so ring/collective tests
spawn a child with the flag set before jax imports.
"""
from __future__ import annotations

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout
