"""Zero-copy session forking (the RowClone analogue): the refcounted CoW
fork table, the engine's zero-dispatch fork fast path and deferred-copy
write-break, fork-aware eviction (demotion vs destruction), cluster
materialization, fault interplay (detect once, repair every alias), and
the RowClone FPM/PSM pricing the movement layer quotes for all of it.

The property test (hypothesis, with fixed-case fallback streams) drives
random fork/write/evict/release sequences and asserts refcount
conservation after every step: physical rows == unique alias targets,
zero leaks and zero double-frees at drain.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from _multidev import run_with_devices

from repro.analysis import testlib as TL
from repro.configs import get_reduced
from repro.core.dram.spec import DDR3_1600
from repro.faults import repair_row, restore_session, snapshot_sessions
from repro.fork import ForkPageTable
from repro.models import lm
from repro.serve.cluster import Cluster
from repro.serve.engine import Engine, Request, UnknownSession


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("tinyllama-1.1b")
    params = lm.init_lm(cfg, jax.random.key(0))
    return cfg, params


def _drain(eng, toks=None):
    while eng.active:
        for _, req in eng.step():
            if toks is not None:
                toks[req.uid] = [int(t) for t in req.generated]


def _suspended_template(eng, uid, prompt):
    """Prefill ``prompt`` once and leave it suspended (max_new=1 completes
    at the prefill token)."""
    eng.submit(Request(uid=uid, prompt=prompt, max_new=1))
    assert uid in eng.session_pos and not eng.active


# ---------------------------------------------------------------------------
# ForkPageTable: the ledger in isolation
# ---------------------------------------------------------------------------

def test_table_bind_fork_release_lifecycle():
    ft = ForkPageTable()
    ft.bind(10, 3)
    assert ft.resolve(10) == 3 and ft.refcount(10) == 1 and not ft.shared(10)
    assert ft.fork_child(10, 11) == 3
    assert ft.fork_child(10, 12) == 3
    assert ft.refcount(11) == 3 and ft.shared(12)
    assert ft.aliases(3) == (10, 11, 12)
    assert ft.shared_rows() == {3: 3}
    assert ft.release(11) is None            # still shared: row survives
    assert ft.release(10) is None
    assert ft.release(12) == 3               # last alias frees the row
    assert len(ft) == 0 and not ft.refs


def test_table_bind_rejects_double_claims():
    ft = ForkPageTable()
    ft.bind(1, 0)
    with pytest.raises(ValueError, match="already mapped"):
        ft.bind(1, 2)
    with pytest.raises(ValueError, match="already owned"):
        ft.bind(2, 0)
    with pytest.raises(ValueError, match="already mapped"):
        ft.fork_child(1, 1)


def test_table_write_break_exclusive_is_a_noop():
    ft = ForkPageTable()
    ft.bind(1, 4)
    assert ft.write_break(1) == 4            # no alloc needed, no copy
    assert ft.refcount(1) == 1


def test_table_write_break_detaches_shared():
    ft = ForkPageTable()
    ft.bind(1, 4)
    ft.fork_child(1, 2)
    with pytest.raises(ValueError, match="alloc callback"):
        ft.write_break(2)
    assert ft.write_break(2, alloc=lambda uid: 7) == 7
    assert ft.resolve(1) == 4 and ft.resolve(2) == 7
    assert ft.refcount(1) == 1 and ft.refcount(2) == 1
    ft.check_conserved()


def test_table_write_break_follows_an_alloc_side_demotion():
    """The alloc callback may demote the very shared row the uid is
    detaching from (engine: uid's home index IS the shared row); the
    bookkeeping must follow the repoint, not the stale row."""
    ft = ForkPageTable()
    ft.bind(1, 0)
    ft.fork_child(1, 2)

    def alloc(uid):
        ft.repoint(0, 5)                     # demotion: bytes moved 0 -> 5
        return 0

    assert ft.write_break(2, alloc=alloc) == 0
    assert ft.resolve(1) == 5 and ft.refcount(1) == 1
    assert ft.resolve(2) == 0 and ft.refcount(2) == 1
    ft.check_conserved()


def test_table_repoint_moves_the_family_as_one_unit():
    ft = ForkPageTable()
    ft.bind(1, 2)
    ft.fork_child(1, 5)
    ft.fork_child(1, 9)
    assert ft.repoint(2, 6) == (1, 5, 9)
    assert ft.refs == {6: 3}
    with pytest.raises(ValueError, match="already owned"):
        ft.repoint(6, 6)
    with pytest.raises(KeyError):
        ft.repoint(2, 7)                     # old row no longer mapped
    ft.check_conserved()


# ---------------------------------------------------------------------------
# refcount conservation under random op streams (property test)
# ---------------------------------------------------------------------------

N_ROWS = 8


def _run_stream(ops):
    """Interpret a (op, arg) stream against a ForkPageTable plus a model
    free-list; assert the conservation invariants after EVERY step and
    zero leaks / zero double-frees at drain."""
    ft = ForkPageTable()
    free = set(range(N_ROWS))
    uids, next_uid = [], 0
    for op, arg in ops:
        if op == 0 and free:                           # admit a fresh uid
            row = min(free)
            free.remove(row)
            ft.bind(next_uid, row)
            uids.append(next_uid)
            next_uid += 1
        elif op == 1 and uids:                         # fork a child
            ft.fork_child(uids[arg % len(uids)], next_uid)
            uids.append(next_uid)
            next_uid += 1
        elif op == 2 and uids and free:                # CoW write-break
            uid = uids[arg % len(uids)]

            def alloc(u):
                row = min(free)
                free.remove(row)
                return row

            ft.write_break(uid, alloc=alloc)
        elif op == 3 and uids:                         # release/evict
            freed = ft.release(uids.pop(arg % len(uids)))
            if freed is not None:
                assert freed not in free, "double-free"
                free.add(freed)
        ft.check_conserved()
        # physical rows in use == unique alias targets, disjoint from free
        assert len(set(ft.phys_of.values())) == len(ft.refs)
        assert set(ft.refs).isdisjoint(free)
        assert len(ft.refs) + len(free) == N_ROWS      # no leaked rows
    for uid in list(uids):                             # drain
        freed = ft.release(uid)
        if freed is not None:
            assert freed not in free, "double-free at drain"
            free.add(freed)
    assert len(ft) == 0 and not ft.refs
    assert free == set(range(N_ROWS)), "leaked rows at drain"


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 63)),
                max_size=60))
def test_refcount_conservation_random_streams(ops):
    _run_stream(ops)


@pytest.mark.parametrize("ops", [
    [],
    [(0, 0)] * N_ROWS + [(3, 0)] * N_ROWS,             # fill then drain
    [(0, 0), (1, 0), (1, 0), (2, 1), (3, 0), (3, 0), (3, 0)],
    [(0, 0), (1, 0)] * 6 + [(2, i) for i in range(7)] + [(3, 0)] * 5,
    [(0, 0), (0, 0), (1, 1), (3, 1), (1, 0), (2, 0), (3, 2), (3, 0)],
], ids=["empty", "fill_drain", "fork_break", "deep_family", "interleaved"])
def test_refcount_conservation_fixed_streams(ops):
    """Fixed-case fallback for the hypothesis stream test (runs — and
    guards the same invariants — even where hypothesis is absent)."""
    _run_stream(ops)


# ---------------------------------------------------------------------------
# engine: zero-dispatch fork, CoW divergence, fork-aware eviction
# ---------------------------------------------------------------------------

def test_fork_fast_path_is_zero_dispatch(setup):
    """fork_many is pure host bookkeeping: zero fused dispatches and zero
    device->host transfers over the window (the RowClone-FPM analogue,
    pinned via the dispatch-delta asserter)."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=12)
    _suspended_template(eng, 0, rng.integers(0, cfg.vocab_size, 8)
                        .astype(np.int32))
    before = TL.snapshot_stats(eng)
    eng.fork_many(0, [5, 6, 7], seed_tokens=[11, 22, 33])
    TL.assert_dispatch_delta(before, eng.stats, decode=0, host=0)
    assert eng.stats["forks"] == 3
    assert eng.stats["bytes_not_copied"] == 3 * eng.snapshot_bytes
    phys = eng.forks.resolve(0)
    assert all(eng.forks.resolve(c) == phys for c in (5, 6, 7))
    assert eng.forks.refcount(0) == 4
    assert all(eng.session_pos[c] == eng.session_pos[0] for c in (5, 6, 7))
    assert eng.shared_uids() == frozenset({0, 5, 6, 7})


def test_fork_validation(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=12)
    with pytest.raises(UnknownSession):
        eng.fork(0, 1)                       # parent never suspended
    _suspended_template(eng, 0, rng.integers(0, cfg.vocab_size, 6)
                        .astype(np.int32))
    with pytest.raises(ValueError, match="already in use"):
        eng.fork(0, 0)
    eng.fork(0, 5)
    with pytest.raises(ValueError, match="already in use"):
        eng.fork(0, 5)
    slot = eng.resume(0, extra_new=3)
    with pytest.raises(ValueError, match="active"):
        eng.fork(0, 6)                       # parent must be quiescent
    eng.suspend(slot)


def test_forked_children_decode_bit_exactly(setup):
    """Fork-served children produce byte-identical tokens to independently
    prefilled sessions with the same seeds: aliasing (and the CoW detach on
    their first suspend) is invisible to the data path."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    seeds = [3, 1000, 3]                     # two children share a seed

    eng = Engine(cfg, params, slots=3, max_len=96, n_sessions=12)
    _suspended_template(eng, 0, prompt)
    eng.fork_many(0, [4, 5, 6], seed_tokens=seeds)
    toks_forked = {}
    eng.resume_many([4, 5, 6], extra_new=5)
    _drain(eng, toks_forked)

    ref = Engine(cfg, params, slots=3, max_len=96, n_sessions=12)
    ref.adopt_jits(eng)
    toks_ref = {}
    for uid, seed in zip((4, 5, 6), seeds):
        ref.submit(Request(uid=uid, prompt=prompt, max_new=1))
        ref.reseed(uid, seed)
    ref.resume_many([4, 5, 6], extra_new=5)
    _drain(ref, toks_ref)

    assert toks_forked == toks_ref
    assert toks_forked[4] == toks_forked[6]          # same seed, same path
    assert toks_forked[4] != toks_forked[5]          # divergence diverges
    # CoW happened: each child detached onto its own row at suspend; the
    # parent keeps the original snapshot, now exclusive again
    rows = {eng.forks.resolve(u) for u in (0, 4, 5, 6)}
    assert len(rows) == 4
    assert not eng.shared_uids()


def test_parent_snapshot_survives_child_divergence(setup):
    """After children diverge and write-break away, the parent resumes from
    its original snapshot bit-exactly (the deferred copy never touched the
    shared row)."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)

    ref = Engine(cfg, params, slots=2, max_len=96, n_sessions=12)
    _suspended_template(ref, 0, prompt)
    toks = {}
    ref.resume_many([0], extra_new=4)
    _drain(ref, toks)
    want = toks[0]

    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=12)
    eng.adopt_jits(ref)
    _suspended_template(eng, 0, prompt)
    eng.fork_many(0, [4, 5], seed_tokens=[9, 10])
    eng.resume_many([4, 5], extra_new=4)
    _drain(eng)
    got = {}
    eng.resume_many([0], extra_new=4)
    _drain(eng, got)
    assert got[0] == want


def test_collision_demotes_shared_rows_and_evicts_exclusive(setup):
    """Fork-aware eviction accounting: a store-index collision DESTROYS an
    exclusive snapshot (``evictions``) but MIGRATES a shared one
    (``demotions``) — every alias stays resumable, and the stats split the
    two outcomes."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    n = 12
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=n)
    _suspended_template(eng, 0, prompt)              # row 0, exclusive
    _suspended_template(eng, 1, prompt)              # row 1, then shared
    eng.fork_many(1, [7, 8])
    toks_before = {}
    eng.resume_many([7], extra_new=3)
    _drain(eng, toks_before)
    eng.fork(1, 9)                                   # re-share after 7 left

    # uid n collides with row 0 (exclusive): destroyed
    _suspended_template(eng, n, prompt)
    assert eng.stats["evictions"] == 1 and eng.stats["demotions"] == 0
    with pytest.raises(UnknownSession):
        eng.resume(0, extra_new=2)
    # uid n+1 collides with row 1 (shared by 1, 8, 9): demoted, not
    # destroyed — the family's bytes moved to a free row as one unit
    _suspended_template(eng, n + 1, prompt)
    assert eng.stats["demotions"] == 1 and eng.stats["evictions"] == 1
    new_row = eng.forks.resolve(1)
    assert new_row != 1 and eng.forks.refcount(1) == 3
    assert eng.verify_failure_count() == 0
    toks_after = {}
    eng.resume_many([8], extra_new=3)                # same seed as 7 had
    _drain(eng, toks_after)
    assert toks_after[8] == toks_before[7]           # bytes moved intact
    assert eng.verify_failure_count() == 0           # sidecar moved too


def test_verify_store_counts_shared_corruption_once(setup):
    """One corrupted physical row aliased by N sessions is ONE detection
    (the scrub walks physical rows, not logical sessions)."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=12)
    _suspended_template(eng, 0, rng.integers(0, cfg.vocab_size, 6)
                        .astype(np.int32))
    eng.fork_many(0, [4, 5, 6])
    assert int(eng.verify_store()) == 0
    eng.corrupt_stored(eng.forks.resolve(0), page=0, byte=3, xor=0x40)
    assert int(eng.verify_store()) == 1              # once, not 4x


# ---------------------------------------------------------------------------
# pricing: the rowclone mechanism and the fork plan
# ---------------------------------------------------------------------------

def test_rowclone_mechanism_prices_fpm_at_one_hop():
    """hops=1 (in-subarray alias) prices as RowClone FPM — the Table-1
    RC-IntraSA row: 83.75 ns, 2 activate-precharge pairs of energy — and
    materialization across h subarrays grows by the LISA hop rate."""
    s = DDR3_1600
    assert s.copy_latency("rowclone", 1) == pytest.approx(
        s.copy_latency("rc_intrasa"))
    assert s.copy_energy("rowclone", 1) == pytest.approx(
        2 * s.energy.e_act_pre)
    assert s.copy_latency("rowclone", 1) == pytest.approx(83.75)
    hop = s.copy_latency("rowclone", 5) - s.copy_latency("rowclone", 4)
    assert hop == pytest.approx(s.lisa.t_rbm_hop)
    # the serving gate: aliasing beats the channel copy by >= 10x
    assert s.copy_latency("memcpy") / s.copy_latency("rowclone", 1) >= 10


def test_engine_fork_plan_quotes_the_rowclone_gap(setup):
    cfg, params = setup
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    c = eng.plan_fork.cost
    assert c.bytes == eng.snapshot_bytes             # bytes NOT copied
    assert c.ns_memcpy / c.ns_lisa >= 10
    assert [leg.kind for leg in eng.plan_fork.legs] == ["page_alias"]


# ---------------------------------------------------------------------------
# cluster: same-replica alias vs cross-replica materialization
# ---------------------------------------------------------------------------

def test_cluster_fork_alias_and_materialization(setup):
    cfg, params = setup
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=96,
                 n_sessions=8)
    cl.submit(Request(uid=0, prompt=prompt, max_new=1), replica=0)
    assert not cl.active

    cl.fork(0, 4)                                    # same replica: alias
    assert cl.residence[4] == 0
    assert cl.replicas[0].forks.refcount(0) == 2
    assert cl.cluster_stats["fork_materializations"] == 0

    cl.fork(0, 5, replica=1, seed_token=17)          # cross: materialize
    assert cl.residence[5] == 1
    assert cl.cluster_stats["fork_materializations"] == 1
    assert cl.cluster_stats["migrated_bytes"] > 0
    # the parent's refcount is untouched (the copy was an admission, not
    # an alias), and the child is an exclusive row on the destination
    assert cl.replicas[0].forks.refcount(0) == 2
    assert cl.replicas[1].forks.refcount(5) == 1

    # both children decode bit-exactly vs the alias child with same seed
    cl.replicas[0].reseed(4, 17)
    toks = {}
    for uid in (4, 5):
        slot = cl.resume(uid, extra_new=4)
        r = cl.active[slot]
        while cl.active:
            cl.step()
        toks[uid] = list(r.generated)
    assert toks[4] == toks[5]
    assert cl.verify_failure_count() == 0            # sidecar traveled


def test_fail_replica_clears_the_fork_table(setup):
    cfg, params = setup
    rng = np.random.default_rng(7)
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=96,
                 n_sessions=8)
    cl.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 6)
                      .astype(np.int32), max_new=1), replica=0)
    cl.fork(0, 4)
    assert cl.shared_uids() == frozenset({0, 4})
    cl.fail_replica(0)
    assert len(cl.replicas[0].forks) == 0
    assert cl.shared_uids() == frozenset()


# ---------------------------------------------------------------------------
# faults: one snapshot per physical row; one repair heals the family
# ---------------------------------------------------------------------------

def test_snapshot_stores_shared_pages_once_and_repairs_all_aliases(setup):
    """A fork family snapshots its shared row ONCE (carrier + meta-only
    aliases); after the shared row corrupts AND the replica dies, restoring
    the carrier once re-attaches every alias — one staged copy, one repair,
    the whole family verify-clean and bit-exact."""
    cfg, params = setup
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=96,
                 n_sessions=8)
    cl.submit(Request(uid=0, prompt=prompt, max_new=1), replica=0)
    cl.fork(0, 4, seed_token=21)
    cl.fork(0, 5, seed_token=22)

    # clean-run reference for child 4's continuation
    ref = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    ref.adopt_jits(cl.replicas[0])
    _suspended_template(ref, 4, prompt)
    ref.reseed(4, 21)
    want = {}
    ref.resume_many([4], extra_new=4)
    _drain(ref, want)

    snaps, cost = snapshot_sessions(cl)
    # ONE physical row staged for the 3-session family, not 3 (the cost
    # covers the carrier's pages + sidecar, under 2 rows' worth of bytes)
    assert cl.replicas[0].snapshot_bytes <= cost.bytes \
        < 2 * cl.replicas[0].snapshot_bytes
    assert snaps[0].pages is not None                # uid 0 carries
    for c in (4, 5):
        assert snaps[c].pages is None and snaps[c].alias_of == 0

    eng = cl.replicas[0]
    eng.corrupt_stored(eng.forks.resolve(0), page=0, byte=2, xor=0x08)
    assert int(eng.verify_store()) == 1              # detected ONCE
    cl.fail_replica(0)

    # owners first, aliases re-attach for free
    assert restore_session(cl, snaps[0], 1).bytes > 0
    assert restore_session(cl, snaps[4], 1).bytes == 0
    assert restore_session(cl, snaps[5], 1).bytes == 0
    eng1 = cl.replicas[1]
    assert eng1.forks.refcount(0) == 3
    assert int(eng1.verify_store()) == 0             # one repair healed all
    got = {}
    eng1.resume_many([4], extra_new=4)
    _drain(eng1, got)
    assert got[4] == want[4]
    assert cl.verify_failure_count() == 0


def test_repair_row_heals_a_live_shared_row_in_place(setup):
    """Pre-resume repair of a corrupt SHARED row on a LIVE replica: the
    carrier's snapshot overwrites the physical row in place — fork table,
    refcounts and per-alias seed tokens untouched — so one staged copy
    heals every alias.  (restore_session here would re-admit the carrier
    and demote the still-corrupt row to the siblings.)"""
    cfg, params = setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=96,
                 n_sessions=8)
    cl.submit(Request(uid=0, prompt=prompt, max_new=1), replica=0)
    cl.fork(0, 4, seed_token=31)
    cl.fork(0, 5, seed_token=32)
    snaps, _ = snapshot_sessions(cl)

    eng = cl.replicas[0]
    eng.corrupt_stored(eng.forks.resolve(0), page=1, byte=3, xor=0x11)
    assert int(eng.verify_store()) == 1
    cost = repair_row(cl, snaps[0], 0)
    assert cost is not None and cost.bytes > 0
    assert int(eng.verify_store()) == 0              # whole row healed
    assert eng.forks.refcount(0) == 3                # family untouched
    assert eng.session_tok[4] == 31 and eng.session_tok[5] == 32

    # an alias (meta-only) snapshot or a departed uid cannot repair
    assert repair_row(cl, snaps[4], 0) is None
    eng.resume_many([5], extra_new=4)
    got = {}
    _drain(eng, got)
    assert len(got[5]) == 4                          # serves clean post-heal
    assert cl.verify_failure_count() == 0


def test_alias_restore_without_carrier_reports_lost(setup):
    cfg, params = setup
    rng = np.random.default_rng(9)
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=96,
                 n_sessions=8)
    cl.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 6)
                      .astype(np.int32), max_new=1), replica=0)
    cl.fork(0, 4)
    snaps, _ = snapshot_sessions(cl)
    cl.fail_replica(0)
    assert restore_session(cl, snaps[4], 1) is None  # carrier not resident
    assert 4 not in cl.replicas[1].session_pos


# ---------------------------------------------------------------------------
# the cross-replica fork plan on a real 4-device mesh
# ---------------------------------------------------------------------------

MESH_FORK_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import movement as MV
from repro.core.lisa.topology import MeshTopology

mesh = jax.make_mesh((4,), ("replica",))
SRC, DST = 1, 3
pool = jax.random.randint(jax.random.key(1), (4, 8, 8, 128), 0, 256,
                          jnp.int32).astype(jnp.uint8)
src_table = jnp.asarray([2, 3], jnp.int32)
dst_table = jnp.asarray([5, 6], jnp.int32)
plan = MV.plan(MV.Transfer(MV.Tier("slow", index=SRC, axis="replica"),
                           MV.Tier("slow", index=DST, axis="replica"),
                           MV.Layout.raw_pages(2, 8, 128, jnp.uint8),
                           kind="fork"),
               topo=MeshTopology(4))
# a cross-replica fork MATERIALIZES: the same gather -> hop chain ->
# scatter legs as a migration, not a page_alias
assert [l.kind for l in plan.legs] == ["page_gather", "hop_chain",
                                       "page_scatter"]

def body(shard):
    local = shard.reshape(8, 8, 128)
    env = MV.execute(plan, src_pool=local, src_table=src_table,
                     dst_pool=local, dst_table=dst_table)
    out = jnp.where(jax.lax.axis_index("replica") == DST,
                    env["dst_pool"], local)
    return out.reshape(shard.shape)

out = np.asarray(jax.jit(jax.shard_map(
    body, mesh=mesh, in_specs=P("replica"), out_specs=P("replica"),
    check_rep=False))(pool))
want = np.asarray(pool).copy()
want[DST][np.asarray(dst_table)] = want[SRC][np.asarray(src_table)]
assert (out == want).all(), "materialized fork pages did not land bit-exactly"
print("MESH_FORK_OK")
"""


def test_fork_materialization_plan_executes_on_real_mesh():
    """The cross-replica ``fork``-kind plan executes its hop chain as a
    real ppermute on a 4-device mesh — a materialized fork is a true copy
    over the fabric, landing bit-exactly in the destination pool."""
    out = run_with_devices(MESH_FORK_CODE, 4)
    assert "MESH_FORK_OK" in out
