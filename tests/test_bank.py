"""Bank-level contention units and scheduler integration: refresher
windows, row-state machines, multiplexer overlap/serialization, the
decode-inside-tRFC stall, idle fast-forward vs pending refresh, the
unified lane-advance accounting, and the percentile/backoff pins."""
import math

import jax
import numpy as np
import pytest

from repro import movement as MV
from repro import sched
from repro.configs import get_reduced
from repro.core.dram.bank import BankMachine, Refresher, RequestMultiplexer
from repro.core.dram.spec import DDR3_1600, DramTiming
from repro.models import lm
from repro.sched.metrics import Decision, Metrics, percentile_ns
from repro.serve.cluster import Cluster
from repro.serve.engine import Engine

T = DDR3_1600.timing


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("tinyllama-1.1b")
    params = lm.init_lm(cfg, jax.random.key(0))
    return cfg, params


def _fresh(t, uid, *, priority=1, slo=math.inf, tokens=3, plen=5, seed=0):
    rng = np.random.default_rng(seed + uid)
    return sched.Arrival(t_ns=t, uid=uid, kind="fresh", priority=priority,
                         slo_ns=slo, new_tokens=tokens,
                         prompt=rng.integers(0, 1000, plen).astype(np.int32))


# ---------------------------------------------------------------------------
# refresher: absolute-time windows
# ---------------------------------------------------------------------------

def test_refresher_windows_are_absolute_time():
    r = Refresher(tREFI=1000.0, tRFC=100.0)
    assert r.window(1) == (1000.0, 1100.0)
    assert r.window(3) == (3000.0, 3100.0)
    with pytest.raises(ValueError, match="1-indexed"):
        r.window(0)
    # no window at t=0: the rank starts fresh
    assert r.window_at(0.0) is None and r.window_at(999.9) is None
    assert r.window_at(1000.0) == 1 and r.window_at(1099.9) == 1
    assert r.window_at(1100.0) is None
    assert r.next_free(1050.0) == 1100.0
    assert r.next_free(1100.0) == 1100.0
    assert r.stall_ns(1050.0) == pytest.approx(50.0)
    assert r.refreshes_before(999.0) == 0
    assert r.refreshes_before(5500.0) == 5


def test_refresher_fast_forward_cannot_skip_windows():
    """Jumping the clock across N windows changes NOTHING about where the
    next one sits: windows derive from absolute time, not from a counter
    the jump could leave behind."""
    r = Refresher(tREFI=1000.0, tRFC=100.0)
    # a clock that crawled to 5050 and one that jumped there agree
    assert r.window_at(5050.0) == 5
    assert r.next_free(5050.0) == 5100.0
    assert r.refreshes_before(5050.0) == 5


def test_refresher_validation():
    with pytest.raises(ValueError, match="tRFC"):
        Refresher(tREFI=100.0, tRFC=100.0)
    with pytest.raises(ValueError, match="tRFC"):
        Refresher(tREFI=100.0, tRFC=0.0)


def test_spec_presets_carry_refresh_timing():
    assert 0.0 < T.tRFC < T.tREFI
    assert T.tREFI == pytest.approx(7800.0)     # 64 ms / 8192 rows
    assert T.tRFC == pytest.approx(260.0)       # DDR3 4 Gb
    with pytest.raises(ValueError, match="tRFC"):
        DramTiming(tREFI=100.0, tRFC=200.0)


# ---------------------------------------------------------------------------
# bank machine: same-bank serialization, open-page row policy, refresh
# ---------------------------------------------------------------------------

def _machine(tREFI=1e9, tRFC=1.0):
    return BankMachine(T, Refresher(tREFI, tRFC))


def test_bank_serializes_same_bank_requests_exactly():
    b = _machine()
    s0, e0 = b.accept(0.0, 100.0)
    s1, e1 = b.accept(0.0, 50.0)        # ready at 0, but the bank is busy
    assert (s0, e0) == (0.0, 100.0)
    assert (s1, e1) == (100.0, 150.0)
    assert b.queue_stall_ns == pytest.approx(100.0)


def test_bank_row_policy_hit_free_miss_pays():
    b = _machine()
    _, e0 = b.accept(0.0, 10.0, row=7)          # cold: ACT only
    assert e0 == pytest.approx(T.tRCD + 10.0)
    s1, e1 = b.accept(e0, 10.0, row=7)          # row hit: no overhead
    assert e1 - s1 == pytest.approx(10.0)
    # row miss with a row open: wait out tRAS from ACT, then tRP + tRCD
    t_act = T.tRCD - T.tRCD            # ACT at start+overhead-tRCD == 0.0
    s2, e2 = b.accept(e1, 10.0, row=9)
    assert s2 >= t_act + T.tRAS
    assert e2 - s2 == pytest.approx(T.tRP + T.tRCD + 10.0)
    assert (b.n_row_hits, b.n_row_misses) == (1, 2)


def test_bank_start_pushed_out_of_refresh_window():
    b = _machine(tREFI=1000.0, tRFC=100.0)
    s, e = b.accept(1010.0, 20.0)
    assert s == 1100.0 and e == 1120.0
    assert b.refresh_stall_ns == pytest.approx(90.0)


# ---------------------------------------------------------------------------
# multiplexer: overlap vs serialization, pass-through, decode gate
# ---------------------------------------------------------------------------

def test_mux_disabled_is_pure_passthrough():
    m = RequestMultiplexer(DDR3_1600, enabled=False)
    assert m.submit(5.0, 5.0, 100.0) == (5.0, 105.0)
    assert m.wave([(0, 100.0), (0, 100.0)], 0.0) == 100.0   # no queueing
    assert m.decode_gate(7850.0) == 7850.0                  # no refresh
    assert m.stats["n_requests"] == 0


def test_mux_disjoint_banks_overlap_same_bank_serializes():
    m = RequestMultiplexer(DDR3_1600, n_banks=8)
    # disjoint banks: the wave completes in max, not sum
    assert m.wave([(0, 100.0), (1, 80.0), (2, 60.0)], 0.0) == 100.0
    # same bank: serializes exactly — completion is the sum of services
    m2 = RequestMultiplexer(DDR3_1600, n_banks=8)
    assert m2.wave([(3, 100.0), (3, 80.0), (3, 60.0)], 0.0) == 240.0
    assert m2.stats["queue_stall_ns"] == pytest.approx(100.0 + 180.0)


def test_mux_bank_of_is_deterministic_mod_map():
    m = RequestMultiplexer(DDR3_1600, n_banks=8)
    assert [m.bank_of(u) for u in (0, 7, 8, 15)] == [0, 7, 0, 7]
    with pytest.raises(ValueError, match="bank"):
        m.submit(8, 0.0, 1.0)
    with pytest.raises(ValueError, match="n_banks"):
        RequestMultiplexer(DDR3_1600, n_banks=0)


def test_mux_decode_gate_stalls_inside_trfc():
    m = RequestMultiplexer(DDR3_1600)
    assert m.decode_gate(100.0) == 100.0
    # inside window 1 (7800..8060): pushed to its end
    assert m.decode_gate(7900.0) == pytest.approx(8060.0)
    assert m.stats["n_decode_stalls"] == 1
    assert m.stats["decode_refresh_stall_ns"] == pytest.approx(160.0)
    snap = m.snapshot()
    assert snap["n_banks"] == 8 and snap["enabled"]
    assert len(snap["per_bank_requests"]) == 8


def test_contend_pairs_isolated_cost_with_contended_window():
    m = RequestMultiplexer(DDR3_1600, n_banks=4)
    cost = MV.MovementCost(4096, 2, 100.0, 900.0, 1.0, 5.0)
    a = MV.contend(cost, m, bank=1, ready_ns=0.0)
    assert (a.start_ns, a.end_ns) == (0.0, 100.0)
    assert a.stall_ns == 0.0 and a.cost is cost
    b = MV.contend(cost, m, bank=1, ready_ns=10.0)   # queued behind a
    assert b.start_ns == 100.0 and b.stall_ns == pytest.approx(90.0)
    c = MV.contend(cost, m, bank=2, ready_ns=10.0, mechanism="memcpy")
    assert c.end_ns - c.start_ns == pytest.approx(900.0)


# ---------------------------------------------------------------------------
# percentile pin (single- and two-element buckets)
# ---------------------------------------------------------------------------

def test_percentile_linear_small_buckets():
    assert percentile_ns([], 99) is None
    assert percentile_ns([42.0], 50) == 42.0
    assert percentile_ns([42.0], 99) == 42.0
    # two elements under method="linear": p50 is the midpoint, p99
    # interpolates 99% of the way — the exact values a method change
    # (e.g. "nearest") would break
    assert percentile_ns([10.0, 20.0], 50) == pytest.approx(15.0)
    assert percentile_ns([10.0, 20.0], 99) == pytest.approx(19.9)
    assert percentile_ns([10.0, 20.0], 0) == 10.0


# ---------------------------------------------------------------------------
# backoff bucket: the advantage ratio is fault-rate-invariant
# ---------------------------------------------------------------------------

def test_backoff_never_skews_the_mechanism_ratio():
    """The same priced schedule under 0 vs heavy retry backoff reports the
    SAME lisa/memcpy advantage: backoff rides in its own bucket, never in
    the per-mechanism movement ns (the old accounting added it to both,
    drifting the ratio toward 1 with the fault rate)."""
    def run(backoff):
        mets = Metrics()
        mets.record_decision(Decision(tick=1, kind="resume_wave", n_items=2,
                                      ns_lisa=200.0, ns_memcpy=1800.0,
                                      uj_lisa=1.0, uj_memcpy=9.0))
        mets.record_decision(Decision(tick=2, kind="retry_wave", n_items=3,
                                      ns_lisa=300.0, ns_memcpy=2700.0,
                                      uj_lisa=1.5, uj_memcpy=13.5,
                                      backoff_ns=backoff))
        return mets.movement_totals()
    calm, chaotic = run(0.0), run(50_000.0)
    assert calm["advantage"] == pytest.approx(9.0)
    assert chaotic["advantage"] == calm["advantage"]     # invariant
    assert chaotic["backoff_ns"] == pytest.approx(50_000.0)
    assert chaotic["ns_lisa"] == calm["ns_lisa"]


# ---------------------------------------------------------------------------
# scheduler integration: refresh × the tick loop
# ---------------------------------------------------------------------------

def test_decode_tick_inside_trfc_observes_the_stall(setup):
    """An idle fast-forward lands the clock so the first decode issues at
    exactly a refresh window's start (prefill ends at 3*tREFI): the decode
    stalls for the full tRFC, metrics record it, and the windows the jump
    crossed are still accounted (absolute-time windows — satellite 4)."""
    cfg, params = setup
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    prefill_ns = 250.0 * 5                       # plen=5 at default pricing
    t_arrive = 3 * T.tREFI - prefill_ns          # decode lands at 3*tREFI
    s = sched.Scheduler(eng, arrivals=[_fresh(t_arrive, 0, tokens=3)],
                        cfg=sched.SchedConfig(contention=True))
    out = s.run()
    assert out["jobs_completed"] == 1
    # the jump crossed windows 1 and 2 without "executing" them, yet
    # window 3 still blocked at its absolute time
    assert s.mux.refreshes_before(s.now_ns) >= 3
    assert s.mux.stats["n_decode_stalls"] >= 1
    st = out["stalls"]["refresh"]
    assert st["n"] >= 1 and st["ns"] >= T.tRFC   # first stall is the full
    assert s.mux.stats["decode_refresh_stall_ns"] >= T.tRFC


def test_contention_off_run_reports_no_stalls(setup):
    cfg, params = setup
    eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
    arrivals = [_fresh(3 * T.tREFI - 1250.0, 0, tokens=3)]
    s = sched.Scheduler(eng, arrivals=arrivals, cfg=sched.SchedConfig())
    out = s.run()
    assert out["jobs_completed"] == 1
    assert "stalls" not in out                   # schema unchanged when off
    assert s.mux.stats["n_requests"] == 0


def test_contention_shifts_the_clock_never_the_bill(setup):
    """Contention-on vs -off over the same arrivals: identical jobs and
    identical movement bills (pricing untouched).  The clock shifts both
    ways by design — same-bank queues and refresh windows delay, while
    disjoint-bank wave members overlap instead of serializing — so the
    invariant is the bill, not a one-sided latency ordering."""
    cfg, params = setup
    arrivals = [_fresh(i * 400.0, i, tokens=2, plen=4) for i in range(6)]
    outs, nows = [], []
    for contention in (False, True):
        eng = Engine(cfg, params, slots=2, max_len=96, n_sessions=8)
        s = sched.Scheduler(eng, arrivals=list(arrivals),
                            cfg=sched.SchedConfig(contention=contention))
        outs.append(s.run())
        nows.append(s.now_ns)
    off, on = outs
    assert on["jobs_completed"] == off["jobs_completed"] == 6
    assert on["movement"]["ns_lisa"] == off["movement"]["ns_lisa"]
    assert on["movement"]["advantage"] == off["movement"]["advantage"]


def test_sched_config_validates_n_banks():
    with pytest.raises(ValueError, match="n_banks"):
        sched.SchedConfig(n_banks=0)


# ---------------------------------------------------------------------------
# lane-advance regression (satellite 1): one lanes vector per tick
# ---------------------------------------------------------------------------

def test_cluster_advance_is_decode_plus_single_max_over_lanes(setup):
    """The cluster tick advances by decode + max over replicas of each
    replica's TOTAL lane (complete-suspends AND wave execution in one
    vector).  The old accounting summed two phase maxima — pinned here by
    requiring a tick where that formula strictly overcharges."""
    cfg, params = setup
    wl = sched.WorkloadConfig(n_fresh=8, n_followups=16, mean_gap_ns=800.0,
                              arrival="bursty", burst=4, zipf_s=1.5,
                              think_ns=1500.0)
    arrivals = sched.generate_workload(wl, seed=4, vocab_size=cfg.vocab_size)
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=96,
                 n_sessions=sched.n_sessions_for(wl))
    s = sched.ClusterScheduler(cl, arrivals=arrivals)
    out = s.run()
    assert out["jobs_completed"] == 24
    assert s.lane_log
    overlap_seen = False
    for entry in s.lane_log:
        comp, fin = entry["complete_lanes"], entry["lanes"]
        exec_part = [f - c for f, c in zip(fin, comp)]
        # the contract: ONE max over the unified lanes
        assert entry["advance"] == pytest.approx(
            entry["decode_ns"] + max(fin, default=0.0))
        if max(comp) > 0 and max(exec_part) > 0:
            old = entry["decode_ns"] + max(comp) + max(exec_part)
            assert entry["advance"] <= old + 1e-9
            if entry["advance"] < old - 1e-9:
                overlap_seen = True              # the old formula overpaid
    assert overlap_seen
