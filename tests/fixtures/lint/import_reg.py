"""Planted violation: call-site registry mutation.  Linted AS IF it lived
under src/repro/; `import-time-registration` must fire exactly once — the
module-level decorator registration must NOT count."""
from repro.movement.registry import register_backend


@register_backend("fixture_noop")           # import time: clean
def _noop(plan, env):
    return env


def lazy_register():
    register_backend("fixture_late")(_noop)     # call site: finding
