"""Planted violation: wall-clock read in the virtual-clock domain.
Linted AS IF it lived under src/repro/sched/; `wallclock-in-virtual-clock`
must fire exactly once (the seeded default_rng must NOT count)."""
import time

import numpy as np


def jitter(seed):
    rng = np.random.default_rng(seed)           # seeded: clean
    return time.time() + rng.standard_normal()  # wall clock: finding
