"""Planted violation: a device sync inside tick-loop code.  Linted AS IF
it were src/repro/sched/scheduler.py; `host-sync-in-hot-loop` must fire
exactly once (the jnp.asarray host->device staging must NOT count)."""
import jax.numpy as jnp


class FakeScheduler:
    def tick(self, handle, toks):
        staged = jnp.asarray(toks)          # host->device: fine
        return handle.item(), staged        # device sync mid-tick: finding
