"""Planted violation: raw movement-kernel call outside the backend
registry.  tests/test_analysis.py lints this module AS IF it lived at a
src/repro path outside the allowlist; `movement-raw-backend` must fire
exactly once (the import and the docstring mention of villa_gather must
NOT count — the rule is call-site AST, not text)."""
from repro.kernels import villa_gather


def sneak_pages(pool, table):
    # bypasses movement.plan(): unpriced movement the Table-1 accounting
    # never sees
    return villa_gather(pool, table)
