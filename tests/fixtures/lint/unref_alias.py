"""Planted `unrefcounted-alias` violation.

tests/test_analysis.py lints this module AS IF it lived at a
src/repro/serve path (the rule's scope — serving code, where the fork
table's alias ledger is live).  The bare wave below drives the
``_suspend_many`` scatter with no fork-table refcount call in the same
function: if a forked session aliases one of the target rows, the scatter
overwrites every alias's bytes with one writer's snapshot.  The rule must
fire exactly once — on the bare wave, and NOT on the compliant one, whose
``write_break`` CoW-detaches each writer before the scatter.
"""


class SneakyEngine:
    def suspend_wave_bare(self, slots, idxs):
        # scatters into possibly-shared rows; no refcount API in sight
        self.sessions, self.session_sums = self._suspend_many(
            self.cache, self.sessions, self.session_sums, slots, idxs)

    def suspend_wave_compliant(self, slots, uids):
        idxs = [self.forks.write_break(u, alloc=self._claim_row)
                for u in uids]
        self.sessions, self.session_sums = self._suspend_many(
            self.cache, self.sessions, self.session_sums, slots, idxs)
