"""Planted violation: non-strict JSON artifact write.  `json-nan` must
fire exactly once — the strict write below must NOT count."""
import json


def write_metrics(path, metrics):
    with open(path, "w") as f:
        json.dump(metrics, f, indent=2)         # finding: NaN would leak
    return json.dumps(metrics, allow_nan=False)  # strict: clean
