"""Planted ``unclosed-span`` violation: ``leaky`` opens a span and never
closes it — every later span on the lane would nest under it.  The other
two functions show the sanctioned shapes (context manager; paired close)
and must stay clean."""


def leaky(tracer):
    s = tracer.begin_span("tick", lane=0, cat="tick")   # <- finding
    return s


def balanced(tracer):
    s = tracer.begin_span("tick", lane=0, cat="tick")
    tracer.end_span(s)
    return s


def managed(tracer):
    with tracer.span("tick", lane=0, cat="tick") as s:
        return s
