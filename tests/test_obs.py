"""One observable timeline: the virtual-clock span tracer (repro.obs).

The contracts pinned here:
  * the span tree is well-formed — children sit inside their parent's
    modeled-ns interval on the same lane, and siblings on a lane never
    overlap (per-lane cursors are monotone);
  * the Chrome-trace export is BYTE-stable across two identical seeded
    runs (traces are artifacts, diffs must mean something);
  * tracing performs zero device dispatches and changes no scheduling
    decision (host bookkeeping only);
  * movement-leg spans partition the Decision ledger exactly: legs sum to
    their move, moves sum to their decision, decisions sum to
    ``Metrics.movement_totals()`` — bit-for-bit, all four cost fields;
  * fault/retry spans agree with the chaos ledger's incident counters;
  * the committed ``ROOFLINE_REPORT.json`` covers every audited entry
    point with positive traffic and a kernel attribution.
"""
import json
import os
import sys

import jax
import pytest

from repro import sched
from repro.analysis import testlib as TL
from repro.analysis.lint import find_repo_root
from repro.configs import get_reduced
from repro.faults import FaultInjector, FaultSpec
from repro.models import lm
from repro.obs import NULL_TRACER, Span, Tracer, chrome_trace, trace_events
from repro.serve.cluster import Cluster
from repro.serve.engine import Engine

FIELDS = ("ns_lisa", "ns_memcpy", "uj_lisa", "uj_memcpy")


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("tinyllama-1.1b")
    return cfg, lm.init_lm(cfg, jax.random.key(0))


def _wl():
    return sched.WorkloadConfig(n_fresh=4, n_followups=8,
                                mean_gap_ns=1500.0, arrival="bursty",
                                burst=2)


def _base_run(cfg, params, traced=True):
    wl = _wl()
    arrivals = sched.generate_workload(wl, seed=3,
                                       vocab_size=cfg.vocab_size)
    eng = Engine(cfg, params, slots=2, max_len=48,
                 n_sessions=sched.n_sessions_for(wl))
    tr = Tracer() if traced else None
    s = sched.Scheduler(eng, arrivals=arrivals, tracer=tr)
    s.run()
    return s, eng, tr


def _cluster_run(cfg, params):
    wl = _wl()
    arrivals = sched.generate_workload(wl, seed=3,
                                       vocab_size=cfg.vocab_size)
    inj = FaultInjector(FaultSpec(rate=0.3, seed=11, max_retries=4,
                                  replica_failures=((18, 1),)))
    cl = Cluster(cfg, params, n_replicas=2, slots=2, max_len=48,
                 n_sessions=sched.n_sessions_for(wl), faults=inj)
    tr = Tracer()
    s = sched.ClusterScheduler(cl, arrivals=arrivals, snapshot_every=4,
                               tracer=tr)
    s.run()
    return s, cl, tr, inj


@pytest.fixture(scope="module")
def base_run(setup):
    return _base_run(*setup)


@pytest.fixture(scope="module")
def cluster_run(setup):
    return _cluster_run(*setup)


# ---------------------------------------------------------------------------
# tracer unit behavior
# ---------------------------------------------------------------------------

def test_tracer_basic_nesting_and_cursor():
    tr = Tracer()
    with tr.span("tick", lane=0, cat="tick") as t:
        d = tr.emit("decode", 1000.0, lane=0, cat="decode")
    assert d.parent is t and t.parent is None
    assert d.t0_ns == 0.0 and d.t1_ns == 1000.0
    assert t.t1_ns >= d.t1_ns
    assert tr.now(0) == 1000.0
    tr.seek(0, 500.0)                       # monotone: never rewinds
    assert tr.now(0) == 1000.0


def test_end_span_enforces_innermost():
    tr = Tracer()
    outer = tr.begin_span("outer")
    tr.begin_span("inner")
    with pytest.raises(RuntimeError, match="innermost"):
        tr.end_span(outer)


def test_move_span_residual_makes_legs_sum_exact():
    tr = Tracer()
    totals = (0.3, 0.7, 0.1, 0.2)
    # three legs whose naive sum would NOT hit the totals bit-for-bit
    items = [("a", (0.1, 0.2, 0.03, 0.07), {}),
             ("b", (0.1, 0.3, 0.04, 0.06), {}),
             ("c", (0.1, 0.2, 0.03, 0.07), {})]
    tr.move_span("resume_wave", 0, totals, items)
    legs = [s for s in tr.spans if s.cat == "leg"]
    for j, f in enumerate(FIELDS):
        acc = 0.0
        for l in legs:
            acc += l.attrs[f]
        assert acc == totals[j]


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    s = NULL_TRACER.begin_span("x")
    assert NULL_TRACER.end_span(s) is s
    NULL_TRACER.move_span("w", 0, (0, 0, 0, 0), [])
    NULL_TRACER.seek_all(1e9)
    assert NULL_TRACER.now(3) == 0.0
    assert NULL_TRACER.rollup()["spans"] == 0
    assert NULL_TRACER.spans == []


# ---------------------------------------------------------------------------
# span tree well-formedness
# ---------------------------------------------------------------------------

def _assert_tree_well_formed(tr: Tracer):
    siblings = {}
    for s in tr.spans:
        if s.instant:
            continue
        if s.parent is not None:
            assert s.lane == s.parent.lane, (s, s.parent)
            assert s.parent.t0_ns <= s.t0_ns, (s, s.parent)
            assert s.t1_ns <= s.parent.t1_ns, (s, s.parent)
        key = (s.lane, s.parent.index if s.parent else None)
        siblings.setdefault(key, []).append(s)
    for key, group in siblings.items():
        for prev, nxt in zip(group, group[1:]):
            assert nxt.t0_ns >= prev.t1_ns, (key, prev, nxt)


def test_span_tree_well_formed_base(base_run):
    _, _, tr = base_run
    assert len(tr.spans) > 0
    _assert_tree_well_formed(tr)


def test_span_tree_well_formed_cluster_lanes(cluster_run):
    s, cl, tr, _ = cluster_run
    _assert_tree_well_formed(tr)
    # all lanes in use: scheduler, one per replica, write-behind
    lanes = {sp.lane for sp in tr.spans}
    assert lanes == set(range(cl.n_replicas + 2)), lanes
    # replica movement lanes carry the priced waves, lane 0 the tick phases
    assert all(sp.lane == 0 for sp in tr.spans if sp.cat == "tick")
    assert all(sp.lane > 0 for sp in tr.spans if sp.cat == "move")


# ---------------------------------------------------------------------------
# byte-stable export
# ---------------------------------------------------------------------------

def test_chrome_trace_byte_stable_and_strict(setup, base_run, cluster_run):
    def reject(const):
        raise ValueError(f"non-strict JSON constant {const}")

    _, _, tr1 = base_run
    _, _, tr2 = _base_run(*setup)
    b1, b2 = chrome_trace(tr1), chrome_trace(tr2)
    assert b1 == b2                          # byte-identical, same seed
    _, _, ctr1, _ = cluster_run
    _, _, ctr2, _ = _cluster_run(*setup)
    assert chrome_trace(ctr1) == chrome_trace(ctr2)

    doc = json.loads(b1, parse_constant=reject)
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and len(evs) > len(tr1.spans) - 1
    assert doc["displayTimeUnit"] == "ns"
    assert doc["otherData"]["clock"] == "modeled-virtual-ns"
    for ev in evs:
        assert ev["ph"] in ("X", "i", "M"), ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
    # metadata names every lane
    names = [ev for ev in evs if ev["ph"] == "M"]
    assert names and names[0]["args"]["name"] == "scheduler"


def test_trace_events_match_span_count(base_run):
    _, _, tr = base_run
    evs = trace_events(tr)
    meta = [e for e in evs if e["ph"] == "M"]
    assert len(evs) == len(tr.spans) + len(meta)


# ---------------------------------------------------------------------------
# zero device work, zero schedule impact
# ---------------------------------------------------------------------------

def test_tracing_adds_zero_dispatches_and_changes_nothing(setup, base_run):
    s_traced, eng_traced, _ = base_run
    s_plain, eng_plain, _ = _base_run(*setup, traced=False)
    # identical device-side story: tracing is host bookkeeping only
    TL.assert_dispatch_delta(eng_plain.stats, eng_traced.stats,
                             decode=0, host=0)
    assert eng_plain.stats == eng_traced.stats
    # identical schedule and identical bill
    assert s_plain.metrics.movement_totals() == \
        s_traced.metrics.movement_totals()
    plain = s_plain.metrics.summary()
    traced = s_traced.metrics.summary()
    tr_block = traced.pop("trace")
    assert "trace" not in plain              # untraced summaries unchanged
    assert plain == traced
    assert tr_block["spans"] > 0


# ---------------------------------------------------------------------------
# movement additivity: legs -> moves -> decisions -> totals, bit-for-bit
# ---------------------------------------------------------------------------

def _assert_additivity(metrics, tr: Tracer):
    moves = [s for s in tr.spans if s.cat == "move"]
    legs = [s for s in tr.spans if s.cat == "leg"]
    by_parent = {}
    for l in legs:
        p = l.parent.index if l.parent is not None else None
        acc = by_parent.setdefault(p, [0.0] * 4)
        for i, f in enumerate(FIELDS):
            acc[i] += l.attrs[f]
    per_dec = {}
    for m in moves:
        got = by_parent.get(m.index, [0.0] * 4)
        for i, f in enumerate(FIELDS):
            assert got[i] == m.attrs[f], (m.name, f)     # legs == move
        acc = per_dec.setdefault(m.attrs["decision"], [0.0] * 4)
        for i, f in enumerate(FIELDS):
            acc[i] += m.attrs[f]
    n_priced = 0
    for di, dec in enumerate(metrics.decisions):
        want = (dec.ns_lisa, dec.ns_memcpy, dec.uj_lisa, dec.uj_memcpy)
        if di not in per_dec:
            assert want == (0.0, 0.0, 0.0, 0.0), (di, dec.kind)
            continue
        n_priced += 1
        got = per_dec[di]
        for i in range(4):
            assert got[i] == want[i], (di, dec.kind, FIELDS[i])
    assert n_priced == len(per_dec)          # no orphaned move spans
    # the exact association movement_totals() uses: per-decision, in order
    tot = [0.0] * 4
    for di in range(len(metrics.decisions)):
        for i in range(4):
            tot[i] += per_dec.get(di, (0.0,) * 4)[i]
    mt = metrics.movement_totals()
    for i, f in enumerate(FIELDS):
        assert tot[i] == mt[f], f            # bit-for-bit


def test_leg_spans_sum_to_movement_totals_base(base_run):
    s, _, tr = base_run
    assert any(sp.cat == "move" for sp in tr.spans)
    _assert_additivity(s.metrics, tr)


def test_leg_spans_sum_to_movement_totals_cluster_chaos(cluster_run):
    s, _, tr, _ = cluster_run
    kinds = {sp.attrs["wave"] for sp in tr.spans if sp.cat == "move"}
    assert "snapshot_wave" in kinds          # the chaos kinds are traced too
    _assert_additivity(s.metrics, tr)


# ---------------------------------------------------------------------------
# fault spans agree with the chaos ledger
# ---------------------------------------------------------------------------

def test_fault_spans_match_ledger(cluster_run):
    s, _, tr, _ = cluster_run
    counters = s.metrics.fault_summary()["counters"]
    inj_marks = [sp for sp in tr.spans
                 if sp.cat == "fault" and sp.name == "fault_injected"]
    fail_marks = [sp for sp in tr.spans
                  if sp.cat == "fault" and sp.name == "replica_failure"]
    assert len(inj_marks) == counters.get("injected", 0)
    assert len(fail_marks) == counters.get("replica_failures", 0)
    retry_moves = [sp for sp in tr.spans if sp.cat == "move"
                   and sp.attrs["wave"] == "retry_wave"]
    assert len(retry_moves) == s.metrics.decision_counts().get(
        "retry_wave", 0)
    assert sum(sp.attrs["retries"] for sp in retry_moves) == \
        counters.get("retries", 0)
    # every retry move still carries its trailing backoff leg marker; its
    # residual is ZERO now that backoff lives in the Decision's own
    # latency bucket (never in the per-mechanism movement ns)
    for sp in retry_moves:
        kids = [l for l in tr.spans
                if l.cat == "leg" and l.parent is sp]
        assert kids and kids[-1].name == "backoff"
        for f in FIELDS:
            assert kids[-1].attrs[f] == pytest.approx(0.0, abs=1e-6)
    backoff_total = sum(d.backoff_ns for d in s.metrics.decisions)
    if any(sp.attrs.get("backoff_ns", 0.0) > 0 for sp in retry_moves):
        assert backoff_total > 0.0
    assert backoff_total == pytest.approx(
        sum(sp.attrs.get("backoff_ns", 0.0) for sp in retry_moves))


# ---------------------------------------------------------------------------
# roofline report schema (the committed artifact)
# ---------------------------------------------------------------------------

def test_roofline_report_schema_covers_entry_points():
    root = find_repo_root()
    path = os.path.join(root, "ROOFLINE_REPORT.json")
    assert os.path.exists(path), "run `python benchmarks/run.py roofline`"
    sys.path.insert(0, os.path.join(root, "benchmarks"))
    try:
        from run import _check_roofline
    finally:
        sys.path.pop(0)
    with open(path) as f:
        rep = json.load(f)
    errs = []
    _check_roofline(rep, errs)
    assert errs == []
    assert rep["n_entry_points"] >= 9
