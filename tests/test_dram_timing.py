"""Table 1 / Sec. 2 / Sec. 3.3 of the paper, reproduced exactly under the
default ``DDR3_1600`` preset."""
import importlib

import pytest

from repro.core.dram.spec import DDR3_1600

# Table 1 (paper): mechanism -> (latency ns, energy uJ).  memcpy latency is
# blank in the table; Fig. 2 shows it ~= RC-InterSA.
TABLE1 = {
    "RC-InterSA": (1363.75, 4.33),
    "RC-Bank": (701.25, 2.08),
    "RC-IntraSA": (83.75, 0.06),
    "LISA-RISC-1": (148.5, 0.09),
    "LISA-RISC-7": (196.5, 0.12),
    "LISA-RISC-15": (260.5, 0.17),
}


def test_table1_latencies_exact():
    got = DDR3_1600.table1()
    for mech, (lat, _) in TABLE1.items():
        assert got[mech][0] == pytest.approx(lat, abs=1e-9), mech


def test_table1_energies_match_to_rounding():
    got = DDR3_1600.table1()
    for mech, (_, ene) in TABLE1.items():
        assert round(got[mech][1], 2) == pytest.approx(ene, abs=1e-9), mech


def test_timing_shim_is_gone():
    """The deprecated ``core/dram/timing`` alias module finished its
    deprecation cycle and was deleted: the historical names live only in
    ``spec`` now, and a stale import must fail loudly."""
    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.core.dram.timing")


def test_memcpy_energy_exact_and_latency_close_to_intersa():
    # energy 6.2 uJ exact; latency within 3% of RC-InterSA (Fig. 2).
    assert DDR3_1600.copy_energy("memcpy") == pytest.approx(6.2, abs=1e-9)
    rc = DDR3_1600.copy_latency("rc_intersa")
    rel = abs(DDR3_1600.copy_latency("memcpy") - rc) / rc
    assert rel < 0.03


def test_lisa_vs_rowclone_headline_numbers():
    # paper: 9x latency and 48x energy reduction vs RC-InterSA (1-hop RISC
    # is the headline; hop-7 keeps >6x latency)
    s = DDR3_1600
    assert s.copy_latency("rc_intersa") / s.copy_latency("lisa", 1) > 9.0
    assert s.copy_energy("rc_intersa") / s.copy_energy("lisa", 1) == \
        pytest.approx(48.1, rel=0.01)
    # 69x energy vs memcpy (Sec. 5.1)
    assert s.copy_energy("memcpy") / s.copy_energy("lisa", 1) == \
        pytest.approx(68.9, rel=0.01)


def test_rbm_bandwidth_claim():
    # 500 GB/s vs 19.2 GB/s channel = 26x (Sec. 2)
    assert DDR3_1600.rbm_bw_gbps == pytest.approx(500.0, rel=1e-3)
    assert DDR3_1600.rbm_bw_gbps / DDR3_1600.channel_bw_gbps == \
        pytest.approx(26.04, rel=0.01)


def test_lisa_risc_linear_in_hops():
    lats = [DDR3_1600.copy_latency("lisa", h) for h in range(1, 16)]
    diffs = {round(b - a, 6) for a, b in zip(lats, lats[1:])}
    assert diffs == {8.0}


def test_lip_precharge():
    # 13 ns -> 5 ns, 2.6x (Sec. 3.3)
    assert DDR3_1600.precharge_latency(False) == 13.0
    assert DDR3_1600.precharge_latency(True) == 5.0
    assert (DDR3_1600.precharge_latency(False)
            / DDR3_1600.precharge_latency(True)) == 2.6


def test_invalid_hops_raise():
    with pytest.raises(ValueError):
        DDR3_1600.copy_latency("lisa", 0)
    with pytest.raises(ValueError):
        DDR3_1600.copy_energy("lisa", 0)
