"""The analyzer analyzed: every lint rule fires exactly once on its planted
fixture, the dispatch auditor catches dropped donation / host callbacks /
dtype widening on synthetic entry points, the real tree is clean with an
empty waiver file, and the shared testlib asserters behave.

Fixtures are PARSED, never imported — importing ``import_reg.py`` would
mutate the real backend registry.
"""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import dispatch as D
from repro.analysis import testlib as TL
from repro.analysis.dispatch import AuditTarget, EntryContract
from repro.analysis.entrypoints import default_targets, prefill_buckets
from repro.analysis.findings import (Finding, Report, is_waived,
                                     load_waivers, split_waived)
from repro.analysis.lint import find_repo_root, lint_file, run_lint
from repro.analysis.rules import LintRule, get_rule, register_rule, rule_ids

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "lint")


def _fixture_source(name):
    with open(os.path.join(FIXTURES, name)) as f:
        return f.read()


# ---------------------------------------------------------------------------
# AST rules: planted violations fire exactly once
# ---------------------------------------------------------------------------

PLANTED = [
    # (fixture file, path the module pretends to live at, rule that fires)
    ("raw_backend.py", "src/repro/serve/sneaky.py", "movement-raw-backend"),
    ("host_sync_tick.py", "src/repro/sched/scheduler.py",
     "host-sync-in-hot-loop"),
    ("nan_json.py", "benchmarks/fixture.py", "json-nan"),
    ("wallclock.py", "src/repro/sched/fixture.py",
     "wallclock-in-virtual-clock"),
    ("import_reg.py", "src/repro/movement/fixture.py",
     "import-time-registration"),
    ("unref_alias.py", "src/repro/serve/fixture.py", "unrefcounted-alias"),
    ("unclosed_span.py", "src/repro/obs/fixture.py", "unclosed-span"),
]


@pytest.mark.parametrize("fixture,spoofed_path,rule",
                         PLANTED, ids=[p[2] for p in PLANTED])
def test_planted_violation_fires_exactly_once(fixture, spoofed_path, rule):
    findings = lint_file(spoofed_path, _fixture_source(fixture))
    assert [f.rule for f in findings] == [rule], findings
    assert findings[0].path == spoofed_path
    assert findings[0].line > 0


def test_raw_backend_allowed_in_backend_registry():
    """The same raw call is CLEAN where the architecture places it."""
    src = _fixture_source("raw_backend.py")
    assert lint_file("src/repro/movement/backends.py", src) == []
    assert lint_file("src/repro/kernels/ops.py", src) == []


def test_host_sync_sanctioned_functions_are_structural():
    """step_end's one transfer per step is allowlisted IN THE RULE, not in
    the waiver file: the same .item() is a finding in any other function."""
    src = ("class Engine:\n"
           "    def step_end(self, handle):\n"
           "        return handle.item()\n"
           "    def tick_helper(self, handle):\n"
           "        return handle.item()\n")
    findings = lint_file("src/repro/serve/engine.py", src)
    assert [f.rule for f in findings] == ["host-sync-in-hot-loop"]
    assert findings[0].line == 5            # tick_helper's, not step_end's


def test_host_sync_out_of_scope_module_is_clean():
    src = "def f(x):\n    return x.item()\n"
    assert lint_file("src/repro/roofline/hlo.py", src) == []


def test_wallclock_rule_covers_obs_package():
    """The tracer records MODELED ns only; a wall-clock read under obs/
    would stamp host time onto the virtual timeline."""
    src = "import time\n\n\ndef stamp():\n    return time.time()\n"
    findings = lint_file("src/repro/obs/clock.py", src)
    assert [f.rule for f in findings] == ["wallclock-in-virtual-clock"]
    assert lint_file("src/repro/roofline/clock.py", src) == []


# ---------------------------------------------------------------------------
# the real tree is clean; the waiver file is empty
# ---------------------------------------------------------------------------

def test_clean_tree_zero_findings_empty_waivers():
    root = find_repo_root()
    report = run_lint(repo_root=root)
    assert report.findings == [], [str(f) for f in report.findings]
    assert report.waived == []
    assert report.files_scanned > 50        # it really walked the tree
    assert set(report.rules) == set(rule_ids())
    # the committed waiver file exists and is EMPTY (comments only)
    assert load_waivers(os.path.join(root, "LINT_WAIVERS")) == []


def test_waiver_matching_and_strict_report():
    f = Finding(rule="json-nan", path="benchmarks/x.py", line=7, message="m")
    assert is_waived(f, ["json-nan:benchmarks/x.py"])
    assert is_waived(f, ["json-nan:benchmarks/x.py:7"])
    assert not is_waived(f, ["json-nan:benchmarks/x.py:8"])
    assert not is_waived(f, ["json-nan:benchmarks/y.py"])
    active, waived = split_waived([f], ["json-nan:benchmarks/x.py"])
    assert active == [] and waived == [f]


def test_report_is_strict_json(tmp_path):
    rep = Report(roots=["src/repro"], rules=["json-nan"],
                 findings=[Finding("json-nan", "a.py", 1, "m")])
    path = tmp_path / "r.json"
    rep.write(str(path))
    loaded = json.loads(path.read_text())
    assert loaded["schema"] == "repro-lint-report/v1"
    assert loaded["counts"]["findings"] == 1
    # NaN anywhere in the report must fail at WRITE time
    rep.audit = {"bad": float("nan")}
    with pytest.raises(ValueError):
        rep.write(str(path))


def test_rule_registry_contract():
    """Fourth registry instance, same contract as mechanisms/backends/
    policies: same-class re-registration is reload-safe, an impostor class
    under a taken id raises."""
    from repro.analysis.rules import JsonNanRule
    assert register_rule(JsonNanRule) is JsonNanRule       # reload-safe

    with pytest.raises(ValueError, match="already registered"):
        @register_rule
        class Impostor(LintRule):
            id = "json-nan"
    assert type(get_rule("json-nan")).__name__ == "JsonNanRule"
    with pytest.raises(ValueError, match="unknown lint rule"):
        get_rule("no-such-rule")


# ---------------------------------------------------------------------------
# dispatch auditor on synthetic entry points
# ---------------------------------------------------------------------------

def _args2():
    return jnp.zeros((2, 2)), jnp.ones((2, 2))


def test_audit_donation_dropped_fires():
    """The planted 'donation dropped' fixture: a wrapper re-jitted WITHOUT
    donate_argnums while the contract still promises in-place update."""
    fn = jax.jit(lambda c, s: (c + 1.0, s * 2.0))        # donation dropped
    t = AuditTarget("fixture", fn, _args2(),
                    EntryContract(donate=frozenset({1})))
    rec, findings = D.audit_target(t, compiled=False)
    assert [f.rule for f in findings] == ["audit-donation"]
    assert "silently dropped" in findings[0].message
    assert rec["donated_leaves"] == 0


def test_audit_undeclared_donation_fires():
    fn = jax.jit(lambda c, s: (c + 1.0, s * 2.0), donate_argnums=(0,))
    t = AuditTarget("fixture", fn, _args2(), EntryContract())
    _, findings = D.audit_target(t, compiled=False)
    assert [f.rule for f in findings] == ["audit-donation"]
    assert "does not declare" in findings[0].message


def test_audit_honored_donation_is_clean():
    fn = jax.jit(lambda c, s: (c + 1.0, s * 2.0), donate_argnums=(1,))
    t = AuditTarget("fixture", fn, _args2(),
                    EntryContract(donate=frozenset({1})))
    rec, findings = D.audit_target(t, compiled=True)
    assert findings == []
    assert rec["donated_leaves"] == rec["expected_donated_leaves"] == 1
    assert rec["hlo_donor_marks"] >= 1
    assert rec["hlo_host_transfer_ops"] == 0


def test_audit_uint8_upcast_fires():
    fn = jax.jit(lambda pages: pages.astype(jnp.float32).sum())
    t = AuditTarget("fixture", fn, (jnp.zeros(8, jnp.uint8),),
                    EntryContract(uint8_preserving=True))
    rec, findings = D.audit_target(t, compiled=False)
    assert [f.rule for f in findings] == ["audit-dtype"]
    assert rec["uint8_upcasts"] == 1


def test_audit_bitcast_page_path_is_clean():
    """The real page discipline — bitcast, never convert — audits clean."""
    fn = jax.jit(
        lambda x: jax.lax.bitcast_convert_type(x, jnp.uint8).reshape(-1))
    t = AuditTarget("fixture", fn, (jnp.zeros((2, 4), jnp.float32),),
                    EntryContract(uint8_preserving=True))
    rec, findings = D.audit_target(t, compiled=False)
    assert findings == []
    assert rec["uint8_upcasts"] == 0


def test_audit_host_callback_fires():
    def leaky(x):
        jax.debug.print("x = {x}", x=x)      # a host callback in the graph
        return x + 1.0

    t = AuditTarget("fixture", jax.jit(leaky), (jnp.zeros(2),),
                    EntryContract())
    rec, findings = D.audit_target(t, compiled=False)
    assert [f.rule for f in findings] == ["audit-host-transfer"]
    assert rec["jaxpr_host_transfer_eqns"] >= 1


def test_audit_bucket_stability():
    class FakeEngine:
        max_len = 32

        def _bucket_len(self, n):
            return n                          # exact lengths: unbounded keys

    assert D.audit_bucket_stability(FakeEngine(), [16, 32]) != []

    class Bucketed(FakeEngine):
        def _bucket_len(self, n):
            return min(max(16, 1 << (n - 1).bit_length()), self.max_len)

    assert D.audit_bucket_stability(Bucketed(), [16, 32]) == []


def test_default_targets_audit_clean():
    """Every registered jitted entry point honors its documented contract
    (lowering + jaxpr layers; CI's lint-audit job adds the compiled-HLO
    walk)."""
    targets, engine = default_targets()
    extra = D.audit_bucket_stability(engine, prefill_buckets(engine))
    audit = D.run_audit(targets, compiled=False, extra_findings=extra)
    assert audit["findings"] == [], audit["findings"]
    names = {t["name"] for t in audit["targets"]}
    assert {"decode", "suspend", "suspend_many", "resume", "resume_many",
            "migrate", "simulate_params"} <= names
    assert any(n.startswith("prefill[") for n in names)
    for rec in audit["targets"]:
        assert rec["donated_leaves"] == rec["expected_donated_leaves"]
        assert rec["jaxpr_host_transfer_eqns"] == 0


# ---------------------------------------------------------------------------
# the shared testlib asserters (what the engine/cluster/sched tests gate on)
# ---------------------------------------------------------------------------

def test_testlib_compile_count_contract():
    counts = {"decode": 1, "resume_many": 2, "suspend": 0, "probe": -1}
    TL.assert_compile_count(counts, "decode", 1)
    TL.assert_compile_count(counts, "resume_many", range(3))
    TL.assert_compile_count(counts, "probe", 1)          # -1 == unknown
    TL.assert_compile_at_most(counts, "resume_many", 2)
    with pytest.raises(AssertionError, match="decode compiled 1x"):
        TL.assert_compile_count(counts, "decode", 2)
    with pytest.raises(AssertionError, match="> bound"):
        TL.assert_compile_at_most(counts, "resume_many", 1)


def test_testlib_dispatch_delta():
    before = {"decode_dispatches": 3, "host_transfers": 3}
    after = {"decode_dispatches": 9, "host_transfers": 9}
    TL.assert_dispatch_delta(before, after, decode=6, host=6)
    with pytest.raises(AssertionError, match="decode dispatches"):
        TL.assert_dispatch_delta(before, after, decode=5)
    with pytest.raises(AssertionError, match="host transfers"):
        TL.assert_dispatch_delta(before, after, decode=6, host=5)
