"""Data correctness of the functional DRAM bank (RBM semantics)."""
import jax
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.dram import substrate as S
from repro.core.dram.spec import DDR3_1600

SPEC = DDR3_1600.with_geometry(8, 8, 64)


def _bank(spec=SPEC, seed=0):
    return S.make_bank(spec, key=jax.random.key(seed))


def test_activate_latches_row():
    b = _bank()
    b2 = S.activate(b, 3, 5)
    assert (b2.row_buffer[3] == b.cells[3, 5]).all()
    assert int(b2.open_row[3]) == 5


def test_rbm_requires_adjacency_and_precharged_dst():
    b = _bank()
    b = S.activate(b, 2, 1)
    far = S.rbm(b, 2, 5)                   # not adjacent: no-op on validity
    assert not bool(far.rb_valid[5])
    b_open = S.activate(b, 3, 0)           # dst open: rbm must not latch
    blocked = S.rbm(b_open, 2, 3)
    assert (blocked.row_buffer[3] == b_open.row_buffer[3]).all()
    ok = S.rbm(b, 2, 3)                    # adjacent + precharged: latches
    assert bool(ok.rb_valid[3])
    assert (ok.row_buffer[3] == b.row_buffer[2]).all()


def test_rbm_violation_invalidates_destination_buffer():
    """Regression: a violated RBM must leave ``rb_valid[dst] = False`` even
    when the destination buffer was previously valid (the docstring's
    contract — a misfired RBM disturbs the destination sense amps, so the
    stale buffer must not stay trustworthy)."""
    b = _bank()
    b = S.activate(b, 5, 1)                # dst buffer valid via its own ACT
    b = S.precharge(b, 5)                  # ...then precharged
    # rb_valid[5] was cleared by precharge; re-latch via a real RBM first:
    b = S.activate(b, 4, 2)
    b = S.rbm(b, 4, 5)                     # valid RBM: dst 5 now valid
    assert bool(b.rb_valid[5])
    bad = S.rbm(b, 1, 5)                   # not adjacent -> violated
    assert not bool(bad.rb_valid[5]), \
        "violated RBM must invalidate the destination buffer"
    assert (bad.row_buffer[5] == b.row_buffer[5]).all()   # data untouched


@pytest.mark.parametrize("src_sa,src_row,dst_sa,dst_row",
                         [(0, 0, 7, 7), (6, 3, 1, 2), (3, 1, 4, 1)])
def test_lisa_risc_copy_moves_data(src_sa, src_row, dst_sa, dst_row):
    b = _bank()
    want = b.cells[src_sa, src_row]
    res = S.lisa_risc_copy(b, src_sa, src_row, dst_sa, dst_row, spec=SPEC)
    assert isinstance(res, S.CopyResult)
    b2, lat, ene = res                     # CopyResult unpacks like a tuple
    assert (b2.cells[dst_sa, dst_row] == want).all()
    hops = abs(dst_sa - src_sa)
    assert lat == pytest.approx(SPEC.copy_latency("lisa", hops))
    assert ene == pytest.approx(SPEC.copy_energy("lisa", hops))
    # source row unchanged
    assert (b2.cells[src_sa, src_row] == want).all()


def test_broadcast_latches_all_destinations():
    b = _bank()
    want = b.cells[1, 4]
    b2, lat, ene = S.lisa_broadcast(b, 1, 4, (0, 3, 6), 2, spec=SPEC)
    for d in (0, 3, 6):
        assert (b2.cells[d, 2] == want).all()
    # cost: chains to 6 (5 hops fwd) and 0 (1 hop bwd) + 2 extra restores
    t = SPEC.timing
    assert lat == pytest.approx(SPEC.copy_latency("lisa", 6)
                                + 2 * (t.tRAS + t.tRP))
    # multicast beats N separate copies (the paper's 1-to-N argument)
    separate = sum(SPEC.copy_latency("lisa", abs(d - 1)) for d in (0, 3, 6))
    assert lat < separate


def test_rowclone_copy_correct_but_slow():
    b = _bank()
    want = b.cells[2, 3]
    b2, lat, ene = S.rowclone_intersa_copy(b, 2, 3, 6, 1, spec=SPEC)
    assert (b2.cells[6, 1] == want).all()
    assert lat == pytest.approx(SPEC.copy_latency("rc_intersa"))


def test_execute_copy_dispatches_registry_mechanisms():
    b = _bank()
    want = b.cells[1, 2]
    for mech in ("lisa", "rc_intersa", "rc_bank", "memcpy"):
        res = S.execute_copy(b, mech, 1, 2, 4, 3, spec=SPEC)
        assert (res.state.cells[4, 3] == want).all(), mech
        assert res.latency_ns == pytest.approx(
            SPEC.copy_latency(mech, 3)), mech
    res = S.execute_copy(b, "rc_intrasa", 1, 2, 1, 5, spec=SPEC)
    assert (res.state.cells[1, 5] == want).all()
    with pytest.raises(ValueError, match="unknown copy mechanism"):
        S.execute_copy(b, "teleport", 1, 2, 4, 3, spec=SPEC)
    with pytest.raises(ValueError):
        S.execute_copy(b, "rc_intrasa", 1, 2, 4, 3, spec=SPEC)


@settings(max_examples=20, deadline=None)
@given(src=st.integers(0, 7), dst=st.integers(0, 7),
       row_s=st.integers(0, 7), row_d=st.integers(0, 7),
       seed=st.integers(0, 100))
def test_copy_property_any_pair(src, dst, row_s, row_d, seed):
    if src == dst:
        return
    b = _bank(seed=seed)
    want = b.cells[src, row_s]
    b2, lat, _ = S.lisa_risc_copy(b, src, row_s, dst, row_d, spec=SPEC)
    assert (b2.cells[dst, row_d] == want).all()
    # untouched subarrays keep their cells
    for sa in range(8):
        if sa not in (src, dst):
            assert (b2.cells[sa] == b.cells[sa]).all()
    assert lat >= SPEC.copy_latency("lisa", 1)
