"""Data correctness of the functional DRAM bank (RBM semantics)."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dram import substrate as S
from repro.core.dram import timing as T


def _bank(n_sa=8, rows=8, row_bytes=64, seed=0):
    return S.make_bank(n_sa, rows, row_bytes, jax.random.key(seed))


def test_activate_latches_row():
    b = _bank()
    b2 = S.activate(b, 3, 5)
    assert (b2.row_buffer[3] == b.cells[3, 5]).all()
    assert int(b2.open_row[3]) == 5


def test_rbm_requires_adjacency_and_precharged_dst():
    b = _bank()
    b = S.activate(b, 2, 1)
    far = S.rbm(b, 2, 5)                   # not adjacent: no-op on validity
    assert not bool(far.rb_valid[5])
    b_open = S.activate(b, 3, 0)           # dst open: rbm must not latch
    blocked = S.rbm(b_open, 2, 3)
    assert (blocked.row_buffer[3] == b_open.row_buffer[3]).all()
    ok = S.rbm(b, 2, 3)                    # adjacent + precharged: latches
    assert bool(ok.rb_valid[3])
    assert (ok.row_buffer[3] == b.row_buffer[2]).all()


@pytest.mark.parametrize("src_sa,src_row,dst_sa,dst_row",
                         [(0, 0, 7, 7), (6, 3, 1, 2), (3, 1, 4, 1)])
def test_lisa_risc_copy_moves_data(src_sa, src_row, dst_sa, dst_row):
    b = _bank()
    want = b.cells[src_sa, src_row]
    b2, lat, ene = S.lisa_risc_copy(b, src_sa, src_row, dst_sa, dst_row)
    assert (b2.cells[dst_sa, dst_row] == want).all()
    hops = abs(dst_sa - src_sa)
    assert lat == pytest.approx(T.latency_lisa_risc(hops))
    assert ene == pytest.approx(T.energy_lisa_risc(hops))
    # source row unchanged
    assert (b2.cells[src_sa, src_row] == want).all()


def test_broadcast_latches_all_destinations():
    b = _bank()
    want = b.cells[1, 4]
    b2, lat, ene = S.lisa_broadcast(b, 1, 4, (0, 3, 6), 2)
    for d in (0, 3, 6):
        assert (b2.cells[d, 2] == want).all()
    # cost: chains to 6 (5 hops fwd) and 0 (1 hop bwd) + 2 extra restores
    assert lat == pytest.approx(T.latency_lisa_risc(6)
                                + 2 * (T.DDR3.tRAS + T.DDR3.tRP))
    # multicast beats N separate copies (the paper's 1-to-N argument)
    separate = sum(T.latency_lisa_risc(abs(d - 1)) for d in (0, 3, 6))
    assert lat < separate


def test_rowclone_copy_correct_but_slow():
    b = _bank()
    want = b.cells[2, 3]
    b2, lat, ene = S.rowclone_intersa_copy(b, 2, 3, 6, 1)
    assert (b2.cells[6, 1] == want).all()
    assert lat == pytest.approx(T.latency_rc_inter_sa())


@settings(max_examples=20, deadline=None)
@given(src=st.integers(0, 7), dst=st.integers(0, 7),
       row_s=st.integers(0, 7), row_d=st.integers(0, 7),
       seed=st.integers(0, 100))
def test_copy_property_any_pair(src, dst, row_s, row_d, seed):
    if src == dst:
        return
    b = _bank(seed=seed)
    want = b.cells[src, row_s]
    b2, lat, _ = S.lisa_risc_copy(b, src, row_s, dst, row_d)
    assert (b2.cells[dst, row_d] == want).all()
    # untouched subarrays keep their cells
    for sa in range(8):
        if sa not in (src, dst):
            assert (b2.cells[sa] == b.cells[sa]).all()
    assert lat >= T.latency_lisa_risc(1)
